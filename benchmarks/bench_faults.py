"""Paper Figs. 8 & 9 — DF under random thread delays and crash-stop faults.

Delays (Fig 8): DF_BB's simulated iteration time grows with delay
probability/duration (everyone waits at the barrier); DF_LF degrades only
marginally.  Crashes (Fig 9): DF_BB deadlocks (DNF) if any thread crashes;
DF_LF finishes with graceful slowdown and unchanged error.
"""
from __future__ import annotations

import sys

from benchmarks.common import (SUITE, Row, emit, linf, reference_ranks,
                               run_variant, timed, updated_snapshots)
from repro.core import pagerank as pr
from repro.core.faults import FaultPlan

BATCH_FRAC = 1e-4
N_THREADS = 64
DELAY_PROBS = (0.0, 1e-3, 1e-2, 1e-1)
DELAY_MS = (50.0, 100.0, 200.0)
CRASHES = (0, 1, 2, 4, 8, 16, 32, 56)


def main(out: str = "results/bench_faults.csv", *, quick: bool = False,
         mode: str = "both"):
    rows = []
    graphs = ["web", "road"] if not quick else ["web"]
    delay_ms = DELAY_MS if not quick else (100.0,)
    probs = DELAY_PROBS if not quick else (0.0, 1e-2)
    crashes = CRASHES if not quick else (0, 1, 32)

    for gname in graphs:
        hg = SUITE[gname]()
        g_prev, g_cur, batch, _ = updated_snapshots(hg, BATCH_FRAC, seed=11)
        r_prev = pr.reference_pagerank(g_prev, iterations=250)
        ref = reference_ranks(g_cur)

        if mode in ("both", "delay"):
            for dms in delay_ms:
                for p in probs:
                    for m in ("df_bb", "df_lf"):
                        plan = FaultPlan(n_threads=N_THREADS, delay_prob=p,
                                         delay_ms=dms, seed=13)
                        res = run_variant(m, g_prev, g_cur, batch, r_prev,
                                          faults=plan)
                        err = linf(res.ranks, ref[:res.ranks.shape[0]])
                        rows.append(Row(
                            "faults_delay", gname, m, p, res.wall_time_s,
                            res.stats.sweeps, res.stats.edges_processed,
                            err, res.stats.sim_time_ms,
                            extra=f"delay_ms={dms:g};"
                                  f"dnf={int(res.stats.dnf)}"))

        if mode in ("both", "crash"):
            for nc in crashes:
                for m in ("df_bb", "df_lf"):
                    plan = FaultPlan(n_threads=N_THREADS, n_crashed=nc,
                                     crash_window=8, seed=17)
                    res = run_variant(m, g_prev, g_cur, batch, r_prev,
                                      faults=plan, max_iterations=2000)
                    err = linf(res.ranks, ref[:res.ranks.shape[0]])
                    rows.append(Row(
                        "faults_crash", gname, m, nc, res.wall_time_s,
                        res.stats.sweeps, res.stats.edges_processed, err,
                        res.stats.sim_time_ms,
                        extra=f"converged={int(res.stats.converged)};"
                              f"dnf={int(res.stats.dnf)}"))
    emit(rows, out)
    # invariants the paper claims
    crash_lf = [r for r in rows if r.bench == "faults_crash"
                and r.method == "df_lf"]
    assert all("converged=1" in r.extra for r in crash_lf), \
        "DF_LF must converge under every crash count"
    crash_bb = [r for r in rows if r.bench == "faults_crash"
                and r.method == "df_bb" and r.x > 0]
    assert all("dnf=1" in r.extra for r in crash_bb), \
        "DF_BB must DNF when any thread crashes"
    print("# fault invariants hold: DF_LF always converges; "
          "DF_BB deadlocks on any crash")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
