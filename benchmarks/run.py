"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (DESIGN.md §6) plus the roofline report.
``--quick`` trims graph counts/sweep points for CI-speed runs; the default
is the full container-scale suite.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


SECTIONS = [
    ("Fig 1  (chunk/block-size trade-off)", "benchmarks.bench_chunk_tradeoff"),
    ("Fig 5  (temporal graphs)", "benchmarks.bench_temporal"),
    ("Fig 6  (strong scaling)", "benchmarks.bench_scaling"),
    ("Fig 7  (batch-size sweep + error)", "benchmarks.bench_batch_sweep"),
    ("S5.2.3 (stability)", "benchmarks.bench_stability"),
    ("Fig 8/9 (delays + crashes)", "benchmarks.bench_faults"),
    ("kernels (pallas block-SpMV)", "benchmarks.bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on section names")
    args = ap.parse_args()

    failures = []
    for title, module in SECTIONS:
        if args.only and args.only not in module and args.only not in title:
            continue
        print(f"\n===== {title} [{module}] =====", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"# section done in {time.time() - t0:.1f}s")
        except Exception as e:
            failures.append((module, e))
            traceback.print_exc()
    print("\n===== roofline (from dry-run artifacts) =====", flush=True)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception as e:
        failures.append(("benchmarks.roofline", e))
        traceback.print_exc()

    if failures:
        print(f"\n{len(failures)} benchmark section(s) FAILED: "
              f"{[m for m, _ in failures]}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
