"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (DESIGN.md §6) plus the roofline report.
``--quick`` trims graph counts/sweep points for CI-speed runs; the default
is the full container-scale suite.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
import traceback


SMOKE_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_smoke.json")


STREAM_SIZES = (12, 14)         # log2 vertex counts for the stream scenario
STREAM_BATCHES = 6              # delta batches per stream
STREAM_BATCH_EDGES = 8          # fixed batch size (edges) across sizes

SERVICE_SESSIONS = 3            # concurrent sessions in the service scenario
SERVICE_BATCHES = 4             # update batches submitted per session
SERVICE_BATCH_EDGES = 8         # edges per batch
SERVICE_QUERY_CLIENTS = 3       # concurrent readers during the drain
SERVICE_QUERIES_PER_CLIENT = 6  # reads each client issues

SERVE_LOAD_STREAMS = 2          # durable update streams under overload
SERVE_LOAD_LOG2_N = 10          # graph size per stream
SERVE_LOAD_QUEUE_DEPTH = 4      # admission-control bound per stream
SERVE_LOAD_BURSTS = 24          # submit bursts per stream
SERVE_LOAD_BURST = 8            # submits per burst = 2x the queue bound
SERVE_LOAD_BURST_GAP_S = 0.25   # gap between bursts (dispatches interleave)
SERVE_LOAD_CLIENTS = 24         # concurrent query clients
SERVE_LOAD_READS = 15           # reads per client (~360 reads total)
SERVE_LOAD_KILL_AFTER = 2       # dispatches before stream 0 is killed

CHAOS_STREAMS = 2               # durable streams under the chaos soak
CHAOS_STEPS = 8                 # soak steps (one update round + scrub each)
CHAOS_LOG2_N = 10               # graph size per stream
CHAOS_BATCH_EDGES = 8           # edges per update batch
CHAOS_SEED = 93                 # ChaosPlan seed: same seed, same schedule
CHAOS_RATE = 0.25               # extra seeded events beyond the required set
CHAOS_REQUIRE = ("rank", "tile", "slot", "mirror", "graph",
                 "scatter_drop", "scatter_dup", "slot_dead")

SHARDED_DEVICES = 8             # forced host devices for the sharded scenario
SHARDED_BATCHES = 6             # DF batches per partitioner
SHARDED_LOG2_N = 10             # graph size (subprocess recompiles per part.)

RECOVERY_LOG2_N = 10            # graph size for the kill+restore scenario
RECOVERY_KILL_AFTER = 4         # durable batches applied before SIGKILL
RECOVERY_AFTER = 2              # batches served post-restore

PPR_N = 512                     # vertices in the walk-engine scenario
PPR_AVG_DEG = 6                 # powerlaw generator target degree
PPR_R_CURVE = (4, 16, 64)       # walks/vertex sweep (accuracy vs R)
PPR_L = 64                      # walk-length cap
PPR_SEED_SETS = 8               # seed sets averaged into each L1 point
PPR_SEEDS_PER_SET = 3           # |S| per personalized query
PPR_BATCHES = 6                 # delta batches for the localization record
PPR_BATCH_EDGES = 8             # edges per delta batch
PPR_USERS = 1000                # simulated personalized-query users
PPR_TOP_K = 10                  # ranking depth per user query


def _smoke_service() -> dict:
    """Multi-session serving scenario: N concurrent dynamic streams behind
    per-stream queues (``repro.api.PageRankService``, the serve-engine slot
    design), with concurrent query clients reading degraded-mode (from the
    per-slot snapshots) while the queues drain.  Records per-session
    p50/p95 update latency and retrace counts, the service-level request
    latency (queue wait included), and the query p50/p95 + staleness
    bound.  Sessions share the jit caches, so post-warmup retraces must
    stay 0 across **all** sessions — the multi-tenant streaming acceptance
    signal.  ``coalesce=False`` keeps one dispatch per submitted batch so
    the per-request latency series stays comparable across runs (the
    coalescing dispatcher is exercised by ``serve_load``)."""
    import threading

    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankService, ServingConfig
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    graphs = [kmer_chains(1 << 12, seed=30 + s)
              for s in range(SERVICE_SESSIONS)]
    svc = PageRankService(
        graphs,
        config=EngineConfig(engine="pallas", block_size=64,
                            active_policy="rc"),
        serving=ServingConfig(coalesce=False))
    cur = list(graphs)
    for j in range(SERVICE_BATCHES):
        for i in range(len(cur)):
            dels, ins = random_batch(cur[i], SERVICE_BATCH_EDGES / cur[i].m,
                                     seed=500 + 10 * i + j)
            svc.submit(i, dels, ins)
            cur[i] = cur[i].apply_batch(dels, ins)

    def _client(cid: int) -> None:
        for r in range(SERVICE_QUERIES_PER_CLIENT):
            s = (cid + r) % SERVICE_SESSIONS
            if r % 2 == 0:
                svc.query(s, [0, 1, 2, 3])
            else:
                svc.top_k(s, 5)

    readers = [threading.Thread(target=_client, args=(c,))
               for c in range(SERVICE_QUERY_CLIENTS)]
    for t in readers:
        t.start()
    svc.run_until_drained()        # updates drain while the readers read
    for t in readers:
        t.join()
    out = svc.report()
    out["batches_per_session"] = SERVICE_BATCHES
    # parity: every session's served ranks vs the independent oracle on its
    # final graph
    errs = []
    for i, hg in enumerate(cur):
        ref = pr.numpy_reference(hg.snapshot(block_size=64), iterations=300)
        n = svc.sessions[i].n
        errs.append(float(pr.linf(svc.sessions[i].R[:n],
                                  jnp.asarray(ref[:n]))))
    out["linf_vs_reference_max"] = max(errs)
    return out


def _smoke_serve_load() -> dict:
    """Overload + chaos serving scenario (the PR-6 acceptance scenario):
    durable update streams driven at ~2x their admission-control bound by
    burst submitters, hundreds of concurrent degraded-mode reads, and a
    slot killed mid-load so the watchdog must fail it over and drain its
    queue to the respawn.  Records queue-wait vs per-batch compute
    percentiles (continuous dispatch bounds wait by ONE in-flight
    dispatch — never the stacked multi-dispatch waits of the old
    per-tick barrier),
    shed/deadline/retry counters (bounded queues shed instead of growing),
    query latency + staleness bounds, the watchdog event log, and oracle
    parity of every surviving slot against the accepted-batch lineage."""
    import tempfile
    import threading

    import jax.numpy as jnp
    from repro.api import (AdmissionRejected, EngineConfig, PageRankService,
                           PageRankSession, ServingConfig)
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    store_root = tempfile.mkdtemp(prefix="repro-serve-load-")
    # max_iterations=2000: the post-failover drain dispatch coalesces
    # several bursts into one batch and reconverges from the restored
    # checkpoint+WAL state, which can legitimately need more than the
    # 500-sweep default at tau=1e-10 — give it headroom rather than
    # serving a capped iterate in the acceptance scenario
    cfg = EngineConfig(engine="pallas", block_size=64, active_policy="rc",
                       durability="wal", checkpoint_interval=4,
                       max_iterations=2000)
    sessions = [
        PageRankSession.from_graph(
            kmer_chains(1 << SERVE_LOAD_LOG2_N, seed=80 + s), config=cfg,
            store_dir=os.path.join(store_root, f"slot{s}"))
        for s in range(SERVE_LOAD_STREAMS)]
    svc = PageRankService(
        sessions,
        serving=ServingConfig(max_queue_depth=SERVE_LOAD_QUEUE_DEPTH,
                              shed_policy="reject", deadline_s=30.0,
                              staleness_budget_s=0.25,
                              heartbeat_timeout_s=15.0))
    svc.inject_session_fault(0, after_dispatches=SERVE_LOAD_KILL_AFTER,
                             kind="dead")

    # accepted-batch lineage per stream: `cur` advances only on admitted
    # submits, so the end state is the oracle for whatever survived
    # shedding — robust to which particular submits get rejected
    cur = [s.hg for s in sessions]
    submitted = [0] * SERVE_LOAD_STREAMS
    shed_local = [0] * SERVE_LOAD_STREAMS

    def _submitter(s: int) -> None:
        for b in range(SERVE_LOAD_BURSTS):
            for k in range(SERVE_LOAD_BURST):   # 2x the queue bound, fast
                dels, ins = random_batch(
                    cur[s], SERVICE_BATCH_EDGES / cur[s].m,
                    seed=9000 + 100 * s + 10 * b + k)
                try:
                    svc.submit(s, dels, ins)
                except AdmissionRejected:
                    shed_local[s] += 1
                    continue
                submitted[s] += 1
                cur[s] = cur[s].apply_batch(dels, ins)
            time.sleep(SERVE_LOAD_BURST_GAP_S)

    def _client(cid: int) -> None:
        for r in range(SERVE_LOAD_READS):
            s = (cid + r) % SERVE_LOAD_STREAMS
            if r % 3 == 0:
                svc.top_k(s, 5)
            else:
                svc.query(s, [(cid + 7 * r) % sessions[s].n])

    with svc:                       # background dispatch + watchdog
        writers = [threading.Thread(target=_submitter, args=(s,))
                   for s in range(SERVE_LOAD_STREAMS)]
        readers = [threading.Thread(target=_client, args=(c,))
                   for c in range(SERVE_LOAD_CLIENTS)]
        for t in writers + readers:
            t.start()
        for t in writers + readers:
            t.join()
        svc.run_until_drained()
    out = svc.report()
    out["offered_per_stream"] = SERVE_LOAD_BURSTS * SERVE_LOAD_BURST
    out["accepted_per_stream"] = list(submitted)
    out["overload_factor"] = round(
        SERVE_LOAD_BURST / SERVE_LOAD_QUEUE_DEPTH, 2)
    out["deadline_miss_rate"] = round(
        out["deadline_misses"] / max(out["requests_done"], 1), 4)
    # the acceptance ratio: with coalescing, a queued request waits at most
    # the ONE in-flight dispatch (ratio ~<=1 even at 2x overload — an
    # instantaneous burst lands right as a dispatch starts, so its wait is
    # that dispatch's full wall time), where the old per-tick barrier
    # design stacked waits several dispatches deep (ratio >> 1)
    out["queue_wait_over_compute_p50"] = round(
        out["queue_wait_p50_ms"] / max(out["exec_p50_ms"], 1e-9), 3)
    errs = []
    for s in range(SERVE_LOAD_STREAMS):
        ref = pr.numpy_reference(cur[s].snapshot(block_size=64),
                                 iterations=300)
        sess = svc.sessions[s]
        errs.append(float(pr.linf(sess.ranks[:sess.n],
                                  jnp.asarray(ref[:sess.n]))))
    out["linf_vs_reference_max"] = max(errs)
    return out


def _smoke_chaos() -> dict:
    """Silent-corruption chaos scenario (the PR-7 acceptance scenario):
    durable streams under a seeded :class:`~repro.core.chaos.ChaosPlan`
    composing every corruption kind (rank/tile/slot-table/mirror bit
    flips, dropped + duplicated operand scatters, host-graph corruption)
    with a session-domain slot kill, on a reproducible schedule.  Each
    soak step applies one update round, injects that step's scheduled
    faults through the public surfaces, and runs one synchronous
    deterministic scrub (``svc.scrub(deep=True, repair=True)``) so every
    detection is attributable to exactly one injection.  Gates: every
    injected corruption detected, at least one repair at every ladder
    rung (frontier / rebuild / restore), a clean final scrub, and oracle
    parity of the accepted-batch lineage on every stream."""
    import tempfile

    import jax.numpy as jnp
    from repro.api import (EngineConfig, IntegrityConfig, PageRankService,
                           PageRankSession, ServingConfig)
    from repro.core import pagerank as pr
    from repro.core.chaos import ChaosPlan
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    plan = ChaosPlan(seed=CHAOS_SEED, steps=CHAOS_STEPS,
                     streams=CHAOS_STREAMS, require=CHAOS_REQUIRE,
                     rate=CHAOS_RATE)
    store_root = tempfile.mkdtemp(prefix="repro-chaos-")
    # auto_repair=False: updates only *flag* (fused invariants), the
    # harness's explicit scrub both detects and repairs — keeping the
    # injected→detected accounting exactly 1:1.  max_iterations headroom
    # for the post-restore re-converge, as in serve_load.
    cfg = EngineConfig(engine="pallas", block_size=64, active_policy="rc",
                       durability="wal", checkpoint_interval=4,
                       max_iterations=2000,
                       integrity=IntegrityConfig(auto_repair=False))
    sessions = [
        PageRankSession.from_graph(
            kmer_chains(1 << CHAOS_LOG2_N, seed=140 + s), config=cfg,
            store_dir=os.path.join(store_root, f"slot{s}"))
        for s in range(CHAOS_STREAMS)]
    svc = PageRankService(sessions, serving=ServingConfig(coalesce=False))

    # accepted-batch lineage per stream = the parity oracle at the end
    cur = [s.hg for s in sessions]
    seed_ctr = iter(range(100_000))

    def _advance(s: int) -> None:
        dels, ins = random_batch(cur[s], CHAOS_BATCH_EDGES / cur[s].m,
                                 seed=7000 + next(seed_ctr))
        svc.submit(s, dels, ins)
        cur[s] = cur[s].apply_batch(dels, ins)

    injected = detected = repaired_clean = 0
    repairs_by_rung: dict = {}
    detect_lat = []
    for step in range(plan.steps):
        for s in range(CHAOS_STREAMS):
            _advance(s)
        svc.run_until_drained()
        t_inject = {}
        for ev in plan.events_at(step):
            if ev.session_fault() is not None:
                # session-domain composition: the next dispatch kills the
                # slot; the synchronous watchdog poll fails it over from
                # its durable store and drains the queue to the respawn
                svc.inject_session_fault(ev.stream, kind="dead")
                _advance(ev.stream)
                continue
            svc.sessions[ev.stream].inject_corruption(ev.corruption())
            t_inject[ev.stream] = time.perf_counter()
            injected += 1
            if ev.kind.startswith("scatter"):
                _advance(ev.stream)   # scatter faults tear the NEXT update
        svc.run_until_drained()
        for s, rep in svc.scrub(deep=True, repair=True).items():
            if not rep.failures:
                continue
            detected += 1
            if s in t_inject:
                detect_lat.append(time.perf_counter() - t_inject.pop(s))
            for rung in rep.repairs:
                repairs_by_rung[rung] = repairs_by_rung.get(rung, 0) + 1
            repaired_clean += int(rep.ok)
    final = svc.scrub(deep=True, repair=True)
    out = svc.report()
    errs = []
    for s in range(CHAOS_STREAMS):
        ref = pr.numpy_reference(cur[s].snapshot(block_size=64),
                                 iterations=300)
        sess = svc.sessions[s]
        errs.append(float(pr.linf(sess.ranks[:sess.n],
                                  jnp.asarray(ref[:sess.n]))))
    out["plan"] = {"seed": plan.seed, "steps": plan.steps,
                   "streams": plan.streams, "counts": plan.counts()}
    out["corruption_injected"] = injected
    out["corruption_detected"] = detected
    out["repaired_clean"] = repaired_clean
    out["repairs_by_rung"] = repairs_by_rung
    out["detection_latency_max_s"] = (round(max(detect_lat), 6)
                                      if detect_lat else 0.0)
    out["final_scrub_ok"] = all(r.ok for r in final.values())
    out["linf_vs_reference_max"] = max(errs)
    return out


_SHARDED_SCRIPT = textwrap.dedent("""
    import json
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import rmat

    N_DEV, N_BATCHES, LOG2_N = %(n_dev)d, %(n_batches)d, %(log2_n)d
    hg0 = rmat(LOG2_N, avg_degree=6, seed=3)
    r0 = jnp.asarray(pr.numpy_reference(hg0.snapshot(block_size=64),
                                        iterations=300))
    batches = []
    cur = hg0
    for i in range(N_BATCHES):
        dels, ins = random_batch(cur, 2e-3, seed=700 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)

    out = {"n_devices": N_DEV, "n": hg0.n, "batches": N_BATCHES,
           "partitioners": {}}
    for part in ("contiguous", "hash", "bfs_blocks"):
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(topology="sharded", n_shards=N_DEV,
                                     partitioner=part), r0=r0)
        sess.warmup()
        for dels, ins in batches:
            assert sess.update(dels, ins).stats.converged
        rep = sess.report()
        out["partitioners"][part] = {
            "edge_cut": round(rep.edge_cut, 4),
            "p50_ms": round(rep.p50_s * 1e3, 3),
            "p95_ms": round(rep.p95_s * 1e3, 3),
            "retraces_post_warmup": rep.retraces_post_warmup,
            "total_sweeps": rep.total_sweeps,
            "collective_bytes_per_sweep": rep.collective_bytes_per_sweep,
            "linf_vs_reference": float(np.max(np.abs(
                sess.ranks[:sess.n] - ref[:sess.n]))),
        }
        sess.close()
    print("SHARDED-JSON:" + json.dumps(out))
""")


def _smoke_sharded() -> dict:
    """Sharded-topology scenario: the same DF stream through a
    ``topology="sharded"`` session on an 8-host-device mesh, once per
    partitioner.  Runs in a subprocess (the XLA device count is locked at
    first jax init — the benchmark process must keep its single device)
    and records per-partitioner edge-cut, p50/p95 update latency,
    post-warmup retraces (must be 0) and oracle parity."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" \
        % SHARDED_DEVICES
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    script = _SHARDED_SCRIPT % {"n_dev": SHARDED_DEVICES,
                                "n_batches": SHARDED_BATCHES,
                                "log2_n": SHARDED_LOG2_N}
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError("sharded smoke subprocess failed:\n"
                           + out.stderr[-3000:])
    payload = [ln for ln in out.stdout.splitlines()
               if ln.startswith("SHARDED-JSON:")]
    return json.loads(payload[-1][len("SHARDED-JSON:"):])


_RECOVERY_CHILD = textwrap.dedent("""
    import sys, time
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    store_dir, log2_n, kill_after = (sys.argv[1], int(sys.argv[2]),
                                     int(sys.argv[3]))
    hg = kmer_chains(1 << log2_n, seed=4)
    r0 = jnp.asarray(pr.numpy_reference(hg.snapshot(block_size=64),
                                        iterations=300))
    cfg = EngineConfig(engine="pallas", block_size=64, durability="wal",
                       checkpoint_interval=100)
    sess = PageRankSession.from_graph(hg, config=cfg, r0=r0,
                                      store_dir=store_dir)
    cur = hg
    for i in range(kill_after):
        dels, ins = random_batch(cur, 8 / cur.m, seed=60 + i)
        sess.update(dels, ins)
        cur = cur.apply_batch(dels, ins)
    print("RECOVERY-READY", flush=True)   # the parent SIGKILLs us here
    time.sleep(300)
""")


def _smoke_recovery() -> dict:
    """Process-fault scenario (docs/FAULTS.md): a subprocess runs a
    durable streaming session, is SIGKILLed mid-run, and the session is
    restored here — recovery wall time, replayed-batch count, post-restore
    retraces and parity against an uninterrupted session are recorded.
    Restore must be bit-for-bit (same r0, same batch seeds, same jitted
    hot path) with zero post-restore retraces."""
    import select
    import shutil
    import signal
    import tempfile
    import numpy as np
    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    hg = kmer_chains(1 << RECOVERY_LOG2_N, seed=4)
    r0 = jnp.asarray(pr.numpy_reference(hg.snapshot(block_size=64),
                                        iterations=300))
    n_total = RECOVERY_KILL_AFTER + RECOVERY_AFTER
    batches, cur = [], hg
    for i in range(n_total):
        dels, ins = random_batch(cur, 8 / cur.m, seed=60 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)

    oracle = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=64), r0=r0)
    for dels, ins in batches:
        assert oracle.update(dels, ins).stats.converged

    store_dir = tempfile.mkdtemp(prefix="repro-recovery-")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    # child stderr goes to a FILE, not a pipe: a chatty XLA child filling
    # an undrained stderr pipe would deadlock against our stdout readline
    with tempfile.TemporaryFile(mode="w+") as err:
        child = subprocess.Popen(
            [sys.executable, "-c", _RECOVERY_CHILD, store_dir,
             str(RECOVERY_LOG2_N), str(RECOVERY_KILL_AFTER)],
            env=env, stdout=subprocess.PIPE, stderr=err, text=True)
        try:
            deadline = time.time() + 600
            line = ""
            while "RECOVERY-READY" not in line:
                if time.time() > deadline or (line == ""
                                              and child.poll() is not None):
                    err.seek(0)
                    raise RuntimeError("recovery child failed:\n"
                                       + err.read()[-3000:])
                # select-gate the readline so a silently hung child trips
                # the deadline instead of blocking forever
                ready, _, _ = select.select([child.stdout], [], [], 5.0)
                line = child.stdout.readline() if ready else ""
            os.kill(child.pid, signal.SIGKILL)   # crash-stop, no cleanup
            child.wait(timeout=60)
        finally:
            if child.poll() is None:
                child.kill()

    t0 = time.time()
    sess = PageRankSession.restore(store_dir)
    recovery_wall_s = time.time() - t0
    rep = sess.report()
    post = []
    for dels, ins in batches[RECOVERY_KILL_AFTER:]:
        post.append(sess.update(dels, ins))
    rep2 = sess.report()
    linf = float(np.max(np.abs(np.asarray(sess.R)
                               - np.asarray(oracle.R))))
    shutil.rmtree(store_dir, ignore_errors=True)
    return {
        "n": sess.n,
        "killed_after_batches": RECOVERY_KILL_AFTER,
        "replayed_batches": rep.replayed_batches,
        "recovery_wall_s": round(recovery_wall_s, 4),
        "post_restore_batches": len(post),
        "post_restore_retraces": rep2.retraces_post_warmup,
        "post_restore_p50_ms": round(float(np.percentile(
            [r.wall_time_s for r in post], 50)) * 1e3, 3),
        "linf_vs_uninterrupted": linf,
    }


def _smoke_stream() -> dict:
    """Streaming scenario: K fixed-size delta batches through the
    recompile-free runtime (core/stream.py) at two graph sizes, once per
    driver (the fused pull driver and the residual forward-push driver on
    the same tile pool — docs/ENGINES.md).  Records per-batch p50/p95
    latency, the post-warmup retrace count of each fused driver (must be
    0), per-driver ``edges_processed`` totals with the pull/push ratio
    (the push acceptance signal: ≥5× fewer edges at equal L∞), and the
    large/small latency ratio — per-batch cost tracking batch size, not
    graph size, is the streaming acceptance signal."""
    import jax.numpy as jnp
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.core.stream import run_stream
    from repro.graphs.generators import kmer_chains

    out = {"batch_edges": STREAM_BATCH_EDGES, "n_batches": STREAM_BATCHES,
           "sizes": {}}
    p50s = []
    for lg in STREAM_SIZES:
        hg = kmer_chains(1 << lg, seed=4)
        g = hg.snapshot(block_size=64)
        r0 = jnp.asarray(pr.numpy_reference(g, iterations=300))

        # materialize the batch list once (and its final graph, for the
        # parity oracle) — a single generation pass
        batch_list = []
        cur = hg
        for i in range(STREAM_BATCHES):
            dels, ins = random_batch(cur, STREAM_BATCH_EDGES / cur.m,
                                     seed=70 + i)
            batch_list.append((dels, ins))
            cur = cur.apply_batch(dels, ins)
        ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)

        reps = {}
        for driver in ("pull", "push"):
            reps[driver] = run_stream(hg, batch_list, block_size=64, r0=r0,
                                      active_policy="rc", driver=driver)
        rep, prep = reps["pull"], reps["push"]
        p50s.append(rep.p50_s)

        def _row(r):
            return {
                "p50_ms": round(r.p50_s * 1e3, 3),
                "p95_ms": round(r.p95_s * 1e3, 3),
                "retraces_post_warmup": r.retraces_post_warmup,
                "sweeps_last": r.results[-1].stats.sweeps,
                "edges_processed": int(sum(
                    b.stats.edges_processed for b in r.results)),
                "linf_vs_reference": float(pr.linf(
                    r.final_ranks[:g.n], jnp.asarray(ref[:g.n]))),
            }

        # the per-size row keeps the historical pull-driver schema at top
        # level (dashboards key on it) and nests the push row next to it
        row = {"n": g.n, "m": g.m, **_row(rep), "push": _row(prep)}
        row["edges_ratio_pull_over_push"] = round(
            row["edges_processed"] / max(row["push"]["edges_processed"], 1),
            3)
        row["p50_delta_ms_push_minus_pull"] = round(
            (prep.p50_s - rep.p50_s) * 1e3, 3)
        out["sizes"][str(1 << lg)] = row
    out["latency_ratio_large_over_small"] = round(p50s[-1] / p50s[0], 3)
    return out


def _smoke_ppr() -> dict:
    """Walk-engine personalized-PageRank scenario (the sweep-free engine's
    acceptance record).  Three measurements on one seeded power-law graph:

    * **accuracy vs R** — mean L1 error of the walk PPR estimate against
      the exact dense personalized oracle (``pr.ppr_numpy_reference``)
      over ``PPR_SEED_SETS`` seed sets, one point per R in
      ``PPR_R_CURVE`` (must shrink as R grows; gated at the largest R);
    * **per-delta localization** — regenerated-walk counts per update
      batch on a walk session (regenerated ≤ touched-walk mass < total
      walks, and 0 post-warmup retraces on the walk-buffer ladder);
    * **per-user serving** — ``PPR_USERS`` simulated users issuing
      seed-set top-k reads through a ``PageRankService`` (degraded-mode
      snapshot reads), recorded as query p50/p95.
    """
    import numpy as np
    from repro.api import EngineConfig, PageRankService, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.core.walk_engine import WalkState
    from repro.graphs.generators import powerlaw

    hg = powerlaw(PPR_N, PPR_AVG_DEG, seed=17)
    g = hg.snapshot(block_size=64)
    rng = np.random.default_rng(23)
    seed_sets = [rng.choice(PPR_N, PPR_SEEDS_PER_SET, replace=False)
                 for _ in range(PPR_SEED_SETS)]
    oracles = {tuple(s.tolist()): pr.ppr_numpy_reference(
        g, s, iterations=300) for s in seed_sets}

    out = {"graph": {"n": hg.n, "m": hg.m}, "walk_length": PPR_L,
           "seed_sets": PPR_SEED_SETS, "seeds_per_set": PPR_SEEDS_PER_SET,
           "l1_vs_R": {}}
    for R in PPR_R_CURVE:
        ws = WalkState(hg, R=R, L=PPR_L, seed=5)
        errs = []
        for s in seed_sets:
            est = np.asarray(ws.ppr(s))
            ref = oracles[tuple(s.tolist())][:hg.n]
            errs.append(float(np.abs(est - ref).sum()))
        out["l1_vs_R"][str(R)] = round(float(np.mean(errs)), 4)

    # -- per-delta localization on a live walk session -----------------------
    mid_r = PPR_R_CURVE[len(PPR_R_CURVE) // 2]
    cfg = EngineConfig(engine="walk", walks_per_vertex=mid_r,
                       walk_length=PPR_L, walk_seed=5)
    sess = PageRankSession.from_graph(hg, config=cfg)
    sess.warmup()
    cur = hg
    batches = []
    for j in range(PPR_BATCHES):
        dels, ins = random_batch(cur, PPR_BATCH_EDGES / cur.m, seed=900 + j)
        res = sess.update(dels, ins)
        cur = cur.apply_batch(dels, ins)
        batches.append({"regenerated_walks": res.regenerated_walks,
                        "touched_walks": res.touched_walks,
                        "total_walks": res.total_walks,
                        "wall_ms": round(res.wall_time_s * 1e3, 3)})
    rep = sess.report()
    out["localization"] = {
        "R": mid_r, "batches": batches,
        "retraces_post_warmup": rep.retraces_post_warmup,
        "bucket_retraces_post_warmup": rep.bucket_retraces_post_warmup,
    }
    sess.close()

    # -- 1k simulated users through the serving surface ----------------------
    svc = PageRankService([hg, hg], config=cfg)
    walls = []
    urng = np.random.default_rng(41)
    # one warm call per stream: the top-k query kernel legitimately
    # compiles once per (|S|, k) shape — users all share that shape
    for s in range(2):
        svc.ppr_query(s, urng.choice(PPR_N, PPR_SEEDS_PER_SET,
                                     replace=False), PPR_TOP_K)
    for u in range(PPR_USERS):
        seeds = urng.choice(PPR_N, PPR_SEEDS_PER_SET, replace=False)
        t0 = time.perf_counter()
        r = svc.ppr_query(u % 2, seeds, PPR_TOP_K)
        walls.append(time.perf_counter() - t0)
        assert len(r.values) == PPR_TOP_K
    out["serving"] = {
        "users": PPR_USERS, "top_k": PPR_TOP_K,
        "query_p50_ms": round(float(np.percentile(walls, 50)) * 1e3, 3),
        "query_p95_ms": round(float(np.percentile(walls, 95)) * 1e3, 3),
        "degraded_reads": True,
    }
    svc.stop()
    return out


def smoke(out: str = SMOKE_OUT) -> dict:
    """Tiny per-engine perf snapshot: one DF_LF dynamic update per engine,
    plus the streaming scenario (K delta batches, per-batch latency), the
    service scenario (N concurrent sessions with concurrent query clients,
    per-session p50/p95 + query staleness), the serve_load scenario
    (durable streams at 2x overload with shedding, degraded reads and a
    watchdog-recovered slot kill), the chaos scenario (a seeded
    composed-fault soak: silent corruption injected and repaired via the
    integrity subsystem, gated on detection and repair-ladder coverage)
    and the sharded scenario (a topology="sharded" session on an
    8-host-device mesh, per-partitioner edge-cut/latency).

    Records sweeps, edges_processed, wall time and the frontier-work ratio
    edges_processed / (m · sweeps) — the Pallas engine's ratio ≪ 1 is the
    "frontier-proportional work" acceptance signal; the stream section's
    flat per-batch latency with 0 post-warmup retraces is the streaming
    acceptance signal.  Wired into tier-1 as a non-failing step
    (tests/test_bench_smoke.py) so the perf trajectory is recorded on
    every run.
    """
    from benchmarks.common import updated_snapshots  # noqa: F401 (jax cfg)
    import jax.numpy as jnp
    from repro.core import pagerank as pr
    from repro.core import pallas_engine as pe
    from repro.core.delta import random_batch
    from repro.core.frontier import batch_to_device
    from repro.graphs.generators import kmer_chains
    from repro.kernels.block_spmv import ops

    # k-mer chains: the paper's locality-friendly class — a tiny batch's
    # perturbation stays inside the touched chains, so frontier work is
    # visibly ≪ |E| per sweep even at container scale (64 blocks)
    hg0 = kmer_chains(1 << 12, seed=4)
    g0 = hg0.snapshot(block_size=64)
    r_prev = jnp.asarray(pr.numpy_reference(g0, iterations=300))
    dels, ins = random_batch(hg0, 2e-4, seed=7)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    ref1 = pr.numpy_reference(g1, iterations=300)
    batch = batch_to_device(g1, dels, ins)

    report = {"graph": {"n": g1.n, "m": g1.m,
                        "batch_edges": int(len(dels) + len(ins))},
              "engines": {}}
    # dense runs BB (full SpMV per iteration: the work_ratio≈1 baseline);
    # the frontier engines run the paper's DF_LF with the per-chunk
    # converged-flag policy ("rc", §4.3).  The pallas pull matrix is built
    # once outside the timed calls (in production it is maintained
    # incrementally), so the warm second call is true steady state.  The
    # pallas engine runs its platform tile backend (ops.default_backend():
    # Pallas kernels on TPU, the XLA tile path on CPU containers).
    pmat = pe.build_pull_matrix(g1)
    for engine, mode in (("dense", "bb"), ("blocked", "lf"),
                         ("pallas", "lf")):
        ekw = {"pallas_mat": pmat} if engine == "pallas" else {}

        def go():
            return pr.df_pagerank(g0, g1, batch, r_prev, mode=mode,
                                  engine=engine, active_policy="rc", **ekw)
        res = go()
        res = go()      # second call = warm jit caches → steady-state time
        s = res.stats
        report["engines"][engine] = {
            "mode": mode,
            "converged": bool(res.converged),
            "sweeps": int(s.sweeps),
            "edges_processed": int(s.edges_processed),
            "frontier_work_ratio": (
                s.edges_processed / (g1.m * max(s.sweeps, 1))),
            "wall_time_s": round(res.wall_time_s, 4),
            "linf_vs_reference": float(pr.linf(res.ranks[:g1.n],
                                               ref1[:g1.n])),
        }
        if engine == "pallas":
            report["engines"][engine]["backend"] = ops.default_backend()

    report["stream"] = _smoke_stream()
    report["service"] = _smoke_service()
    report["serve_load"] = _smoke_serve_load()
    report["chaos"] = _smoke_chaos()
    report["sharded"] = _smoke_sharded()
    report["recovery"] = _smoke_recovery()
    report["ppr"] = _smoke_ppr()

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    print(f"# smoke report written to {os.path.abspath(out)}")
    return report


SECTIONS = [
    ("Fig 1  (chunk/block-size trade-off)", "benchmarks.bench_chunk_tradeoff"),
    ("Fig 5  (temporal graphs)", "benchmarks.bench_temporal"),
    ("Fig 6  (strong scaling)", "benchmarks.bench_scaling"),
    ("Fig 7  (batch-size sweep + error)", "benchmarks.bench_batch_sweep"),
    ("S5.2.3 (stability)", "benchmarks.bench_stability"),
    ("Fig 8/9 (delays + crashes)", "benchmarks.bench_faults"),
    ("kernels (pallas block-SpMV)", "benchmarks.bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="per-engine smoke snapshot → BENCH_smoke.json")
    ap.add_argument("--only", default=None,
                    help="substring filter on section names")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return

    failures = []
    for title, module in SECTIONS:
        if args.only and args.only not in module and args.only not in title:
            continue
        print(f"\n===== {title} [{module}] =====", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"# section done in {time.time() - t0:.1f}s")
        except Exception as e:
            failures.append((module, e))
            traceback.print_exc()
    print("\n===== roofline (from dry-run artifacts) =====", flush=True)
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception as e:
        failures.append(("benchmarks.roofline", e))
        traceback.print_exc()

    if failures:
        print(f"\n{len(failures)} benchmark section(s) FAILED: "
              f"{[m for m, _ in failures]}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
