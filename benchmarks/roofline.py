"""§Roofline (deliverable g): three-term roofline per (arch × shape) from
the compiled dry-run artifacts in results/dryrun.json.

Terms (TPU v5e targets):
    compute    = HLO_FLOPs_per_chip   / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_chip   / 819 GB/s HBM
    collective = wire_bytes_per_chip  / 50 GB/s per ICI link

HLO flops/bytes come from ``compiled.cost_analysis()``.  XLA counts a
``while`` body once, so LM cells (scan-over-layers, scan-over-microbatches)
are corrected exactly with the L=1/L=2 probe compiles:

    layer      = P2 − P1                      (incl. that layer's opt cost)
    nonlayer   = 2·P1 − P2
    per_mb     = (nonlayer − opt_nonlayer) + L·(layer − opt_layer)
    total      = opt_total + microbatches · per_mb

with the optimizer split analytically (14 flops/param; p/g/m/v traffic).
GNN / recsys / pagerank mains unroll their loops — no correction.
Collective bytes need no correction: the HLO parser multiplies by each
while's ``known_trip_count``.

Output: markdown table + per-cell bottleneck notes (printed, and written to
results/roofline.md for EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
CHIPS = {"single": 256, "multi": 512}

RESULTS = "results/dryrun.json"
OUT_MD = "results/roofline.md"


def corrected_terms(rec: Dict, chips: int) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    raw_f = rec["cost"]["flops"]
    raw_b = rec["cost"]["bytes_accessed"]
    probes = rec.get("probes")
    if probes and "layer1" in probes and "layer2" in probes:
        L = rec["n_scan_layers"]
        mb = rec.get("microbatches", 1)
        n_total = rec.get("param_count", 0)
        n_layer = rec.get("layer_param_count", 0)
        opt_f = rec.get("opt_flops", 0.0) / chips
        opt_b = rec.get("opt_bytes", 0.0) / chips
        frac_layer = (n_layer / n_total) if n_total else 0.0
        opt_layer_f = opt_f * frac_layer
        opt_layer_b = opt_b * frac_layer
        opt_nonlayer_f = opt_f - L * opt_layer_f
        opt_nonlayer_b = opt_b - L * opt_layer_b

        def total(p1, p2, opt_all, opt_layer, opt_nonlayer):
            layer = p2 - p1
            nonlayer = 2 * p1 - p2
            per_mb = max(nonlayer - opt_nonlayer, 0.0) \
                + L * max(layer - opt_layer, 0.0)
            return opt_all + mb * per_mb

        f = total(probes["layer1"]["cost"]["flops"],
                  probes["layer2"]["cost"]["flops"],
                  opt_f, opt_layer_f, opt_nonlayer_f)
        b = total(probes["layer1"]["cost"]["bytes_accessed"],
                  probes["layer2"]["cost"]["bytes_accessed"],
                  opt_b, opt_layer_b, opt_nonlayer_b)
        corrected = True
    else:
        f, b, corrected = raw_f, raw_b, False
    wire = rec["collectives"]["total_wire_bytes"]
    t_c = f / PEAK_FLOPS
    t_m = b / HBM_BW
    t_x = wire / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = rec.get("model_flops", 0.0)
    ratio = mf / (f * chips) if f > 0 else float("nan")
    return {
        "flops_per_chip": f, "bytes_per_chip": b, "wire_per_chip": wire,
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom, "model_flops": mf, "useful_ratio": ratio,
        "corrected": corrected, "raw_flops": raw_f,
        "peak_gb": rec.get("memory", {}).get("peak_bytes", 0) / 1e9,
        # fraction of the step's bound time that is useful peak compute —
        # the MFU estimate this report scores on
        "roofline_fraction": (
            (mf / chips / PEAK_FLOPS) / max(t_c, t_m, t_x)
            if f > 0 and max(t_c, t_m, t_x) > 0 else float("nan")),
    }


FIX_HINTS = {
    "compute": "compute-bound: raise per-chip utilization (larger "
               "microbatch / fuse small ops / cut remat recompute)",
    "memory": "HBM-bound: cut activation/optimizer traffic (bf16 states, "
              "fused optimizer, better layouts)",
    "collective": "collective-bound: change the sharding so collectives "
                  "move activations, not weights (TP/PP instead of "
                  "per-microbatch FSDP regathers; frontier-sparse "
                  "exchange for graphs)",
}


def build_table(results: Dict, mesh: str = "single") -> str:
    chips = CHIPS[mesh]
    lines = [
        f"### Roofline — {mesh}-pod mesh ({chips} chips, v5e: "
        f"197 TF bf16 / 819 GB/s HBM / 50 GB/s/link)",
        "",
        "| cell | kind | t_compute (s) | t_memory (s) | t_collective (s) |"
        " dominant | MODEL_FLOPS | useful/HLO | roofline frac | "
        "peak GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    notes = []
    for key in sorted(results):
        if not key.startswith(mesh + ":"):
            continue
        rec = results[key]
        cell = key.split(":", 1)[1]
        if rec.get("status") == "skipped":
            lines.append(f"| {cell} | {rec.get('kind','-')} | — | — | — | "
                         f"skipped-by-rule | — | — | — | — |")
            continue
        t = corrected_terms(rec, chips)
        if t is None:
            lines.append(f"| {cell} | {rec.get('kind','-')} | — | — | — | "
                         f"ERROR | — | — | — | — |")
            continue
        lines.append(
            f"| {cell} | {rec['kind']} | {t['t_compute']:.3g} | "
            f"{t['t_memory']:.3g} | {t['t_collective']:.3g} | "
            f"**{t['dominant']}** | {t['model_flops']:.3g} | "
            f"{t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} | "
            f"{t['peak_gb']:.2f} |")
        notes.append(f"- **{cell}** — dominant: {t['dominant']}; "
                     f"{FIX_HINTS[t['dominant']]}.")
    return "\n".join(lines + ["", "Per-cell bottleneck notes:", ""] + notes)


def main(path: str = RESULTS, out: str = OUT_MD) -> None:
    if not os.path.exists(path):
        print(f"# roofline: {path} missing — run "
              f"`python -m repro.launch.dryrun --all` first")
        return
    with open(path) as f:
        results = json.load(f)
    md = build_table(results, "single")
    print(md)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        f.write(md + "\n")
    print(f"\n# written to {out}")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
