"""Paper §5.2.3 — stability: delete a random batch, update ranks, re-insert
the same batch, update again; the L∞ distance to the original ranks must be
≈ 0 (the paper reports ≤ 5.7e-10)."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import SUITE, Row, emit, linf, updated_snapshots
from repro.core import blocked as blk
from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core.delta import pure_deletion_batch

FRACS = (1e-4, 1e-3, 1e-2)
# tightest τ first: it visits the full slot-capacity ladder, so the looser
# runs that follow can only hit existing jit cache entries
TAUS = (1e-11, 1e-10, 1e-9, 1e-8)


def tau_sweep(g0, g1, batch, r0, *, quick: bool = False) -> list:
    """τ sensitivity on DF_LF.  α/τ/τ_f are traced operands on the sweep
    kernel, so this entire sweep reuses the jit cache entries of the first
    run — the compile counter is recorded in the CSV to keep it honest."""
    rows = []
    taus = TAUS if not quick else TAUS[:2]
    entries0 = None
    for tau in taus:
        res = pr.df_pagerank(g0, g1, batch, r0, mode="lf", tau=tau)
        entries = blk.sweep._cache_size()
        if entries0 is None:
            entries0 = entries          # first τ pays all compilation
        rows.append(Row("tau_sweep", "web", "df_lf", tau, res.wall_time_s,
                        res.stats.sweeps, res.stats.edges_processed,
                        extra=f"jit_entries={entries};"
                              f"new_since_first_tau={entries - entries0}"))
    assert rows[-1].extra.endswith("new_since_first_tau=0"), \
        "a τ change must not recompile the sweep"
    return rows


def main(out: str = "results/bench_stability.csv", *, quick: bool = False):
    rows = []
    graphs = ["web", "kmer"] if not quick else ["web"]
    fracs = FRACS if not quick else (1e-3,)
    for gname in graphs:
        hg = SUITE[gname]()
        cap = 1024 * ((hg.m * 2 + 2 * hg.n) // 1024 + 3)
        g0 = hg.snapshot(edge_capacity=cap)
        r0 = pr.reference_pagerank(g0, iterations=200)
        empty = np.zeros((0, 2), np.int64)
        for frac in fracs:
            dels = pure_deletion_batch(hg, frac, seed=23)
            hg_del = hg.apply_batch(dels, empty)
            g_del = hg_del.snapshot(edge_capacity=cap)
            hg_back = hg_del.apply_batch(empty, dels)
            g_back = hg_back.snapshot(edge_capacity=cap)
            assert np.array_equal(hg.edges, hg_back.edges)
            for mode, name in (("bb", "df_bb"), ("lf", "df_lf"),
                               ("bb", "nd_bb"), ("lf", "nd_lf")):
                if name.startswith("df"):
                    b1 = fr.batch_to_device(g_del, dels, empty)
                    r1 = pr.df_pagerank(g0, g_del, b1, r0, mode=mode)
                    b2 = fr.batch_to_device(g_back, empty, dels)
                    r2 = pr.df_pagerank(g_del, g_back, b2, r1.ranks,
                                        mode=mode)
                else:
                    r1 = pr.nd_pagerank(g_del, r0, mode=mode)
                    r2 = pr.nd_pagerank(g_back, r1.ranks, mode=mode)
                err = linf(r2.ranks, r0[:r2.ranks.shape[0]])
                rows.append(Row("stability", gname, name, frac, 0.0,
                                r2.stats.sweeps, r2.stats.edges_processed,
                                err))
    worst = max(r.error for r in rows)
    emit(rows, out)           # persist the stability sweep before the rider
    # τ sensitivity rider: single-compile hyperparameter sweep, on the same
    # snapshot family (capacity formula + block size) as every other row
    g_web, g_web1, batch_w, _ = updated_snapshots(SUITE["web"](), 1e-3,
                                                  seed=31)
    r_web = pr.reference_pagerank(g_web, iterations=200)
    rows.extend(tau_sweep(g_web, g_web1, batch_w, r_web, quick=quick))
    emit(rows, out)
    print(f"# worst delete+reinsert L_inf: {worst:.3e} "
          f"(paper: <= 5.7e-10)")
    assert worst <= 5e-9, "stability invariant violated"
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
