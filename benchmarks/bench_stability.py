"""Paper §5.2.3 — stability: delete a random batch, update ranks, re-insert
the same batch, update again; the L∞ distance to the original ranks must be
≈ 0 (the paper reports ≤ 5.7e-10).

Runs through :class:`repro.api.PageRankSession` (one session per variant,
two ``update`` calls each) — the delete/re-insert pair is exactly the
dynamic-stream contract the session API owns, and ``report()`` gives the
per-session retrace accounting the CSV records."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import SUITE, Row, emit, linf
from repro.api import EngineConfig, PageRankSession
from repro.core import blocked as blk
from repro.core import pagerank as pr
from repro.core.delta import pure_deletion_batch, random_batch

FRACS = (1e-4, 1e-3, 1e-2)
# tightest τ first: it visits the full slot-capacity ladder, so the looser
# runs that follow can only hit existing jit cache entries
TAUS = (1e-11, 1e-10, 1e-9, 1e-8)

EMPTY = np.zeros((0, 2), np.int64)


def tau_sweep(hg, dels, ins, r0, *, quick: bool = False) -> list:
    """τ sensitivity on DF_LF.  α/τ/τ_f are traced operands on the sweep
    kernel, so this entire sweep reuses the jit cache entries of the first
    run — the compile counter is recorded in the CSV to keep it honest."""
    rows = []
    taus = TAUS if not quick else TAUS[:2]
    entries0 = None
    for tau in taus:
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(mode="lf", tau=tau), r0=r0)
        res = sess.update(dels, ins, variant="df")
        entries = blk.sweep._cache_size()
        if entries0 is None:
            entries0 = entries          # first τ pays all compilation
        rows.append(Row("tau_sweep", "web", "df_lf", tau, res.wall_time_s,
                        res.stats.sweeps, res.stats.edges_processed,
                        extra=f"jit_entries={entries};"
                              f"new_since_first_tau={entries - entries0}"))
    assert rows[-1].extra.endswith("new_since_first_tau=0"), \
        "a τ change must not recompile the sweep"
    return rows


def main(out: str = "results/bench_stability.csv", *, quick: bool = False):
    rows = []
    graphs = ["web", "kmer"] if not quick else ["web"]
    fracs = FRACS if not quick else (1e-3,)
    for gname in graphs:
        hg = SUITE[gname]()
        r0 = pr.reference_pagerank(hg.snapshot(), iterations=200)
        r0h = np.asarray(r0)
        for frac in fracs:
            dels = pure_deletion_batch(hg, frac, seed=23)
            hg_back = hg.apply_batch(dels, EMPTY).apply_batch(EMPTY, dels)
            assert np.array_equal(hg.edges, hg_back.edges)
            for mode, name in (("bb", "df_bb"), ("lf", "df_lf"),
                               ("bb", "nd_bb"), ("lf", "nd_lf")):
                variant = name.split("_")[0]
                sess = PageRankSession.from_graph(
                    hg, config=EngineConfig(mode=mode), r0=r0)
                sess.update(dels, EMPTY, variant=variant)     # delete ...
                r2 = sess.update(EMPTY, dels, variant=variant)  # re-insert
                err = linf(sess.ranks[:hg.n], r0h[:hg.n])
                rep = sess.report()
                # retrace accounting exists only for the compiled-driver
                # engines (pallas/distributed); omit the -1 sentinel noise
                retr = ("" if rep.retraces_post_warmup < 0 else
                        f"retraces={rep.retraces_post_warmup}")
                rows.append(Row("stability", gname, name, frac, 0.0,
                                r2.stats.sweeps, r2.stats.edges_processed,
                                err, extra=retr))
    worst = max(r.error for r in rows)
    emit(rows, out)           # persist the stability sweep before the rider
    # τ sensitivity rider: single-compile hyperparameter sweep on one
    # random update batch of the web graph
    hg_w = SUITE["web"]()
    dels_w, ins_w = random_batch(hg_w, 1e-3, seed=31)
    r_web = pr.reference_pagerank(hg_w.snapshot(), iterations=200)
    rows.extend(tau_sweep(hg_w, dels_w, ins_w, r_web, quick=quick))
    emit(rows, out)
    print(f"# worst delete+reinsert L_inf: {worst:.3e} "
          f"(paper: <= 5.7e-10)")
    assert worst <= 5e-9, "stability invariant violated"
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
