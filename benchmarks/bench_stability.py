"""Paper §5.2.3 — stability: delete a random batch, update ranks, re-insert
the same batch, update again; the L∞ distance to the original ranks must be
≈ 0 (the paper reports ≤ 5.7e-10)."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import SUITE, Row, emit, linf
from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core.delta import pure_deletion_batch

FRACS = (1e-4, 1e-3, 1e-2)


def main(out: str = "results/bench_stability.csv", *, quick: bool = False):
    rows = []
    graphs = ["web", "kmer"] if not quick else ["web"]
    fracs = FRACS if not quick else (1e-3,)
    for gname in graphs:
        hg = SUITE[gname]()
        cap = 1024 * ((hg.m * 2 + 2 * hg.n) // 1024 + 3)
        g0 = hg.snapshot(edge_capacity=cap)
        r0 = pr.reference_pagerank(g0, iterations=200)
        empty = np.zeros((0, 2), np.int64)
        for frac in fracs:
            dels = pure_deletion_batch(hg, frac, seed=23)
            hg_del = hg.apply_batch(dels, empty)
            g_del = hg_del.snapshot(edge_capacity=cap)
            hg_back = hg_del.apply_batch(empty, dels)
            g_back = hg_back.snapshot(edge_capacity=cap)
            assert np.array_equal(hg.edges, hg_back.edges)
            for mode, name in (("bb", "df_bb"), ("lf", "df_lf"),
                               ("bb", "nd_bb"), ("lf", "nd_lf")):
                if name.startswith("df"):
                    b1 = fr.batch_to_device(g_del, dels, empty)
                    r1 = pr.df_pagerank(g0, g_del, b1, r0, mode=mode)
                    b2 = fr.batch_to_device(g_back, empty, dels)
                    r2 = pr.df_pagerank(g_del, g_back, b2, r1.ranks,
                                        mode=mode)
                else:
                    r1 = pr.nd_pagerank(g_del, r0, mode=mode)
                    r2 = pr.nd_pagerank(g_back, r1.ranks, mode=mode)
                err = linf(r2.ranks, r0[:r2.ranks.shape[0]])
                rows.append(Row("stability", gname, name, frac, 0.0,
                                r2.stats.sweeps, r2.stats.edges_processed,
                                err))
    emit(rows, out)
    worst = max(r.error for r in rows)
    print(f"# worst delete+reinsert L_inf: {worst:.3e} "
          f"(paper: <= 5.7e-10)")
    assert worst <= 5e-9, "stability invariant violated"
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
