"""Kernel-layer benchmark: the block-sparse SpMV Pallas kernel.

CPU interpret-mode wall time is meaningless for a TPU kernel, so this bench
reports what IS meaningful off-hardware:
  * correctness vs the pure-jnp oracle across tile sizes (allclose);
  * structural efficiency: stored-tile density (nnz / tile capacity), the
    VMEM working set per grid step, and MXU-alignment of the tile shapes —
    the quantities the §Roofline kernel analysis is based on;
  * the OR-semiring frontier-expansion path vs the segment_max oracle.
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from benchmarks.common import SUITE, Row, emit
from repro.kernels.block_spmv import ops, ref

BLOCKS = (64, 128, 256)


def main(out: str = "results/bench_kernels.csv", *, quick: bool = False):
    rows = []
    # interpret=True executes the kernel body in Python per grid step —
    # kernel-validation graphs stay small (structure, not scale, matters)
    import repro.graphs.generators as gen
    kernel_suite = {"web": lambda: gen.rmat(10, 8, seed=1),
                    "road": lambda: gen.grid_road(32, seed=3)}
    graphs = ["web", "road"] if not quick else ["web"]
    blocks = BLOCKS if not quick else (128,)
    for gname in graphs:
        hg = kernel_suite[gname]()
        e = hg.edges
        n = hg.n
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random(n), jnp.float32)
        for B in blocks:
            mat = ops.build_block_sparse(e[:, 1], e[:, 0], n, n, block=B)
            y = ops.block_spmv(mat, x, interpret=True)
            y_ref = ref.spmv_ref(e[:, 1], e[:, 0], n, x)
            err = float(jnp.max(jnp.abs(y - y_ref[:y.shape[0]])))
            nnz = len(e)
            n_tiles = int(mat.tiles.shape[0])
            density = nnz / (n_tiles * B * B)
            vmem_kib = (B * B + 2 * B) * 4 / 1024
            rows.append(Row(
                "kernel_spmv", gname, f"pallas_B{B}", B, 0.0, 0, nnz, err,
                extra=(f"tiles={n_tiles};density={density:.4f};"
                       f"vmem_kib={vmem_kib:.0f};"
                       f"mxu_aligned={int(B % 128 == 0)}")))
            assert err < 1e-4, f"pallas SpMV mismatch: {err}"
            # OR-semiring frontier expansion
            flags = jnp.zeros((n,), jnp.float32).at[
                jnp.asarray(rng.integers(0, n, 32))].set(1.0)
            hit = ops.block_spmv(mat, flags, semiring="or", interpret=True)
            hit_ref = (ref.spmv_ref(e[:, 1], e[:, 0], n, flags) > 0)
            err_or = float(jnp.max(jnp.abs(
                hit - hit_ref[:hit.shape[0]].astype(jnp.float32))))
            rows.append(Row("kernel_expand", gname, f"pallas_or_B{B}", B,
                            0.0, 0, nnz, err_or))
            assert err_or == 0.0, "OR-semiring expansion mismatch"
    emit(rows, out)
    print("# pallas kernels match oracles across block sizes")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
