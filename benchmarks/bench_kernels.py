"""Kernel-layer benchmark: the block-sparse SpMV Pallas kernel.

CPU interpret-mode wall time is meaningless for a TPU kernel, so this bench
reports what IS meaningful off-hardware:
  * correctness vs the pure-jnp oracle across tile sizes (allclose);
  * structural efficiency: stored-tile density (nnz / tile capacity), the
    VMEM working set per grid step, and MXU-alignment of the tile shapes —
    the quantities the §Roofline kernel analysis is based on;
  * the OR-semiring frontier-expansion path vs the segment_max oracle.
"""
from __future__ import annotations

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import SUITE, Row, emit
from repro.kernels.block_spmv import ops, ref

BLOCKS = (64, 128, 256)


def _bench_build(e: np.ndarray, n: int, block: int, gname: str,
                 rows: list) -> None:
    """Build-path microbenchmark: full vectorized build vs apply_delta on a
    1% batch — the structural win of the incremental builder."""
    t0 = time.perf_counter()
    mat = ops.build_block_sparse(e[:, 1], e[:, 0], n, n, block=block)
    jax.block_until_ready(mat.tiles)
    t_full = time.perf_counter() - t0
    b = max(1, len(e) // 100)
    rng = np.random.default_rng(1)
    dr = rng.integers(0, n, b)
    dc = rng.integers(0, n, b)
    ones = np.ones(b, np.float32)
    # warm the scatter-add jit; block so async dispatch can't hide the work
    jax.block_until_ready(ops.apply_delta(mat, dr, dc, ones).tiles)
    t0 = time.perf_counter()
    jax.block_until_ready(ops.apply_delta(mat, dr, dc, ones).tiles)
    t_delta = time.perf_counter() - t0
    rows.append(Row("kernel_build", gname, f"full_B{block}", block,
                    t_full, 0, len(e),
                    extra=f"tiles={int(mat.tiles.shape[0])}"))
    rows.append(Row("kernel_build", gname, f"delta_B{block}", block,
                    t_delta, 0, b,
                    extra=f"speedup_vs_full={t_full / max(t_delta, 1e-9):.1f}x"))


def main(out: str = "results/bench_kernels.csv", *, quick: bool = False):
    rows = []
    # interpret=True executes the kernel body in Python per grid step —
    # kernel-validation graphs stay small (structure, not scale, matters)
    import repro.graphs.generators as gen
    kernel_suite = {"web": lambda: gen.rmat(10, 8, seed=1),
                    "road": lambda: gen.grid_road(32, seed=3)}
    graphs = ["web", "road"] if not quick else ["web"]
    blocks = BLOCKS if not quick else (128,)
    for gname in graphs:
        hg = kernel_suite[gname]()
        e = hg.edges
        n = hg.n
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random(n), jnp.float32)
        for B in blocks:
            mat = ops.build_block_sparse(e[:, 1], e[:, 0], n, n, block=B)
            y = ops.block_spmv(mat, x, interpret=True, backend="pallas")
            y_ref = ref.spmv_ref(e[:, 1], e[:, 0], n, x)
            err = float(jnp.max(jnp.abs(y - y_ref[:y.shape[0]])))
            nnz = len(e)
            n_tiles = int(mat.tiles.shape[0])
            density = nnz / (n_tiles * B * B)
            vmem_kib = (B * B + 2 * B) * 4 / 1024
            rows.append(Row(
                "kernel_spmv", gname, f"pallas_B{B}", B, 0.0, 0, nnz, err,
                extra=(f"tiles={n_tiles};density={density:.4f};"
                       f"vmem_kib={vmem_kib:.0f};"
                       f"mxu_aligned={int(B % 128 == 0)}")))
            assert err < 1e-4, f"pallas SpMV mismatch: {err}"
            # OR-semiring frontier expansion
            flags = jnp.zeros((n,), jnp.float32).at[
                jnp.asarray(rng.integers(0, n, 32))].set(1.0)
            hit = ops.block_spmv(mat, flags, semiring="or", interpret=True,
                                 backend="pallas")
            hit_ref = (ref.spmv_ref(e[:, 1], e[:, 0], n, flags) > 0)
            err_or = float(jnp.max(jnp.abs(
                hit - hit_ref[:hit.shape[0]].astype(jnp.float32))))
            rows.append(Row("kernel_expand", gname, f"pallas_or_B{B}", B,
                            0.0, 0, nnz, err_or))
            assert err_or == 0.0, "OR-semiring expansion mismatch"
            # frontier-compacted variant: a strict subset of active
            # row-blocks must reproduce the full result on those blocks
            n_rb = mat.n_rb
            ids = np.full(n_rb, -1, np.int32)
            sub = np.arange(0, n_rb, 2, dtype=np.int32)
            ids[:len(sub)] = sub
            xp = jnp.zeros((mat.n_cb * B,), x.dtype).at[:n].set(x)
            ya = np.asarray(ops.block_spmv_active(
                mat, xp, jnp.asarray(ids), interpret=True,
                backend="pallas"))
            ya = np.concatenate(
                [ya, np.zeros(n_rb * B - len(ya))]).reshape(n_rb, B)
            yf = np.asarray(y_ref)
            yf = np.concatenate([yf, np.zeros(n_rb * B - len(yf))])
            err_act = max(float(np.abs(ya[rb] - yf.reshape(n_rb, B)[rb]).max())
                          for rb in sub)
            rows.append(Row("kernel_spmv_active", gname,
                            f"pallas_active_B{B}", B, 0.0, 0, nnz, err_act,
                            extra=f"active_blocks={len(sub)}/{n_rb}"))
            assert err_act < 1e-4, f"active SpMV mismatch: {err_act}"
            # XLA tile path (the CPU production backend): parity + warm time
            y_xla = ops.block_spmv(mat, x, backend="xla")
            err_xla = float(jnp.max(jnp.abs(y_xla - y_ref[:y_xla.shape[0]])))
            assert err_xla < 1e-4, f"xla tile SpMV mismatch: {err_xla}"
            t0 = time.perf_counter()
            jax.block_until_ready(ops.block_spmv(mat, x, backend="xla"))
            t_xla = time.perf_counter() - t0
            rows.append(Row("kernel_spmv", gname, f"xla_B{B}", B, t_xla, 0,
                            nnz, err_xla,
                            extra="backend=xla;warm_wall_time"))
        _bench_build(e, n, blocks[-1], gname, rows)
    emit(rows, out)
    print("# pallas kernels match oracles across block sizes")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
