"""Paper Fig. 1 analogue — the chunk-size trade-off, TPU-native version.

On the CPU+OpenMP original, small chunks cut barrier wait but raise
scheduling overhead.  In the blocked-frontier engine the same dial is the
vertex-block size: small blocks → tighter frontier (fewer wasted edges,
less padding) but more per-block scheduling overhead; large blocks → the
opposite.  We sweep block_size and report total edges processed (work),
sweeps, wall time, and the simulated barrier-wait fraction for BB (the
Fig. 1 percentage labels).

Runs through :class:`repro.api.PageRankSession` — ``block_size`` is a
config axis and the thread-fault schedule enters via the unified
``fault_domain`` axis (docs/FAULTS.md)."""
from __future__ import annotations

import sys

from benchmarks.common import SUITE, Row, emit
from repro.api import EngineConfig, PageRankSession, ThreadFaultDomain
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.faults import FaultPlan

BLOCK_SIZES = (64, 256, 1024, 4096)
BATCH_FRAC = 1e-4


def main(out: str = "results/bench_chunk_tradeoff.csv",
         *, quick: bool = False):
    rows = []
    graphs = ["web", "social"] if not quick else ["web"]
    sizes = BLOCK_SIZES if not quick else (256, 1024)
    for gname in graphs:
        hg = SUITE[gname]()
        dels, ins = random_batch(hg, BATCH_FRAC, seed=41)
        for bs in sizes:
            r_prev = pr.reference_pagerank(hg.snapshot(block_size=bs),
                                           iterations=250)
            for mode in ("bb", "lf"):
                cfg = EngineConfig(
                    mode=mode, block_size=bs,
                    fault_domain=ThreadFaultDomain(FaultPlan(n_threads=64)))
                sess = PageRankSession.from_graph(hg, config=cfg, r0=r_prev)
                res = sess.update(dels, ins, variant="df")
                st = res.stats
                # simulated per-thread imbalance: barrier wait fraction is
                # 1 − mean(work)/max(work) per sweep, aggregated by time
                rows.append(Row(
                    "chunk_tradeoff", gname, f"df_{mode}", bs,
                    res.wall_time_s, st.sweeps, st.edges_processed,
                    sim_ms=st.sim_time_ms,
                    extra=f"blocks={st.blocks_processed}"))
    emit(rows, out)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
