"""Paper Fig. 1 analogue — the chunk-size trade-off, TPU-native version.

On the CPU+OpenMP original, small chunks cut barrier wait but raise
scheduling overhead.  In the blocked-frontier engine the same dial is the
vertex-block size: small blocks → tighter frontier (fewer wasted edges,
less padding) but more per-block scheduling overhead; large blocks → the
opposite.  We sweep block_size and report total edges processed (work),
sweeps, wall time, and the simulated barrier-wait fraction for BB (the
Fig. 1 percentage labels)."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import SUITE, Row, emit
from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.faults import FaultPlan, T_BLOCK_NS, T_EDGE_NS

BLOCK_SIZES = (64, 256, 1024, 4096)
BATCH_FRAC = 1e-4


def main(out: str = "results/bench_chunk_tradeoff.csv",
         *, quick: bool = False):
    rows = []
    graphs = ["web", "social"] if not quick else ["web"]
    sizes = BLOCK_SIZES if not quick else (256, 1024)
    for gname in graphs:
        hg = SUITE[gname]()
        dels, ins = random_batch(hg, BATCH_FRAC, seed=41)
        hg_cur = hg.apply_batch(dels, ins)
        cap = 1024 * ((hg.m * 2 + 2 * hg.n) // 1024 + 3)
        for bs in sizes:
            g_prev = hg.snapshot(block_size=bs, edge_capacity=cap)
            g_cur = hg_cur.snapshot(block_size=bs, edge_capacity=cap)
            batch = fr.batch_to_device(g_cur, dels, ins)
            r_prev = pr.reference_pagerank(g_prev, iterations=250)
            for mode in ("bb", "lf"):
                plan = FaultPlan(n_threads=64)
                res = pr.df_pagerank(g_prev, g_cur, batch, r_prev,
                                     mode=mode, faults=plan)
                st = res.stats
                # simulated per-thread imbalance: barrier wait fraction is
                # 1 − mean(work)/max(work) per sweep, aggregated by time
                rows.append(Row(
                    "chunk_tradeoff", gname, f"df_{mode}", bs,
                    res.wall_time_s, st.sweeps, st.edges_processed,
                    sim_ms=st.sim_time_ms,
                    extra=f"blocks={st.blocks_processed}"))
    emit(rows, out)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
