"""Paper Fig. 6 — strong scaling of DF_BB / DF_LF over 1..64 pseudo-threads
on a fixed batch (1e-4|E|), using the simulated-time model (per-thread work
= edges·t_edge + blocks·t_block; BB takes the max over ALL threads at the
barrier, LF overlaps — see repro/core/faults.py).

The paper reports 14.5×(BB) / 21.3×(LF) at 64 threads with NUMA effects; the
simulated model reproduces the *shape* (LF scales further than BB because
the barrier waits on the slowest thread)."""
from __future__ import annotations

import sys

from benchmarks.common import SUITE, Row, emit, run_variant, updated_snapshots
from repro.core import pagerank as pr
from repro.core.faults import FaultPlan

THREADS = (1, 2, 4, 8, 16, 32, 64)
BATCH_FRAC = 1e-4


def main(out: str = "results/bench_scaling.csv", *, quick: bool = False):
    rows = []
    graphs = ["web", "social"] if not quick else ["web"]
    threads = THREADS if not quick else (1, 8, 64)
    for gname in graphs:
        hg = SUITE[gname]()
        g_prev, g_cur, batch, _ = updated_snapshots(hg, BATCH_FRAC, seed=31)
        r_prev = pr.reference_pagerank(g_prev, iterations=250)
        base = {}
        for m in ("df_bb", "df_lf"):
            for t in threads:
                plan = FaultPlan(n_threads=t)
                res = run_variant(m, g_prev, g_cur, batch, r_prev,
                                  faults=plan)
                ms = res.stats.sim_time_ms
                if t == threads[0]:
                    base[m] = ms
                rows.append(Row("scaling", gname, m, t, res.wall_time_s,
                                res.stats.sweeps,
                                res.stats.edges_processed,
                                sim_ms=ms,
                                extra=f"speedup={base[m] / max(ms, 1e-9):.2f}"
                                ))
    emit(rows, out)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
