"""Tiered-storage scaling bench — host-paged cold tiles, device hot set.

Drives streaming DF sessions over a size ladder under *shrinking device
budgets* (``EngineConfig.device_budget_bytes``) and records, per
(n, budget) row:

  * p50 / p95 per-batch update latency,
  * device bytes by component (tile pool / slot tables / operand mirrors /
    walk buffers) and bytes/vertex, from ``report()``'s memory audit,
  * hot-set hit rate and the full tiering counter block,
  * checkpoint + restore wall time (durability is budget-independent:
    ``save()`` serializes host truth, so these should be flat across
    budgets at fixed n),
  * post-warmup retraces (must be 0 — the hot path stays compile-free
    under admission/eviction because gathers are bucket-padded).

Plus a blocked-oracle parity check at the largest dense-fitting size of
the tier (full snapshot + ``run_blocked`` vs the tiered session's ranks),
and an R-MAT/power-law row at modest n: dense 64x64 tiles make
low-locality power-law graphs pool-quadratic (every edge lands in its own
tile), so the *scaling curve* uses the road-network family the tiering is
built for while the R-MAT row records the adversarial datapoint.

Tiers::

    python -m benchmarks.scale --smoke    # CI tier: n = 4K..16K, seconds
    python -m benchmarks.scale            # default: n = 64K..262K
    python -m benchmarks.scale --full     # adds the n = 1M acceptance row

``--driver push`` runs the ladder under the residual forward-push driver
(same tile pool, work ∝ residual mass — docs/ENGINES.md); the smoke tier
always appends one push row at half budget so BENCH_scale.json records
the push-under-tiering datapoint on every CI run.

The multi-million extension beyond ``--full`` (n = 4M, side 2048) is a
manual run: same command with ``--side 2048`` after confirming ~20 GB of
host headroom for the tile pool — see docs/SCALE.md for the sizing rule.

Rows warm-start from a host-computed reference (``_reference_ranks``):
the bench measures *streaming* behavior under a budget, and the cold
solve is engine-bound and budget-independent (deployments restore from
checkpoints; the tiered cold-solve path is tested at small n in
tests/test_tiering.py).

Writes ``BENCH_scale.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time
from typing import List, Optional

import numpy as np

from repro.api import EngineConfig, PageRankSession
from repro.core import blocked as blk
from repro.core import pagerank as pr
from repro.core import tiering
from repro.graphs.generators import grid_road, rmat

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_scale.json")

# (side, tau, batches, batch_edges) per tier; n = side^2
SMOKE_LADDER = ((64, 1e-8, 4, 16), (128, 1e-8, 4, 16))
DEFAULT_LADDER = ((256, 1e-7, 4, 32), (512, 1e-7, 3, 32))
FULL_LADDER = ((1024, 1e-6, 2, 32),)

BUDGET_FRACS = (1.0, 0.5)
SMOKE_EXTRA_FRAC = 0.25          # smallest smoke row also runs quarter-budget


def _pool_bytes(hg, block_size: int = 64) -> int:
    """Host-tier size of the full tile pool for this graph (the number the
    budget fractions are taken against)."""
    g0 = hg.snapshot(block_size=block_size)
    src, dst = g0.in_edges_host()
    pool = tiering.HostTilePool.from_edges(
        dst, src, g0.n_pad, g0.n_pad, block=block_size,
        dtype=np.dtype(np.float32))
    return int(pool.nbytes)


def _local_batch(rng, n: int, k: int, window: int = 4096) -> np.ndarray:
    """Insertion batch with temporal locality: endpoints drawn from one
    random window of ids (real streams touch a working set, not the whole
    id space — and a graph-wide batch makes every row-block hot, which
    benchmarks the engine, not the tiering)."""
    base = int(rng.integers(0, max(n - window, 1)))
    return base + rng.integers(0, min(window, n), (k, 2))


def _reference_ranks(hg) -> np.ndarray:
    """Host-computed warm start (f64 bincount power iteration).  The bench
    measures *streaming* behavior under a budget; the cold solve is
    engine-bound and identical across budgets, so every row starts from
    the same converged reference (real deployments restore from a
    checkpoint).  The tiered cold-solve path itself is covered at small n
    in tests/test_tiering.py."""
    g = hg.snapshot(block_size=64)
    return pr.numpy_reference(g, iterations=200).astype(np.float32)


def _run_row(hg, *, tau: float, batches: int, batch_edges: int,
             budget_frac: float, pool_bytes: int, seed: int,
             graph_name: str, r0: Optional[np.ndarray] = None,
             driver: str = "pull") -> dict:
    import jax.numpy as jnp
    n = hg.n
    budget = max(int(pool_bytes * budget_frac), 1)
    cfg = EngineConfig(engine="pallas", tau=tau, block_size=64,
                       dtype="float32", device_budget_bytes=budget,
                       driver=driver)
    t0 = time.perf_counter()
    sess = PageRankSession.from_graph(
        hg, config=cfg, r0=None if r0 is None else jnp.asarray(r0))
    init_s = time.perf_counter() - t0
    sess.warmup()

    rng = np.random.default_rng(seed)
    walls: List[float] = []
    converged = 0
    for _ in range(batches):
        ins = _local_batch(rng, n, batch_edges)
        dels = np.zeros((0, 2), np.int64)
        t0 = time.perf_counter()
        res = sess.update(dels, ins)
        walls.append(time.perf_counter() - t0)
        converged += int(res.stats.converged)

    rep = sess.report()
    # durability is budget-independent: save() walks host truth
    tmp = tempfile.mkdtemp(prefix="bench_scale_ckpt_")
    try:
        t0 = time.perf_counter()
        sess.save(tmp)
        ckpt_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        restored = PageRankSession.restore(tmp)
        restore_s = time.perf_counter() - t0
        restore_linf = float(np.max(np.abs(
            np.asarray(restored.ranks) - np.asarray(sess.ranks))))
        restored.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    row = {
        "graph": graph_name,
        "n": n,
        "m": hg.m,
        "driver": driver,
        "budget_frac": budget_frac,
        "budget_bytes": budget,
        "pool_bytes": pool_bytes,
        "tau": tau,
        "batches": batches,
        "batch_edges": batch_edges,
        "batches_converged": converged,
        "init_s": round(init_s, 4),
        "p50_batch_s": round(float(np.percentile(walls, 50)), 4),
        "p95_batch_s": round(float(np.percentile(walls, 95)), 4),
        "ckpt_s": round(ckpt_s, 4),
        "restore_s": round(restore_s, 4),
        "restore_linf": restore_linf,
        "retraces_post_warmup": rep.retraces_post_warmup,
        "bucket_retraces_post_warmup": rep.bucket_retraces_post_warmup,
        "hit_rate": rep.tiering["hit_rate"],
        "tiering": rep.tiering,
        "device_bytes": rep.device_bytes,
        "bytes_per_vertex": round(rep.bytes_per_vertex, 2),
    }
    final_ranks = np.asarray(sess.ranks).copy()
    final_hg = sess.hg
    sess.close()
    return row, final_ranks, final_hg


def _oracle_parity(hg, ranks: np.ndarray, *, tau: float) -> dict:
    """Blocked Gauss-Seidel oracle on the final snapshot vs the tiered
    session's served ranks (the dense-fitting cross-engine check).  The
    oracle warm-starts from its own host reference — it still converges
    to its own fixed point, just without paying 100+ cold sweeps."""
    import jax.numpy as jnp
    g = hg.snapshot(block_size=64)
    R0 = jnp.asarray(pr.numpy_reference(g, iterations=200)
                     .astype(np.float32))
    R, st = blk.run_blocked(g, R0, g.vertex_valid, mode="lf", tau=tau,
                            active_policy="rc")
    linf = float(np.max(np.abs(np.asarray(R)[:g.n] - ranks[:g.n])))
    return {"n": g.n, "m": g.m, "linf": linf,
            "oracle_converged": bool(st.converged)}


def main(*, smoke: bool = False, full: bool = False,
         side: Optional[int] = None, driver: str = "pull",
         out: str = OUT) -> dict:
    if smoke:
        ladder = SMOKE_LADDER
    elif full:
        ladder = DEFAULT_LADDER + FULL_LADDER
    else:
        ladder = DEFAULT_LADDER
    if side is not None:            # manual multi-million extension
        ladder = ladder + ((side, 1e-6, 2, 32),)

    import jax
    report = {
        "meta": {
            "tier": ("smoke" if smoke else "full" if full else "default"),
            "backend": jax.default_backend(),
            "warm_start": "host_reference",
            "budget_fracs": list(BUDGET_FRACS),
            "driver": driver,
            "generated_unix": int(time.time()),
        },
        "rows": [],
    }

    parity_candidate = None
    for i, (s, tau, batches, batch_edges) in enumerate(ladder):
        hg = grid_road(s, seed=7)
        pool_b = _pool_bytes(hg)
        r0 = _reference_ranks(hg)
        fracs = BUDGET_FRACS
        if smoke and i == 0:
            fracs = BUDGET_FRACS + (SMOKE_EXTRA_FRAC,)
        if s >= 1024:
            # the acceptance row needs budget < pool; a second full-budget
            # pass would double an engine-bound hour for no new signal
            fracs = (0.5,)
        for frac in fracs:
            row, ranks, final_hg = _run_row(
                hg, tau=tau, batches=batches, batch_edges=batch_edges,
                budget_frac=frac, pool_bytes=pool_b, seed=11 + i,
                graph_name=f"grid_road({s})", r0=r0, driver=driver)
            report["rows"].append(row)
            print(f"[scale] {row['graph']} {driver} budget={frac} "
                  f"p50={row['p50_batch_s']}s hit={row['hit_rate']:.3f} "
                  f"retr={row['retraces_post_warmup']}", flush=True)
            # parity at the LARGEST dense-fitting size: track the biggest
            # sub-budget row whose oracle run is affordable (n <= 262144)
            if frac < 1.0 and hg.n <= 262144:
                parity_candidate = (final_hg, ranks, tau)

    # the adversarial power-law datapoint (modest n: dense tiles make
    # R-MAT pool-quadratic — recorded, not scaled)
    rm = rmat(12, 8, seed=9, chunk_edges=1 << 15)
    pool_b = _pool_bytes(rm)
    row, ranks, final_hg = _run_row(
        rm, tau=1e-8, batches=3, batch_edges=16, budget_frac=0.5,
        pool_bytes=pool_b, seed=3, graph_name="rmat(2^12)",
        r0=_reference_ranks(rm), driver=driver)
    report["rows"].append(row)
    if parity_candidate is None:
        parity_candidate = (final_hg, ranks, 1e-8)

    # the push-driver datapoint under a budget (driver="push" composes
    # with tiering: a push to a non-resident row defers into the refill
    # bitmap — docs/ENGINES.md).  Recorded, not a parity candidate; the
    # full push ladder is `--driver push`.
    if smoke and driver == "pull":
        s0 = SMOKE_LADDER[0][0]
        hg_push = grid_road(s0, seed=7)
        row, _, _ = _run_row(
            hg_push, tau=SMOKE_LADDER[0][1], batches=SMOKE_LADDER[0][2],
            batch_edges=SMOKE_LADDER[0][3], budget_frac=0.5,
            pool_bytes=_pool_bytes(hg_push), seed=11,
            graph_name=f"grid_road({s0})", r0=_reference_ranks(hg_push),
            driver="push")
        report["rows"].append(row)
        print(f"[scale] {row['graph']} push budget=0.5 "
              f"p50={row['p50_batch_s']}s retr="
              f"{row['retraces_post_warmup']}", flush=True)

    hg_p, ranks_p, tau_p = parity_candidate
    report["oracle_parity"] = _oracle_parity(hg_p, ranks_p, tau=tau_p)
    print(f"[scale] oracle parity n={report['oracle_parity']['n']} "
          f"linf={report['oracle_parity']['linf']:.3e}", flush=True)

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier: tiny ladder, seconds")
    ap.add_argument("--full", action="store_true",
                    help="adds the n=1M acceptance row")
    ap.add_argument("--side", type=int, default=None,
                    help="manual extension: extra grid side (n = side^2)")
    ap.add_argument("--driver", choices=("pull", "push"), default="pull",
                    help="convergence driver for the ladder rows "
                         "(docs/ENGINES.md; smoke tier always appends one "
                         "push datapoint)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    main(smoke=args.smoke, full=args.full, side=args.side,
         driver=args.driver, out=args.out)
