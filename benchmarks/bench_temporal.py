"""Paper Fig. 5 — real-world temporal-network workload: load a 90% prefix,
then stream the remaining edges as insertion batches, updating PageRanks
per batch with all six methods."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import (Row, emit, geomean, linf, reference_ranks,
                               run_variant, timed)
from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core.delta import temporal_batches
from repro.core.graph import HostGraph
from repro.graphs.generators import temporal_stream

METHODS = ("static_bb", "static_lf", "nd_bb", "nd_lf", "df_bb", "df_lf")


def main(out: str = "results/bench_temporal.csv", *, quick: bool = False):
    n = 8192 if quick else 32768
    m_total = n * 12
    stream = temporal_stream(n, m_total, seed=5)
    rows = []
    for batch_frac in ((1e-3,) if quick else (1e-4, 1e-3)):
        prefix, batches = temporal_batches(stream, prefix_frac=0.9,
                                           batch_frac=batch_frac)
        hg = HostGraph(n, prefix)
        cap = 1024 * ((m_total * 2 + 2 * n) // 1024 + 2)
        n_batches = 3 if quick else 6
        totals = {m: 0.0 for m in METHODS}
        err_max = {m: 0.0 for m in METHODS}
        r_prev = pr.reference_pagerank(
            hg.snapshot(edge_capacity=cap), iterations=250)
        for bi, ins in enumerate(batches):
            if bi >= n_batches:
                break
            hg_cur = hg.apply_batch(np.zeros((0, 2), np.int64), ins)
            g_prev = hg.snapshot(edge_capacity=cap)
            g_cur = hg_cur.snapshot(edge_capacity=cap)
            batch = fr.batch_to_device(g_cur, np.zeros((0, 2), np.int64),
                                       ins)
            ref = reference_ranks(g_cur)
            for m in METHODS:
                r = timed(lambda m=m: run_variant(m, g_prev, g_cur, batch,
                                                  r_prev))
                res = r["result"]
                totals[m] += r["time_s"]
                err_max[m] = max(err_max[m],
                                 linf(res.ranks, ref[:res.ranks.shape[0]]))
            hg = hg_cur
            r_prev = ref
        for m in METHODS:
            rows.append(Row("temporal", f"stream_n{n}", m, batch_frac,
                            totals[m] / n_batches, n_batches, 0,
                            err_max[m]))
    emit(rows, out)
    base = {r.method: r.time_s for r in rows if r.x == rows[0].x}
    if "df_lf" in base:
        for m in METHODS:
            if m != "df_lf":
                print(f"# DF_LF speedup over {m} (temporal): "
                      f"{base[m] / base['df_lf']:.2f}x")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
