"""Paper Fig. 7 — runtime + error of Static/ND/DF × BB/LF over batch sizes.

Validates the headline claims at container scale:
  * DF_LF is the fastest dynamic method for small batches (paper: 4.6× vs
    ND_LF up to 1e-3|E|);
  * past ~1e-3|E| the frontier saturates and DF loses its edge (crossover);
  * DF error vs the reference stays within [0, 1e-9) at τ = 1e-10.
"""
from __future__ import annotations

import sys

from benchmarks.common import (SUITE, Row, emit, geomean, linf,
                               reference_ranks, run_variant, timed,
                               updated_snapshots)
from repro.core import pagerank as pr

BATCH_FRACS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2)
METHODS = ("static_bb", "static_lf", "nd_bb", "nd_lf", "df_bb", "df_lf")


def main(out: str = "results/bench_batch_sweep.csv", *, quick: bool = False):
    rows = []
    fracs = BATCH_FRACS if not quick else (1e-4, 1e-2)
    graphs = list(SUITE) if not quick else ["web", "road"]
    speedups = {m: [] for m in METHODS}
    for gname in graphs:
        hg = SUITE[gname]()
        for frac in fracs:
            g_prev, g_cur, batch, _ = updated_snapshots(hg, frac, seed=7)
            r_prev = pr.reference_pagerank(g_prev, iterations=250)
            ref = reference_ranks(g_cur)
            times = {}
            for m in METHODS:
                r = timed(lambda m=m: run_variant(
                    m, g_prev, g_cur, batch, r_prev), repeats=2)
                res = r["result"]
                err = linf(res.ranks, ref[:res.ranks.shape[0]])
                times[m] = r["time_s"]
                rows.append(Row("batch_sweep", gname, m, frac, r["time_s"],
                                res.stats.sweeps,
                                res.stats.edges_processed, err))
            if frac <= 1e-3:
                for m in METHODS:
                    if m != "df_lf":
                        speedups[m].append(times[m] / times["df_lf"])
    emit(rows, out)
    for m in METHODS:
        if speedups[m]:
            print(f"# DF_LF speedup over {m} (batch<=1e-3|E|): "
                  f"{geomean(speedups[m]):.2f}x")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
