"""Shared benchmark machinery: graph fixtures, timed runs, CSV/markdown
reporting.  Sizes are laptop-scale (CPU container) but structurally mirror
the paper's dataset classes; every benchmark prints a CSV block the
EXPERIMENTS.md tables are generated from."""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                    # noqa: E402

# Paper-grade validation: f64 ranks + τ=1e-10 (§5.1.2).  Model code is
# dtype-explicit so this only affects the PageRank engines run here.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp                                       # noqa: E402

from repro.core import pagerank as pr                         # noqa: E402
from repro.core import frontier as fr                         # noqa: E402
from repro.core.delta import random_batch                     # noqa: E402
from repro.core.graph import HostGraph                        # noqa: E402
from repro.graphs import generators as gen                    # noqa: E402

# Benchmark-scale graph suite (keyed to the paper's Table 2 classes).
# Sizes are the largest that keep the full suite in CPU-container budget;
# the DF locality effect needs graphs big enough that a small batch's
# decay-bounded frontier is ≪ |V| (paper graphs are 3M–214M vertices).
SUITE = {
    "web":    lambda: gen.rmat(15, 12, seed=1),          # power-law web
    "social": lambda: gen.rmat(13, 40, seed=2),          # dense social
    "road":   lambda: gen.grid_road(256, seed=3),        # road lattice
    "kmer":   lambda: gen.kmer_chains(1 << 17, seed=4),  # k-mer chains
}

TAU = 1e-10
SNAPSHOT_KW = dict(block_size=128)   # finer chunks cut frontier-block inflation


@dataclasses.dataclass
class Row:
    bench: str
    graph: str
    method: str
    x: float                 # batch fraction / thread count / block size ...
    time_s: float
    sweeps: int
    edges: int
    error: float = float("nan")
    sim_ms: float = float("nan")
    extra: str = ""

    def csv(self) -> str:
        return (f"{self.bench},{self.graph},{self.method},{self.x:g},"
                f"{self.time_s:.4f},{self.sweeps},{self.edges},"
                f"{self.error:.3e},{self.sim_ms:.3f},{self.extra}")


CSV_HEADER = ("bench,graph,method,x,time_s,sweeps,edges,error,"
              "sim_ms,extra")


def emit(rows: Sequence[Row], out: Optional[str] = None) -> None:
    lines = [CSV_HEADER] + [r.csv() for r in rows]
    text = "\n".join(lines)
    print(text, flush=True)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            f.write(text + "\n")


def updated_snapshots(hg: HostGraph, frac: float, seed: int):
    """(g_prev_snap, g_cur_snap, batch_dev, hg_cur) for one random update."""
    dels, ins = random_batch(hg, frac, seed=seed)
    hg_cur = hg.apply_batch(dels, ins)
    cap = 1024 * max(2, (hg.m * 2 + 2 * hg.n) // 1024 + 2)
    g_prev = hg.snapshot(edge_capacity=cap, **SNAPSHOT_KW)
    g_cur = hg_cur.snapshot(edge_capacity=cap, **SNAPSHOT_KW)
    batch = fr.batch_to_device(g_cur, dels, ins)
    return g_prev, g_cur, batch, hg_cur


def timed(fn: Callable, *, repeats: int = 2) -> Dict:
    """Run fn repeats× and keep the MIN wall time: the first call pays jit
    compilation for any new (snapshot-family, K-bucket) signature, so
    single-shot timings mix compile and run (fn must block_until_ready
    internally — PagerankResult does)."""
    best = None
    res = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return {"time_s": best, "result": res}


def run_variant(name: str, g_prev, g_cur, batch, r_prev, *, faults=None,
                engine: Optional[str] = None, **kw) -> pr.PagerankResult:
    """Dispatch one of the paper variants through a
    :class:`repro.api.PageRankSession` (snapshot mode, the registry-
    resolved engine — blocked on CPU containers, the fused pallas engine
    on TPU).  This is the modern form of the deprecated ``*_pagerank``
    shims: bit-identical results, no DeprecationWarning, and the config
    goes through ``EngineConfig`` validation."""
    from repro.api import EngineConfig, PageRankSession
    from repro.core.graph import initial_ranks, pad_ranks

    variant, mode = name.rsplit("_", 1)
    if variant not in ("static", "nd", "dt", "df") or mode not in ("bb",
                                                                   "lf"):
        raise ValueError(name)
    kw = dict(kw)
    mat = kw.pop("pallas_mat", None)
    aux = kw.pop("pallas_aux", None)
    backend = kw.pop("pallas_backend", None)
    cfg = EngineConfig.from_kwargs(mode=mode, engine=engine, faults=faults,
                                   backend=backend, **kw)
    if variant == "static":
        R0 = initial_ranks(g_cur, pr.default_dtype())
        affected, expand = g_cur.vertex_valid, False
    elif variant == "nd":
        R0 = pad_ranks(g_cur, r_prev)
        affected, expand = g_cur.vertex_valid, False
    elif variant == "dt":
        R0 = pad_ranks(g_cur, r_prev)
        affected, expand = fr.dt_affected(g_prev, g_cur, batch), False
    else:   # df
        R0 = pad_ranks(g_cur, r_prev)
        affected, expand = fr.initial_affected(g_prev, g_cur, batch), True
    sess = PageRankSession.from_snapshot(g_cur, config=cfg, r0=R0)
    return sess._converge(R0, affected, expand=expand, mat=mat, aux=aux)


def reference_ranks(g) -> jnp.ndarray:
    """Paper §5.1.5 reference: barrier-based static at tiny tolerance."""
    return pr.reference_pagerank(g, iterations=250)


def linf(a, b) -> float:
    return pr.linf(a, b)


def geomean(xs: Sequence[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))
