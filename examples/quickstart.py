"""Quickstart — the paper's workload end-to-end.

Maintains PageRank over a stream of batch updates on a dynamic graph with
the lock-free Dynamic Frontier engine (DF_LF), validating every update
against the reference and comparing work/time with the Naive-dynamic
baseline (ND_LF).  This is the end-to-end driver for the paper's kind of
system (dynamic graph-algorithm serving).

    PYTHONPATH=src python examples/quickstart.py [--batches 5]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)   # paper-grade f64 validation

import numpy as np                                          # noqa: E402

from repro.core import frontier as fr                       # noqa: E402
from repro.core import pagerank as pr                       # noqa: E402
from repro.core.delta import random_batch                   # noqa: E402
from repro.graphs.generators import grid_road               # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-frac", type=float, default=1e-5)
    ap.add_argument("--side", type=int, default=256)
    args = ap.parse_args()

    print("building dynamic graph (road-network class)...")
    hg = grid_road(args.side, seed=0)
    cap = 1024 * ((hg.m * 3 + 2 * hg.n) // 1024 + 3)
    print(f"  |V|={hg.n:,}  |E|={hg.m:,}")

    g = hg.snapshot(edge_capacity=cap)
    ranks = pr.reference_pagerank(g, iterations=250)
    print("initial PageRank computed; streaming batch updates:\n")

    tot_df, tot_nd = 0.0, 0.0
    for step in range(args.batches):
        dels, ins = random_batch(hg, args.batch_frac, seed=100 + step)
        hg_new = hg.apply_batch(dels, ins)
        g_prev, g_cur = g, hg_new.snapshot(edge_capacity=cap)
        batch = fr.batch_to_device(g_cur, dels, ins)

        t0 = time.perf_counter()
        df = pr.df_pagerank(g_prev, g_cur, batch, ranks, mode="lf")
        t_df = time.perf_counter() - t0
        t0 = time.perf_counter()
        nd = pr.nd_pagerank(g_cur, ranks, mode="lf")
        t_nd = time.perf_counter() - t0

        ref = pr.reference_pagerank(g_cur, iterations=250)
        err = pr.linf(df.ranks, ref[:df.ranks.shape[0]])
        assert err < 1e-9, f"error {err} out of the paper's band"
        if step > 0:                      # skip jit warm-up timings
            tot_df += t_df
            tot_nd += t_nd
        print(f"batch {step}: |Δ|={len(dels) + len(ins):4d}  "
              f"DF_LF {t_df:6.3f}s ({df.stats.sweeps} sweeps, "
              f"{df.stats.edges_processed / 1e6:6.2f}M edges)   "
              f"ND_LF {t_nd:6.3f}s ({nd.stats.sweeps} sweeps, "
              f"{nd.stats.edges_processed / 1e6:6.2f}M edges)   "
              f"L_inf={err:.2e}")
        hg, g, ranks = hg_new, g_cur, df.ranks

    if tot_df > 0:
        print(f"\nDF_LF vs ND_LF wall-time speedup "
              f"(excl. warm-up): {tot_nd / tot_df:.2f}x")
    print("all updates stayed within the paper's 1e-9 error band ✓")


if __name__ == "__main__":
    main()
