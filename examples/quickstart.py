"""Quickstart — the paper's workload end-to-end, on the session API.

Opens one :class:`repro.api.PageRankSession` over a dynamic road-network
graph and maintains PageRank through a stream of batch updates with the
lock-free Dynamic Frontier engine (DF_LF): each ``update`` is the
recompile-free O(batch) hot path.  Every update is validated against the
reference solver and compared with the Naive-dynamic baseline (ND_LF) run
on a throwaway ``fork()`` of the same session — the what-if mechanism.

    PYTHONPATH=src python examples/quickstart.py [--batches 5]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)   # paper-grade f64 validation

from repro.api import EngineConfig, PageRankSession              # noqa: E402
from repro.core import pagerank as pr                            # noqa: E402
from repro.core.delta import random_batch                        # noqa: E402
from repro.graphs.generators import grid_road                    # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-frac", type=float, default=1e-5)
    ap.add_argument("--side", type=int, default=256)
    args = ap.parse_args()

    print("building dynamic graph (road-network class)...")
    hg = grid_road(args.side, seed=0)
    print(f"  |V|={hg.n:,}  |E|={hg.m:,}")

    # one handle owns graph state, ranks and the incremental engine
    # operands; construction runs the initial solve
    sess = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", tau=1e-10, block_size=64))
    sess.warmup()     # trace the per-batch pipeline → steady-state timings
    print("initial PageRank computed; streaming batch updates:\n")

    tot_df, tot_nd = 0.0, 0.0
    for step in range(args.batches):
        dels, ins = random_batch(sess.hg, args.batch_frac, seed=100 + step)
        nd_sess = sess.fork()           # what-if branch: same state, no copy

        df = sess.update(dels, ins)                       # DF_LF hot path
        nd = nd_sess.update(dels, ins, variant="nd")      # ND_LF baseline

        ref = pr.reference_pagerank(sess.hg.snapshot(block_size=64),
                                    iterations=250)
        err = pr.linf(df.ranks, ref[:df.ranks.shape[0]])
        assert err < 1e-9, f"error {err} out of the paper's band"
        if step > 0:    # step 0 pays the ND path's (expand=False) jit trace
            tot_df += df.wall_time_s
            tot_nd += nd.wall_time_s
        print(f"batch {step}: |Δ|={len(dels) + len(ins):4d}  "
              f"DF_LF {df.wall_time_s:6.3f}s ({df.stats.sweeps} sweeps, "
              f"{df.stats.edges_processed / 1e6:6.2f}M edges)   "
              f"ND_LF {nd.wall_time_s:6.3f}s ({nd.stats.sweeps} sweeps, "
              f"{nd.stats.edges_processed / 1e6:6.2f}M edges)   "
              f"L_inf={err:.2e}")

    rep = sess.report()
    vals, ids = sess.top_k(5)           # device-side: 5 values transferred
    print(f"\nsession report: {rep.n_updates} updates, "
          f"p50 {rep.p50_s * 1e3:.1f} ms, p95 {rep.p95_s * 1e3:.1f} ms, "
          f"retraces post-warmup: {rep.retraces_post_warmup}")
    print("top-5 vertices: "
          + ", ".join(f"{i}={v:.2e}" for i, v in zip(ids, vals)))
    if tot_df > 0:
        print(f"DF_LF vs ND_LF wall-time speedup "
              f"(excl. warm-up): {tot_nd / tot_df:.2f}x")
    print("all updates stayed within the paper's 1e-9 error band ✓")


if __name__ == "__main__":
    main()
