"""Fault-tolerance demonstration (paper §5.3–5.4 live), session API.

Runs DF_LF under increasingly hostile fault schedules — random thread
delays, crash-stop failures up to 56/64 threads, and a partial first pass
through the initial marking phase (exercising the helping mechanism) —
and shows that the barrier-based DF_BB deadlocks where DF_LF completes
with unchanged accuracy.  Each scenario is one ``PageRankSession`` whose
``EngineConfig`` carries the fault plan; the base config is shared and
``replace()``d per scenario.

The final scenario climbs one fault domain up (docs/FAULTS.md): the whole
*process* "crashes" with a durable session mid-stream, and
``PageRankSession.restore`` replays the write-ahead log back to
bit-identical ranks.

    PYTHONPATH=src python examples/fault_tolerant_pagerank.py
"""
import sys
import tempfile
import warnings

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                           # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro.api import EngineConfig, PageRankSession          # noqa: E402
from repro.core import frontier as fr                        # noqa: E402
from repro.core import pagerank as pr                        # noqa: E402
from repro.core.delta import random_batch                    # noqa: E402
from repro.core.faults import FaultPlan                      # noqa: E402
from repro.graphs.generators import rmat                     # noqa: E402


def main() -> None:
    hg = rmat(13, 16, seed=0)
    dels, ins = random_batch(hg, 1e-4, seed=1)
    base = EngineConfig(engine="pallas", mode="lf", block_size=64)

    # reference state: pre-batch ranks + post-batch oracle
    g_prev = hg.snapshot(block_size=64)
    r_prev = pr.reference_pagerank(g_prev, iterations=250)
    hg_cur = hg.apply_batch(dels, ins)
    g_cur = hg_cur.snapshot(block_size=64)
    ref = pr.reference_pagerank(g_cur, iterations=250)
    print(f"|V|={hg.n:,} |E|={hg.m:,}  batch={len(dels) + len(ins)}\n")

    def run(cfg: EngineConfig):
        """One scenario = one session over the pre-batch graph, one DF
        update under the scenario's fault plan."""
        sess = PageRankSession.from_graph(hg, config=cfg, r0=r_prev)
        return sess.update(dels, ins)

    print("-- no faults ------------------------------------------------")
    res = run(base)
    base_ms = res.stats.sim_time_ms
    print(f"DF_LF: converged={res.stats.converged} "
          f"sweeps={res.stats.sweeps} "
          f"err={pr.linf(res.ranks, ref[:res.ranks.shape[0]]):.2e}")

    print("\n-- random thread delays (100 ms, p=1e-2/thread/sweep) -----")
    plan = FaultPlan(n_threads=64, delay_prob=1e-2, delay_ms=100, seed=3)
    for mode in ("bb", "lf"):
        res = run(base.replace(mode=mode, faults=plan))
        print(f"DF_{mode.upper()}: converged={res.stats.converged} "
              f"sim_time={res.stats.sim_time_ms:8.1f} ms "
              f"err={pr.linf(res.ranks, ref[:res.ranks.shape[0]]):.2e}")

    print("\n-- crash-stop: 56 of 64 threads crash ----------------------")
    plan = FaultPlan(n_threads=64, n_crashed=56, crash_window=4, seed=5)
    res_bb = run(base.replace(mode="bb", faults=plan))
    print(f"DF_BB: converged={res_bb.stats.converged} "
          f"DNF={res_bb.stats.dnf}   <- barrier deadlocks")
    res_lf = run(base.replace(faults=plan))
    slow = res_lf.stats.sim_time_ms / max(base_ms, 1e-9)
    print(f"DF_LF: converged={res_lf.stats.converged} "
          f"sim_time={res_lf.stats.sim_time_ms:8.1f} ms "
          f"({slow:.1f}x no-fault time) "
          f"err={pr.linf(res_lf.ranks, ref[:res_lf.ranks.shape[0]]):.2e}")
    assert res_lf.stats.converged and res_bb.stats.dnf

    print("\n-- helping: first marking pass covers only 30% of Δ --------")
    # the helping mechanism lives in the marking phase (paper Alg. 2 lines
    # 5-16) and keeps its dedicated entry point on the legacy surface
    batch = fr.batch_to_device(g_cur, dels, ins)
    rng = np.random.default_rng(7)
    first_pass = jnp.asarray(rng.random(batch.shape[0]) < 0.3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = pr.df_pagerank(g_prev, g_cur, batch, r_prev, mode="lf",
                             helping_first_pass=first_pass)
    print(f"DF_LF+helping: converged={res.stats.converged} "
          f"err={pr.linf(res.ranks, ref[:res.ranks.shape[0]]):.2e} "
          f"(survivors re-marked the abandoned updates)")
    assert res.stats.converged

    print("\n-- process crash: durable session, WAL replay --------------")
    # the process fault domain: every batch is durably logged before it
    # touches device state; a crash-stop loses nothing that was
    # acknowledged (docs/FAULTS.md)
    store = tempfile.mkdtemp(prefix="repro-durable-")
    durable = PageRankSession.from_graph(
        hg, config=base.replace(durability="wal", checkpoint_interval=2),
        r0=r_prev, store_dir=store)
    live = PageRankSession.from_graph(hg, config=base, r0=r_prev)
    cur = hg
    for i in range(3):
        d_i, i_i = random_batch(cur, 1e-4, seed=20 + i)
        durable.update(d_i, i_i)
        live.update(d_i, i_i)
        cur = cur.apply_batch(d_i, i_i)
    del durable                      # crash-stop: no close(), no flush
    restored = PageRankSession.restore(store)
    rep = restored.report()
    err = pr.linf(restored.R, live.R)
    print(f"restored: replayed={rep.replayed_batches} WAL batch(es) in "
          f"{rep.recovery_time_s * 1e3:.0f} ms, "
          f"bit-for-bit err={err:.1e}")
    assert err == 0.0
    print("\nall fault scenarios completed with accurate ranks ✓")


if __name__ == "__main__":
    main()
