"""Dynamic-Frontier applied to a GNN (beyond-paper generalization).

Maintains GraphSAGE node embeddings over a stream of edge updates: instead
of re-running the full forward after each batch, only the DF-affected
receptive cone is recomputed (τ_f gates the expansion, exactly like the
paper's PageRank frontier).  Validates the incremental embeddings against
the full recompute and reports the recompute fraction.

    PYTHONPATH=src python examples/incremental_gnn.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np                                          # noqa: E402
import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.configs import get_arch                          # noqa: E402
from repro.core import incremental as inc                   # noqa: E402
from repro.models.gnn import graphsage                      # noqa: E402
from repro.models.gnn.common import GraphBatch              # noqa: E402


def build_graph(rng, n, e, d_feat):
    return {
        "nodes": jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        "senders": rng.integers(0, n, e),
        "receivers": rng.integers(0, n, e),
    }


def main() -> None:
    spec = get_arch("graphsage-reddit")
    cfg = spec.build_cfg(d_feat=32, n_out=8)
    rng = np.random.default_rng(0)
    n, e = 4096, 16384
    raw = build_graph(rng, n, e, cfg.d_feat)
    params = graphsage.init(cfg, jax.random.PRNGKey(0))
    layer_fns = inc.full_gnn_layers(graphsage, params, cfg)

    def batch_of(senders, receivers):
        return GraphBatch(nodes=raw["nodes"],
                          senders=jnp.asarray(senders, jnp.int32),
                          receivers=jnp.asarray(receivers, jnp.int32))

    g = batch_of(raw["senders"], raw["receivers"])
    cache = [raw["nodes"]]
    h = raw["nodes"]
    for fn in layer_fns:
        h = fn(g, h)
        cache.append(h)
    print(f"graph: n={n} e={e}; layers={cfg.n_layers}; "
          f"embeddings cached\n")

    tau_f = 1e-4   # embedding-scale frontier tolerance
    for step in range(4):
        # batch update: rewire 8 random edges
        idx = rng.integers(0, e, 8)
        old = np.stack([raw["senders"][idx], raw["receivers"][idx]], 1)
        raw["senders"][idx] = rng.integers(0, n, 8)
        raw["receivers"][idx] = rng.integers(0, n, 8)
        new = np.stack([raw["senders"][idx], raw["receivers"][idx]], 1)
        g = batch_of(raw["senders"], raw["receivers"])

        sources = inc.edge_update_sources(n, old, new)
        h_inc, cache, stats = inc.incremental_gnn_update(
            layer_fns, g, raw["nodes"], cache, sources, tau_f=tau_f)

        # oracle: full recompute
        h_full = raw["nodes"]
        for fn in layer_fns:
            h_full = fn(g, h_full)
        err = float(jnp.max(jnp.abs(h_inc - h_full)))
        frac = stats["recomputed"] / stats["total"]
        print(f"update {step}: recomputed {stats['recomputed']:6d}/"
              f"{stats['total']} node-layers ({frac:6.1%})  "
              f"L_inf vs full recompute = {err:.2e}")
        assert err < 5e-2, "incremental drifted beyond the τ_f band"
        cache[-1] = h_full  # refresh cache exactly (as a deployment would
        # periodically, bounding τ_f drift accumulation)
        cache = [raw["nodes"]] + [c for c in cache[1:]]
    print("\nincremental embeddings stayed within the τ_f band ✓")


if __name__ == "__main__":
    main()
