"""Train a small LM end-to-end with the full substrate stack: synthetic
Markov token stream → grad-accum train step (AdamW, cosine schedule) →
atomic checkpointing with auto-resume.

Default is a ~10M-param model for CPU-container speed; ``--size 100m``
selects the ~100M configuration (same code path, longer wall time).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume-drill
"""
import argparse
import subprocess
import sys
import time

sys.path.insert(0, "src")

import jax                                                  # noqa: E402

from repro.ckpt.checkpoint import Checkpointer              # noqa: E402
from repro.data import pipeline as dp                       # noqa: E402
from repro.models.transformer import model as M             # noqa: E402
from repro.models.transformer.config import TransformerConfig  # noqa: E402
from repro.optim import adam                                # noqa: E402
from repro.train import trainer                             # noqa: E402

SIZES = {
    # ~10M params: CPU-fast demonstration config
    "10m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                d_ff=1024, vocab=4096),
    # ~100M params: the deliverable-scale config (same pipeline)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=16384),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=SIZES, default="10m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume-drill", action="store_true",
                    help="kill the run mid-way, relaunch, verify resume")
    args = ap.parse_args()

    if args.resume_drill:
        base = [sys.executable, __file__, "--size", args.size,
                "--steps", str(args.steps), "--batch", str(args.batch),
                "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir]
        print("== resume drill: phase 1 (will be preempted) ==")
        subprocess.run(base + ["--steps", str(args.steps // 2)], check=True)
        print("== resume drill: phase 2 (auto-resume to the end) ==")
        subprocess.run(base, check=True)
        print("resume drill complete ✓")
        return

    cfg = TransformerConfig(name=f"lm-{args.size}", dtype="float32",
                            attn_q_chunk=128, **SIZES[args.size])
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    acfg = adam.AdamConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps)
    tcfg = trainer.TrainConfig(microbatches=args.microbatches)
    step_fn = jax.jit(trainer.build_train_step(trainer.lm_loss(cfg), acfg,
                                               tcfg),
                      donate_argnums=(0, 1))
    opt = adam.init_state(params, acfg)

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if ckpt.latest_step is not None:
        params, opt, start = ckpt.restore(ckpt.latest_step, params, opt)
        print(f"auto-resumed from step {start}")
    if start >= args.steps:
        print("nothing to do (checkpoint is at/after --steps)")
        return

    stream = dp.prefetch(dp.lm_stream(cfg.vocab, args.batch, args.seq,
                                      seed=0, start=start), depth=2)
    t0 = time.time()
    first_loss = None
    for i, batch in enumerate(stream):
        step = start + i
        if step >= args.steps:
            break
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        if first_loss is None:
            first_loss = loss
        if step % 25 == 0 or step == args.steps - 1:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tps:,.0f} tok/s")
        if (step + 1) % 50 == 0:
            ckpt.save(params, opt, step + 1)
    ckpt.save(params, opt, args.steps)
    print(f"final loss {loss:.4f} (first {first_loss:.4f}) — "
          f"{'learning ✓' if loss < first_loss else 'NOT learning ✗'}")
    assert loss < first_loss


if __name__ == "__main__":
    main()
