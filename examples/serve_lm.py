"""Batched LM serving with continuous batching (prefill + fused decode).

Runs the ServeEngine on a reduced Qwen-family config: requests of mixed
prompt lengths stream through a fixed slot set; finished slots are refilled
without draining the batch.  The full-scale decode_32k / long_500k serving
programs are proven by the multi-pod dry-run; this exercises the same code
path end-to-end on CPU.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                          # noqa: E402
import jax                                                  # noqa: E402

from repro.configs import get_arch                          # noqa: E402
from repro.models.transformer import model as M             # noqa: E402
from repro.serve.engine import Request, ServeEngine         # noqa: E402


def main() -> None:
    spec = get_arch("qwen1.5-4b")
    cfg = spec.smoke_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=4, cache_len=128)

    rng = np.random.default_rng(0)
    n_req = 12
    for uid in range(n_req):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(8, 48))),
                           max_new_tokens=16))

    t0 = time.time()
    finished = eng.run_until_drained()
    wall = time.time() - t0
    n_tok = sum(len(r.out) for r in finished)
    assert len(finished) == n_req, "engine dropped requests"
    print(f"drained {n_req} requests / {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / max(wall, 1e-9):.1f} tok/s aggregate)")
    print("continuous batching kept slots busy; decode is one fused step "
          "over all live slots ✓")


if __name__ == "__main__":
    main()
