"""Smoke wiring for the tiered scaling bench (benchmarks/scale.py).

Runs the CI tier (``--smoke``: n = 4K..16K grids + the adversarial R-MAT
row) end-to-end and sanity-checks the emitted JSON.  Infrastructure
failures skip rather than fail — the bench's correctness claims live in
tests/test_tiering.py; this guards the wiring (ladder, budget fractions,
JSON schema, parity plumbing).  The default/full tiers and the
multi-million ``--side`` extension are manual runs (docs/SCALE.md).
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.mark.slow
def test_scale_bench_smoke(tmp_path):
    from benchmarks import scale

    out = str(tmp_path / "BENCH_scale.json")
    try:
        report = scale.main(smoke=True, out=out)
    except Exception as e:                      # pragma: no cover
        pytest.skip(f"scale benchmark infrastructure failed: {e!r}")

    assert os.path.exists(out)
    with open(out) as f:
        on_disk = json.load(f)
    assert on_disk["meta"]["tier"] == "smoke"
    rows = on_disk["rows"]
    # ladder × budgets + the adversarial R-MAT row + the half-budget
    # push-driver row (benchmarks/scale.py --driver; docs/ENGINES.md)
    assert len(rows) == len(scale.SMOKE_LADDER) * len(scale.BUDGET_FRACS) + 3
    push_rows = [r for r in rows if r["driver"] == "push"]
    assert len(push_rows) == 1
    assert push_rows[0]["budget_frac"] == 0.5
    for row in rows:
        assert row["batches_converged"] == row["batches"], row["graph"]
        assert row["retraces_post_warmup"] == 0, row["graph"]
        assert row["bucket_retraces_post_warmup"] == 0, row["graph"]
        assert 0.0 <= row["hit_rate"] <= 1.0
        assert row["budget_bytes"] <= row["pool_bytes"]
        assert row["bytes_per_vertex"] > 0
        assert row["restore_linf"] == 0.0       # durability is host truth
        assert row["device_bytes"]["tile_pool"] <= row["budget_bytes"]
    # at least one row ran under genuine budget pressure
    assert any(r["budget_frac"] < 1.0 and r["tiering"]["evictions"] > 0
               for r in rows)
    parity = on_disk["oracle_parity"]
    assert parity["oracle_converged"]
    assert parity["linf"] < 1e-6
    assert report["oracle_parity"]["linf"] == parity["linf"]
