"""Model-layer property tests: structural invariants of the transformer,
GNN, and recsys implementations that the dry-run alone cannot check."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models.transformer import model as M
from repro.models.transformer.config import MoEConfig, TransformerConfig


def _tiny_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=64, dtype="float32", param_dtype="float32",
                attn_q_chunk=16, remat=False)
    base.update(kw)
    return TransformerConfig(**base)


# -- causality -----------------------------------------------------------------

def test_causality_future_tokens_do_not_leak():
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    t1 = jax.random.randint(key, (1, 24), 0, cfg.vocab)
    t2 = t1.at[0, 20].set((t1[0, 20] + 1) % cfg.vocab)   # change a LATE token
    l1, _ = M.forward(params, t1, cfg)
    l2, _ = M.forward(params, t2, cfg)
    # logits strictly before position 20 must be identical
    np.testing.assert_allclose(np.asarray(l1[0, :20]),
                               np.asarray(l2[0, :20]), atol=1e-6)
    assert not np.allclose(np.asarray(l1[0, 20]), np.asarray(l2[0, 20]))


def test_gqa_with_kv_equal_heads_is_mha():
    """q_per_kv == 1 must reduce to plain MHA math (no grouping effects):
    permuting head order in (wq, wk, wv, wo) consistently leaves the output
    invariant."""
    cfg = _tiny_cfg(n_kv_heads=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    base, _ = M.forward(params, tokens, cfg)
    perm = np.array([2, 0, 3, 1])
    p2 = dict(params)
    for nm in ("wq", "wk", "wv"):
        p2[f"layers/{nm}"] = params[f"layers/{nm}"][:, :, perm, :]
    p2["layers/wo"] = params["layers/wo"][:, perm, :, :]
    out, _ = M.forward(p2, tokens, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-4, atol=2e-5)


def test_attention_chunking_is_exact():
    """q-chunked attention must equal unchunked (pure memory optimization)."""
    cfg_a = _tiny_cfg(attn_q_chunk=4)
    cfg_b = _tiny_cfg(attn_q_chunk=1024)
    params = M.init_params(cfg_a, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg_a.vocab)
    la, _ = M.forward(params, tokens, cfg_a)
    lb, _ = M.forward(params, tokens, cfg_b)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-3,
                               atol=1e-5)


def test_scan_vs_unrolled_layers_identical():
    cfg_s = _tiny_cfg(scan_layers=True)
    cfg_u = _tiny_cfg(scan_layers=False)
    params = M.init_params(cfg_s, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg_s.vocab)
    ls, _ = M.forward(params, tokens, cfg_s)
    lu, _ = M.forward(params, tokens, cfg_u)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lu), rtol=1e-3,
                               atol=1e-5)


def test_vocab_padding_preserves_loss():
    """Padding the vocab (perf knob) must not change the training loss."""
    cfg = _tiny_cfg(vocab=60)
    cfg_pad = _tiny_cfg(vocab=60, pad_vocab_to_multiple=32)   # → 64
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pp = dict(params)
    pp["emb"] = jnp.zeros((cfg_pad.vocab_padded, cfg.d_model)).at[
        :60].set(params["emb"])
    pp["head"] = jnp.zeros((cfg.d_model, cfg_pad.vocab_padded)).at[
        :, :60].set(params["head"])
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 60)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 60)
    l1, _ = M.loss_fn(params, tokens, labels, cfg)
    l2, _ = M.loss_fn(pp, tokens, labels, cfg_pad)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_moe_capacity_dropping_monotone():
    """Higher capacity factor must not increase routing drops: outputs with
    cf=8 (no drops) are the reference; cf=0.25 must differ (drops occur)."""
    mk = lambda cf: _tiny_cfg(moe=MoEConfig(n_experts=4, top_k=2,
                                            capacity_factor=cf))
    cfg_hi = mk(8.0)
    params = M.init_params(cfg_hi, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg_hi.vocab)
    hi, _ = M.forward(params, tokens, cfg_hi)
    lo, _ = M.forward(params, tokens, mk(0.25))
    assert not np.allclose(np.asarray(hi), np.asarray(lo))
    mid, _ = M.forward(params, tokens, mk(8.0))
    np.testing.assert_allclose(np.asarray(hi), np.asarray(mid))


# -- EGNN equivariance -----------------------------------------------------------

def test_egnn_is_e3_equivariant():
    """Rotating + translating input coordinates must rotate/translate the
    output coordinates and leave the feature outputs invariant."""
    from repro.models.gnn import egnn
    from repro.models.gnn.common import GraphBatch
    spec = get_arch("egnn")
    cfg = spec.smoke_cfg()
    params = egnn.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n, e = 24, 96
    nodes = jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    snd = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    rcv = jnp.asarray(rng.integers(0, n, e), jnp.int32)

    # random rotation (QR) + translation
    Q = np.linalg.qr(rng.normal(size=(3, 3)))[0].astype(np.float32)
    t = rng.normal(size=(1, 3)).astype(np.float32)

    g1 = GraphBatch(nodes=nodes, senders=snd, receivers=rcv, pos=pos)
    g2 = GraphBatch(nodes=nodes, senders=snd, receivers=rcv,
                    pos=pos @ Q.T + t)
    h1, x1 = egnn.forward(params, cfg, g1)
    h2, x2 = egnn.forward(params, cfg, g2)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(x1 @ Q.T + t), np.asarray(x2),
                               rtol=1e-3, atol=1e-4)


def test_gnn_node_permutation_equivariance():
    """GraphSAGE full-graph logits must permute with the node relabeling."""
    from repro.models.gnn import graphsage
    from repro.models.gnn.common import GraphBatch
    spec = get_arch("graphsage-reddit")
    cfg = spec.smoke_cfg()
    params = graphsage.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n, e = 20, 60
    nodes = rng.normal(size=(n, cfg.d_feat)).astype(np.float32)
    snd = rng.integers(0, n, e)
    rcv = rng.integers(0, n, e)
    perm = rng.permutation(n)
    inv = np.argsort(perm)

    g1 = GraphBatch(nodes=jnp.asarray(nodes),
                    senders=jnp.asarray(snd, jnp.int32),
                    receivers=jnp.asarray(rcv, jnp.int32))
    g2 = GraphBatch(nodes=jnp.asarray(nodes[perm]),
                    senders=jnp.asarray(inv[snd], jnp.int32),
                    receivers=jnp.asarray(inv[rcv], jnp.int32))
    o1 = graphsage.forward(params, cfg, g1)
    o2 = graphsage.forward(params, cfg, g2)
    np.testing.assert_allclose(np.asarray(o1)[perm], np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


# -- recsys embedding bag ----------------------------------------------------------

def test_embedding_bag_matches_manual():
    from repro.models.recsys.embedding import embedding_bag, fielded_lookup
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray([3, 7, 7, 11, 0], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 2], jnp.int32)
    out = embedding_bag(table, ids, seg, 3)
    exp = np.stack([np.asarray(table[3] + table[7]),
                    np.asarray(table[7] + table[11]),
                    np.asarray(table[0])])
    np.testing.assert_allclose(np.asarray(out), exp, rtol=1e-6)
    # mean combiner
    out = embedding_bag(table, ids, seg, 3, combiner="mean")
    np.testing.assert_allclose(np.asarray(out)[0],
                               np.asarray((table[3] + table[7]) / 2),
                               rtol=1e-6)
    # fielded fast path == take for bag=1
    f_ids = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(fielded_lookup(table, f_ids)),
        np.asarray(jnp.take(table, f_ids, axis=0)), rtol=1e-6)


def test_sharded_lookup_matches_dense():
    """masked local-take + psum == plain take (subprocess, 4 devices)."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.models.recsys.embedding import sharded_lookup
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("model",))
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 64, (5, 3)), jnp.int32)
        out = sharded_lookup(table, ids, mesh)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.take(table, ids, axis=0)),
                                   rtol=1e-6)
        print("SHARDED-LOOKUP-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED-LOOKUP-OK" in r.stdout


# -- incremental GNN scaling (beyond-paper experiment, test-sized) -----------------

def test_incremental_gnn_work_scales_with_update():
    from repro.core import incremental as inc
    from repro.models.gnn import graphsage
    from repro.models.gnn.common import GraphBatch
    spec = get_arch("graphsage-reddit")
    cfg = spec.build_cfg(d_feat=8, n_out=4)
    rng = np.random.default_rng(0)
    n, e = 2048, 6144
    nodes = jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32)
    snd = rng.integers(0, n, e)
    rcv = rng.integers(0, n, e)
    params = graphsage.init(cfg, jax.random.PRNGKey(0))
    fns = inc.full_gnn_layers(graphsage, params, cfg)
    g = GraphBatch(nodes=nodes, senders=jnp.asarray(snd, jnp.int32),
                   receivers=jnp.asarray(rcv, jnp.int32))
    cache, h = [nodes], nodes
    for fn in fns:
        h = fn(g, h)
        cache.append(h)
    fracs = []
    for k in (2, 64):
        idx = rng.integers(0, e, k)
        old = np.stack([snd[idx], rcv[idx]], 1)
        sources = inc.edge_update_sources(n, old, old)
        _, _, stats = inc.incremental_gnn_update(fns, g, nodes, cache,
                                                 sources, tau_f=1e-3)
        fracs.append(stats["recomputed"] / stats["total"])
    assert fracs[0] < fracs[1] < 1.0, fracs
    assert fracs[0] < 0.25, f"small update recomputed {fracs[0]:.0%}"


def test_f8_kv_cache_structural():
    """float8 KV cache (decode-memory §Perf knob): cache stores f8, decode
    stays within a bounded drift of the full-precision forward at smoke
    scale (production use needs per-head scale calibration — documented)."""
    from repro.configs import get_arch
    spec = get_arch("phi4-mini-3.8b")
    cfg = spec.smoke_cfg()
    cfg8 = dataclasses.replace(cfg, cache_dtype="float8_e4m3fn")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    lf, _ = M.forward(params, tokens, cfg)
    lp, cache = M.prefill(params, tokens[:, :-1], cfg8, cache_len=S + 4)
    assert cache["k"].dtype == jnp.dtype("float8_e4m3fn")
    ld, _ = M.decode_step(params, cache, tokens[:, -1], jnp.int32(S - 1),
                          cfg8)
    drift = float(np.abs(np.asarray(ld) - np.asarray(lf[:, -1])).max())
    assert drift < 0.5, f"f8 cache logit drift {drift}"
    # top-1 token agreement on the greedy continuation
    agree = (np.argmax(np.asarray(ld), -1)
             == np.argmax(np.asarray(lf[:, -1]), -1)).mean()
    assert agree >= 0.5
