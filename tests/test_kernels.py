"""Tile-SpMV backends vs pure-jnp oracle.

Sweeps shapes, block sizes, densities and dtypes across both backends (the
Pallas kernels in interpret mode and the XLA gather/einsum tile path);
property tests assert the algebraic invariants the PageRank engines rely on
(linearity, OR-idempotence).  The property tests require ``hypothesis`` and
are skipped (not errored) where it is absent.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container without hypothesis: skip, don't error
    HAVE_HYPOTHESIS = False

from repro.kernels.block_spmv.ops import (build_block_sparse, block_spmv,
                                          pagerank_pull_step,
                                          frontier_expand_op)
from repro.kernels.block_spmv.ref import spmv_ref, pagerank_pull_step_ref

BACKENDS = ["pallas", "xla"]


def _random_edges(n_rows, n_cols, m, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_rows, m), rng.integers(0, n_cols, m)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_rows,n_cols,m", [
    (17, 17, 40), (64, 64, 500), (130, 70, 900), (300, 300, 4000),
    (1000, 1000, 20000), (128, 512, 2000),
])
@pytest.mark.parametrize("block", [8, 32, 128])
def test_spmv_shapes_match_ref(n_rows, n_cols, m, block, backend):
    rows, cols = _random_edges(n_rows, n_cols, m, seed=n_rows + block)
    x = jnp.asarray(np.random.default_rng(1).random(n_cols), jnp.float32)
    mat = build_block_sparse(rows, cols, n_rows, n_cols, block=block)
    y = block_spmv(mat, x, interpret=True, backend=backend)
    yref = spmv_ref(rows, cols, n_rows, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_padded_build_matches_exact(backend):
    """Capacity-padded layout (streaming) computes the same product."""
    rows, cols = _random_edges(300, 300, 3000, seed=3)
    x = jnp.asarray(np.random.default_rng(3).random(300), jnp.float32)
    exact = build_block_sparse(rows, cols, 300, 300, block=64)
    padded = build_block_sparse(rows, cols, 300, 300, block=64, padded=True)
    assert padded.tiles.shape[0] >= exact.tiles.shape[0]
    assert padded.max_tiles >= exact.max_tiles
    np.testing.assert_allclose(
        np.asarray(block_spmv(padded, x, interpret=True, backend=backend)),
        np.asarray(block_spmv(exact, x, interpret=True, backend=backend)),
        rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_spmv_dtypes(dtype, tol, backend):
    rows, cols = _random_edges(256, 256, 3000, seed=0)
    x = jnp.asarray(np.random.default_rng(2).random(256), dtype)
    mat = build_block_sparse(rows, cols, 256, 256, block=64,
                             dtype=np.float32)
    mat = mat.__class__(**{**mat.__dict__,
                           "tiles": mat.tiles.astype(dtype)})
    y = block_spmv(mat, x, interpret=True, backend=backend)
    yref = spmv_ref(rows, cols, 256, x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref), rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("block", [16, 64])
def test_or_semiring_matches_ref(block, backend):
    rows, cols = _random_edges(400, 400, 5000, seed=4)
    f = jnp.asarray(np.random.default_rng(5).random(400) < 0.1, jnp.float32)
    mat = build_block_sparse(rows, cols, 400, 400, block=block)
    y = block_spmv(mat, f, semiring="or", interpret=True, backend=backend)
    yref = spmv_ref(rows, cols, 400, f, semiring="or")
    assert bool(jnp.all(y == yref))


@pytest.mark.parametrize("backend", BACKENDS)
def test_or_semiring_weighted_is_normalized(backend):
    """OR output is a 0/1 indicator even for fractional matrix values, on
    both backends and on the active/bucketed variants (the Pallas active
    kernel once leaked raw tile values here)."""
    from repro.kernels.block_spmv.ops import (block_spmv_active,
                                              block_spmv_active_bucketed)
    rows, cols = _random_edges(200, 200, 1200, seed=12)
    vals = np.full(1200, 0.3, np.float32)
    mat = build_block_sparse(rows, cols, 200, 200, block=32, values=vals)
    f = jnp.asarray(np.random.default_rng(13).random(200) < 0.1, jnp.float32)
    y = block_spmv(mat, f, semiring="or", interpret=True, backend=backend)
    assert bool(jnp.all((y == 0) | (y == 1)))
    ids = jnp.arange(mat.n_rb, dtype=jnp.int32)
    ya = block_spmv_active(mat, f, ids, semiring="or", interpret=True,
                           backend=backend)
    assert bool(jnp.all(ya == y))
    yb = block_spmv_active_bucketed(mat, f, ids, jnp.asarray(mat.n_rb),
                                    semiring="or", interpret=True,
                                    backend=backend)
    assert bool(jnp.all(yb == y))


@pytest.mark.parametrize("backend", BACKENDS)
def test_weighted_values(backend):
    rows, cols = _random_edges(100, 100, 700, seed=6)
    vals = np.random.default_rng(7).random(700).astype(np.float32)
    x = jnp.asarray(np.random.default_rng(8).random(100), jnp.float32)
    mat = build_block_sparse(rows, cols, 100, 100, block=32, values=vals)
    y = block_spmv(mat, x, interpret=True, backend=backend)
    yref = spmv_ref(rows, cols, 100, x, values=vals)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_pull_step_op(backend):
    rng = np.random.default_rng(9)
    n, m = 500, 4000
    src, dst = _random_edges(n, n, m, seed=9)
    # pull matrix A[v,u] = 1 for edge u→v → rows=dst, cols=src
    mat = build_block_sparse(dst, src, n, n, block=64)
    out_deg = np.maximum(np.bincount(src, minlength=n), 1)
    inv = jnp.asarray(1.0 / out_deg, jnp.float32)
    r = jnp.asarray(rng.random(n), jnp.float32)
    r = r / r.sum()
    y = pagerank_pull_step(mat, r, inv, n, interpret=True, backend=backend)
    yref = pagerank_pull_step_ref(dst, src, n, r, inv, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-5,
                               atol=2e-6)


def test_frontier_expand_matches_engine_semantics():
    """OR kernel on the pull layout == out_neighbor_or on the snapshot."""
    from repro.core.graph import HostGraph, out_neighbor_or
    rng = np.random.default_rng(10)
    n = 256
    edges = np.stack([rng.integers(0, n, 1500),
                      rng.integers(0, n, 1500)], 1)
    hg = HostGraph(n, edges)
    g = hg.snapshot(block_size=64)
    src = np.asarray(g.src)[:g.m]
    dst = np.asarray(g.dst)[:g.m]
    mat = build_block_sparse(dst, src, n, n, block=64)
    flags = jnp.asarray(rng.random(n) < 0.07)
    for backend in BACKENDS:
        ours = frontier_expand_op(mat, flags, interpret=True,
                                  backend=backend) > 0
        theirs = out_neighbor_or(g, jnp.concatenate(
            [flags, jnp.zeros(g.n_pad - n, bool)]))[:n]
        assert bool(jnp.all(ours == theirs))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 60), st.integers(1, 400),
           st.integers(0, 2 ** 31 - 1))
    def test_property_linearity(n, m, seed):
        """SpMV is linear: A(ax + by) == a·Ax + b·Ay."""
        rows, cols = _random_edges(n, n, m, seed=seed)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.random(n), jnp.float32)
        y = jnp.asarray(rng.random(n), jnp.float32)
        mat = build_block_sparse(rows, cols, n, n, block=8)
        lhs = block_spmv(mat, 2.0 * x + 3.0 * y, interpret=True)
        rhs = 2.0 * block_spmv(mat, x, interpret=True) + \
            3.0 * block_spmv(mat, y, interpret=True)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(4, 80), st.integers(1, 500),
           st.integers(0, 2 ** 31 - 1))
    def test_property_or_idempotent_monotone(n, m, seed):
        """OR expansion is idempotent in its inputs and monotone in the flag
        set — the properties that make the paper's helping mechanism
        race-free."""
        rows, cols = _random_edges(n, n, m, seed=seed)
        rng = np.random.default_rng(seed + 1)
        f1 = rng.random(n) < 0.2
        f2 = f1 | (rng.random(n) < 0.1)          # superset
        mat = build_block_sparse(rows, cols, n, n, block=8)
        y1 = block_spmv(mat, jnp.asarray(f1, jnp.float32), semiring="or",
                        interpret=True)
        y1b = block_spmv(mat, jnp.asarray(f1, jnp.float32), semiring="or",
                         interpret=True)
        y2 = block_spmv(mat, jnp.asarray(f2, jnp.float32), semiring="or",
                        interpret=True)
        assert bool(jnp.all(y1 == y1b))               # deterministic/idempotent
        assert bool(jnp.all(y2 >= y1))                # monotone
else:                                # pragma: no cover - env-dependent
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_suite_requires_hypothesis():
        pass
