"""Corruption fault domain: invariant scrubbing, checksummed device state
and the repair ladder (core/integrity.py + session.verify + the service
scrubber; docs/FAULTS.md §corruption).

Each injectable corruption kind must be DETECTED by the right check and
REPAIRED at the right ladder rung, with post-repair ranks matching the
accepted-batch oracle to 1e-9.  A seeded :class:`ChaosPlan` soak composes
all kinds against a serving fleet (``-m chaos``; excluded from the fast
marker path only by its own runtime, not by the slow marker).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (ChaosPlan, EngineConfig, IntegrityConfig,
                       PageRankService, PageRankSession, ServingConfig)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.graphs.generators import rmat

BS = 64


def _cfg(*, auto_repair=False, **over):
    base = dict(engine="pallas", block_size=BS, active_policy="rc",
                max_iterations=2000,
                integrity=IntegrityConfig(auto_repair=auto_repair))
    base.update(over)
    return EngineConfig(**base)


def _stream(sess, hg, n_batches, *, seed0=500):
    """Drive a few accepted batches, tracking the host-graph lineage."""
    cur = hg
    for i in range(n_batches):
        dels, ins = random_batch(cur, 8 / max(cur.m, 1), seed=seed0 + i)
        sess.update(dels, ins)
        cur = cur.apply_batch(dels, ins)
    return cur


def _oracle_linf(sess, cur):
    ref = pr.numpy_reference(cur.snapshot(block_size=BS), iterations=300)
    return float(pr.linf(sess.R[:cur.n], jnp.asarray(ref[:cur.n])))


@pytest.fixture(scope="module")
def graph():
    return rmat(9, avg_degree=6, seed=11)


# ---------------------------------------------------------------------------
# per-kind detection + ladder rung
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,rung", [
    ("rank", "frontier"),       # invariant violation → DF re-mark + helping
    ("tile", "rebuild"),        # tile-pool flip → operand rebuild
    ("slot", "rebuild"),        # slot-table flip → operand rebuild
    ("mirror", "rebuild"),      # mirror flip → operand rebuild
])
def test_detect_and_repair(graph, kind, rung):
    sess = PageRankSession.from_graph(graph, config=_cfg())
    cur = _stream(sess, graph, 2)
    assert sess.verify(repair=False).ok, "pre-injection state must be clean"
    sess.inject_corruption(kind, seed=3)
    rep = sess.verify(repair=True, deep=True)
    assert rep.failures, f"{kind} corruption went undetected"
    assert rep.ok, f"repair failed: {rep.failures}"
    assert rung in rep.repairs, (kind, rep.repairs)
    assert _oracle_linf(sess, cur) <= 1e-9
    integ = sess.report().integrity
    assert integ["corruption_detected"] == 1
    assert integ["repairs"][rung] >= 1
    # the state is clean again: a fresh scrub is a no-op
    assert sess.verify(repair=False).ok
    sess.close()


@pytest.mark.parametrize("kind", ["scatter_drop", "scatter_dup"])
def test_torn_scatter_detected_by_mirror_digests(graph, kind):
    """A dropped/duplicated operand scatter tears device operands away
    from the host-truth twins; the chunked mirror digests catch it and
    the rebuild rung re-derives the operands from host truth."""
    sess = PageRankSession.from_graph(graph, config=_cfg())
    cur = _stream(sess, graph, 1)
    sess.inject_corruption(kind)
    dels, ins = random_batch(cur, 8 / cur.m, seed=901)
    sess.update(dels, ins)          # the tear happens inside this update
    cur = cur.apply_batch(dels, ins)
    rep = sess.verify(repair=True, deep=False)
    assert any(f["check"] == "mirror_digest" for f in rep.failures), \
        rep.failures
    assert rep.ok and "rebuild" in rep.repairs
    assert _oracle_linf(sess, cur) <= 1e-9
    sess.close()


def test_graph_corruption_restores_from_store(graph, tmp_path):
    """Host-truth damage (the deep graph digest) cannot be repaired from
    the host — the ladder escalates to the checkpoint+WAL restore rung."""
    sess = PageRankSession.from_graph(
        graph, config=_cfg(durability="wal", checkpoint_interval=2),
        store_dir=str(tmp_path / "store"))
    cur = _stream(sess, graph, 3)
    sess.inject_corruption("graph", seed=7)
    rep = sess.verify(repair=True, deep=True)
    assert any(f["check"] == "graph_digest" for f in rep.failures)
    assert rep.ok and "restore" in rep.repairs
    assert _oracle_linf(sess, cur) <= 1e-9
    assert sess.report().integrity["repairs"]["restore"] >= 1
    sess.close()


def test_fused_drive_detects_and_auto_repairs(graph):
    """The zero-extra-sync path: a deferred corruption lands right before
    a batch applies, the drive's fused invariant vector flags it, and
    ``update`` climbs the ladder automatically (auto_repair=True).

    The injected kind is ``tile`` — damage to the pull matrix the driver
    actually multiplies by — because the drive cannot converge it away:
    the wrong fixed point carries a mass error the fused gate must flag.
    (A ``rank`` flip, by contrast, may legitimately self-heal when the
    vertex's chunk re-activates — the drive recomputes it from clean
    in-neighbors and there is nothing left to detect; and a ``mirror``
    flip is LATENT damage to a host-patching operand that only the
    scrubber's chunked digests can see.)"""
    sess = PageRankSession.from_graph(graph, config=_cfg(auto_repair=True))
    cur = _stream(sess, graph, 1)
    sess.inject_corruption("tile", seed=5, defer=True)
    dels, ins = random_batch(cur, 8 / cur.m, seed=911)
    sess.update(dels, ins)
    cur = cur.apply_batch(dels, ins)
    integ = sess.report().integrity
    assert integ["corruption_detected"] >= 1
    assert sum(integ["repairs"].values()) >= 1
    assert sess.verify(repair=False).ok
    assert _oracle_linf(sess, cur) <= 1e-9
    sess.close()


def test_verify_clean_is_cheap_and_counts(graph):
    sess = PageRankSession.from_graph(graph, config=_cfg())
    before = sess.report().integrity["checks_run"]
    rep = sess.verify(repair=False, deep=True)
    assert rep.ok and not rep.failures and not rep.repairs
    assert rep.checks_run > 0
    assert sess.report().integrity["checks_run"] == before + rep.checks_run
    sess.close()


# ---------------------------------------------------------------------------
# config round-trip + counters
# ---------------------------------------------------------------------------

def test_integrity_config_roundtrips_through_store(graph, tmp_path):
    icfg = IntegrityConfig(mass_tol=1e-5, scrub_interval_s=0.05,
                           auto_repair=False)
    sess = PageRankSession.from_graph(
        graph, config=_cfg(durability="wal", checkpoint_interval=1,
                           integrity=icfg),
        store_dir=str(tmp_path / "s"))
    _stream(sess, graph, 2)
    sess.save()
    sess.close()
    back = PageRankSession.restore(str(tmp_path / "s"))
    got = back.config.integrity
    assert isinstance(got, IntegrityConfig)
    assert got.mass_tol == pytest.approx(1e-5)
    assert got.scrub_interval_s == pytest.approx(0.05)
    assert got.auto_repair is False
    assert back.verify(repair=False).ok
    back.close()


def test_engine_config_coerces_integrity_dict():
    cfg = EngineConfig(engine="pallas",
                       integrity={"mass_tol": 1e-5, "auto_repair": False})
    assert isinstance(cfg.integrity, IntegrityConfig)
    assert cfg.integrity.mass_tol == pytest.approx(1e-5)
    with pytest.raises((TypeError, ValueError)):
        EngineConfig(engine="pallas", integrity={"no_such_knob": 1})


# ---------------------------------------------------------------------------
# bucket-retrace split (satellite: keep the zero-retrace bar assertable)
# ---------------------------------------------------------------------------

def test_bucket_retraces_counted_separately(graph):
    """Legitimate operand-bucket growth (the doubling ladder) compiles
    once per bucket; those compiles land in ``bucket_retraces`` and MUST
    NOT pollute ``retraces_post_warmup``, which stays the zero-retrace
    acceptance bar."""
    sess = PageRankSession.from_graph(graph, config=_cfg())
    cur = _stream(sess, graph, 2)
    # a much larger batch forces tile-pool / delta-bucket growth
    # (unique candidate pairs, deduped by key, none already present)
    rng = np.random.default_rng(77)
    cand = np.stack([rng.integers(0, cur.n, 8 * cur.m),
                     rng.integers(0, cur.n, 8 * cur.m)], 1).astype(np.int64)
    cand = cand[cand[:, 0] != cand[:, 1]]
    cand = cand[np.unique(cand[:, 0] * cur.n + cand[:, 1],
                          return_index=True)[1]]
    ins = cand[~cur.has_edges(cand)][:cur.m]
    res = sess.update(np.zeros((0, 2), np.int64), ins)
    assert res.bucket_retraces >= 0
    rep = sess.report()
    assert rep.retraces_post_warmup == 0, \
        "bucket growth leaked into the retrace bar"
    assert rep.bucket_retraces_post_warmup == res.bucket_retraces
    sess.close()


# ---------------------------------------------------------------------------
# service scrubber
# ---------------------------------------------------------------------------

def _mk_service(graph, *, serving=None, n=2, auto_repair=False):
    sessions = [PageRankSession.from_graph(
        rmat(8, avg_degree=6, seed=20 + s), config=_cfg(
            auto_repair=auto_repair))
        for s in range(n)]
    return PageRankService(
        sessions, serving=serving or ServingConfig(coalesce=False,
                                                   scrub=False))


def test_service_scrub_detects_and_repairs(graph):
    svc = _mk_service(graph)
    svc.sessions[1].inject_corruption("mirror", seed=9)
    reports = svc.scrub(deep=True, repair=True)
    assert set(reports) == {0, 1}
    assert reports[0].ok and not reports[0].failures
    assert reports[1].failures and reports[1].ok
    out = svc.report()
    assert out["integrity"]["scrubs_run"] >= 1
    assert out["integrity"]["corruption_detected"] == 1
    assert out["integrity"]["repairs"].get("rebuild", 0) >= 1
    svc.stop()


def test_background_scrubber_thread(graph):
    """With ``ServingConfig(scrub=True)`` a daemon scrubber sweeps idle
    slots at each slot's ``scrub_interval_s`` and repairs what it finds."""
    import time
    sessions = [PageRankSession.from_graph(
        rmat(8, avg_degree=6, seed=30 + s),
        config=_cfg(integrity=IntegrityConfig(auto_repair=True,
                                              scrub_interval_s=0.02)))
        for s in range(2)]
    svc = PageRankService(
        sessions, serving=ServingConfig(coalesce=False, scrub=True))
    svc.start()
    try:
        svc.sessions[0].inject_corruption("rank", seed=13)
        deadline = time.perf_counter() + 30.0
        while time.perf_counter() < deadline:
            integ = svc.report().get("integrity", {})
            if integ.get("corruption_detected", 0) >= 1:
                break
            time.sleep(0.05)
    finally:
        svc.stop()
    integ = svc.report()["integrity"]
    assert integ["scrubs_run"] >= 1
    assert integ["corruption_detected"] >= 1
    assert sum(integ["repairs"].values()) >= 1
    assert svc.sessions[0].verify(repair=False).ok


# ---------------------------------------------------------------------------
# seeded chaos soak (composes every kind; mirrors the benchmark scenario)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_plan_soak(tmp_path):
    plan = ChaosPlan(seed=17, steps=4, streams=2,
                     require=("rank", "mirror", "graph", "scatter_drop"),
                     rate=0.0)
    counts = plan.counts()
    assert sum(counts.values()) >= 4
    cfg = _cfg(durability="wal", checkpoint_interval=2)
    sessions = [PageRankSession.from_graph(
        rmat(9, avg_degree=6, seed=40 + s), config=cfg,
        store_dir=str(tmp_path / f"slot{s}")) for s in range(2)]
    svc = PageRankService(
        sessions, serving=ServingConfig(coalesce=False, scrub=False))
    cur = {s: sessions[s].hg for s in range(2)}
    seed = iter(range(10_000))

    def advance(s):
        dels, ins = random_batch(cur[s], 8 / cur[s].m,
                                 seed=6000 + next(seed))
        svc.submit(s, dels, ins)
        cur[s] = cur[s].apply_batch(dels, ins)

    injected = detected = 0
    for step in range(plan.steps):
        for s in range(2):
            advance(s)
        svc.run_until_drained()
        for ev in plan.events_at(step):
            fault = ev.corruption()
            if fault is None:
                continue
            svc.sessions[ev.stream].inject_corruption(fault)
            injected += 1
            if fault.kind in ("scatter_drop", "scatter_dup"):
                advance(ev.stream)      # the tear needs a consuming update
        svc.run_until_drained()
        reports = svc.scrub(deep=True, repair=True)
        detected += sum(1 for r in reports.values() if r.failures)
        assert all(r.ok for r in reports.values())
    assert injected >= 4
    assert detected == injected, (detected, injected)
    # final state: clean and oracle-tight on every stream
    final = svc.scrub(deep=True, repair=False)
    assert all(r.ok and not r.failures for r in final.values())
    for s in range(2):
        ref = pr.numpy_reference(cur[s].snapshot(block_size=BS),
                                 iterations=300)
        sess = svc.sessions[s]
        assert float(pr.linf(sess.R[:sess.n],
                             jnp.asarray(ref[:sess.n]))) <= 1e-9
    svc.stop()
