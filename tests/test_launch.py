"""Launch-layer tests: registry completeness vs the assignment, HLO
collective parser, analytic FLOPs accounting, roofline correction algebra."""
import numpy as np
import pytest

from repro.configs import get_arch, iter_cells, list_archs


# -- registry covers the assigned matrix ---------------------------------------

ASSIGNED = {
    "qwen1.5-4b": "lm", "phi4-mini-3.8b": "lm", "nemotron-4-340b": "lm",
    "granite-moe-3b-a800m": "lm", "mixtral-8x22b": "lm",
    "gatedgcn": "gnn", "egnn": "gnn", "graphsage-reddit": "gnn",
    "meshgraphnet": "gnn", "autoint": "recsys",
}


def test_all_assigned_archs_registered():
    archs = set(list_archs())
    for a, fam in ASSIGNED.items():
        assert a in archs, f"missing assigned arch {a}"
        assert get_arch(a).family == fam
    assert "pagerank-df" in archs          # the paper's own workload


def test_cell_matrix_is_40():
    cells = list(iter_cells(include_skipped=True))
    assert len(cells) == 40                # 5·4 + 4·4 + 1·4
    runnable = list(iter_cells(include_skipped=False))
    # long_500k skipped for the 4 full-attention LM archs only
    assert len(runnable) == 36
    skipped = [(s.arch_id, sh.name) for s, sh in cells
               if sh.skip]
    assert all(name == "long_500k" for _, name in skipped)
    assert ("mixtral-8x22b", "long_500k") not in skipped   # SWA runs it


def test_assigned_hyperparameters_exact():
    q = get_arch("qwen1.5-4b").build_cfg()
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (40, 2560, 20, 20, 6912, 151936, True)
    n = get_arch("nemotron-4-340b").build_cfg()
    assert (n.n_layers, n.d_model, n.n_heads, n.n_kv_heads, n.d_ff,
            n.vocab, n.mlp) == (96, 18432, 96, 8, 73728, 256000,
                                "squared_relu")
    m = get_arch("mixtral-8x22b").build_cfg()
    assert (m.n_layers, m.d_model, m.moe.n_experts, m.moe.top_k,
            m.sliding_window) == (56, 6144, 8, 2, 4096)
    g = get_arch("gatedgcn").build_cfg()
    assert (g.n_layers, g.d_hidden) == (16, 70)
    a = get_arch("autoint").build_cfg()
    assert (a.n_sparse, a.embed_dim, a.n_attn_layers, a.n_heads,
            a.d_attn) == (39, 16, 3, 2, 32)


def test_param_counts_match_names():
    assert abs(get_arch("qwen1.5-4b").build_cfg().param_count()
               - 3.95e9) < 0.3e9
    assert abs(get_arch("nemotron-4-340b").build_cfg().param_count()
               - 341e9) < 15e9
    assert abs(get_arch("mixtral-8x22b").build_cfg().param_count()
               - 141e9) < 8e9
    g = get_arch("granite-moe-3b-a800m").build_cfg()
    assert abs(g.param_count() - 3.4e9) < 0.5e9
    assert abs(g.active_param_count() - 0.95e9) < 0.3e9


# -- HLO collective parser -------------------------------------------------------

from repro.launch import hlo_analysis as H     # noqa: E402

SYNTH_HLO = """\
HloModule jit_f, is_scheduled=true

%body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %all-reduce.5 = f32[64,64]{1,0} all-reduce(%x), channel_id=3, replica_groups=[4,4]<=[16], to_apply=%add
}

%cond.2 (p: (s32[], f32[64,64])) -> pred[] {
  %c = s32[] constant(7)
}

ENTRY %main (a: f32[64,64]) -> f32[] {
  %w = (s32[], f32[64,64]{1,0}) while(%t), condition=%cond.2, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %all-gather.1 = f32[64,256]{1,0} all-gather(%a), channel_id=1, replica_groups=[4,4]<=[16], dimensions={1}
  ROOT %all-reduce.9 = f32[] all-reduce(%s), channel_id=2, replica_groups=[1,16]<=[16], to_apply=%add
}
"""


def test_shape_bytes():
    assert H.shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
    assert H.shape_bytes("bf16[8,2]") == 32
    assert H.shape_bytes("(f32[4], s8[8])") == 24
    assert H.shape_bytes("pred[]") == 1


def test_collective_parser_loop_aware():
    st = H.analyze_collectives(SYNTH_HLO)
    # while body all-reduce counted 7×, entry ops once
    assert st.counts["all-reduce"] == 7 + 1
    assert st.counts["all-gather"] == 1
    ar_body = 7 * (64 * 64 * 4)            # operand bytes × trips
    ar_root = 4
    assert st.operand_bytes["all-reduce"] == ar_body + ar_root
    # all-gather operand = output/group
    assert st.operand_bytes["all-gather"] == 64 * 256 * 4 / 4
    # wire: all-reduce 2(g-1)/g·in ; group sizes 4 and 16
    expect = 2 * ar_body * 3 / 4 + 2 * ar_root * 15 / 16
    assert abs(st.wire_bytes["all-reduce"] - expect) < 1e-6


# -- analytic flops ---------------------------------------------------------------

def test_lm_model_flops_scale():
    from repro.launch import flops as F
    spec = get_arch("qwen1.5-4b")
    cfg = spec.build_cfg()
    tr = F.lm_model_flops(cfg, spec.shape("train_4k"))
    # ≈ 6·N·D with N≈4e9, D=1M tokens (attention adds ~10%)
    assert 0.9 * 6 * 3.95e9 * 4096 * 256 < tr < 1.5 * 6 * 3.95e9 * 4096 * 256
    de = F.lm_model_flops(cfg, spec.shape("decode_32k"))
    assert de < tr / 1000                  # one token vs a full batch

    mx = get_arch("mixtral-8x22b")
    mcfg = mx.build_cfg()
    nowin = mx.build_cfg(sliding_window=None)
    lf = F.lm_model_flops(mcfg, mx.shape("long_500k"))
    # SWA bounds decode attention by the window, not the 524k context
    full_attn = F.lm_attn_fwd_flops(nowin, 1, 1, 524288, causal=False)
    swa_attn = F.lm_attn_fwd_flops(mcfg, 1, 1, 524288, causal=False)
    assert lf < 2 * F.lm_matmul_params(mcfg) * 1 + full_attn
    assert swa_attn * 120 < full_attn      # 128× window saving


def test_moe_active_flops_smaller():
    from repro.launch import flops as F
    mx = get_arch("mixtral-8x22b").build_cfg()
    assert F.lm_matmul_params(mx, active=True) < \
        0.35 * F.lm_matmul_params(mx, active=False)


# -- roofline correction algebra ---------------------------------------------------

def test_roofline_probe_correction():
    import importlib.util
    import os
    spec_path = os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks", "roofline.py")
    spec = importlib.util.spec_from_file_location("roofline_mod", spec_path)
    R = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(R)

    # synthetic: layer costs 10 flops, nonlayer 5, opt 2 (all per-chip);
    # L=4 layers, mb=3 microbatches
    chips = 256
    opt = 2.0 * chips
    rec = {
        "status": "ok",
        "cost": {"flops": 1.0, "bytes_accessed": 1.0},   # raw (unused)
        "probes": {
            "layer1": {"cost": {"flops": 10 + 5 + 2, "bytes_accessed": 1}},
            "layer2": {"cost": {"flops": 2 * 10 + 5 + 2,
                                "bytes_accessed": 1}},
        },
        "n_scan_layers": 4, "microbatches": 3,
        "param_count": 100, "layer_param_count": 0,   # opt → nonlayer
        "opt_flops": opt, "opt_bytes": 0.0,
        "collectives": {"total_wire_bytes": 0.0},
        "model_flops": 0.0, "memory": {"peak_bytes": 0},
    }
    t = R.corrected_terms(rec, chips)
    # total = opt + mb·(nonlayer₊ + L·layer) = 2 + 3·(5 + 4·10) = 137
    assert abs(t["flops_per_chip"] - 137.0) < 1e-6
