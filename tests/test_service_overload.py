"""Overload, degraded-read and chaos-under-load behavior of
:class:`repro.api.PageRankService` (docs/FAULTS.md "session" domain;
docs/API.md serving lifecycle).

Covers the serving-policy axis end to end: admission control sheds with
machine-readable reasons instead of growing queues without bound; deadlines
expire queued work and count late completions; transient dispatch failures
retry with backoff; reads are served degraded from bounded-staleness
snapshots (and survive an in-flight update or a dead slot); malformed
batches are rejected before any device scatter or WAL append; and a slot
killed or stalled mid-load is failed over by the watchdog with its queue
drained to the respawn, converging to oracle parity.
"""
import os
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (AdmissionRejected, EngineConfig, PageRankService,
                       PageRankSession, ServingConfig, SweepCapWarning)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.graphs.generators import rmat

BLOCK = 64


def _cfg(**kw):
    return EngineConfig(engine="pallas", block_size=BLOCK, **kw)


def _batches(hg, k, seed0=0):
    """k sequential random batches + the graph after each prefix."""
    out, cur = [], hg
    for i in range(k):
        d, ins = random_batch(cur, 1e-2, seed=seed0 + i)
        out.append((d, ins))
        cur = cur.apply_batch(d, ins)
    return out, cur


@pytest.fixture(scope="module")
def hg():
    return rmat(8, avg_degree=5, seed=11)


# ---------------------------------------------------------------------------
# admission control + shedding
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_reject_policy_raises_with_machine_readable_reason(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(), warmup=False,
            serving=ServingConfig(max_queue_depth=2))
        bs, _ = _batches(hg, 3)
        for d, ins in bs[:2]:
            svc.submit(0, d, ins)
        with pytest.raises(AdmissionRejected) as ei:
            svc.submit(0, *bs[2])
        reason = ei.value.reason
        assert reason["code"] == "queue_full"
        assert reason["stream"] == 0
        assert reason["queue_depth"] == 2
        assert reason["max_queue_depth"] == 2
        assert reason["shed_policy"] == "reject"
        # the queue did NOT grow past its bound, and the shed is recorded
        assert len(svc.queue) == 2
        rep = svc.report()
        assert rep["requests_shed"] == 1
        assert rep["shed_reasons"] == {"queue_full": 1}

    def test_drop_oldest_policy_sheds_head_keeps_newest(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(), warmup=False,
            serving=ServingConfig(max_queue_depth=2,
                                  shed_policy="drop_oldest"))
        bs, _ = _batches(hg, 3)
        uids = [svc.submit(0, d, ins) for d, ins in bs]   # no raise
        assert [r.uid for r in svc.queue] == uids[1:]     # oldest shed
        shed = svc.shed_requests[0]
        assert shed.uid == uids[0]
        assert shed.shed_reason["code"] == "queue_full_dropped_oldest"
        rep = svc.report()
        assert rep["shed_reasons"] == {"queue_full_dropped_oldest": 1}


# ---------------------------------------------------------------------------
# deadlines + retries
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_expired_queued_request_is_shed_before_dispatch(self, hg):
        svc = PageRankService([hg], config=_cfg(), warmup=False)
        bs, _ = _batches(hg, 1)
        uid = svc.submit(0, *bs[0], deadline_s=1e-4)
        time.sleep(0.01)
        assert svc.step() == 0          # never dispatched
        assert svc.sessions[0].report().n_updates == 0
        shed = svc.shed_requests[0]
        assert shed.uid == uid
        assert shed.shed_reason["code"] == "deadline_expired"
        rep = svc.report()
        assert rep["deadline_misses"] == 1
        assert rep["requests_shed"] == 1

    def test_late_completion_counts_as_deadline_miss(self, hg):
        svc = PageRankService([hg], config=_cfg())
        sess = svc.sessions[0]
        orig = sess.update

        def slow_update(d, i, **kw):
            time.sleep(0.08)
            return orig(d, i, **kw)

        sess.update = slow_update
        bs, _ = _batches(hg, 1)
        svc.submit(0, *bs[0], deadline_s=0.03)
        svc.run_until_drained()
        req = svc.finished[0]
        assert req.done and req.deadline_missed
        assert svc.report()["deadline_misses"] == 1

    def test_transient_failure_retries_with_backoff(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(),
            serving=ServingConfig(max_retries=2, retry_backoff_s=1e-3))
        sess = svc.sessions[0]
        orig, calls = sess.update, {"n": 0}

        def flaky_update(d, i, **kw):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient device hiccup")
            return orig(d, i, **kw)

        sess.update = flaky_update
        bs, cur = _batches(hg, 1)
        svc.submit(0, *bs[0])
        done = svc.run_until_drained()
        assert len(done) == 1 and done[0].done
        assert done[0].attempts == 2
        assert svc.report()["retries"] == 1
        ref = pr.numpy_reference(cur.snapshot(block_size=BLOCK),
                                 iterations=300)
        assert pr.linf(sess.R[:cur.n], jnp.asarray(ref[:cur.n])) < 1e-8


# ---------------------------------------------------------------------------
# degraded-mode reads
# ---------------------------------------------------------------------------

class TestDegradedReads:
    def test_reads_report_bounded_staleness(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(),
            serving=ServingConfig(staleness_budget_s=10.0))
        bs, _ = _batches(hg, 2)
        for d, ins in bs:
            svc.submit(0, d, ins)
        svc.run_until_drained()
        res = svc.query(0, [0, 1, 2])
        assert res.degraded
        assert res.staleness_s >= 0.0
        assert res.lag_updates == 0     # snapshot refreshed after dispatch
        assert np.asarray(res).shape == (3,)
        # snapshot values match the live session exactly (shared arrays)
        np.testing.assert_array_equal(
            np.asarray(res), np.asarray(svc.sessions[0].query([0, 1, 2])))
        vals, verts = svc.top_k(0, 4)   # tuple-unpacks like the session
        assert vals.shape == (4,) and verts.shape == (4,)
        q = svc.report()["queries"]
        assert q["served"] == 2
        assert q["staleness_max_s"] >= 0.0

    def test_stale_snapshot_refreshes_when_idle(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(),
            serving=ServingConfig(staleness_budget_s=0.01))
        bs, _ = _batches(hg, 1)
        svc.submit(0, *bs[0])
        svc.run_until_drained()
        time.sleep(0.05)                # snapshot goes stale past budget
        res = svc.query(0, [0])
        assert res.staleness_s <= 0.05  # refreshed at read time
        assert res.lag_updates == 0

    def test_reads_survive_slot_death(self, hg):
        svc = PageRankService([hg], config=_cfg(), warmup=False,
                              serving=ServingConfig(watchdog=False))
        before = np.asarray(svc.query(0, [0, 1]))
        sess = svc.sessions[0]
        sess._service = None            # crash-stop, not a clean close
        sess.close()
        res = svc.query(0, [0, 1])      # still served, from the snapshot
        assert res.degraded
        np.testing.assert_array_equal(np.asarray(res), before)

    def test_disabled_degraded_reads_serve_live(self, hg):
        svc = PageRankService(
            [hg], config=_cfg(), warmup=False,
            serving=ServingConfig(degraded_reads=False))
        res = svc.query(0, [0])
        assert not res.degraded
        assert res.staleness_s == 0.0


# ---------------------------------------------------------------------------
# input validation before scatter / WAL
# ---------------------------------------------------------------------------

class TestInputValidation:
    BAD = [
        (np.array([[0, np.nan]]), "non-finite"),
        (np.array([[0, np.inf]]), "non-finite"),
        (np.array([[0.5, 1.0]]), "non-integral"),
        (np.array([[0, 10 ** 6]]), "out-of-range"),
        (np.array([[-1, 2]]), "out-of-range"),
        (np.array([[1, 2], [1, 2]]), "duplicate"),
        (np.array([[1, 2, 3]]), "edge pairs"),
        (np.array([["a", "b"]], dtype=object), "object"),
    ]

    @pytest.mark.parametrize("bad,msg", BAD)
    def test_session_update_rejects_malformed(self, hg, bad, msg):
        sess = PageRankSession.from_graph(hg, config=_cfg())
        with pytest.raises(ValueError, match=msg):
            sess.update(np.zeros((0, 2)), bad)
        assert sess.report().n_updates == 0     # nothing applied

    def test_self_loop_and_del_ins_overlap_rejected(self, hg):
        sess = PageRankSession.from_graph(hg, config=_cfg())
        with pytest.raises(ValueError, match="self-loop"):
            sess.update(np.zeros((0, 2)), np.array([[3, 3]]))
        with pytest.raises(ValueError, match="both deletions"):
            sess.update(np.array([[1, 2]]), np.array([[1, 2]]))

    def test_service_rejects_at_admission_not_in_queue(self, hg):
        svc = PageRankService([hg], config=_cfg(), warmup=False)
        with pytest.raises(ValueError, match="non-finite"):
            svc.submit(0, np.zeros((0, 2)), np.array([[0, np.nan]]))
        assert svc.queue == []          # never admitted

    def test_bad_batch_never_reaches_wal(self, hg, tmp_path):
        store = str(tmp_path / "s0")
        sess = PageRankSession.from_graph(
            hg, config=_cfg(durability="wal"), store_dir=store)
        good, cur = _batches(hg, 1, seed0=33)
        sess.update(*good[0])
        with pytest.raises(ValueError, match="out-of-range"):
            sess.update(np.zeros((0, 2)), np.array([[0, 10 ** 6]]))
        sess.close()
        # the restore replays exactly the one good batch — the rejected
        # batch left no WAL record to poison the replay
        twin = PageRankSession.restore(store)
        assert twin._batch_index == 1
        ref = pr.numpy_reference(cur.snapshot(block_size=BLOCK),
                                 iterations=300)
        assert pr.linf(twin.ranks[:cur.n], jnp.asarray(ref[:cur.n])) < 1e-8


# ---------------------------------------------------------------------------
# sweep-cap surfacing (no more silent capping)
# ---------------------------------------------------------------------------

class TestSweepCap:
    def test_capped_update_warns_and_reports(self, hg):
        sess = PageRankSession.from_graph(hg, config=_cfg(max_iterations=1))
        bs, _ = _batches(hg, 1, seed0=70)
        with pytest.warns(SweepCapWarning, match="max_iterations"):
            res = sess.update(*bs[0])
        assert not res.converged
        rep = sess.report()
        assert rep.sweep_cap_hits == 1
        assert rep.batches_converged == 0

    def test_converged_update_does_not_warn(self, hg):
        import warnings
        sess = PageRankSession.from_graph(hg, config=_cfg())
        bs, _ = _batches(hg, 1, seed0=71)
        with warnings.catch_warnings():
            warnings.simplefilter("error", SweepCapWarning)
            res = sess.update(*bs[0])
        assert res.converged
        rep = sess.report()
        assert rep.sweep_cap_hits == 0 and rep.batches_converged == 1

    def test_run_stream_aggregates_convergence(self, hg):
        from repro.core.stream import run_stream
        bs, _ = _batches(hg, 3, seed0=72)
        rep = run_stream(hg, bs, block_size=BLOCK)
        assert rep.batches_converged == 3
        assert rep.sweep_cap_hits == 0 and rep.all_converged


# ---------------------------------------------------------------------------
# chaos under load: watchdog failover drains the queue to the respawn
# ---------------------------------------------------------------------------

class TestFailoverUnderLoad:
    def _durable(self, hg, tmp_path, name):
        return PageRankSession.from_graph(
            hg, config=_cfg(durability="wal", checkpoint_interval=2),
            store_dir=str(tmp_path / name))

    def test_dead_slot_drains_to_respawn_sync(self, hg, tmp_path):
        svc = PageRankService([self._durable(hg, tmp_path, "dead")])
        svc.inject_session_fault(0, after_dispatches=1, kind="dead")
        bs, cur = _batches(hg, 3, seed0=50)
        for d, ins in bs:               # interleave so the fault fires
            svc.submit(0, d, ins)
            svc.step()
        done = svc.run_until_drained()
        assert len(done) == 3 and all(r.done for r in done)
        rep = svc.report()
        events = rep["watchdog"]
        assert len(events) == 1
        assert events[0]["kind"] == "dead"
        assert events[0]["domain"] == "session"
        assert events[0]["drained_requests"] >= 1
        # two records on the respawned session: the process-domain restore
        # itself + the session-domain watchdog drain
        assert rep["sessions"][0]["recoveries"] == 2
        ref = pr.numpy_reference(cur.snapshot(block_size=BLOCK),
                                 iterations=300)
        assert pr.linf(svc.sessions[0].ranks[:cur.n],
                       jnp.asarray(ref[:cur.n])) < 1e-8

    def test_stuck_slot_fails_over_under_background_load(self, hg,
                                                         tmp_path):
        svc = PageRankService(
            [self._durable(hg, tmp_path, "stuck")],
            serving=ServingConfig(heartbeat_timeout_s=1.0))
        svc.inject_session_fault(0, after_dispatches=1, kind="stuck",
                                 stall_s=6.0)
        svc.start()
        try:
            bs, cur = _batches(hg, 4, seed0=60)
            for d, ins in bs:
                svc.submit(0, d, ins)
                time.sleep(0.15)
        finally:
            svc.stop()
        rep = svc.report()
        assert rep["requests_done"] == 4
        assert rep["requests_queued"] == 0
        events = rep["watchdog"]
        assert events and events[0]["kind"] == "stuck"
        assert events[0]["drained_requests"] >= 1
        ref = pr.numpy_reference(cur.snapshot(block_size=BLOCK),
                                 iterations=300)
        assert pr.linf(svc.sessions[0].ranks[:cur.n],
                       jnp.asarray(ref[:cur.n])) < 1e-8

    def test_failover_drain_orders_stranded_before_midrecovery_submits(
            self, hg, tmp_path):
        # A durable dead slot keeps accepting submits while the respawn is
        # restoring.  Those land in the (cleared) queue before the drain
        # re-queues the stranded pre-kill batches, so the drain must
        # PREPEND the stranded run: delta batches are order-sensitive, and
        # stranded delete(e) + mid-recovery insert(e) nets to insert (edge
        # survives) only in submit order — the inverted order nets to a
        # delete, silently diverging the served ranks from the
        # accepted-batch lineage.
        svc = PageRankService([self._durable(hg, tmp_path, "order")])
        svc.inject_session_fault(0, after_dispatches=0, kind="dead")
        e = hg.edges[:1]                    # one existing edge
        none = np.zeros((0, 2), np.int64)
        orig_failover = svc.failover

        def failover_then_submit(stream, **kw):
            out = orig_failover(stream, **kw)
            svc.submit(0, none, e)          # re-insert e mid-recovery
            return out

        svc.failover = failover_then_submit
        svc.submit(0, e, none)              # delete e (stranded by kill)
        svc.step()              # dispatch dies; watchdog drains + respawns
        done = svc.run_until_drained()
        assert len(done) == 2 and all(r.done for r in done)
        # submit order [delete(e), insert(e)] nets to e present
        assert svc.sessions[0].hg.has_edges(e).all()
        ref = pr.numpy_reference(hg.snapshot(block_size=BLOCK),
                                 iterations=300)
        assert pr.linf(svc.sessions[0].ranks[:hg.n],
                       jnp.asarray(ref[:hg.n])) < 1e-8

    def test_dead_slot_without_store_sheds_with_reason(self, hg):
        svc = PageRankService([hg], config=_cfg())    # no durability
        svc.inject_session_fault(0, after_dispatches=0, kind="dead")
        bs, _ = _batches(hg, 2, seed0=65)
        for d, ins in bs:
            svc.submit(0, d, ins)
        svc.run_until_drained(max_ticks=20)
        rep = svc.report()
        assert rep["requests_done"] == 0
        assert rep["requests_shed"] == 2
        assert rep["shed_reasons"] == {"slot_dead": 2}
        assert rep["watchdog"] and \
            "no store" in rep["watchdog"][0]["description"]
