"""Public-API surface snapshot + the session-level streaming guarantees.

The ``repro.api`` surface (``__all__``, the ``EngineConfig`` field set, the
registered builtin engines) is snapshotted here so changes to it are
deliberate — update the expected sets in the same PR that changes the
surface, with a docs/API.md entry to match.

Also asserts the PR-2 streaming acceptance criteria *through the new
surface*: ``PageRankSession.update`` must re-enter the fused driver with
zero post-warmup retraces, and its ranks must match the from-scratch
rebuild path bit-tightly.
"""
import numpy as np
import pytest
import jax.numpy as jnp

import repro.api as api
from repro.api import EngineConfig, PageRankSession, registry
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.graphs.generators import rmat

EXPECTED_API = {
    "AdmissionRejected",
    "CapabilityError",
    "ChaosEvent",
    "ChaosPlan",
    "CorruptionFault",
    "CorruptionFaultDomain",
    "IntegrityConfig",
    "IntegrityReport",
    "EngineConfig",
    "Engine",
    "PageRankService",
    "PageRankSession",
    "ReadResult",
    "RecoveryRecord",
    "ServingConfig",
    "SessionFault",
    "SessionReport",
    "SessionStore",
    "ShardFault",
    "ShardFaultDomain",
    "StreamBatchResult",
    "SweepCapWarning",
    "ThreadFaultDomain",
    "UpdateRequest",
    "register",
    "registry",
}

EXPECTED_CONFIG_FIELDS = {
    "alpha", "tau", "tau_f", "mode", "engine", "backend", "tile",
    "block_size", "active_policy", "max_iterations", "faults", "dtype",
    "topology", "n_shards", "partitioner", "exchange",
    "fault_domain", "durability", "checkpoint_interval", "integrity",
    "walks_per_vertex", "walk_length", "walk_seed",
    "device_budget_bytes", "driver",
}

EXPECTED_BUILTIN_ENGINES = {"dense", "blocked", "pallas", "distributed",
                            "walk"}


def test_api_all_snapshot():
    assert set(api.__all__) == EXPECTED_API
    for name in api.__all__:        # every exported name must resolve
        assert getattr(api, name) is not None


def test_engine_config_field_snapshot():
    import dataclasses
    assert set(f.name for f in dataclasses.fields(EngineConfig)) == \
        EXPECTED_CONFIG_FIELDS
    assert set(EngineConfig.valid_keys()) == EXPECTED_CONFIG_FIELDS


def test_builtin_engines_registered():
    assert EXPECTED_BUILTIN_ENGINES <= set(registry.names())


def test_session_core_methods_exist():
    for m in ("from_graph", "from_snapshot", "update", "recompute",
              "query", "top_k", "ppr_query", "report", "fork", "warmup",
              "close", "save", "restore", "inject_shard_fault", "verify",
              "inject_corruption", "__enter__", "__exit__"):
        assert callable(getattr(PageRankSession, m)), m


# ---------------------------------------------------------------------------
# streaming guarantees through the session surface
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_setup():
    hg = rmat(9, avg_degree=6, seed=3)
    g = hg.snapshot(block_size=64)
    r0 = jnp.asarray(pr.numpy_reference(g, iterations=300))
    batches = []
    cur = hg
    for i in range(4):
        dels, ins = random_batch(cur, 5e-3, seed=300 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    return hg, g, r0, batches, cur


def test_session_update_zero_retraces_post_warmup(stream_setup):
    """The tentpole acceptance bar: after warmup, a ≥3-batch stream of
    session updates must not retrace the fused driver."""
    hg, g, r0, batches, _ = stream_setup
    sess = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=64), r0=r0)
    sess.warmup()
    sizes = [sess.update(dels, ins).driver_cache_size
             for dels, ins in batches]
    assert len(sizes) >= 3
    assert sizes[0] >= 0, "jit cache stats unavailable"
    assert sizes[-1] == sizes[0], f"driver retraced during stream: {sizes}"
    rep = sess.report()
    assert rep.retraces_post_warmup == 0
    assert rep.n_updates == len(batches)


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_session_update_matches_rebuild(stream_setup):
    """Stream-mode session results must match the rebuild-everything path
    (same engine, same hyperparameters) on insertion+deletion batches."""
    hg, g, r0, batches, _ = stream_setup
    sess = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=64), r0=r0)
    cur, r_ref = hg, r0
    for dels, ins in batches:
        res = sess.update(dels, ins)
        g_prev = cur.snapshot(block_size=64)
        cur = cur.apply_batch(dels, ins)
        g_new = cur.snapshot(block_size=64)
        from repro.core.frontier import batch_to_device
        oracle = pr.df_pagerank(
            g_prev, g_new, batch_to_device(g_new, dels, ins), r_ref,
            mode="lf", engine="pallas")
        r_ref = oracle.ranks
        assert res.stats.converged
        assert pr.linf(res.ranks, oracle.ranks) < 1e-12
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)
    assert pr.linf(sess.R[:cur.n], jnp.asarray(ref[:cur.n])) < 1e-9


def test_session_partial_reads_match_full_ranks(stream_setup):
    hg, g, r0, batches, _ = stream_setup
    sess = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=64), r0=r0)
    sess.update(*batches[0])
    full = sess.ranks
    ids = np.array([0, 1, sess.n - 1])
    got = sess.query(ids)
    np.testing.assert_allclose(got, full[[0, 1, sess.n - 1]])
    # malformed ids raise instead of silently reading 0 / device-erroring
    with pytest.raises(ValueError, match="out of range"):
        sess.query([0, sess.n_pad + 5])
    with pytest.raises(ValueError, match="out of range"):
        sess.query(-3)
    vals, idx = sess.top_k(5)
    order = np.argsort(full[:sess.n])[::-1][:5]
    np.testing.assert_allclose(vals, full[order])
    assert (np.diff(vals) <= 0).all()
    assert sess.report().queries_served == len(ids) + 5
    rep = sess.report()             # single-device topology fields
    assert rep.topology == "single" and rep.n_shards is None
    assert rep.edge_cut is None and rep.partitioner is None
