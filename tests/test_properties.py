"""Hypothesis property tests over the system's invariants
(repro/core/properties.py; each mirrors a claim the paper relies on)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property suite requires hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core import properties as prop
from repro.core.delta import (coalesce_batches, random_batch,
                              validate_edge_batch)
from repro.core.faults import FaultPlan
from repro.core.graph import HostGraph

SET = settings(max_examples=15, deadline=None)


def _graph(n: int, m: int, seed: int) -> HostGraph:
    rng = np.random.default_rng(seed)
    e = np.stack([rng.integers(0, n, m), rng.integers(0, n, m)], 1)
    return HostGraph(n, e)


@st.composite
def graph_and_batch(draw):
    n = draw(st.integers(16, 200))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    frac = draw(st.sampled_from([1e-2, 0.05, 0.2]))
    return n, m, seed, frac


# -- I1: rank conservation -----------------------------------------------------

@SET
@given(st.integers(16, 150), st.integers(0, 2 ** 16))
def test_rank_conservation(n, seed):
    hg = _graph(n, 3 * n, seed)
    g = hg.snapshot()
    r = pr.reference_pagerank(g, iterations=150)
    assert prop.rank_conservation_error(g, r) < 1e-6


# -- I2: idempotent marking (the helping mechanism's correctness) --------------

@SET
@given(graph_and_batch())
def test_marking_idempotent(gb):
    n, m, seed, frac = gb
    hg = _graph(n, m, seed)
    dels, ins = random_batch(hg, frac, seed=seed + 1)
    hg2 = hg.apply_batch(dels, ins)
    g1, g2 = hg.snapshot(), hg2.snapshot()
    batch = fr.batch_to_device(g2, dels, ins)
    assert prop.marking_idempotent(g1, g2, batch)


# -- I2b: helping == single-pass marking, any first-pass subset ----------------

@SET
@given(graph_and_batch(), st.floats(0.0, 1.0))
def test_helping_equals_full_marking(gb, coverage):
    n, m, seed, frac = gb
    hg = _graph(n, m, seed)
    dels, ins = random_batch(hg, frac, seed=seed + 2)
    hg2 = hg.apply_batch(dels, ins)
    g1, g2 = hg.snapshot(), hg2.snapshot()
    batch = fr.batch_to_device(g2, dels, ins)
    rng = np.random.default_rng(seed)
    first_pass = jnp.asarray(rng.random(batch.shape[0]) < coverage)
    full = fr.initial_affected(g1, g2, batch)
    helped, checked, _ = fr.initial_affected_with_helping(
        g1, g2, batch, first_pass)
    assert bool(jnp.array_equal(full, helped))
    assert bool(checked.all())


# -- I3: frontier monotonicity --------------------------------------------------

@SET
@given(graph_and_batch())
def test_frontier_monotone(gb):
    n, m, seed, frac = gb
    hg = _graph(n, m, seed)
    g = hg.snapshot()
    rng = np.random.default_rng(seed)
    flags = jnp.asarray(rng.random(g.n_pad) < 0.1)
    grown, _ = fr.expand_frontier(g, flags, flags, jnp.zeros_like(flags))
    assert prop.frontier_monotone(flags, grown)


# -- I4: fault-schedule soundness ----------------------------------------------

@SET
@given(st.integers(1, 64), st.integers(0, 63), st.floats(0, 0.9),
       st.integers(0, 2 ** 16))
def test_fault_schedule_sound(n_threads, n_crashed, delay_prob, seed):
    n_crashed = min(n_crashed, n_threads - 1)  # at least one survivor
    plan = FaultPlan(n_threads=n_threads, n_crashed=n_crashed,
                     delay_prob=delay_prob, delay_ms=10, seed=seed)
    assert prop.fault_schedule_sound(plan)


# -- I5: delete+reinsert round trip ---------------------------------------------

@SET
@given(graph_and_batch())
def test_delete_insert_roundtrip(gb):
    n, m, seed, frac = gb
    hg = _graph(n, m, seed)
    if hg.m == 0:
        return
    rng = np.random.default_rng(seed)
    k = max(1, int(frac * hg.m))
    batch = hg.edges[rng.choice(hg.m, size=min(k, hg.m), replace=False)]
    assert prop.delete_insert_roundtrip(hg, batch)


# -- engine-level: DF == reference within the paper's band ----------------------

@settings(max_examples=6, deadline=None)
@given(graph_and_batch(), st.sampled_from(["bb", "lf"]),
       st.sampled_from(["affected", "rc"]))
def test_df_matches_reference(gb, mode, policy):
    n, m, seed, frac = gb
    hg = _graph(n, m, seed)
    dels, ins = random_batch(hg, frac, seed=seed + 3)
    hg2 = hg.apply_batch(dels, ins)
    g1, g2 = hg.snapshot(), hg2.snapshot()
    batch = fr.batch_to_device(g2, dels, ins)
    r_prev = pr.reference_pagerank(g1, iterations=250)
    res = pr.df_pagerank(g1, g2, batch, r_prev, mode=mode,
                         active_policy=policy)
    ref = pr.reference_pagerank(g2, iterations=250)
    assert res.stats.converged
    assert prop.ranks_match_reference(res.ranks, ref, tol=1e-9)


# -- batch coalescing: one folded batch ≡ the sequential stream -----------------

@st.composite
def batch_stream(draw):
    """An n-vertex graph seed plus an ordered run of update batches.

    Batches deliberately contain duplicate keys within a side, edges
    deleted in one batch and reinserted in a later one, and deletions of
    edges that never existed — everything set-semantics application must
    absorb and coalescing must net out."""
    n = draw(st.integers(8, 64))
    n_batches = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)

    def pairs(k):
        if k == 0:
            return np.zeros((0, 2), np.int64)
        src = rng.integers(0, n, k)
        # never a self-loop (src+1..src+n-1 mod n excludes src)
        dst = (src + 1 + rng.integers(0, n - 1, k)) % n
        return np.stack([src, dst], 1).astype(np.int64)

    batches = [(pairs(int(rng.integers(0, 7))), pairs(int(rng.integers(0, 7))))
               for _ in range(n_batches)]
    return n, seed, batches


@SET
@given(batch_stream())
def test_coalesce_equals_sequential(nb):
    """Applying the coalesced batch once must land on exactly the edge set
    the sequential stream produces, and the folded batch must be valid by
    construction (no duplicates, no del/ins overlap)."""
    n, seed, batches = nb
    hg = _graph(n, 2 * n, seed)
    seq = hg
    for d, i in batches:
        seq = seq.apply_batch(d, i)
    dels, ins = coalesce_batches(batches, n)
    validate_edge_batch(dels, ins, n)
    one = hg.apply_batch(dels, ins)
    assert np.array_equal(seq.edges, one.edges)


@SET
@given(st.integers(8, 48), st.integers(0, 2 ** 16))
def test_coalesce_delete_then_reinsert(n, seed):
    rng = np.random.default_rng(seed)
    hg = _graph(n, 3 * n, seed)
    if hg.m == 0:
        return
    edge = hg.edges[rng.integers(hg.m)][None, :]
    z = np.zeros((0, 2), np.int64)
    # delete then reinsert nets to an insertion: the edge survives
    dels, ins = coalesce_batches([(edge, z), (z, edge)], n)
    assert len(dels) == 0 and np.array_equal(ins, edge)
    assert hg.apply_batch(dels, ins).has_edges(edge).all()
    # insert then delete nets to a deletion: the edge is gone
    dels, ins = coalesce_batches([(z, edge), (edge, z)], n)
    assert len(ins) == 0 and np.array_equal(dels, edge)
    assert not hg.apply_batch(dels, ins).has_edges(edge).any()


@SET
@given(st.integers(8, 48), st.lists(st.booleans(), min_size=1, max_size=6),
       st.integers(0, 2 ** 16))
def test_coalesce_duplicate_key_last_write_wins(n, ops, seed):
    """The same edge touched across many batches collapses to its final
    operation regardless of the op ordering."""
    rng = np.random.default_rng(seed)
    src = int(rng.integers(0, n))
    dst = int((src + 1 + rng.integers(0, n - 1)) % n)
    edge = np.array([[src, dst]], np.int64)
    z = np.zeros((0, 2), np.int64)
    batches = [(z, edge) if is_ins else (edge, z) for is_ins in ops]
    dels, ins = coalesce_batches(batches, n)
    if ops[-1]:
        assert len(dels) == 0 and np.array_equal(ins, edge)
    else:
        assert len(ins) == 0 and np.array_equal(dels, edge)


# -- HostGraph functional semantics ---------------------------------------------

@SET
@given(st.integers(8, 64), st.integers(8, 128), st.integers(0, 2 ** 16))
def test_apply_batch_is_functional(n, m, seed):
    hg = _graph(n, m, seed)
    before = hg.edges.copy()
    dels, ins = random_batch(hg, 0.3, seed=seed)
    hg.apply_batch(dels, ins)           # must NOT mutate the original
    assert np.array_equal(before, hg.edges)


# -- W1/W2: walk-store determinism (core/walk_engine.py) -----------------------
#
# W1: a delta applied through delta-localized regeneration leaves the walk
# buffers AND visit counters bit-identical to regenerating every walk from
# scratch on the updated graph — per-walk draws are a pure function of
# (seed, walk id), so incremental == full exactly, not just statistically.
# W2: delete-then-reinsert of the same edges is a no-op on the buffers
# (sorted adjacency rows restore bit-for-bit, hence so do the walks).

def _loopless(n: int, m: int, seed: int) -> HostGraph:
    hg = _graph(n, m, seed)
    e = hg.edges
    return HostGraph(n, e[e[:, 0] != e[:, 1]])


@SET
@given(st.integers(12, 48), st.integers(12, 96), st.integers(0, 2 ** 16))
def test_walk_delta_equals_full_regeneration(n, m, seed):
    from repro.core.incremental import effective_batch
    from repro.core.walk_engine import WalkState
    hg = _loopless(n, m, seed)
    dels, ins = random_batch(hg, 0.2, seed=seed + 1)
    keep = np.asarray(ins)[:, 0] != np.asarray(ins)[:, 1]
    ins = np.asarray(ins)[keep]
    ws = WalkState(hg, R=4, L=12, seed=7)
    de, ie = effective_batch(hg, dels, ins)
    ws.apply_batch(de, ie)
    full = WalkState(hg.apply_batch(dels, ins), R=4, L=12, seed=7)
    assert np.array_equal(np.asarray(ws.walks), np.asarray(full.walks))
    assert np.array_equal(np.asarray(ws.counts), np.asarray(full.counts))


@SET
@given(st.integers(12, 48), st.integers(12, 96), st.integers(0, 2 ** 16))
def test_walk_delete_reinsert_noop(n, seed_m, seed):
    from repro.core.incremental import effective_batch
    from repro.core.walk_engine import WalkState
    hg = _loopless(n, seed_m, seed)
    if hg.m == 0:
        return
    rng = np.random.default_rng(seed)
    edges = hg.edges[rng.choice(hg.m, min(4, hg.m), replace=False)]
    ws = WalkState(hg, R=4, L=12, seed=11)
    walks0, counts0 = np.asarray(ws.walks).copy(), np.asarray(ws.counts).copy()
    none = np.zeros((0, 2), np.int64)
    ws.apply_batch(*effective_batch(hg, edges, none))
    hg2 = hg.apply_batch(edges, none)
    ws.apply_batch(*effective_batch(hg2, none, edges))
    assert np.array_equal(np.asarray(ws.walks), walks0)
    assert np.array_equal(np.asarray(ws.counts), counts0)


# -- P1: driver equivalence (ISSUE 10) -----------------------------------------
# The residual forward-push driver and the fused pull driver converge to the
# SAME fixed point: both stop at per-vertex residual/change <= tau, so each
# final iterate sits within ||r||_1 * a/(1-a) <= n*tau*a/(1-a) of the true
# PageRank vector — the drivers may differ by at most twice that bound, on
# any graph family (incl. the PR-8 powerlaw generator) and on streams that
# delete and reinsert edges.

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["uniform", "powerlaw"]), st.integers(0, 2 ** 10))
def test_push_pull_driver_equivalence(family, seed):
    from repro.api import EngineConfig, PageRankSession
    from repro.graphs.generators import powerlaw
    if family == "powerlaw":
        hg = powerlaw(200, avg_degree=5, seed=seed)
    else:
        hg = _graph(150, 600, seed)
    batches, cur = [], hg
    for i in range(2):
        dels, ins = random_batch(cur, 2e-2, seed=seed * 7 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    if cur.m:                               # delete + reinsert one edge
        e = np.array([[int(cur._keys[0] // cur.n),
                       int(cur._keys[0] % cur.n)]], np.int64)
        none = np.zeros((0, 2), np.int64)
        batches += [(e, none), (none, e)]
    tau, alpha = 1e-10, 0.85
    finals = {}
    for driver in ("pull", "push"):
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(engine="pallas", block_size=64,
                                    tau=tau, alpha=alpha, driver=driver))
        for dels, ins in batches:
            assert sess.update(dels, ins).converged, driver
        finals[driver] = np.asarray(sess.R[:hg.n]).copy()
        sess.close()
    bound = hg.n * tau * alpha / (1.0 - alpha)
    gap = float(np.abs(finals["push"] - finals["pull"]).max())
    assert gap < 2 * bound, (family, seed, gap)
