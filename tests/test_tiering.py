"""Tiered graph storage: host-paged cold tiles + device hot set.

Covers the PR-9 tentpole and its satellites through the public surface:

* tiered streams match the untiered pallas stream within the bounded
  sub-τ abandonment window, at full and fractional budgets;
* a budget far below the pool drains every batch through the refill loop
  with zero post-warmup retraces and no :class:`SweepCapWarning`;
* the capacity-ladder interaction: a grow-then-delete stream under a
  fixed budget evicts/invalidates correctly (no stale-block reads);
* counters, the ``report()`` memory audit (satellite: per-component
  device bytes + bytes/vertex), save/restore budget-independence, fork
  isolation, and the integrity scrubber's host-tier twin;
* the int32 index diet overflow guards and the chunked R-MAT builder's
  seed-reproducibility (satellites);
* the blocked oracle's :class:`EdgePager` parity + ``paged_snapshot``.
"""
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import EngineConfig, PageRankSession, SweepCapWarning
from repro.core import blocked as blk
from repro.core import tiering
from repro.core.graph import HostGraph
from repro.graphs.generators import grid_road, rmat

TAU = 1e-8
# the maxdr convergence escape abandons waves whose per-sweep change is
# <= tau, so two runs may differ by ~tau * alpha / (1 - alpha) ≈ 5.7 tau
ABANDON_TOL = 1e-6


def _pool_bytes(hg, block_size=64):
    g0 = hg.snapshot(block_size=block_size)
    src, dst = g0.in_edges_host()
    pool = tiering.HostTilePool.from_edges(
        dst, src, g0.n_pad, g0.n_pad, block=block_size,
        dtype=np.dtype(np.float32))
    return int(pool.nbytes)


def _cfg(budget=None, tau=TAU):
    return EngineConfig(engine="pallas", tau=tau, block_size=64,
                        dtype="float32", device_budget_bytes=budget)


def _local_stream(n, batches, k=16, seed=11, window=1024):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(batches):
        base = int(rng.integers(0, max(n - window, 1)))
        ins = base + rng.integers(0, min(window, n), (k, 2))
        out.append((np.zeros((0, 2), np.int64), ins))
    return out


def _run_stream(hg, cfg, stream):
    sess = PageRankSession.from_graph(hg, config=cfg)
    sess.warmup()
    stats = [sess.update(d, i).stats for d, i in stream]
    return sess, stats


# ---------------------------------------------------------------------------
# parity + drain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("frac", [1.0, 0.5])
def test_tiered_stream_matches_untiered(frac):
    hg = grid_road(32, seed=7)
    stream = _local_stream(hg.n, 3)
    budget = max(int(_pool_bytes(hg) * frac), 1)
    tiered, st_t = _run_stream(hg, _cfg(budget), stream)
    plain, st_p = _run_stream(hg, _cfg(None), stream)
    assert all(s.converged for s in st_t)
    assert all(s.converged for s in st_p)
    linf = float(np.max(np.abs(np.asarray(tiered.ranks)
                               - np.asarray(plain.ranks))))
    assert linf < ABANDON_TOL, linf
    rep = tiered.report()
    assert rep.tiering is not None
    assert rep.retraces_post_warmup == 0
    tiered.close(), plain.close()


def test_tight_budget_drains_without_sweep_cap():
    """A budget holding only a fraction of the pool must still converge
    every batch via the deferred-refill loop — no SweepCapWarning, no
    retraces, evictions actually exercised."""
    hg = grid_road(64, seed=7)
    stream = _local_stream(hg.n, 4, window=4096)
    budget = _pool_bytes(hg) // 2
    with warnings.catch_warnings():
        warnings.simplefilter("error", SweepCapWarning)
        sess, stats = _run_stream(hg, _cfg(budget), stream)
    assert all(s.converged for s in stats)
    rep = sess.report()
    t = rep.tiering
    assert t["refill_drives"] > 0          # deferrals happened and drained
    assert t["evictions"] > 0              # budget pressure was real
    assert t["resident_blocks"] * 0 == 0 and t["slab_bytes"] <= budget
    assert rep.retraces_post_warmup == 0
    assert rep.bucket_retraces_post_warmup == 0
    sess.close()


def test_counters_and_hit_rate_sane():
    hg = grid_road(32, seed=7)
    sess, _ = _run_stream(hg, _cfg(_pool_bytes(hg) // 2),
                          _local_stream(hg.n, 3))
    t = sess.report().tiering
    for key in ("hits", "misses", "evictions", "admitted_tiles",
                "transfer_bytes", "refill_drives", "refill_stalls"):
        assert t[key] >= 0, key
    assert t["hits"] + t["misses"] > 0
    assert 0.0 <= t["hit_rate"] <= 1.0
    assert t["transfer_bytes"] > 0         # gathers actually moved bytes
    assert t["slab_tiles"] * t["slab_bytes"] >= 0
    assert t["pool_bytes"] >= t["slab_bytes"]
    sess.close()


def test_budget_below_floor_raises():
    hg = grid_road(16, seed=0)
    with pytest.raises(ValueError, match="too small to make a single"):
        PageRankSession.from_graph(hg, config=_cfg(budget=64))


# ---------------------------------------------------------------------------
# capacity-ladder interaction (satellite): grow then delete under pressure
# ---------------------------------------------------------------------------

def test_capacity_ladder_shrink_and_eviction():
    """Grow-then-delete stream under a fixed budget: pool growth rewidens
    the slot tables while eviction cycles the slab; results must match the
    untiered run batch-for-batch (any stale-block read would diverge) and
    the driver must not retrace post-warmup."""
    hg = grid_road(32, seed=3)
    n = hg.n
    rng = np.random.default_rng(5)
    # growth phase: long-range inserts force fresh tiles (ladder growth);
    # shrink phase: delete exactly those edges again
    grow = [rng.integers(0, n, (24, 2)) for _ in range(3)]
    stream = [(np.zeros((0, 2), np.int64), g) for g in grow]
    stream += [(g, np.zeros((0, 2), np.int64)) for g in reversed(grow)]
    budget = _pool_bytes(hg) // 2
    tiered, st_t = _run_stream(hg, _cfg(budget), stream)
    plain, st_p = _run_stream(hg, _cfg(None), stream)
    assert all(s.converged for s in st_t)
    linf = float(np.max(np.abs(np.asarray(tiered.ranks)
                               - np.asarray(plain.ranks))))
    assert linf < ABANDON_TOL, linf
    rep = tiered.report()
    assert rep.retraces_post_warmup == 0
    assert rep.tiering["evictions"] > 0
    # the scrubber cross-checks slab tiles against host truth — a stale
    # resident block would fail the CRC here
    assert tiered.hot.scrub() == []
    tiered.close(), plain.close()


# ---------------------------------------------------------------------------
# memory audit (satellite)
# ---------------------------------------------------------------------------

def test_memory_audit_components_sane():
    hg = grid_road(32, seed=7)
    budget = _pool_bytes(hg) // 2
    sess, _ = _run_stream(hg, _cfg(budget), _local_stream(hg.n, 2))
    rep = sess.report()
    db = rep.device_bytes
    for comp in ("ranks", "tile_pool", "slot_tables", "operand_mirrors"):
        assert comp in db and db[comp] > 0, comp
    # the device tile pool is the bounded slab, not the host pool
    assert db["tile_pool"] <= budget
    assert db["tile_pool"] == rep.tiering["slab_bytes"]
    assert rep.bytes_per_vertex == pytest.approx(
        sum(db.values()) / sess.n)
    # untiered twin holds the whole pool on device
    plain, _ = _run_stream(hg, _cfg(None), _local_stream(hg.n, 2))
    assert plain.report().device_bytes["tile_pool"] > db["tile_pool"]
    sess.close(), plain.close()


# ---------------------------------------------------------------------------
# durability / fork / integrity
# ---------------------------------------------------------------------------

def test_save_restore_budget_independent(tmp_path):
    """Checkpoints serialize host truth: a session saved under one budget
    restores bit-identically under another (or untiered)."""
    hg = grid_road(32, seed=7)
    sess, _ = _run_stream(hg, _cfg(_pool_bytes(hg) // 2),
                          _local_stream(hg.n, 2))
    d = str(tmp_path / "ckpt")
    sess.save(d)
    ref = np.asarray(sess.ranks).copy()
    for cfg in (_cfg(_pool_bytes(hg)), _cfg(None)):
        back = PageRankSession.restore(d, config=cfg)
        np.testing.assert_array_equal(np.asarray(back.ranks), ref)
        # restored session must keep streaming under its new budget
        dels, ins = _local_stream(hg.n, 1, seed=99)[0]
        assert back.update(dels, ins).stats.converged
        back.close()
    sess.close()


def test_fork_isolated():
    hg = grid_road(32, seed=7)
    sess, _ = _run_stream(hg, _cfg(_pool_bytes(hg) // 2),
                          _local_stream(hg.n, 1))
    child = sess.fork()
    before = np.asarray(child.ranks).copy()
    dels, ins = _local_stream(hg.n, 1, seed=42)[0]
    sess.update(dels, ins)
    np.testing.assert_array_equal(np.asarray(child.ranks), before)
    assert child.update(dels, ins).stats.converged
    child.close(), sess.close()


def test_verify_scrubs_host_tier():
    """The integrity scrubber's checksum twin is the HOST tier: a tiered
    session must scrub clean through verify() (mass_tol relaxed to f32
    scale — the default is calibrated for f64 sessions)."""
    hg = grid_road(32, seed=7)
    cfg = EngineConfig(engine="pallas", tau=TAU, block_size=64,
                       dtype="float32",
                       device_budget_bytes=_pool_bytes(hg) // 2,
                       integrity={"mass_tol": 1e-4})
    sess, _ = _run_stream(hg, cfg, _local_stream(hg.n, 2))
    rep = sess.verify()
    assert rep.ok, rep
    assert rep.checks_run > 0
    sess.close()


# ---------------------------------------------------------------------------
# int32 index diet (satellite)
# ---------------------------------------------------------------------------

def test_snapshot_indices_are_int32():
    g = grid_road(16, seed=0).snapshot(block_size=64)
    for name in ("src", "dst", "osrc", "odst"):
        assert np.asarray(getattr(g, name)).dtype == np.int32, name


def test_snapshot_overflow_guard_fires_before_allocation():
    hg = grid_road(16, seed=0)
    with pytest.raises(OverflowError, match="padded edge capacity"):
        hg.snapshot(block_size=64, edge_capacity=2**31)
    # vertex-count guard: fabricate a too-wide HostGraph header without
    # materializing edges (the guard must fire before any allocation)
    wide = HostGraph.__new__(HostGraph)
    wide.n = 2**31
    wide._keys = np.zeros(0, np.int64)
    with pytest.raises(OverflowError, match="padded vertex count"):
        wide.snapshot(block_size=64)


# ---------------------------------------------------------------------------
# chunked R-MAT (satellite)
# ---------------------------------------------------------------------------

def test_rmat_chunked_matches_monolithic():
    for seed in (0, 5):
        mono = rmat(8, 4, seed=seed)
        for chunk in (64, 1000, 1 << 20):   # many chunks / ragged / single
            chunked = rmat(8, 4, seed=seed, chunk_edges=chunk)
            assert chunked.n == mono.n
            np.testing.assert_array_equal(chunked.edges, mono.edges)


def test_rmat_chunk_edges_validated():
    with pytest.raises(ValueError, match="chunk_edges"):
        rmat(6, 4, chunk_edges=0)


# ---------------------------------------------------------------------------
# EdgePager: the blocked oracle's paged twin
# ---------------------------------------------------------------------------

def test_edge_pager_parity_exact():
    """Paged run_blocked must equal the unpaged run bitwise — the pager
    relocates slices, it never changes them."""
    hg = rmat(8, 4, seed=3)
    g = hg.snapshot(block_size=64)
    R0 = jnp.full((g.n_pad,), np.float32(1.0 / g.n))
    for mode in ("lf", "bb"):
        base, st0 = blk.run_blocked(g, R0, g.vertex_valid, mode=mode,
                                    tau=TAU, active_policy="rc")
        pager = tiering.EdgePager(g, budget_bytes=1 << 26)
        paged, st1 = blk.run_blocked(
            tiering.paged_snapshot(g), R0, g.vertex_valid, mode=mode,
            tau=TAU, active_policy="rc", pager=pager)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(paged))
        assert st1.converged == st0.converged
        assert pager.counters["misses"] > 0


def test_edge_pager_repack_and_slab_content():
    """Drive the repack path directly: a slab sized for half the blocks is
    cycled between two disjoint working sets.  Staged slab slices must
    equal the host CSR slices (address translation only, never content)."""
    g = rmat(8, 4, seed=3).snapshot(block_size=64)
    in_ptr = np.asarray(g.in_block_ptr, np.int64)
    out_ptr = np.asarray(g.out_block_ptr, np.int64)
    sizes = np.maximum(np.diff(in_ptr), np.diff(out_ptr))  # staging need
    floor = int((np.diff(in_ptr) + np.diff(out_ptr)).max())  # ctor floor
    n_blk = len(sizes)
    half = np.arange(n_blk // 2)
    rest = np.arange(n_blk // 2, n_blk)
    budget = (int(max(sizes[half].sum(), sizes[rest].sum(),
                      floor + 1)) + 8) * 16
    pager = tiering.EdgePager(g, budget_bytes=budget)

    def check(ids):
        pager.ensure(ids)
        src = np.asarray(g.src)
        for b in ids.tolist():
            lo, ln = int(pager._in_lo[b]), int(pager._in_len[b])
            np.testing.assert_array_equal(
                pager._hsrc[lo:lo + ln], src[in_ptr[b]:in_ptr[b + 1]])

    check(half)
    check(half)                 # all resident: pure hits
    assert pager.counters["hits"] > 0
    check(rest)                 # evicts the first set (repack)
    check(half)                 # and back
    assert pager.counters["repacks"] >= 1
    assert pager.counters["evictions"] >= 1
    # a want set that cannot fit even alone raises with the sizing rule
    with pytest.raises(ValueError, match="does not fit the edge slab"):
        pager.ensure(np.arange(n_blk))


def test_edge_pager_budget_floor_raises():
    g = rmat(7, 4, seed=1).snapshot(block_size=64)
    with pytest.raises(ValueError, match="raise the budget"):
        tiering.EdgePager(g, budget_bytes=16)
