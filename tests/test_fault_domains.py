"""Unified fault domains: thread/shard/process recovery paths.

The acceptance bars (ISSUE 5 / docs/FAULTS.md):

* **shard** — an 8-shard DF stream loses one shard mid-batch and converges
  to blocked-oracle parity via shard helping (+ elastic re-partition on
  permanent loss), with the recovery visible in ``report()``;
* **process** — a SIGKILLed subprocess running a durable streaming session
  restores from its store, replays the WAL through the zero-retrace hot
  path, and matches the uninterrupted session's ranks **bit-for-bit** with
  zero post-restore retraces;
* every corruption mode of the store (checksum-broken checkpoint leaf,
  truncated WAL tail, crash between checkpoint and the next WAL append,
  restore onto a different device count) recovers to parity with an
  uninterrupted oracle session.
"""
import os
import select
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.api import (EngineConfig, PageRankService, PageRankSession,
                       ServingConfig, SessionStore, ShardFaultDomain,
                       ThreadFaultDomain)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.faults import FaultPlan
from repro.graphs.generators import kmer_chains

BLOCK = 64
N_BATCHES = 6


def _graph():
    return kmer_chains(1 << 10, seed=4)


def _r0(hg):
    return jnp.asarray(pr.numpy_reference(hg.snapshot(block_size=BLOCK),
                                          iterations=300))


def _batches(hg, k=N_BATCHES):
    """Deterministic update stream (same seeds in subprocess scripts)."""
    out, cur = [], hg
    for i in range(k):
        dels, ins = random_batch(cur, 5e-3, seed=100 + i)
        out.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    return out, cur


def _oracle_ranks(hg, r0, batches):
    """Per-batch converged ranks of an uninterrupted pallas session."""
    sess = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=BLOCK), r0=r0)
    out = []
    for dels, ins in batches:
        res = sess.update(dels, ins)
        assert res.stats.converged
        out.append(np.asarray(sess.R).copy())
    return out


@pytest.fixture(scope="module")
def setup():
    hg = _graph()
    r0 = _r0(hg)
    batches, hg_final = _batches(hg)
    oracle = _oracle_ranks(hg, r0, batches)
    return hg, r0, batches, hg_final, oracle


def _durable_cfg(**kw):
    base = dict(engine="pallas", block_size=BLOCK, durability="wal",
                checkpoint_interval=3)
    base.update(kw)
    return EngineConfig.from_kwargs(**base)


# ---------------------------------------------------------------------------
# config / domain validation
# ---------------------------------------------------------------------------

class TestConfigAxis:
    def test_durability_validated(self):
        with pytest.raises(ValueError, match="durability"):
            EngineConfig(durability="paxos")
        with pytest.raises(ValueError, match="checkpoint_interval"):
            EngineConfig(checkpoint_interval=0)

    def test_fault_domain_type_checked(self):
        with pytest.raises(ValueError, match="fault_domain"):
            EngineConfig(fault_domain=object())

    def test_faults_and_thread_domain_exclusive(self):
        plan = FaultPlan(n_threads=4)
        with pytest.raises(ValueError, match="mutually exclusive"):
            EngineConfig(faults=plan,
                         fault_domain=ThreadFaultDomain(plan))

    def test_shard_domain_needs_sharded_topology(self):
        with pytest.raises(ValueError, match="sharded"):
            EngineConfig(fault_domain=ShardFaultDomain())

    def test_thread_domain_rejected_on_sharded_topology(self):
        with pytest.raises(ValueError, match="ShardFaultDomain"):
            EngineConfig(topology="sharded", n_shards=1,
                         fault_domain=ThreadFaultDomain(
                             FaultPlan(n_threads=4)))

    def test_durable_session_needs_store_dir(self):
        with pytest.raises(ValueError, match="store_dir"):
            PageRankSession.from_graph(_graph(), config=_durable_cfg())

    def test_thread_domain_equals_legacy_faults(self):
        """fault_domain=ThreadFaultDomain(plan) is faults=plan under the
        domain interface — bit-identical sweep results."""
        hg = _graph()
        plan = FaultPlan(n_threads=8, n_crashed=2, crash_window=4, seed=5)
        dels, ins = random_batch(hg, 5e-3, seed=7)
        a = PageRankSession.from_graph(
            hg, config=EngineConfig(engine="blocked", block_size=BLOCK,
                                    faults=plan))
        b = PageRankSession.from_graph(
            hg, config=EngineConfig(engine="blocked", block_size=BLOCK,
                                    fault_domain=ThreadFaultDomain(plan)))
        ra = a.update(dels, ins)
        rb = b.update(dels, ins)
        assert ra.stats.converged and rb.stats.converged
        np.testing.assert_array_equal(np.asarray(a.R), np.asarray(b.R))

    def test_inject_shard_fault_requires_sharded(self, setup):
        hg, r0, *_ = setup
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(engine="pallas", block_size=BLOCK),
            r0=r0)
        with pytest.raises(ValueError, match="sharded"):
            sess.inject_shard_fault(0)

    def test_shard_fault_range_validated_at_injection(self, setup):
        """An out-of-mesh shard id must fail at inject/construction time,
        never mid-update (the batch would already be half-applied)."""
        hg, r0, *_ = setup
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(topology="sharded", n_shards=1), r0=r0)
        with pytest.raises(ValueError, match="out of range"):
            sess.inject_shard_fault(5)
        from repro.api import ShardFault
        with pytest.raises(ValueError, match="outside"):
            PageRankSession.from_graph(
                hg, config=EngineConfig(
                    topology="sharded", n_shards=1,
                    fault_domain=ShardFaultDomain([ShardFault(7)])), r0=r0)

    def test_permanent_fault_on_last_shard_degrades_to_transient(
            self, setup):
        """Losing the ONLY shard permanently cannot re-partition — the
        consumed fault degrades to a transient stall instead of raising
        mid-update (the batch is already applied at that point)."""
        hg, r0, batches, *_ = setup
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(topology="sharded", n_shards=1), r0=r0)
        sess.inject_shard_fault(0, permanent=True)
        res = sess.update(*batches[0])
        assert res.stats.converged
        rep = sess.report()
        assert rep.recoveries == 1
        assert rep.recovery_events[0]["permanent"] is False  # degraded
        assert rep.n_shards == 1

    def test_config_shared_schedule_is_cloned_per_session(self, setup):
        """Two sessions sharing one config must each consume their own
        copy of the domain's fault schedule, not steal from a shared
        list."""
        hg, r0, batches, *_ = setup
        from repro.api import ShardFault
        cfg = EngineConfig(topology="sharded", n_shards=1,
                           fault_domain=ShardFaultDomain(
                               [ShardFault(0, permanent=False)]))
        a = PageRankSession.from_graph(hg, config=cfg, r0=r0)
        b = PageRankSession.from_graph(hg, config=cfg, r0=r0)
        assert a.update(*batches[0]).stats.converged
        assert b.update(*batches[0]).stats.converged
        assert a.report().recoveries == 1
        assert b.report().recoveries == 1      # not stolen by session a


# ---------------------------------------------------------------------------
# checkpoint-store corruption handling (satellite: ckpt fixes)
# ---------------------------------------------------------------------------

class TestStoreCorruption:
    def test_restore_latest_skips_corrupt_leaf(self, tmp_path):
        from repro.ckpt.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path))
        p1 = {"w": np.arange(8.0)}
        p2 = {"w": np.arange(8.0) * 3}
        ck.save(p1, {}, 1)
        d2 = ck.save(p2, {}, 2)
        victim = [f for f in os.listdir(d2) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(d2, victim))
        np.save(os.path.join(d2, victim), arr + 1)   # flip the bits
        got = ck.restore_latest({"w": np.zeros(0)}, {})
        assert got is not None and got[2] == 1       # fell back to step 1
        np.testing.assert_array_equal(np.asarray(got[0]["w"]), p1["w"])

    def test_restore_latest_skips_unreadable_manifest(self, tmp_path):
        from repro.ckpt.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path))
        ck.save({"w": np.ones(3)}, {}, 5)
        ck.save({"w": np.ones(3) * 2}, {}, 6)
        with open(os.path.join(str(tmp_path), "step_00000006",
                               "manifest.json"), "w") as f:
            f.write("{not json")
        got = ck.restore_latest({"w": np.zeros(0)}, {})
        assert got[2] == 5

    def test_restore_latest_none_when_all_corrupt(self, tmp_path):
        from repro.ckpt.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path))
        d = ck.save({"w": np.ones(3)}, {}, 1)
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{")
        assert ck.restore_latest({"w": np.zeros(0)}, {}) is None

    def test_save_sweeps_orphaned_tmp_dirs(self, tmp_path):
        from repro.ckpt.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path))
        os.makedirs(os.path.join(str(tmp_path), "step_00000042.tmp"))
        ck.save({"w": np.ones(2)}, {}, 1)
        leftovers = [d for d in os.listdir(str(tmp_path))
                     if d.endswith(".tmp")]
        assert leftovers == []


# ---------------------------------------------------------------------------
# process fault domain: crash → restore parity (satellite: recovery paths)
# ---------------------------------------------------------------------------

class TestProcessRecovery:
    def _durable(self, tmp_path, hg, r0, **cfg_kw):
        return PageRankSession.from_graph(
            hg, config=_durable_cfg(**cfg_kw), r0=r0,
            store_dir=str(tmp_path / "store"))

    def test_restore_replays_wal_to_parity(self, tmp_path, setup):
        hg, r0, batches, _, oracle = setup
        sess = self._durable(tmp_path, hg, r0)     # ckpt every 3 batches
        for dels, ins in batches[:5]:
            sess.update(dels, ins)
        del sess                                    # crash-stop
        rest = PageRankSession.restore(str(tmp_path / "store"))
        rep = rest.report()
        assert rep.recoveries == 1
        assert rep.replayed_batches == 2            # ckpt@3 + WAL 4..5
        assert rep.recovery_time_s > 0
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[4])

    def test_corrupt_checkpoint_leaf_falls_back_and_replays(
            self, tmp_path, setup):
        """A checksum-broken newest checkpoint must not strand the store:
        restore falls back to the previous valid step and replays the
        longer WAL suffix to the same final state."""
        hg, r0, batches, _, oracle = setup
        sess = self._durable(tmp_path, hg, r0, checkpoint_interval=2)
        for dels, ins in batches[:4]:
            sess.update(dels, ins)                  # ckpts at 2 and 4
        del sess
        store = SessionStore(str(tmp_path / "store"))
        d = os.path.join(store.ckpt.dir, "step_00000004")
        victim = [f for f in os.listdir(d) if f.startswith(
            "params__ranks")][0]
        arr = np.load(os.path.join(d, victim))
        np.save(os.path.join(d, victim), arr + 1e-3)
        rest = PageRankSession.restore(str(tmp_path / "store"))
        assert rest.report().replayed_batches == 2  # ckpt@2 + WAL 3..4
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[3])

    def test_truncated_wal_tail_replays_valid_prefix(self, tmp_path, setup):
        """Bytes chopped off the WAL (the crash-mid-append case) drop only
        the torn record: restore lands on the last durable batch."""
        hg, r0, batches, _, oracle = setup
        sess = self._durable(tmp_path, hg, r0, checkpoint_interval=100)
        for dels, ins in batches[:4]:
            sess.update(dels, ins)
        del sess
        store = SessionStore(str(tmp_path / "store"))
        assert store.wal_tip() == 4
        sz = os.path.getsize(store.wal_path)
        with open(store.wal_path, "rb+") as f:
            f.truncate(sz - 11)                     # tear the last record
        assert store.wal_tip() == 3
        rest = PageRankSession.restore(str(tmp_path / "store"))
        assert rest.report().replayed_batches == 3  # ckpt@0 + WAL 1..3
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[2])

    def test_kill_between_checkpoint_and_wal_append(self, tmp_path, setup):
        """Crash after the interval checkpoint but before the next batch's
        WAL append: restore = that checkpoint, zero replays, parity."""
        hg, r0, batches, _, oracle = setup
        sess = self._durable(tmp_path, hg, r0, checkpoint_interval=3)
        for dels, ins in batches[:3]:
            sess.update(dels, ins)     # WAL 1..3 then ckpt@3; nothing after
        del sess
        rest = PageRankSession.restore(str(tmp_path / "store"))
        assert rest.report().replayed_batches == 0
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[2])
        # the stream continues durably from the restored state
        dels, ins = batches[3]
        rest.update(dels, ins)
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[3])

    def test_save_and_restore_without_wal(self, tmp_path, setup):
        """save(dir) is the one-shot durability path for non-durable
        sessions: restore reopens at the save point (no WAL to replay)."""
        hg, r0, batches, _, oracle = setup
        sess = PageRankSession.from_graph(
            hg, config=EngineConfig(engine="pallas", block_size=BLOCK),
            r0=r0)
        for dels, ins in batches[:2]:
            sess.update(dels, ins)
        path = sess.save(str(tmp_path / "snap"))
        assert os.path.exists(path)
        rest = PageRankSession.restore(str(tmp_path / "snap"))
        assert rest.config.durability == "none" and rest.store is None
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[1])

    def test_rejected_batch_rolls_back_wal(self, tmp_path, setup,
                                           monkeypatch):
        """A batch the session REFUSES must not survive in the WAL.
        Two rejection points: a *validation* failure (out-of-range id)
        raises BEFORE the append — no record is ever written; an
        in-process failure AFTER the append (forced here, since
        validation now front-runs the block-grid check) revokes its
        record.  Either way a later restore replays only batches that
        became state."""
        hg, r0, batches, _, oracle = setup
        sess = self._durable(tmp_path, hg, r0, checkpoint_interval=100)
        sess.update(*batches[0])
        store = SessionStore(str(tmp_path / "store"))
        assert store.wal_tip() == 1
        bad_ins = np.array([[sess.n_pad + 3, 0]], np.int64)
        with pytest.raises(ValueError, match="out-of-range"):
            sess.update(np.zeros((0, 2), np.int64), bad_ins)
        assert store.wal_tip() == 1          # rejected pre-append
        real = type(sess)._update_stream

        def _boom(self, *a, **k):
            raise RuntimeError("device fell over mid-apply")
        monkeypatch.setattr(type(sess), "_update_stream", _boom)
        with pytest.raises(RuntimeError, match="mid-apply"):
            sess.update(*batches[1])
        assert store.wal_tip() == 1          # the bad record was revoked
        monkeypatch.setattr(type(sess), "_update_stream", real)
        sess.update(*batches[1])             # the stream continues durably
        del sess
        rest = PageRankSession.restore(str(tmp_path / "store"))
        assert rest.report().replayed_batches == 2
        np.testing.assert_array_equal(np.asarray(rest.R), oracle[1])

    def test_fresh_durable_session_rejects_populated_store(
            self, tmp_path, setup):
        """Opening a NEW durable session on a dir that already holds one
        must fail — interleaving two sessions' logs corrupts both; the
        populated store is reopened via restore()."""
        hg, r0, batches, _, _ = setup
        sess = self._durable(tmp_path, hg, r0)
        sess.update(*batches[0])
        sess.close()
        with pytest.raises(ValueError, match="already holds a session"):
            self._durable(tmp_path, hg, r0)
        rest = PageRankSession.restore(str(tmp_path / "store"))
        assert rest._batch_index == 1

    def test_process_domain_rejected_as_config_axis(self, tmp_path):
        from repro.core.fault_domain import ProcessFaultDomain
        dom = ProcessFaultDomain(SessionStore(str(tmp_path / "s")),
                                 checkpoint_interval=4)
        with pytest.raises(ValueError, match="durability"):
            EngineConfig(fault_domain=dom)

    def test_recompute_on_durable_session_checkpoints(
            self, tmp_path, setup):
        """recompute() replaces served ranks outside the WAL batch stream
        — a durable session must checkpoint it, or restore() would serve
        the pre-recompute vector."""
        hg, r0, batches, _, _ = setup
        sess = self._durable(tmp_path, hg, r0, checkpoint_interval=100)
        sess.update(*batches[0])
        sess.recompute("static")
        served = np.asarray(sess.R).copy()
        del sess                                # crash-stop
        rest = PageRankSession.restore(str(tmp_path / "store"))
        np.testing.assert_array_equal(np.asarray(rest.R), served)

    def test_fork_detaches_from_store(self, tmp_path, setup):
        hg, r0, batches, _, _ = setup
        sess = self._durable(tmp_path, hg, r0)
        sess.update(*batches[0])
        twin = sess.fork()
        assert twin.store is None and twin.store_dir is None
        store = SessionStore(str(tmp_path / "store"))
        tip = store.wal_tip()
        twin.update(*batches[1])            # must NOT touch the parent WAL
        assert store.wal_tip() == tip


# ---------------------------------------------------------------------------
# acceptance: SIGKILL a durable subprocess, restore bit-for-bit
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import sys, time
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import kmer_chains

    store_dir = sys.argv[1]
    hg = kmer_chains(1 << 10, seed=4)
    r0 = jnp.asarray(pr.numpy_reference(hg.snapshot(block_size=64),
                                        iterations=300))
    cfg = EngineConfig(engine="pallas", block_size=64, durability="wal",
                       checkpoint_interval=100)
    sess = PageRankSession.from_graph(hg, config=cfg, r0=r0,
                                      store_dir=store_dir)
    cur = hg
    for i in range(6):
        dels, ins = random_batch(cur, 5e-3, seed=100 + i)
        if i == 4:
            print("READY", flush=True)      # parent SIGKILLs us here
            time.sleep(120)
        sess.update(dels, ins)
        cur = cur.apply_batch(dels, ins)
""")


@pytest.mark.slow
def test_sigkill_restore_bit_for_bit(tmp_path, setup):
    """The process-domain acceptance bar: SIGKILL a subprocess mid-stream,
    restore its durable session, replay the WAL, finish the stream — the
    final ranks match the uninterrupted session bit-for-bit and the
    post-restore updates pay zero retraces."""
    hg, r0, batches, _, oracle = setup
    store_dir = str(tmp_path / "store")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # child stderr goes to a file: an undrained stderr PIPE could fill and
    # deadlock a chatty child against our stdout readline
    with open(tmp_path / "child-stderr.log", "w+") as err:
        child = subprocess.Popen(
            [sys.executable, "-c", _CHILD, store_dir], env=env,
            stdout=subprocess.PIPE, stderr=err, text=True)
        try:
            line = ""
            deadline = time.time() + 300
            while "READY" not in line:
                assert time.time() < deadline, "child never became READY"
                # select-gate so a silently hung child hits the deadline
                # instead of blocking readline forever
                ready, _, _ = select.select([child.stdout], [], [], 5.0)
                line = child.stdout.readline() if ready else ""
                if line == "" and child.poll() is not None:
                    err.seek(0)
                    raise AssertionError(
                        f"child died early: {err.read()[-2000:]}")
            os.kill(child.pid, signal.SIGKILL)     # crash-stop, no cleanup
            child.wait(timeout=60)
        finally:
            if child.poll() is None:
                child.kill()

    rest = PageRankSession.restore(store_dir)
    rep = rest.report()
    assert rep.recoveries == 1
    assert rep.replayed_batches == 4           # WAL held batches 1..4
    assert rep.recovery_events[0]["domain"] == "process"
    np.testing.assert_array_equal(np.asarray(rest.R), oracle[3])
    for dels, ins in batches[4:]:              # finish the stream here
        res = rest.update(dels, ins)
        assert res.stats.converged
    np.testing.assert_array_equal(np.asarray(rest.R), oracle[-1])
    assert rest.report().retraces_post_warmup == 0


# ---------------------------------------------------------------------------
# acceptance: 8-shard stream loses a shard mid-batch (helping recovery)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import rmat

    assert len(jax.devices()) == 8
    hg0 = rmat(10, avg_degree=6, seed=3)
    r0 = jnp.asarray(pr.numpy_reference(hg0.snapshot(block_size=64),
                                        iterations=300))
    batches, cur = [], hg0
    for i in range(6):
        dels, ins = random_batch(cur, 2e-3, seed=900 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)

    oracle = PageRankSession.from_graph(
        hg0, config=EngineConfig(engine="blocked"), r0=r0)
    oracle_ranks = []
    for dels, ins in batches:
        assert oracle.update(dels, ins).stats.converged
        oracle_ranks.append(oracle.ranks[:oracle.n].copy())

    sess = PageRankSession.from_graph(
        hg0, config=EngineConfig(topology="sharded", n_shards=8), r0=r0)
    sess.warmup()
    for i in range(2):
        assert sess.update(*batches[i]).stats.converged
        err = float(np.max(np.abs(sess.ranks[:sess.n] - oracle_ranks[i])))
        assert err < 1e-9, (i, err)

    # kill shard 3 mid-batch (after 2 sweeps of batch 3's drive):
    # the survivors pick up its un-converged row-blocks (helping) and the
    # mesh elastically re-partitions to 7 shards
    sess.inject_shard_fault(3, at_sweep=2, permanent=True)
    res = sess.update(*batches[2])
    assert res.stats.converged
    err = float(np.max(np.abs(sess.ranks[:sess.n] - oracle_ranks[2])))
    assert err < 1e-9, err
    rep = sess.report()
    assert rep.recoveries == 1
    ev = rep.recovery_events[0]
    assert ev["domain"] == "shard" and ev["shard"] == 3
    assert ev["permanent"] is True
    assert ev["helped_vertices"] > 0 and ev["recovery_sweeps"] > 0
    assert ev["wall_time_s"] > 0
    assert rep.n_shards == 7
    assert sess.device_footprint == tuple(
        d for d in range(8) if d != 3)

    # the stream continues recompile-free on the shrunken mesh, and a
    # transient stall (non-permanent) also recovers without re-partition
    for i in range(3, 5):
        assert sess.update(*batches[i]).stats.converged
        err = float(np.max(np.abs(sess.ranks[:sess.n] - oracle_ranks[i])))
        assert err < 1e-9, (i, err)
    sess.inject_shard_fault(2, at_sweep=1, permanent=False)
    assert sess.update(*batches[5]).stats.converged
    err = float(np.max(np.abs(sess.ranks[:sess.n] - oracle_ranks[5])))
    assert err < 1e-9, err
    rep = sess.report()
    assert rep.recoveries == 2
    assert rep.recovery_events[1]["permanent"] is False
    assert rep.n_shards == 7          # transient stall does not shrink

    # a fault made STALE by the earlier shrink (shard 7 no longer exists
    # on the 7-shard mesh) is dropped at consumption, never raised
    # mid-update — inject before the shrink would have been required, so
    # reach into the schedule directly to simulate the race
    from repro.api import ShardFault
    sess._shard_faults._pending.append(ShardFault(7, permanent=True))
    dels, ins = random_batch(cur, 2e-3, seed=990)
    assert sess.update(dels, ins).stats.converged
    rep = sess.report()
    assert rep.recoveries == 2        # stale fault recorded nothing
    assert rep.n_shards == 7
    print("SHARD-HELPING-OK")
""")


@pytest.mark.multidevice
def test_shard_crash_helping_8dev():
    """The shard-domain acceptance bar (subprocess with 8 forced host
    devices — the XLA device count is locked at first jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD-HELPING-OK" in out.stdout


# ---------------------------------------------------------------------------
# elastic rescale: restore onto a different device count
# ---------------------------------------------------------------------------

_RESCALE_SCRIPT = textwrap.dedent("""
    import tempfile
    import numpy as np
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import rmat

    assert len(jax.devices()) == 4
    hg0 = rmat(9, avg_degree=6, seed=3)
    r0 = jnp.asarray(pr.numpy_reference(hg0.snapshot(block_size=64),
                                        iterations=300))
    batches, cur = [], hg0
    for i in range(4):
        dels, ins = random_batch(cur, 2e-3, seed=700 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)

    oracle = PageRankSession.from_graph(
        hg0, config=EngineConfig(engine="blocked"), r0=r0)
    for dels, ins in batches:
        assert oracle.update(dels, ins).stats.converged
    ref = oracle.ranks[:oracle.n]

    store = tempfile.mkdtemp()
    cfg4 = EngineConfig(topology="sharded", n_shards=4, durability="wal",
                        checkpoint_interval=3)    # ckpt@3, WAL replays 4
    sess = PageRankSession.from_graph(hg0, config=cfg4, r0=r0,
                                      store_dir=store)
    for dels, ins in batches:
        assert sess.update(dels, ins).stats.converged
    del sess                                     # crash-stop

    # restore the 4-shard store onto a 2-shard mesh (elastic rescale) ...
    rest2 = PageRankSession.restore(store, config=cfg4.replace(n_shards=2))
    rep = rest2.report()
    assert rep.n_shards == 2 and rep.replayed_batches == 1
    err = float(np.max(np.abs(rest2.ranks[:rest2.n] - ref)))
    assert err < 1e-9, ("2-shard", err)
    rest2.close()

    # ... and onto a single device (topology change), same WAL replay
    rest1 = PageRankSession.restore(
        store, config=EngineConfig(engine="blocked", block_size=64))
    err = float(np.max(np.abs(rest1.ranks[:rest1.n] - ref)))
    assert err < 1e-9, ("single", err)
    print("RESCALE-OK")
""")


@pytest.mark.multidevice
def test_restore_elastic_rescale_4_to_2_and_1():
    """Process-domain restore onto a different device count: a 4-shard
    durable session's store restores as a 2-shard session and as a
    single-device session, both replaying the same WAL to oracle parity."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _RESCALE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESCALE-OK" in out.stdout


# ---------------------------------------------------------------------------
# service failover
# ---------------------------------------------------------------------------

def test_service_failover_respawns_from_store(tmp_path, setup):
    hg, r0, batches, _, oracle = setup
    durable = PageRankSession.from_graph(
        hg, config=_durable_cfg(), r0=r0,
        store_dir=str(tmp_path / "slot0"))
    other = PageRankSession.from_graph(
        hg, config=EngineConfig(engine="pallas", block_size=BLOCK), r0=r0)
    # coalesce=False: the bit-for-bit oracle below needs the WAL to hold
    # the same 3-batch sequence it replays against
    svc = PageRankService([durable, other], warmup=False,
                          serving=ServingConfig(coalesce=False))
    for i in range(3):
        svc.submit(0, *batches[i])
        svc.submit(1, *batches[i])
    svc.run_until_drained()

    durable.close()                      # the slot dies
    with pytest.raises(ValueError, match="closed"):
        svc.submit(0, *batches[3])
    with pytest.raises(ValueError, match="still live"):
        svc.failover(1)                  # live slots are not replaced
    other.close()
    with pytest.raises(ValueError, match="no durable store"):
        svc.failover(1)                  # non-durable slot cannot respawn

    row = svc.failover(0)
    assert row["restored_batch_index"] == 3
    assert row["recovery_time_s"] > 0
    # respawned slot catches up and keeps serving the same stream index
    svc.submit(0, *batches[3])
    svc.run_until_drained()
    np.testing.assert_array_equal(np.asarray(svc.sessions[0].R), oracle[3])
    rep = svc.report()
    assert rep["failovers"] and rep["failovers"][0]["stream"] == 0
    assert rep["sessions"][0]["durability"] == "wal"
    assert rep["sessions"][0]["recoveries"] == 1

    with pytest.raises(ValueError, match="still live"):
        svc.failover(0)
