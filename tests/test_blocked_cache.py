"""jit-cache discipline of the blocked engine: traced hyperparameters and
the bounded slot-capacity ladder."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import blocked as blk
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.frontier import batch_to_device
from repro.graphs.generators import rmat


def test_slot_capacity_ladder():
    assert blk.slot_buckets(100) == (16, 64, 100)
    assert blk.slot_buckets(8) == (8,)
    assert blk.slot_buckets(16) == (16,)
    assert blk.slot_capacity(1, 100) == 16
    assert blk.slot_capacity(17, 100) == 64
    assert blk.slot_capacity(65, 100) == 100     # clamped to n_blocks
    assert blk.slot_capacity(100, 100) == 100
    # capacity shrinks when the frontier shrinks
    assert blk.slot_capacity(70, 100) > blk.slot_capacity(10, 100)
    # every reachable capacity is on the ladder → cache entries bounded
    for n_act in range(1, 101):
        assert blk.slot_capacity(n_act, 100) in blk.slot_buckets(100)


def test_tau_alpha_sweep_hits_one_cache_entry():
    """α/τ/τ_f are traced operands on sweep(): a hyperparameter sweep must
    not add jit cache entries beyond the first compilation."""
    hg = rmat(9, avg_degree=6, seed=2)
    g = hg.snapshot(block_size=64)
    r0 = jnp.asarray(pr.numpy_reference(g, iterations=200))
    dels, ins = random_batch(hg, 5e-3, seed=4)
    hg1 = hg.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    batch = batch_to_device(g1, dels, ins)

    pr.df_pagerank(g, g1, batch, r0, mode="lf", engine="blocked", tau=1e-8)
    before = blk.sweep._cache_size()
    for tau in (1e-9, 1e-10, 3e-10):
        for alpha in (0.85, 0.9):
            res = pr.df_pagerank(g, g1, batch, r0, mode="lf",
                                 engine="blocked", tau=tau, alpha=alpha)
            assert res.converged
    after = blk.sweep._cache_size()
    # new entries may only come from new K buckets, never hyperparameters;
    # the warm-up run already visited this run's K ladder
    assert after == before


def test_cache_entries_bounded_by_ladder():
    """A full static run (frontier decays from all blocks to none) may
    compile at most one sweep per ladder bucket."""
    hg = rmat(10, avg_degree=4, seed=5)
    g = hg.snapshot(block_size=64)            # 16 blocks → ladder (16,)
    n_ladder = len(blk.slot_buckets(g.n_blocks))
    before = blk.sweep._cache_size()
    res = pr.static_pagerank(g, mode="lf", engine="blocked", tau=1e-10)
    assert res.converged
    added = blk.sweep._cache_size() - before
    assert added <= n_ladder
