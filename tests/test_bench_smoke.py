"""Tier-1 wiring for the per-engine smoke benchmark (non-failing step).

Runs ``benchmarks.run.smoke`` and sanity-checks the written
``BENCH_smoke.json``.  Infrastructure failures skip rather than fail — the
point is to *record* the perf trajectory on every tier-1 run, not to gate
on container wall-clock — but correctness claims inside a successful run
(convergence, frontier-proportionality of the Pallas engine) do assert.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.mark.slow
def test_smoke_report():
    from benchmarks.run import smoke, SMOKE_OUT
    try:
        report = smoke()
    except Exception as e:          # non-failing step: record, don't gate
        pytest.skip(f"smoke benchmark infrastructure failed: {e!r}")
    assert os.path.exists(SMOKE_OUT)
    with open(SMOKE_OUT) as f:
        on_disk = json.load(f)
    assert on_disk["engines"].keys() == report["engines"].keys()

    m = report["graph"]["m"]
    for engine, row in report["engines"].items():
        assert row["converged"], engine
        assert row["sweeps"] > 0 and row["edges_processed"] > 0, engine
        assert row["linf_vs_reference"] < 1e-8, engine
    # the acceptance signal: the fused Pallas engine does
    # frontier-proportional work — a small batch costs ≪ one full-graph
    # pass per sweep (dense, by construction, pays m per sweep: ratio 1.0)
    assert report["engines"]["pallas"]["frontier_work_ratio"] < 0.5
    assert report["engines"]["dense"]["frontier_work_ratio"] >= 0.99
    # wall-clock numbers (pallas-vs-blocked ratio, per-batch latency
    # flatness) are *recorded*, not asserted — tier-1 must not gate on
    # container timing (see module docstring); the deterministic streaming
    # acceptance signals below do assert
    stream = report["stream"]
    sizes = list(stream["sizes"].values())
    assert len(sizes) >= 2
    for row in sizes:
        assert row["retraces_post_warmup"] == 0, row
        assert row["p50_ms"] > 0
        assert row["linf_vs_reference"] < 1e-8, row
        # the ISSUE 10 push acceptance: the residual forward-push driver
        # does ≥5× less edge work than the pull driver on the same stream
        # at equal L∞ (same 1e-8 oracle-parity bar) with zero post-warmup
        # retraces on its own jit cache.  Edge counts are deterministic —
        # a structural gate, not a timing one; the p50 delta next to it is
        # recorded, not asserted (container wall-clock).
        push = row["push"]
        assert push["retraces_post_warmup"] == 0, push
        assert push["linf_vs_reference"] < 1e-8, push
        assert push["edges_processed"] > 0
        assert row["edges_ratio_pull_over_push"] >= 5.0, row
    # the service scenario (N concurrent sessions with concurrent query
    # clients): every session must drain its batches with zero post-warmup
    # retraces (the jit caches are shared across sessions), serve accurate
    # ranks, and the degraded-mode reads must be recorded with a staleness
    # bound
    service = report["service"]
    assert service["n_sessions"] >= 2
    assert service["requests_done"] == (service["n_sessions"]
                                        * service["batches_per_session"])
    assert service["requests_queued"] == 0
    assert service["request_p50_ms"] > 0
    for row in service["sessions"]:
        assert row["retraces_post_warmup"] == 0, row
        assert row["n_updates"] == service["batches_per_session"], row
        assert row["sweep_cap_hits"] == 0, row
    assert service["linf_vs_reference_max"] < 1e-8
    q = service["queries"]
    assert q["served"] > 0              # queries ran alongside the drain
    assert q["p50_ms"] > 0 and q["p95_ms"] >= q["p50_ms"]
    assert q["staleness_max_s"] >= 0.0
    # the staleness budget is a bound, not a suggestion: proactive snapshot
    # refresh (ServingConfig.snapshot_refresh_frac) must keep p95 inside it
    assert q["staleness_p95_s"] <= service["serving"]["staleness_budget_s"], q
    # the serve_load scenario (PR-6 overload acceptance): bounded queues
    # shed at 2x overload instead of growing, continuous dispatch bounds
    # queue wait by a single in-flight dispatch, degraded reads stay
    # bounded-stale, and a watchdog-recovered slot kill converges to
    # oracle parity on the accepted-batch lineage
    load = report["serve_load"]
    assert load["requests_done"] > 0
    assert load["requests_queued"] == 0         # no unbounded growth
    assert load["requests_shed"] > 0            # overload was real: shed
    assert load["shed_reasons"].get("queue_full", 0) > 0
    # continuous dispatch + coalescing bound queue wait by ONE in-flight
    # dispatch: a request from an instantaneous burst can wait that whole
    # dispatch (ratio ~1.0), never several stacked dispatches as under the
    # old per-tick barrier (ratio >> 1).  1.5x = the single-dispatch bound
    # plus container scheduling noise — across recorded runs the measured
    # ratio has ranged 0.45..1.0, so a strict < 1.0 gate was flaking on
    # timing luck rather than asserting the invariant
    assert load["queue_wait_p50_ms"] < 1.5 * load["exec_p50_ms"], load
    assert load["deadline_miss_rate"] == 0.0    # generous deadline met
    lq = load["queries"]
    assert lq["served"] >= 100                  # concurrent read load
    assert lq["staleness_max_s"] < 30.0         # bounded, not unbounded
    assert lq["staleness_p95_s"] <= load["serving"]["staleness_budget_s"], lq
    events = load["watchdog"]                   # the mid-load slot kill
    assert any(e["kind"] == "dead" and e["domain"] == "session"
               for e in events)
    assert load["linf_vs_reference_max"] < 1e-8
    # the zero-retrace invariant stays assertable under load: legitimate
    # operand-bucket growth is counted separately (bucket_retraces)
    for row in load["sessions"]:
        if not row.get("closed"):
            assert row["retraces_post_warmup"] == 0, row
    # the chaos scenario (PR-7 acceptance): every seeded silent corruption
    # must be detected by the scrub, repaired clean at some ladder rung
    # (all three rungs exercised across the plan), and the surviving state
    # must match the accepted-batch oracle
    chaos = report["chaos"]
    assert chaos["corruption_injected"] > 0
    assert chaos["corruption_detected"] == chaos["corruption_injected"]
    assert chaos["repaired_clean"] == chaos["corruption_injected"]
    for rung in ("frontier", "rebuild", "restore"):
        assert chaos["repairs_by_rung"].get(rung, 0) >= 1, chaos
    assert chaos["final_scrub_ok"]
    assert chaos["linf_vs_reference_max"] <= 1e-9
    # the sharded scenario (topology="sharded" session on an 8-host-device
    # mesh, one run per partitioner): every partitioner must stay
    # parity-clean with zero post-warmup retraces, and the edge-cut /
    # latency numbers that make the partitioner choice observable must be
    # recorded
    # the recovery scenario (a durable streaming session SIGKILLed in a
    # subprocess, restored here): the WAL must replay every batch applied
    # after the last checkpoint, post-restore updates must be retrace-free,
    # and the restored stream must match the uninterrupted session
    # bit-for-bit (docs/FAULTS.md)
    recovery = report["recovery"]
    assert recovery["replayed_batches"] == recovery["killed_after_batches"]
    assert recovery["post_restore_retraces"] == 0
    assert recovery["linf_vs_uninterrupted"] == 0.0
    assert recovery["recovery_wall_s"] > 0
    assert recovery["post_restore_p50_ms"] > 0
    sharded = report["sharded"]
    assert sharded["n_devices"] >= 2
    assert set(sharded["partitioners"]) == {"contiguous", "hash",
                                            "bfs_blocks"}
    for part, row in sharded["partitioners"].items():
        assert row["retraces_post_warmup"] == 0, (part, row)
        assert row["linf_vs_reference"] < 1e-8, (part, row)
        assert 0.0 <= row["edge_cut"] <= 1.0, (part, row)
        assert row["p50_ms"] > 0 and row["p95_ms"] >= row["p50_ms"], \
            (part, row)
        assert row["collective_bytes_per_sweep"] > 0, (part, row)
    # the ppr scenario (PR-8 acceptance, the sweep-free walk engine):
    # accuracy must improve monotonically from the smallest to the largest
    # R and meet a fixed gate at the largest (seeded, so deterministic);
    # per-delta work must stay localized (regenerated ≤ touched-walk mass,
    # strictly below the global walk count) with zero post-warmup retraces
    # on the walk-buffer ladder; and the 1k simulated personalized-ranking
    # users must all have been served with recorded percentiles
    ppr = report["ppr"]
    curve = ppr["l1_vs_R"]
    rs = sorted(int(r) for r in curve)
    assert len(rs) >= 3
    assert curve[str(rs[-1])] < curve[str(rs[0])], curve   # error shrinks
    assert curve[str(rs[-1])] < 0.6, curve                 # fixed gate @ R=64
    loc = ppr["localization"]
    assert loc["retraces_post_warmup"] == 0, loc
    assert len(loc["batches"]) >= 3
    for row in loc["batches"]:
        assert 0 < row["regenerated_walks"] <= row["touched_walks"], row
        assert row["regenerated_walks"] < row["total_walks"], row
    serving = ppr["serving"]
    assert serving["users"] >= 1000
    assert serving["degraded_reads"]
    assert serving["query_p50_ms"] > 0
    assert serving["query_p95_ms"] >= serving["query_p50_ms"]
