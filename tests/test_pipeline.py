"""Pipeline-parallel train path: the shard_map GPipe forward must match the
reference single-program model bit-for-math (same loss), and its gradients
must drive training.  Runs in a subprocess with 16 forced host devices."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax, dataclasses
    import numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 16
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4, 4),
                             ("data", "model"))

    from repro.models.transformer.config import TransformerConfig
    from repro.models.transformer import model as M
    from repro.train.pipeline import (PipelineConfig, build_pipeline_loss,
                                      pipeline_param_shardings)

    # 8 layers / 4 stages; 8 q-heads / 4 TP; kv=4 (rep=2, H_loc=2 -> one kv
    # head per device); squared-relu exercises the nemotron path
    cfg = TransformerConfig(name="pp-test", n_layers=8, d_model=64,
                            n_heads=8, n_kv_heads=4, d_ff=128, vocab=96,
                            mlp="squared_relu", dtype="float32",
                            param_dtype="float32", remat=True,
                            attn_q_chunk=64)
    B, S = 8, 32
    pcfg = PipelineConfig(stage_axis="model", tp_axis="data", dp_axis=None,
                          microbatches=4)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}

    # reference loss (single-program path)
    ref_loss, _ = M.loss_fn(params, tokens, labels, cfg, aux_weight=0.0)

    loss_fn = build_pipeline_loss(cfg, pcfg, mesh, global_batch=B, seq=S)
    psh = pipeline_param_shardings(cfg, pcfg, mesh)
    params_sh = {k: jax.device_put(v, psh[k]) for k, v in params.items()}
    pp_loss, aux = jax.jit(loss_fn)(params_sh, batch)
    print("ref", float(ref_loss), "pp", float(pp_loss))
    assert abs(float(pp_loss) - float(ref_loss)) < 2e-3 * max(
        1.0, abs(float(ref_loss)))
    assert int(aux["tokens"]) == B * S

    # gradients flow and are finite
    g = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(params_sh, batch)
    for k, v in g.items():
        assert bool(jnp.isfinite(v).all()), k
    gn = sum(float(jnp.sum(jnp.square(v))) for v in jax.tree.leaves(g))
    assert gn > 0.0
    print("grad norm^2", gn)

    # one adam step reduces the loss on the same batch
    from repro.optim import adam
    acfg = adam.AdamConfig(lr=1e-2, warmup_steps=1, total_steps=10,
                           schedule="constant")
    opt = adam.init_state(params_sh, acfg)
    from repro.train import trainer
    step = jax.jit(trainer.build_train_step(loss_fn, acfg))
    p2, opt2, m = step(params_sh, opt, batch)
    l2, _ = jax.jit(loss_fn)(p2, batch)
    print("before", float(pp_loss), "after", float(l2))
    assert float(l2) < float(pp_loss)
    print("PIPELINE-OK")
""")


@pytest.mark.slow
def test_pipeline_matches_reference():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PIPELINE-OK" in r.stdout
