"""Checkpoint/restart, compression, partitioners, incremental-GNN, serving
engine — substrate-layer tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.checkpoint import Checkpointer
from repro.dist import compression as comp
from repro.graphs import partition as part
from repro.graphs.generators import grid_road, rmat


# -- checkpointing ------------------------------------------------------------

def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    p = _params()
    opt = {"m": jax.tree.map(jnp.zeros_like, p), "step": jnp.int32(7)}
    ck.save(p, opt, 10)
    p2, opt2, step = ck.restore(10, p, opt)
    assert step == 10
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(opt2["step"]) == 7


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    p = _params()
    opt = {"step": jnp.int32(0)}
    for s in (10, 20, 30):
        ck.save(p, opt, s)
    assert ck.latest_step == 30
    assert sorted(ck._list_steps()) == [20, 30]


def test_checkpoint_atomicity_tmp_ignored(tmp_path):
    """A leftover .tmp dir from a crashed save must not be restorable."""
    ck = Checkpointer(str(tmp_path))
    p = _params()
    opt = {"step": jnp.int32(0)}
    ck.save(p, opt, 5)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.latest_step == 5


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    p = _params()
    opt = {"step": jnp.int32(0)}
    d = ck.save(p, opt, 3)
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    np.save(os.path.join(d, victim), arr + 1)
    with pytest.raises(IOError):
        ck.restore(3, p, opt)


# -- gradient compression -------------------------------------------------------

def test_bf16_roundtrip_close():
    g = {"a": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    back = comp.bf16_decompress(comp.bf16_compress(g), g)
    assert float(jnp.max(jnp.abs(back["a"] - g["a"]))) < 2e-2


def test_topk_error_feedback_conserves_mass():
    """kept + residual == grad + prior residual, exactly."""
    k = jax.random.PRNGKey(0)
    g = {"a": jax.random.normal(k, (128,))}
    ef = comp.ErrorFeedback.init(g)
    kept, ef2 = comp.topk_compress(g, ef, frac=0.1)
    total = kept["a"] + ef2.residual["a"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["a"]),
                               rtol=1e-6)
    # top-k really kept the k largest magnitudes
    assert int((kept["a"] != 0).sum()) >= 12


def test_topk_residual_applied_next_round():
    g = {"a": jnp.asarray([10.0, 1.0, 0.5, 0.1])}
    ef = comp.ErrorFeedback.init(g)
    kept1, ef = comp.topk_compress(g, ef, frac=0.25)   # keeps 10.0
    assert float(kept1["a"][0]) == 10.0
    zero = {"a": jnp.zeros(4)}
    kept2, ef = comp.topk_compress(zero, ef, frac=0.25)  # residual resurfaces
    assert float(kept2["a"][1]) == 1.0


def test_quantize_8bit_bounds():
    g = jnp.linspace(-3, 3, 100)
    q, s = comp.quantize_8bit(g)
    back = comp.dequantize_8bit(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


# -- graph partitioners ----------------------------------------------------------

def test_partitioners_cover_and_balance():
    hg = rmat(10, 8, seed=0)
    for fn in (lambda: part.contiguous(hg.n, 8),
               lambda: part.hashed(hg.n, 8),
               lambda: part.bfs_blocks(hg, 8)):
        owner = fn()
        assert owner.shape == (hg.n,)
        assert owner.min() >= 0 and owner.max() < 8
        counts = np.bincount(owner, minlength=8)
        assert counts.max() <= 2 * counts[counts > 0].mean()


def test_bfs_partition_cuts_fewer_edges_on_road():
    # pure lattice (no small-world shortcuts: those destroy BFS locality)
    hg = grid_road(48, diag_frac=0.0, seed=0)
    cut_hash = part.edge_cut(hg, part.hashed(hg.n, 16))
    cut_bfs = part.edge_cut(hg, part.bfs_blocks(hg, 16))
    assert cut_bfs < cut_hash * 0.5, (cut_bfs, cut_hash)


# -- incremental GNN (DF beyond paper) --------------------------------------------

def test_incremental_gnn_matches_full():
    from repro.configs import get_arch
    from repro.core import incremental as inc
    from repro.models.gnn import graphsage
    from repro.models.gnn.common import GraphBatch

    spec = get_arch("graphsage-reddit")
    cfg = spec.build_cfg(d_feat=16, n_out=4)
    rng = np.random.default_rng(0)
    n, e = 512, 2048
    nodes = jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32)
    snd = rng.integers(0, n, e)
    rcv = rng.integers(0, n, e)
    params = graphsage.init(cfg, jax.random.PRNGKey(0))
    fns = inc.full_gnn_layers(graphsage, params, cfg)

    g = GraphBatch(nodes=nodes, senders=jnp.asarray(snd, jnp.int32),
                   receivers=jnp.asarray(rcv, jnp.int32))
    cache, h = [nodes], nodes
    for fn in fns:
        h = fn(g, h)
        cache.append(h)

    idx = rng.integers(0, e, 4)
    old = np.stack([snd[idx], rcv[idx]], 1)
    snd[idx] = rng.integers(0, n, 4)
    rcv[idx] = rng.integers(0, n, 4)
    new = np.stack([snd[idx], rcv[idx]], 1)
    g2 = GraphBatch(nodes=nodes, senders=jnp.asarray(snd, jnp.int32),
                    receivers=jnp.asarray(rcv, jnp.int32))
    sources = inc.edge_update_sources(n, old, new)
    # τ_f = 0 ⇒ no cutoff ⇒ incremental must EXACTLY equal full recompute
    h_inc, _, stats = inc.incremental_gnn_update(fns, g2, nodes, cache,
                                                 sources, tau_f=0.0)
    h_full = nodes
    for fn in fns:
        h_full = fn(g2, h_full)
    np.testing.assert_allclose(np.asarray(h_inc), np.asarray(h_full),
                               rtol=1e-5, atol=1e-6)
    assert stats["recomputed"] < stats["total"], "frontier did not prune"


# -- serving engine ---------------------------------------------------------------

def test_serve_engine_continuous_batching():
    from repro.configs import get_arch
    from repro.models.transformer import model as M
    from repro.serve.engine import Request, ServeEngine

    spec = get_arch("phi4-mini-3.8b")
    cfg = spec.smoke_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab, 12),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)

    # greedy engine decode must equal the model's own greedy continuation
    req = Request(uid=99, prompt=rng.integers(0, cfg.vocab, 12),
                  max_new_tokens=4)
    eng2 = ServeEngine(cfg, params, slots=1, cache_len=64)
    eng2.submit(req)
    eng2.run_until_drained()
    toks = jnp.asarray(req.prompt[None, :], jnp.int32)
    expect = []
    for _ in range(4):
        logits, _ = M.forward(params, toks, cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        expect.append(nxt)
        toks = jnp.concatenate(
            [toks, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    assert req.out == expect
