"""PageRankSession lifecycle, EngineConfig/registry validation, deprecation
shims (warning + bit-for-bit routing parity), fork semantics, and the
multi-session service."""
import dataclasses
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (EngineConfig, PageRankService, PageRankSession,
                       ServingConfig, registry)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.frontier import batch_to_device
from repro.graphs.generators import rmat


@pytest.fixture(scope="module")
def dyn():
    hg0 = rmat(9, avg_degree=6, seed=5)
    g0 = hg0.snapshot(block_size=64)
    r_prev = jnp.asarray(pr.numpy_reference(g0, iterations=300))
    dels, ins = random_batch(hg0, 5e-3, seed=21)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    batch = batch_to_device(g1, dels, ins)
    return hg0, g0, hg1, g1, batch, r_prev, dels, ins


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------

class TestEngineConfig:
    def test_defaults_valid(self):
        cfg = EngineConfig()
        assert cfg.resolved_engine in registry.names()
        assert cfg.resolved_backend in ("pallas", "xla")

    @pytest.mark.parametrize("kw", [
        dict(mode="nope"), dict(active_policy="nope"), dict(alpha=0.0),
        dict(alpha=1.5), dict(tau=-1e-9), dict(tau_f=0.0), dict(tile=0),
        dict(block_size=-64), dict(max_iterations=0),
        dict(engine="not-an-engine"), dict(backend="not-a-backend"),
        dict(faults=object()),
    ])
    def test_bad_values_rejected_at_construction(self, kw):
        with pytest.raises(ValueError):
            EngineConfig(**kw)

    def test_unknown_keys_rejected_with_valid_list(self):
        with pytest.raises(TypeError, match="taau.*valid keys"):
            EngineConfig.from_kwargs(taau=1e-9)
        with pytest.raises(TypeError, match="valid keys"):
            EngineConfig().replace(engin="blocked")

    def test_replace_builds_validated_variant(self):
        cfg = EngineConfig(tau=1e-8)
        cfg2 = cfg.replace(alpha=0.9)
        assert cfg2.alpha == 0.9 and cfg2.tau == 1e-8
        with pytest.raises(ValueError):
            cfg.replace(mode="nope")

    def test_tau_f_resolution(self):
        cfg = EngineConfig(tau=1e-6)
        assert cfg.resolved_tau_f(expand=True) == pytest.approx(1e-9)
        assert cfg.resolved_tau_f(expand=False) == float("inf")
        assert EngineConfig(tau_f=1e-4).resolved_tau_f(expand=True) == 1e-4

    def test_env_overrides_validated_eagerly(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENGINE", "bogus")
        with pytest.raises(ValueError, match="REPRO_ENGINE.*registered"):
            EngineConfig()
        monkeypatch.delenv("REPRO_ENGINE")
        monkeypatch.setenv("REPRO_TILE_BACKEND", "bogus")
        with pytest.raises(ValueError, match="REPRO_TILE_BACKEND"):
            EngineConfig()
        monkeypatch.setenv("REPRO_TILE_BACKEND", "xla")
        assert EngineConfig().resolved_backend == "xla"

    def test_env_override_accepts_registered_engine(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENGINE", "dense")
        assert EngineConfig().resolved_engine == "dense"
        assert pr.default_engine() == "dense"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_unknown_engine_error_lists_registered(self):
        with pytest.raises(ValueError, match="blocked.*dense.*pallas"):
            registry.resolve("not-an-engine")

    def test_custom_engine_registers_and_resolves(self):
        class EchoEngine:
            name = "echo-test"

            def run(self, g, R0, affected0, **kw):
                from repro.core.blocked import SweepStats
                return R0, SweepStats(converged=True)

        registry.register(EchoEngine())
        try:
            assert "echo-test" in registry.names()
            assert registry.resolve("echo-test").name == "echo-test"
            with pytest.raises(ValueError, match="already registered"):
                registry.register(EchoEngine())
        finally:
            registry._REGISTRY.pop("echo-test", None)

    def test_invalid_adapters_rejected(self):
        class NoName:
            def run(self):
                pass

        with pytest.raises(ValueError, match="name"):
            registry.register(NoName())

    def test_non_pallas_engines_reject_tile_operands(self, dyn):
        _, g0, _, _, _, r_prev, _, _ = dyn
        with pytest.raises(ValueError, match="only consumed by "
                                             "engine='pallas'"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                pr.nd_pagerank(g0, r_prev, engine="blocked",
                               pallas_backend="xla")


# ---------------------------------------------------------------------------
# deprecation shims: warning + bit-for-bit session parity
# ---------------------------------------------------------------------------

class TestDeprecationShims:
    """Each legacy variant function must emit DeprecationWarning, route
    through PageRankSession, and match the session call bit-for-bit."""

    ENGINE = "blocked"      # deterministic + fast on CPU containers

    def _cfg(self, mode):
        return EngineConfig(mode=mode, engine=self.ENGINE)

    def test_static(self, dyn):
        _, g0, _, _, _, _, _, _ = dyn
        with pytest.warns(DeprecationWarning, match="static_pagerank"):
            res = pr.static_pagerank(g0, mode="bb", engine=self.ENGINE)
        sess = PageRankSession.from_snapshot(g0, config=self._cfg("bb"))
        out = sess.recompute("static")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_nd(self, dyn):
        _, g0, _, _, _, r_prev, _, _ = dyn
        with pytest.warns(DeprecationWarning, match="nd_pagerank"):
            res = pr.nd_pagerank(g0, r_prev, mode="lf", engine=self.ENGINE)
        sess = PageRankSession.from_snapshot(g0, config=self._cfg("lf"),
                                             r0=r_prev)
        out = sess.recompute("nd")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_dt(self, dyn):
        hg0, g0, _, g1, batch, r_prev, dels, ins = dyn
        with pytest.warns(DeprecationWarning, match="dt_pagerank"):
            res = pr.dt_pagerank(g0, g1, batch, r_prev, mode="lf",
                                 engine=self.ENGINE)
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="dt")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_df(self, dyn):
        hg0, g0, _, g1, batch, r_prev, dels, ins = dyn
        with pytest.warns(DeprecationWarning, match="df_pagerank"):
            res = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                                 engine=self.ENGINE)
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="df")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_df_recompute_replays_last_batch(self, dyn):
        """recompute('df') after update == the update itself (same marking,
        same pre-batch ranks)."""
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="df")
        replay = sess.recompute("df")
        assert np.array_equal(np.asarray(out.ranks),
                              np.asarray(replay.ranks))

    def test_recompute_dt_df_require_a_batch(self, dyn):
        hg0, _, _, _, _, r_prev, _, _ = dyn
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        with pytest.raises(ValueError, match="no batch"):
            sess.recompute("df")
        # warmup's internal empty batch must not count as "the last update"
        stream = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64),
            r0=r_prev)
        stream.warmup()
        with pytest.raises(ValueError, match="no batch"):
            stream.recompute("dt")


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------

class TestSessionLifecycle:
    def test_from_graph_initial_solve_matches_reference(self, dyn):
        hg0, g0, _, _, _, _, _, _ = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64))
        ref = pr.numpy_reference(g0, iterations=300)
        assert pr.linf(sess.R[:g0.n], jnp.asarray(ref[:g0.n])) < 1e-8

    def test_bare_snapshot_session_cannot_update(self, dyn):
        _, g0, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_snapshot(
            g0, config=EngineConfig(engine="blocked"), r0=r_prev)
        with pytest.raises(ValueError, match="from_graph"):
            sess.update(dels, ins)

    def test_bad_variant_rejected(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="blocked"), r0=r_prev)
        with pytest.raises(ValueError, match="variant"):
            sess.update(dels, ins, variant="nope")
        with pytest.raises(ValueError, match="variant"):
            sess.recompute("nope")

    def test_config_type_checked(self, dyn):
        hg0 = dyn[0]
        with pytest.raises(TypeError, match="EngineConfig"):
            PageRankSession.from_graph(hg0, config={"alpha": 0.9})

    def test_stream_variants_match_snapshot_oracles(self, dyn):
        """nd/static variants through the stream-mode hot path agree with
        the legacy snapshot-based route."""
        hg0, g0, hg1, g1, batch, r_prev, dels, ins = dyn
        for variant, oracle in (
                ("nd", lambda: pr.nd_pagerank(g1, r_prev, mode="lf",
                                              engine="pallas")),
                ("static", lambda: pr.static_pagerank(g1, mode="lf",
                                                      engine="pallas"))):
            sess = PageRankSession.from_graph(
                hg0, config=EngineConfig(engine="pallas", block_size=64),
                r0=r_prev)
            res = sess.update(dels, ins, variant=variant)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                ref = oracle()
            assert res.stats.converged
            assert pr.linf(res.ranks, ref.ranks) < 1e-12, variant

    def test_concurrent_bucket_compile_not_charged_as_retrace(
            self, dyn, monkeypatch):
        """The fused driver's jit cache is process-wide: a first-visit
        bucket compile by a CONCURRENT session can land inside this
        session's cache-delta window (service dispatch overlaps drives).
        Growth explained by an overlapping first-visit drive must be
        classified as bucket-ladder growth, not an unexpected retrace —
        and growth with no overlapping drive must still be charged."""
        from repro.api import session as sess_mod
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64),
            r0=r_prev)
        sess.update(dels, ins)          # warm: own ladder bucket visited
        real = sess_mod._driver_cache_size
        calls = {"n": 0}

        def growing():                  # every cache1 read sees one entry
            calls["n"] += 1             # more than its cache0 — a compile
            return real() + (1 if calls["n"] % 2 == 0 else 0)

        monkeypatch.setattr(sess_mod, "_driver_cache_size", growing)
        d2, i2 = random_batch(sess.hg, 5e-3, seed=91)
        res = sess.update(d2, i2)       # no overlapping first-visit drive
        assert res.driver_retraces == 1  # → charged as a real retrace
        assert res.bucket_retraces == 0
        monkeypatch.setattr(sess_mod, "_NEW_BUCKET_ACTIVE", 1)
        d3, i3 = random_batch(sess.hg, 5e-3, seed=92)
        res = sess.update(d3, i3)       # concurrent first-visit drive
        assert res.driver_retraces == 0  # explains the growth
        assert res.bucket_retraces == 1

    def test_fork_branches_are_independent(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64),
            r0=r_prev)
        base_m = sess.hg.m
        base_R = np.asarray(sess.R).copy()
        twin = sess.fork()
        assert twin.inc.mat.tiles is sess.inc.mat.tiles  # shared tile pool
        twin.update(dels, ins)
        # parent untouched by the fork's update
        assert sess.hg.m == base_m
        np.testing.assert_array_equal(np.asarray(sess.R), base_R)
        np.testing.assert_array_equal(np.asarray(sess._out_deg),
                                      np.asarray(
                                          sess.hg.snapshot(
                                              block_size=64).out_deg))
        # both branches keep converging independently
        d2, i2 = random_batch(sess.hg, 5e-3, seed=77)
        assert sess.update(d2, i2).stats.converged
        assert twin.report().n_updates == 1
        assert sess.report().n_updates == 1


# ---------------------------------------------------------------------------
# service: N sessions, one queue
# ---------------------------------------------------------------------------

class TestService:
    def test_drains_and_reports_per_session(self):
        graphs = [rmat(8, avg_degree=4, seed=s) for s in (0, 1)]
        svc = PageRankService(
            graphs, config=EngineConfig(engine="pallas", block_size=64),
            serving=ServingConfig(coalesce=False))
        cur = list(graphs)
        for j in range(2):
            for i in range(len(cur)):
                dels, ins = random_batch(cur[i], 1e-2, seed=50 + 10 * i + j)
                svc.submit(i, dels, ins)
                cur[i] = cur[i].apply_batch(dels, ins)
        done = svc.run_until_drained()
        assert len(done) == 4
        assert all(r.done and r.result.stats.converged for r in done)
        assert all(r.latency_s >= r.wait_s >= 0 for r in done)
        rep = svc.report()
        assert rep["requests_done"] == 4 and rep["requests_queued"] == 0
        for row in rep["sessions"]:
            assert row["n_updates"] == 2
            # sessions share the jit caches → no session retraces after
            # the service-level warmup
            assert row["retraces_post_warmup"] == 0
        # session ranks match an independent oracle on the final graphs
        for i, hg in enumerate(cur):
            ref = pr.numpy_reference(hg.snapshot(block_size=64),
                                     iterations=300)
            n = svc.sessions[i].n
            assert pr.linf(svc.sessions[i].R[:n],
                           jnp.asarray(ref[:n])) < 1e-8

    def test_step_coalesces_queue_into_one_update(self):
        hg = rmat(8, avg_degree=4, seed=2)
        svc = PageRankService(
            [hg], config=EngineConfig(engine="pallas", block_size=64))
        cur = hg
        for j in range(3):
            dels, ins = random_batch(cur, 1e-2, seed=90 + j)
            svc.submit(0, dels, ins)
            cur = cur.apply_batch(dels, ins)
        assert svc.step() == 3      # whole run retires in ONE dispatch
        assert svc.queue == []
        assert [r.uid for r in svc.finished] == [1, 2, 3]
        assert svc.sessions[0].report().n_updates == 1  # one scatter
        # last-write-wins fold equals the sequential end state
        ref = pr.numpy_reference(cur.snapshot(block_size=64),
                                 iterations=300)
        assert pr.linf(svc.sessions[0].R[:cur.n],
                       jnp.asarray(ref[:cur.n])) < 1e-8

    def test_fifo_per_stream_without_coalescing(self):
        hg = rmat(8, avg_degree=4, seed=2)
        svc = PageRankService(
            [hg], config=EngineConfig(engine="pallas", block_size=64),
            serving=ServingConfig(coalesce=False))
        cur = hg
        for j in range(3):
            dels, ins = random_batch(cur, 1e-2, seed=90 + j)
            svc.submit(0, dels, ins)
            cur = cur.apply_batch(dels, ins)
        assert svc.step() == 1          # one batch per slot per pass
        assert len(svc.queue) == 2
        assert [r.uid for r in svc.finished] == [1]
        svc.run_until_drained()
        assert [r.uid for r in svc.finished] == [1, 2, 3]

    def test_submit_bad_stream_rejected(self):
        svc = PageRankService(
            [rmat(7, avg_degree=4, seed=0)],
            config=EngineConfig(engine="pallas", block_size=64),
            warmup=False)
        with pytest.raises(ValueError, match="out of range"):
            svc.submit(3, np.zeros((0, 2)), np.zeros((0, 2)))


# ---------------------------------------------------------------------------
# topology axis: config validation + in-process sharded parity (1-shard mesh)
# ---------------------------------------------------------------------------

class TestTopologyConfig:
    @pytest.mark.parametrize("kw", [
        dict(topology="nope"),
        dict(topology="sharded", n_shards=0),
        dict(topology="sharded", n_shards=-2),
        dict(partitioner="metis"),
        dict(exchange="ring"),          # rebuild-only, not a session axis
        dict(exchange="nope"),
        dict(n_shards=4),               # needs topology="sharded"
        dict(engine="distributed"),     # topology selects the engine
        dict(topology="sharded", engine="pallas"),
    ])
    def test_bad_topology_combos_rejected(self, kw):
        with pytest.raises(ValueError):
            EngineConfig(**kw)

    def test_oversubscribed_mesh_rejected(self):
        import jax
        too_many = len(jax.devices()) + 1
        with pytest.raises(ValueError, match="exceeds"):
            EngineConfig(topology="sharded", n_shards=too_many)

    def test_sharded_rejects_fault_plans(self):
        # the sharded sweep has no crash tables (stragglers are the
        # model) — rejected at construction, not silently ignored
        from repro.core import faults as flt
        with pytest.raises(ValueError, match="fault simulation"):
            EngineConfig(topology="sharded", n_shards=1,
                         faults=flt.NO_FAULTS)

    def test_sharded_resolves_distributed_engine(self):
        cfg = EngineConfig(topology="sharded", n_shards=1)
        assert cfg.resolved_engine == "distributed"
        assert cfg.resolved_n_shards == 1
        assert EngineConfig().resolved_n_shards is None
        assert "distributed" in registry.names()

    def test_non_distributed_engines_reject_shard_spec(self, dyn):
        from repro.core.distributed import ShardSpec
        _, g0, _, _, _, r_prev, _, _ = dyn
        eng = registry.resolve("blocked")
        with pytest.raises(ValueError, match="only consumed by "
                                             "engine='distributed'"):
            eng.run(g0, r_prev, g0.vertex_valid, mode="lf", expand=False,
                    alpha=0.85, tau=1e-10, tau_f=None, max_iterations=5,
                    faults=None, tile=512, active_policy="affected",
                    shards=ShardSpec(n_shards=1))


class TestShardedSession:
    """Topology-transparent session over a 1-shard mesh (the in-process
    coverage; the 8-device parity suite lives in
    tests/test_sharded_session.py behind the `multidevice` marker)."""

    CFG = dict(topology="sharded", n_shards=1)

    def test_static_solve_matches_reference(self, dyn):
        hg0, g0, _, _, _, _, _, _ = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(partitioner="bfs_blocks", **self.CFG))
        ref = pr.numpy_reference(g0, iterations=300)
        assert pr.linf(jnp.asarray(sess.ranks[:g0.n]),
                       jnp.asarray(ref[:g0.n])) < 1e-8
        rep = sess.report()
        assert rep.topology == "sharded" and rep.n_shards == 1
        assert rep.partitioner == "bfs_blocks"
        assert 0.0 <= rep.edge_cut <= 1.0

    def test_df_stream_matches_blocked_oracle(self, dyn):
        hg0, g0, _, _, _, r_prev, _, _ = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(**self.CFG), r0=r_prev)
        oracle = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="blocked"), r0=r_prev)
        sess.warmup()
        cur = hg0
        for i in range(3):
            dels, ins = random_batch(cur, 5e-3, seed=400 + i)
            cur = cur.apply_batch(dels, ins)
            res = sess.update(dels, ins)
            ores = oracle.update(dels, ins)
            assert res.stats.converged and ores.stats.converged
            assert np.max(np.abs(sess.ranks[:cur.n]
                                 - oracle.ranks[:cur.n])) < 1e-9, i
        assert sess.report().retraces_post_warmup == 0
        assert sess.report().collective_bytes_per_sweep is not None

    def test_query_topk_translate_through_relabeling(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(partitioner="hash", **self.CFG),
            r0=r_prev)
        sess.update(dels, ins)
        full = sess.ranks
        ids = [0, 3, sess.n - 1]
        np.testing.assert_allclose(sess.query(ids), full[ids])
        vals, idx = sess.top_k(4)
        np.testing.assert_allclose(vals, full[idx])
        order = np.argsort(full[:sess.n])[::-1][:4]
        np.testing.assert_allclose(vals, full[order])

    def test_recompute_variants_and_fork(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(**self.CFG), r0=r_prev)
        with pytest.raises(ValueError, match="no batch"):
            sess.recompute("df")
        out = sess.update(dels, ins)
        replay = sess.recompute("df")
        np.testing.assert_array_equal(np.asarray(out.ranks),
                                      np.asarray(replay.ranks))
        static = sess.recompute("static")
        assert static.stats.converged
        twin = sess.fork()
        d2, i2 = random_batch(sess.hg, 5e-3, seed=88)
        twin.update(d2, i2)
        assert sess.report().n_updates == 1     # parent untouched
        assert twin.report().n_updates == 1
        assert sess.hg.m != twin.hg.m or not np.array_equal(
            np.asarray(sess.R), np.asarray(twin.R))


# ---------------------------------------------------------------------------
# query/top_k ergonomics + session close / context manager
# ---------------------------------------------------------------------------

class TestServingErgonomics:
    def _sess(self, dyn):
        hg0, _, _, _, _, r_prev, _, _ = dyn
        return PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="blocked"), r0=r_prev)

    def test_query_accepts_python_int_and_list(self, dyn):
        sess = self._sess(dyn)
        one = sess.query(3)
        assert one.shape == (1,)
        np.testing.assert_allclose(sess.query([3, 5]),
                                   np.asarray(sess.R)[[3, 5]])
        assert sess.query([]).shape == (0,)     # empty id list is valid

    def test_query_rejects_bad_ids(self, dyn):
        sess = self._sess(dyn)
        with pytest.raises(ValueError, match="out of range"):
            sess.query([-1])
        with pytest.raises(ValueError, match="out of range"):
            sess.query([0, sess.n])
        with pytest.raises(ValueError, match="integers"):
            sess.query([1.5])

    def test_top_k_rejects_bad_k(self, dyn):
        sess = self._sess(dyn)
        with pytest.raises(ValueError, match="must be >= 1"):
            sess.top_k(0)
        with pytest.raises(ValueError, match="integer"):
            sess.top_k(2.5)

    def test_close_is_idempotent_and_guards_reads(self, dyn):
        sess = self._sess(dyn)
        sess.close()
        sess.close()
        assert sess.closed and sess.device_footprint == ()
        for call in (lambda: sess.query([0]), lambda: sess.top_k(1),
                     lambda: sess.update([], []),
                     lambda: sess.recompute("static"), lambda: sess.fork(),
                     lambda: sess.ranks):
            with pytest.raises(ValueError, match="closed"):
                call()
        assert sess.R is None and sess.inc is None   # buffers dropped

    def test_context_manager_closes(self, dyn):
        hg0 = dyn[0]
        with PageRankSession.from_graph(
                hg0, config=EngineConfig(engine="blocked")) as sess:
            assert sess.query([0]).shape == (1,)
        assert sess.closed

    def test_close_unregisters_from_service(self):
        graphs = [rmat(7, avg_degree=4, seed=s) for s in (0, 1)]
        svc = PageRankService(
            graphs, config=EngineConfig(engine="pallas", block_size=64),
            warmup=False)
        assert set(svc.placements()) == {0, 1}
        svc.submit(0, np.zeros((0, 2)), np.zeros((0, 2)))
        svc.sessions[0].close()
        assert svc.sessions[0] is None
        assert svc.queue == []                  # queued batches dropped
        assert set(svc.placements()) == {1}
        with pytest.raises(ValueError, match="closed"):
            svc.submit(0, np.zeros((0, 2)), np.zeros((0, 2)))
        svc.submit(1, np.zeros((0, 2)), np.zeros((0, 2)))   # slot 1 lives
        assert svc.step() == 1
        rep = svc.report()
        assert rep["sessions"][0] == {"stream": 0, "closed": True}
        assert rep["sessions"][1]["devices"]
