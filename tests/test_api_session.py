"""PageRankSession lifecycle, EngineConfig/registry validation, deprecation
shims (warning + bit-for-bit routing parity), fork semantics, and the
multi-session service."""
import dataclasses
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (EngineConfig, PageRankService, PageRankSession,
                       registry)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.frontier import batch_to_device
from repro.graphs.generators import rmat


@pytest.fixture(scope="module")
def dyn():
    hg0 = rmat(9, avg_degree=6, seed=5)
    g0 = hg0.snapshot(block_size=64)
    r_prev = jnp.asarray(pr.numpy_reference(g0, iterations=300))
    dels, ins = random_batch(hg0, 5e-3, seed=21)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    batch = batch_to_device(g1, dels, ins)
    return hg0, g0, hg1, g1, batch, r_prev, dels, ins


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------

class TestEngineConfig:
    def test_defaults_valid(self):
        cfg = EngineConfig()
        assert cfg.resolved_engine in registry.names()
        assert cfg.resolved_backend in ("pallas", "xla")

    @pytest.mark.parametrize("kw", [
        dict(mode="nope"), dict(active_policy="nope"), dict(alpha=0.0),
        dict(alpha=1.5), dict(tau=-1e-9), dict(tau_f=0.0), dict(tile=0),
        dict(block_size=-64), dict(max_iterations=0),
        dict(engine="not-an-engine"), dict(backend="not-a-backend"),
        dict(faults=object()),
    ])
    def test_bad_values_rejected_at_construction(self, kw):
        with pytest.raises(ValueError):
            EngineConfig(**kw)

    def test_unknown_keys_rejected_with_valid_list(self):
        with pytest.raises(TypeError, match="taau.*valid keys"):
            EngineConfig.from_kwargs(taau=1e-9)
        with pytest.raises(TypeError, match="valid keys"):
            EngineConfig().replace(engin="blocked")

    def test_replace_builds_validated_variant(self):
        cfg = EngineConfig(tau=1e-8)
        cfg2 = cfg.replace(alpha=0.9)
        assert cfg2.alpha == 0.9 and cfg2.tau == 1e-8
        with pytest.raises(ValueError):
            cfg.replace(mode="nope")

    def test_tau_f_resolution(self):
        cfg = EngineConfig(tau=1e-6)
        assert cfg.resolved_tau_f(expand=True) == pytest.approx(1e-9)
        assert cfg.resolved_tau_f(expand=False) == float("inf")
        assert EngineConfig(tau_f=1e-4).resolved_tau_f(expand=True) == 1e-4

    def test_env_overrides_validated_eagerly(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENGINE", "bogus")
        with pytest.raises(ValueError, match="REPRO_ENGINE.*registered"):
            EngineConfig()
        monkeypatch.delenv("REPRO_ENGINE")
        monkeypatch.setenv("REPRO_TILE_BACKEND", "bogus")
        with pytest.raises(ValueError, match="REPRO_TILE_BACKEND"):
            EngineConfig()
        monkeypatch.setenv("REPRO_TILE_BACKEND", "xla")
        assert EngineConfig().resolved_backend == "xla"

    def test_env_override_accepts_registered_engine(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENGINE", "dense")
        assert EngineConfig().resolved_engine == "dense"
        assert pr.default_engine() == "dense"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_unknown_engine_error_lists_registered(self):
        with pytest.raises(ValueError, match="blocked.*dense.*pallas"):
            registry.resolve("not-an-engine")

    def test_custom_engine_registers_and_resolves(self):
        class EchoEngine:
            name = "echo-test"

            def run(self, g, R0, affected0, **kw):
                from repro.core.blocked import SweepStats
                return R0, SweepStats(converged=True)

        registry.register(EchoEngine())
        try:
            assert "echo-test" in registry.names()
            assert registry.resolve("echo-test").name == "echo-test"
            with pytest.raises(ValueError, match="already registered"):
                registry.register(EchoEngine())
        finally:
            registry._REGISTRY.pop("echo-test", None)

    def test_invalid_adapters_rejected(self):
        class NoName:
            def run(self):
                pass

        with pytest.raises(ValueError, match="name"):
            registry.register(NoName())

    def test_non_pallas_engines_reject_tile_operands(self, dyn):
        _, g0, _, _, _, r_prev, _, _ = dyn
        with pytest.raises(ValueError, match="only consumed by "
                                             "engine='pallas'"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                pr.nd_pagerank(g0, r_prev, engine="blocked",
                               pallas_backend="xla")


# ---------------------------------------------------------------------------
# deprecation shims: warning + bit-for-bit session parity
# ---------------------------------------------------------------------------

class TestDeprecationShims:
    """Each legacy variant function must emit DeprecationWarning, route
    through PageRankSession, and match the session call bit-for-bit."""

    ENGINE = "blocked"      # deterministic + fast on CPU containers

    def _cfg(self, mode):
        return EngineConfig(mode=mode, engine=self.ENGINE)

    def test_static(self, dyn):
        _, g0, _, _, _, _, _, _ = dyn
        with pytest.warns(DeprecationWarning, match="static_pagerank"):
            res = pr.static_pagerank(g0, mode="bb", engine=self.ENGINE)
        sess = PageRankSession.from_snapshot(g0, config=self._cfg("bb"))
        out = sess.recompute("static")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_nd(self, dyn):
        _, g0, _, _, _, r_prev, _, _ = dyn
        with pytest.warns(DeprecationWarning, match="nd_pagerank"):
            res = pr.nd_pagerank(g0, r_prev, mode="lf", engine=self.ENGINE)
        sess = PageRankSession.from_snapshot(g0, config=self._cfg("lf"),
                                             r0=r_prev)
        out = sess.recompute("nd")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_dt(self, dyn):
        hg0, g0, _, g1, batch, r_prev, dels, ins = dyn
        with pytest.warns(DeprecationWarning, match="dt_pagerank"):
            res = pr.dt_pagerank(g0, g1, batch, r_prev, mode="lf",
                                 engine=self.ENGINE)
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="dt")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_df(self, dyn):
        hg0, g0, _, g1, batch, r_prev, dels, ins = dyn
        with pytest.warns(DeprecationWarning, match="df_pagerank"):
            res = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                                 engine=self.ENGINE)
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="df")
        assert np.array_equal(np.asarray(res.ranks), np.asarray(out.ranks))
        assert res.stats.sweeps == out.stats.sweeps

    def test_df_recompute_replays_last_batch(self, dyn):
        """recompute('df') after update == the update itself (same marking,
        same pre-batch ranks)."""
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        out = sess.update(dels, ins, variant="df")
        replay = sess.recompute("df")
        assert np.array_equal(np.asarray(out.ranks),
                              np.asarray(replay.ranks))

    def test_recompute_dt_df_require_a_batch(self, dyn):
        hg0, _, _, _, _, r_prev, _, _ = dyn
        sess = PageRankSession.from_graph(hg0, config=self._cfg("lf"),
                                          r0=r_prev)
        with pytest.raises(ValueError, match="no batch"):
            sess.recompute("df")
        # warmup's internal empty batch must not count as "the last update"
        stream = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64),
            r0=r_prev)
        stream.warmup()
        with pytest.raises(ValueError, match="no batch"):
            stream.recompute("dt")


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------

class TestSessionLifecycle:
    def test_from_graph_initial_solve_matches_reference(self, dyn):
        hg0, g0, _, _, _, _, _, _ = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64))
        ref = pr.numpy_reference(g0, iterations=300)
        assert pr.linf(sess.R[:g0.n], jnp.asarray(ref[:g0.n])) < 1e-8

    def test_bare_snapshot_session_cannot_update(self, dyn):
        _, g0, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_snapshot(
            g0, config=EngineConfig(engine="blocked"), r0=r_prev)
        with pytest.raises(ValueError, match="from_graph"):
            sess.update(dels, ins)

    def test_bad_variant_rejected(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="blocked"), r0=r_prev)
        with pytest.raises(ValueError, match="variant"):
            sess.update(dels, ins, variant="nope")
        with pytest.raises(ValueError, match="variant"):
            sess.recompute("nope")

    def test_config_type_checked(self, dyn):
        hg0 = dyn[0]
        with pytest.raises(TypeError, match="EngineConfig"):
            PageRankSession.from_graph(hg0, config={"alpha": 0.9})

    def test_stream_variants_match_snapshot_oracles(self, dyn):
        """nd/static variants through the stream-mode hot path agree with
        the legacy snapshot-based route."""
        hg0, g0, hg1, g1, batch, r_prev, dels, ins = dyn
        for variant, oracle in (
                ("nd", lambda: pr.nd_pagerank(g1, r_prev, mode="lf",
                                              engine="pallas")),
                ("static", lambda: pr.static_pagerank(g1, mode="lf",
                                                      engine="pallas"))):
            sess = PageRankSession.from_graph(
                hg0, config=EngineConfig(engine="pallas", block_size=64),
                r0=r_prev)
            res = sess.update(dels, ins, variant=variant)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                ref = oracle()
            assert res.stats.converged
            assert pr.linf(res.ranks, ref.ranks) < 1e-12, variant

    def test_fork_branches_are_independent(self, dyn):
        hg0, _, _, _, _, r_prev, dels, ins = dyn
        sess = PageRankSession.from_graph(
            hg0, config=EngineConfig(engine="pallas", block_size=64),
            r0=r_prev)
        base_m = sess.hg.m
        base_R = np.asarray(sess.R).copy()
        twin = sess.fork()
        assert twin.inc.mat.tiles is sess.inc.mat.tiles  # shared tile pool
        twin.update(dels, ins)
        # parent untouched by the fork's update
        assert sess.hg.m == base_m
        np.testing.assert_array_equal(np.asarray(sess.R), base_R)
        np.testing.assert_array_equal(np.asarray(sess._out_deg),
                                      np.asarray(
                                          sess.hg.snapshot(
                                              block_size=64).out_deg))
        # both branches keep converging independently
        d2, i2 = random_batch(sess.hg, 5e-3, seed=77)
        assert sess.update(d2, i2).stats.converged
        assert twin.report().n_updates == 1
        assert sess.report().n_updates == 1


# ---------------------------------------------------------------------------
# service: N sessions, one queue
# ---------------------------------------------------------------------------

class TestService:
    def test_drains_and_reports_per_session(self):
        graphs = [rmat(8, avg_degree=4, seed=s) for s in (0, 1)]
        svc = PageRankService(
            graphs, config=EngineConfig(engine="pallas", block_size=64))
        cur = list(graphs)
        for j in range(2):
            for i in range(len(cur)):
                dels, ins = random_batch(cur[i], 1e-2, seed=50 + 10 * i + j)
                svc.submit(i, dels, ins)
                cur[i] = cur[i].apply_batch(dels, ins)
        done = svc.run_until_drained()
        assert len(done) == 4
        assert all(r.done and r.result.stats.converged for r in done)
        assert all(r.latency_s >= r.wait_s >= 0 for r in done)
        rep = svc.report()
        assert rep["requests_done"] == 4 and rep["requests_queued"] == 0
        for row in rep["sessions"]:
            assert row["n_updates"] == 2
            # sessions share the jit caches → no session retraces after
            # the service-level warmup
            assert row["retraces_post_warmup"] == 0
        # session ranks match an independent oracle on the final graphs
        for i, hg in enumerate(cur):
            ref = pr.numpy_reference(hg.snapshot(block_size=64),
                                     iterations=300)
            n = svc.sessions[i].n
            assert pr.linf(svc.sessions[i].R[:n],
                           jnp.asarray(ref[:n])) < 1e-8

    def test_fifo_per_stream_one_batch_per_tick(self):
        hg = rmat(8, avg_degree=4, seed=2)
        svc = PageRankService(
            [hg], config=EngineConfig(engine="pallas", block_size=64))
        cur = hg
        for j in range(3):
            dels, ins = random_batch(cur, 1e-2, seed=90 + j)
            svc.submit(0, dels, ins)
            cur = cur.apply_batch(dels, ins)
        assert svc.step() == 1          # one batch per slot per tick
        assert len(svc.queue) == 2
        assert [r.uid for r in svc.finished] == [1]
        svc.run_until_drained()
        assert [r.uid for r in svc.finished] == [1, 2, 3]

    def test_submit_bad_stream_rejected(self):
        svc = PageRankService(
            [rmat(7, avg_degree=4, seed=0)],
            config=EngineConfig(engine="pallas", block_size=64),
            warmup=False)
        with pytest.raises(ValueError, match="out of range"):
            svc.submit(3, np.zeros((0, 2)), np.zeros((0, 2)))
