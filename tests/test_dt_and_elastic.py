"""Two claim-level tests:
 1. the paper's §3.5.2 observation that Dynamic Traversal (DT) cannot beat
    ND — DT marks everything REACHABLE from the update, a superset of DF's
    decay-bounded frontier;
 2. elastic checkpoint restore: a checkpoint written from a 1-device run
    restores onto an 8-device mesh with sharded placement (the framework's
    elastic-rescale claim)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.graphs.generators import rmat


def test_dt_marks_superset_and_matches_reference():
    hg = rmat(11, 8, seed=0)
    cap = 1024 * ((hg.m * 3 + 2 * hg.n) // 1024 + 3)
    dels, ins = random_batch(hg, 1e-3, seed=1)
    hg2 = hg.apply_batch(dels, ins)
    g1 = hg.snapshot(edge_capacity=cap)
    g2 = hg2.snapshot(edge_capacity=cap)
    batch = fr.batch_to_device(g2, dels, ins)
    r_prev = pr.reference_pagerank(g1, iterations=250)
    ref = pr.reference_pagerank(g2, iterations=250)

    # DT's initial affected set ⊇ DF's (reachability vs out-neighbors)
    df0 = fr.initial_affected(g1, g2, batch)
    dt0 = fr.dt_affected(g1, g2, batch)
    assert bool(jnp.all(jnp.logical_or(~df0, dt0)))
    assert int(dt0.sum()) >= int(df0.sum())

    # both converge to the reference.  (The paper's runtime claim — DT
    # "cannot perform better than ND" — is about wall time incl. the BFS
    # marking overhead at 37M+ edge scale; cumulative-edge comparisons are
    # scale-dependent, so only the set/correctness invariants are asserted.)
    dt = pr.dt_pagerank(g1, g2, batch, r_prev, mode="lf")
    df = pr.df_pagerank(g1, g2, batch, r_prev, mode="lf")
    assert dt.stats.converged and df.stats.converged
    assert pr.linf(dt.ranks, ref[:dt.ranks.shape[0]]) < 1e-9
    assert pr.linf(df.ranks, ref[:df.ranks.shape[0]]) < 1e-9
    # DT's first sweep covers at least DF's initial frontier
    assert int(dt0.sum()) >= int(df0.sum())


ELASTIC = textwrap.dedent("""
    import sys, numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import Checkpointer
    ckdir = sys.argv[1]
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    params = {"w": jnp.zeros((16, 4)), "b": jnp.zeros((16,))}
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "step": jnp.int32(0)}
    shard = ({"w": NamedSharding(mesh, P("data", None)),
              "b": NamedSharding(mesh, P("data"))},
             {"m": {"w": NamedSharding(mesh, P("data", None)),
                    "b": NamedSharding(mesh, P("data"))},
              "step": NamedSharding(mesh, P())})
    ck = Checkpointer(ckdir)
    p2, o2, step = ck.restore(7, params, opt, shardings=shard)
    assert step == 7
    w = p2["w"]
    assert len(w.sharding.device_set) == 8, w.sharding
    np.testing.assert_allclose(np.asarray(w),
                               np.arange(64).reshape(16, 4))
    assert int(o2["step"]) == 3
    print("ELASTIC-OK")
""")


@pytest.mark.slow
def test_elastic_restore_onto_8_devices(tmp_path):
    from repro.ckpt.checkpoint import Checkpointer
    # write from THIS (1-device) process
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
              "b": jnp.ones((16,))}
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "step": jnp.int32(3)}
    ck = Checkpointer(str(tmp_path))
    ck.save(params, opt, 7)
    # restore in a subprocess that sees 8 devices, with sharded placement
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", ELASTIC, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ELASTIC-OK" in r.stdout
