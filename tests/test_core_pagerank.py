"""Correctness of the six PageRank variants against the numpy oracle,
plus the paper's stability, fault-tolerance, and helping properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (HostGraph, FaultPlan, df_pagerank, dt_pagerank,
                        nd_pagerank, static_pagerank, reference_pagerank,
                        numpy_reference, linf)
from repro.core.delta import random_batch, pure_deletion_batch
from repro.core.frontier import (batch_to_device, initial_affected,
                                 initial_affected_with_helping, dt_affected)
from repro.graphs.generators import rmat, erdos_renyi, grid_road, kmer_chains

TAU = 1e-10
BAND = 1e-8          # paper: error stays within [0, 1e-9) at τ=1e-10


@pytest.fixture(scope="module")
def dyn_setup():
    hg0 = rmat(11, avg_degree=8, seed=3)
    g0 = hg0.snapshot(block_size=128)
    r_prev = jnp.asarray(numpy_reference(g0, iterations=300))
    dels, ins = random_batch(hg0, 1e-3, seed=11)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=128)
    ref1 = numpy_reference(g1, iterations=300)
    batch = batch_to_device(g1, dels, ins)
    return g0, g1, batch, r_prev, ref1


@pytest.mark.parametrize("gen", [rmat, erdos_renyi])
@pytest.mark.parametrize("mode,engine", [("bb", "dense"), ("bb", "blocked"),
                                         ("lf", "blocked")])
def test_static_matches_oracle(gen, mode, engine):
    hg = gen(9 if gen is rmat else 512, avg_degree=6, seed=1)
    g = hg.snapshot(block_size=64)
    ref = numpy_reference(g, iterations=300)
    res = static_pagerank(g, mode=mode, engine=engine, tau=TAU)
    assert res.converged
    assert linf(res.ranks, ref) < BAND


def test_reference_pagerank_jax_vs_numpy():
    hg = grid_road(48, seed=0)
    g = hg.snapshot(block_size=64)
    assert linf(reference_pagerank(g, iterations=200),
                numpy_reference(g, iterations=200)) < 1e-12


@pytest.mark.parametrize("variant", ["nd", "dt", "df"])
@pytest.mark.parametrize("mode", ["bb", "lf"])
def test_dynamic_variants_match_oracle(dyn_setup, variant, mode):
    g0, g1, batch, r_prev, ref1 = dyn_setup
    if variant == "nd":
        res = nd_pagerank(g1, r_prev, mode=mode)
    elif variant == "dt":
        res = dt_pagerank(g0, g1, batch, r_prev, mode=mode)
    else:
        res = df_pagerank(g0, g1, batch, r_prev, mode=mode)
    assert res.converged
    assert linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND


def test_ranks_sum_to_one(dyn_setup):
    g0, g1, batch, r_prev, _ = dyn_setup
    res = df_pagerank(g0, g1, batch, r_prev, mode="lf")
    assert abs(float(res.ranks[:g1.n].sum()) - 1.0) < 1e-6


def test_stability_delete_then_reinsert(dyn_setup):
    """Paper §5.2.3: delete a batch, update, re-insert, update — final ranks
    must match the original ones (L∞ ≈ 0)."""
    hg0 = rmat(10, avg_degree=8, seed=5)
    g0 = hg0.snapshot(block_size=128)
    r0 = jnp.asarray(numpy_reference(g0, iterations=300))
    dels = pure_deletion_batch(hg0, 1e-3, seed=2)
    hg1 = hg0.apply_batch(dels, np.zeros((0, 2)))
    g1 = hg1.snapshot(block_size=128)
    b1 = batch_to_device(g1, dels, np.zeros((0, 2)))
    r1 = df_pagerank(g0, g1, b1, r0, mode="lf").ranks
    hg2 = hg1.apply_batch(np.zeros((0, 2)), dels)
    g2 = hg2.snapshot(block_size=128)
    b2 = batch_to_device(g2, np.zeros((0, 2)), dels)
    r2 = df_pagerank(g1, g2, b2, r1, mode="lf").ranks
    assert linf(r2[:g0.n], r0[:g0.n]) < BAND


def test_initial_affected_is_out_neighbors(dyn_setup):
    g0, g1, batch, _, _ = dyn_setup
    aff = np.asarray(initial_affected(g0, g1, batch))
    expect = np.zeros(g1.n_pad, dtype=bool)
    b = np.asarray(batch)
    srcs = set(int(u) for u, v in b if u < g1.n)
    for g, hg_edges in ((g0, None), (g1, None)):
        src = np.asarray(g.src)[:g.m]
        dst = np.asarray(g.dst)[:g.m]
        for u, v in zip(src, dst):
            if int(u) in srcs:
                expect[v] = True
    # self-loops mean sources mark themselves too — per paper, source u is
    # marked only via its self-loop (u,u): out-neighbor of u includes u.
    assert (aff == expect[:g1.n_pad]).all()


def test_dt_superset_of_df_initial(dyn_setup):
    g0, g1, batch, _, _ = dyn_setup
    df0 = initial_affected(g0, g1, batch)
    dt0 = dt_affected(g0, g1, batch)
    assert bool(jnp.all(dt0 | ~df0))   # DF initial ⊆ DT reachable set


def test_helping_equals_faultfree_marking(dyn_setup):
    g0, g1, batch, _, _ = dyn_setup
    full = initial_affected(g0, g1, batch)
    fp = np.zeros(batch.shape[0], dtype=bool)
    fp[::3] = True   # first pass only processed a third of the updates
    aff, C, rounds = initial_affected_with_helping(
        g0, g1, batch, jnp.asarray(fp))
    assert bool(jnp.all(aff == full))
    assert bool(C.all())
    assert rounds >= 1


class TestFaultTolerance:
    def _setup(self):
        hg0 = rmat(10, avg_degree=8, seed=7)
        g0 = hg0.snapshot(block_size=64)
        r_prev = jnp.asarray(numpy_reference(g0, iterations=300))
        dels, ins = random_batch(hg0, 1e-3, seed=1)
        hg1 = hg0.apply_batch(dels, ins)
        g1 = hg1.snapshot(block_size=64)
        ref1 = numpy_reference(g1, iterations=300)
        return g0, g1, batch_to_device(g1, dels, ins), r_prev, ref1

    def test_lf_survives_crashes(self):
        g0, g1, batch, r_prev, ref1 = self._setup()
        plan = FaultPlan(n_threads=8, n_crashed=6, crash_window=4, seed=3)
        res = df_pagerank(g0, g1, batch, r_prev, mode="lf", faults=plan)
        assert res.converged
        assert linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND

    def test_bb_stalls_on_crash(self):
        g0, g1, batch, r_prev, _ = self._setup()
        plan = FaultPlan(n_threads=8, n_crashed=1, crash_window=1, seed=3)
        res = df_pagerank(g0, g1, batch, r_prev, mode="bb", faults=plan)
        assert res.stats.dnf and not res.converged

    def test_lf_survives_delays(self):
        g0, g1, batch, r_prev, ref1 = self._setup()
        plan = FaultPlan(n_threads=8, delay_prob=0.4, delay_ms=100, seed=5)
        res = df_pagerank(g0, g1, batch, r_prev, mode="lf", faults=plan)
        assert res.converged
        assert linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND

    def test_crash_slowdown_is_graceful(self):
        """More crashes → more simulated time, but always completes (Fig 9)."""
        g0, g1, batch, r_prev, _ = self._setup()
        times = []
        for k in [0, 4, 6]:
            plan = FaultPlan(n_threads=8, n_crashed=k, crash_window=1, seed=9)
            res = df_pagerank(g0, g1, batch, r_prev, mode="lf", faults=plan)
            assert res.converged
            times.append(res.stats.sim_time_ms)
        assert times[0] <= times[1] <= times[2] * 1.001


class TestDynamicGraphStore:
    def test_apply_batch_roundtrip(self):
        hg = erdos_renyi(256, avg_degree=4, seed=0)
        dels, ins = random_batch(hg, 0.01, seed=1)
        hg2 = hg.apply_batch(dels, ins)
        assert hg2.m == hg.m - len(dels) + len(ins)
        hg3 = hg2.apply_batch(ins, dels)
        assert hg3.m == hg.m
        assert (hg3.edges == hg.edges).all()

    def test_snapshot_degrees(self):
        hg = rmat(8, avg_degree=4, seed=2)
        g = hg.snapshot(block_size=32)
        deg = np.asarray(g.out_deg)[:g.n]
        e = hg.edges
        expect = np.bincount(e[:, 0], minlength=g.n) + 1  # + self-loop
        assert (deg == expect).all()

    def test_block_ptrs_partition_edges(self):
        hg = rmat(8, avg_degree=4, seed=2)
        g = hg.snapshot(block_size=32)
        ibp = np.asarray(g.in_block_ptr)
        assert ibp[0] == 0 and ibp[-1] == g.m
        assert (np.diff(ibp) >= 0).all()
        dst = np.asarray(g.dst)[:g.m]
        for b in range(0, g.n_blocks, max(1, g.n_blocks // 7)):
            sl = dst[ibp[b]:ibp[b + 1]]
            assert ((sl >= b * 32) & (sl < (b + 1) * 32)).all()
