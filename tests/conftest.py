import jax

# Paper uses f64 ranks (§5.1.2); enable x64 for validation-grade tolerances.
# Model code is dtype-explicit everywhere, so this does not change models.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (perf-trajectory recording)")
