"""Residual forward-push driver (repro.core.push_engine, ISSUE 10).

Covers the driver="push" tentpole through the public surface:

* cold solve and streamed delete/insert batches reach the pull driver's
  fixed point (vs the independent numpy oracle AND a per-batch blocked
  df oracle) at equal L∞;
* the exact invariant ``r = b + M·p − p`` holds bit-tight after every
  O(batch) residual seed — the correctness core of the scheme;
* push does strictly less edge work than pull on the same stream (the
  ≥5× smoke-scenario gate lives in tests/test_bench_smoke.py);
* zero post-warmup retraces on the push driver's own jit cache;
* tiering composes: at ``device_budget_bytes = pool/2`` pushed-to
  non-resident rows defer into the refill bitmap (never a mid-sweep
  sync), the final state is parity-clean and the counters land in
  ``report().tiering`` — the ISSUE 10 acceptance criterion;
* work accounting: per-batch sweeps/edges history plus the push-only
  ``residual_mass_last`` / ``pushed_blocks`` in ``report()`` and the
  service per-slot rows (satellite);
* config validation, the dt/recompute contract, delete+reinsert, and
  the always-running push-vs-pull fixed-point property across seeds and
  graph families (hypothesis form in tests/test_properties.py).
"""
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (EngineConfig, IntegrityConfig, PageRankService,
                       PageRankSession, SweepCapWarning)
from repro.core import frontier as fr
from repro.core import pagerank as pr
from repro.core import push_engine as pshe
from repro.core import tiering
from repro.core.delta import random_batch
from repro.core.stream import run_stream
from repro.graphs.generators import grid_road, kmer_chains, powerlaw, rmat

ALPHA = 0.85
TAU = 1e-10
# both drivers stop at per-vertex residual/change <= tau, so each sits
# within ||r||_1 * a/(1-a) <= n * tau * a/(1-a) of the fixed point
def _bound(n):
    return n * TAU * ALPHA / (1.0 - ALPHA)


def _cfg(driver="push", budget=None, **kw):
    return EngineConfig(engine="pallas", block_size=64, driver=driver,
                        device_budget_bytes=budget, **kw)


def _pool_bytes(hg, block_size=64, dtype=np.float64):
    g0 = hg.snapshot(block_size=block_size)
    src, dst = g0.in_edges_host()
    pool = tiering.HostTilePool.from_edges(
        dst, src, g0.n_pad, g0.n_pad, block=block_size,
        dtype=np.dtype(dtype))
    return int(pool.nbytes)


def _stream(hg, k, *, rate=None, seed=50):
    batches, cur = [], hg
    for i in range(k):
        dels, ins = random_batch(cur, rate or 8 / cur.m, seed=seed + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    return batches, cur


def _host_residual(sess):
    """The exact invariant residual from host truth (the yardstick the
    device-resident ``_residual`` must track)."""
    return pshe.residual_from_host(
        sess.hg, sess._out_deg_host, np.asarray(sess.R),
        float(sess.config.alpha))


# ---------------------------------------------------------------------------
# config + construction contract
# ---------------------------------------------------------------------------

class TestConfigValidation:
    def test_unknown_driver_rejected(self):
        with pytest.raises(ValueError, match="driver='spin' invalid"):
            EngineConfig(driver="spin")

    def test_push_requires_pallas(self):
        with pytest.raises(ValueError, match="pallas"):
            EngineConfig(engine="dense", driver="push")

    def test_push_requires_lf_mode(self):
        with pytest.raises(ValueError, match="mode must be 'lf'"):
            EngineConfig(engine="pallas", mode="bb", driver="push")

    def test_push_rejects_integrity(self):
        with pytest.raises(ValueError, match="integrity"):
            EngineConfig(engine="pallas", driver="push",
                         integrity=IntegrityConfig())

    def test_push_requires_stream_session(self):
        g = rmat(7, avg_degree=4, seed=0).snapshot(block_size=64)
        with pytest.raises(ValueError, match="from_graph"):
            PageRankSession.from_snapshot(g, config=_cfg())

    def test_driver_defaults_to_pull(self):
        assert EngineConfig().driver == "pull"


# ---------------------------------------------------------------------------
# fixed-point parity + the residual invariant
# ---------------------------------------------------------------------------

def test_cold_solve_matches_reference():
    hg = rmat(9, avg_degree=6, seed=3)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    ref = pr.numpy_reference(hg.snapshot(block_size=64), iterations=300)
    assert float(pr.linf(sess.R[:hg.n], jnp.asarray(ref[:hg.n]))) \
        < _bound(hg.n)
    # at exit every residual entry is at/below tolerance (or the ulp floor)
    assert float(np.abs(np.asarray(sess._residual)).max()) < 4 * TAU
    sess.close()


def test_invariant_exact_across_updates():
    """r = b + M·p − p must hold to fp-accumulation accuracy after every
    O(batch) seed + drive — deletions included.  This is the load-bearing
    invariant: parity, tiered staleness repair and the a-posteriori error
    bound all derive from it."""
    hg = rmat(8, avg_degree=5, seed=7)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    batches, _ = _stream(hg, 4, rate=3e-2, seed=90)
    for dels, ins in batches:
        res = sess.update(dels, ins)
        assert res.converged
        drift = np.abs(np.asarray(sess._residual) - _host_residual(sess))
        assert float(drift.max()) < 1e-12, float(drift.max())
    sess.close()


def test_stream_matches_blocked_df_oracle():
    """Per-batch parity against the pull df oracle (the blocked-engine
    lineage test_stream.py runs for the pull driver) at equal L∞."""
    hg = rmat(9, avg_degree=6, seed=3)
    g = hg.snapshot(block_size=64)
    r0 = jnp.asarray(pr.numpy_reference(g, iterations=300))
    batches, cur = _stream(hg, 3, rate=5e-3, seed=100)
    sess = PageRankSession.from_graph(hg, config=_cfg(), r0=r0)
    r_ref, prev = r0, hg
    for dels, ins in batches:
        res = sess.update(dels, ins)
        g_prev = prev.snapshot(block_size=64)
        prev = prev.apply_batch(dels, ins)
        g_new = prev.snapshot(block_size=64)
        oracle = pr.df_pagerank(
            g_prev, g_new, fr.batch_to_device(g_new, dels, ins), r_ref,
            mode="lf", engine="pallas")
        r_ref = oracle.ranks
        assert res.stats.converged
        assert float(pr.linf(res.ranks, oracle.ranks)) < 2 * _bound(hg.n)
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)
    assert float(pr.linf(sess.R[:cur.n], jnp.asarray(ref[:cur.n]))) < 1e-8
    sess.close()


def test_delete_then_reinsert_returns_to_fixed_point():
    hg = kmer_chains(1 << 9, seed=4)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    before = np.asarray(sess.R).copy()
    rng = np.random.default_rng(5)
    pick = rng.choice(hg.m, size=12, replace=False)
    edges = np.stack([hg._keys[pick] // hg.n,
                      hg._keys[pick] % hg.n], axis=1)
    assert sess.update(edges, np.zeros((0, 2), np.int64)).converged
    assert sess.update(np.zeros((0, 2), np.int64), edges).converged
    back = np.asarray(sess.R)
    assert float(np.abs(back - before).max()) < 2 * _bound(hg.n)
    sess.close()


# ---------------------------------------------------------------------------
# work + retrace accounting
# ---------------------------------------------------------------------------

def test_zero_retraces_and_less_edge_work_than_pull():
    hg = kmer_chains(1 << 10, seed=4)
    g = hg.snapshot(block_size=64)
    r0 = jnp.asarray(pr.numpy_reference(g, iterations=300))
    batches, cur = _stream(hg, 4, seed=70)
    reps = {d: run_stream(hg, batches, block_size=64, r0=r0,
                          active_policy="rc", driver=d)
            for d in ("pull", "push")}
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)
    edges = {}
    for d, rep in reps.items():
        assert rep.retraces_post_warmup == 0, d
        assert all(r.stats.converged for r in rep.results), d
        assert float(pr.linf(rep.final_ranks[:cur.n],
                             jnp.asarray(ref[:cur.n]))) < 1e-8, d
        edges[d] = sum(r.stats.edges_processed for r in rep.results)
    # work ∝ residual mass beats frontier × sweeps on every stream; the
    # scenario-specific ≥5× gate is asserted on the committed smoke record
    assert edges["push"] < edges["pull"], edges


def test_report_work_accounting():
    hg = rmat(8, avg_degree=5, seed=7)
    batches, _ = _stream(hg, 3, rate=2e-2, seed=20)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    for dels, ins in batches:
        res = sess.update(dels, ins)
        assert res.residual_mass is not None and res.residual_mass >= 0
        assert res.pushed_blocks is not None and res.pushed_blocks > 0
    rep = sess.report()
    assert rep.driver == "push"
    assert len(rep.sweeps_history) == 3
    assert len(rep.edges_processed_history) == 3
    assert rep.edges_processed_history == [
        r.stats.edges_processed for r in sess._history]
    assert rep.residual_mass_last is not None
    assert rep.pushed_blocks is not None and rep.pushed_blocks > 0
    sess.close()

    pull = PageRankSession.from_graph(hg, config=_cfg(driver="pull"))
    pull.update(*batches[0])
    prep = pull.report()
    assert prep.driver == "pull"
    assert len(prep.sweeps_history) == 1
    assert prep.residual_mass_last is None and prep.pushed_blocks is None
    pull.close()


def test_service_rows_expose_driver_accounting():
    hg = rmat(8, avg_degree=5, seed=11)
    svc = PageRankService(
        [PageRankSession.from_graph(hg, config=_cfg()),
         PageRankSession.from_graph(hg, config=_cfg(driver="pull"))],
        warmup=False)
    batches, _ = _stream(hg, 2, rate=1e-2, seed=31)
    # drain between submits: continuous dispatch coalesces queued batches,
    # which would fold both updates into one history entry
    for dels, ins in batches:
        for s in (0, 1):
            svc.submit(s, dels, ins)
        svc.run_until_drained()
    rows = svc.report()["sessions"]
    assert rows[0]["driver"] == "push"
    assert rows[0]["pushed_blocks"] > 0
    assert rows[0]["residual_mass_last"] is not None
    assert len(rows[0]["sweeps_history"]) == 2
    assert rows[1]["driver"] == "pull"
    assert "pushed_blocks" not in rows[1]
    for row in rows:
        assert len(row["edges_processed_history"]) == 2
        assert row["total_edges_processed"] == \
            sum(row["edges_processed_history"])
    svc.stop(drain=False)


# ---------------------------------------------------------------------------
# recompute / variant contract
# ---------------------------------------------------------------------------

def test_dt_update_and_pull_recompute_variants_rejected():
    hg = rmat(7, avg_degree=4, seed=2)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    dels, ins = random_batch(hg, 1e-2, seed=8)
    with pytest.raises(ValueError, match="dt"):
        sess.update(dels, ins, variant="dt")
    for variant in ("df", "dt"):
        with pytest.raises(ValueError, match="static' or 'nd"):
            sess.recompute(variant)
    sess.close()


def test_recompute_nd_and_static_resolve():
    hg = rmat(8, avg_degree=5, seed=9)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    ref = pr.numpy_reference(hg.snapshot(block_size=64), iterations=300)
    for variant in ("nd", "static"):
        out = sess.recompute(variant)
        assert out.stats.converged
        assert float(pr.linf(out.ranks[:hg.n], jnp.asarray(ref[:hg.n]))) \
            < _bound(hg.n), variant
    sess.close()


def test_nd_update_rebuilds_residual():
    hg = rmat(8, avg_degree=5, seed=9)
    sess = PageRankSession.from_graph(hg, config=_cfg())
    dels, ins = random_batch(hg, 2e-2, seed=3)
    assert sess.update(dels, ins, variant="nd").converged
    drift = np.abs(np.asarray(sess._residual) - _host_residual(sess))
    assert float(drift.max()) < 1e-12
    sess.close()


# ---------------------------------------------------------------------------
# tiering composition — the ISSUE 10 acceptance criterion
# ---------------------------------------------------------------------------

def test_tiered_half_budget_parity_and_counters():
    """driver='push' under device_budget_bytes = pool/2: pushed-to
    non-resident rows defer into the refill bitmap (never a mid-sweep
    sync), the refill loop drains every batch, the final state is
    parity-clean vs the untiered push session, and the tiering counters
    are visible in report()."""
    hg = grid_road(32, seed=7)
    budget = _pool_bytes(hg) // 2
    batches, cur = _stream(hg, 3, rate=4e-3, seed=41)

    with warnings.catch_warnings():
        warnings.simplefilter("error", SweepCapWarning)
        tiered = PageRankSession.from_graph(hg, config=_cfg(budget=budget))
        plain = PageRankSession.from_graph(hg, config=_cfg())
        tiered.warmup(), plain.warmup()
        for dels, ins in batches:
            assert tiered.update(dels, ins).converged
            assert plain.update(dels, ins).converged

    linf = float(np.abs(np.asarray(tiered.ranks)
                        - np.asarray(plain.ranks)).max())
    assert linf < 2 * _bound(hg.n), linf
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)
    assert float(pr.linf(tiered.R[:cur.n], jnp.asarray(ref[:cur.n]))) \
        < _bound(cur.n)

    rep = tiered.report()
    t = rep.tiering
    assert t is not None
    assert t["misses"] > 0                 # budget pressure was real
    assert t["refill_drives"] > 0          # deferrals happened and drained
    assert t["slab_bytes"] <= budget
    assert rep.retraces_post_warmup == 0
    # random insertions may grow the tile pool past a capacity bucket —
    # that first-visit compile is the legitimate, separately-counted kind
    assert rep.bucket_retraces_post_warmup <= 1
    assert rep.device_bytes["tile_pool"] <= budget
    # tiered invariant repair is exact too: host-truth residual agrees on
    # every resident row (stale rows sit in the deferred bitmap — drained)
    drift = np.abs(np.asarray(tiered._residual) - _host_residual(tiered))
    assert float(drift.max()) < 1e-12
    tiered.close(), plain.close()


# ---------------------------------------------------------------------------
# push-vs-pull fixed point across graph families (always-running form of
# the tests/test_properties.py hypothesis property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,seed", [
    ("rmat", 1), ("rmat", 5), ("powerlaw", 2), ("kmer", 3),
])
def test_push_pull_same_fixed_point(family, seed):
    hg = {"rmat": lambda: rmat(8, avg_degree=5, seed=seed),
          "powerlaw": lambda: powerlaw(300, avg_degree=6, seed=seed),
          "kmer": lambda: kmer_chains(400, seed=seed)}[family]()
    batches, cur = _stream(hg, 2, rate=2e-2, seed=seed * 13 + 1)
    # append a delete+reinsert pair of an original edge
    e = np.array([[int(hg._keys[0] // hg.n), int(hg._keys[0] % hg.n)]],
                 np.int64)
    zero = np.zeros((0, 2), np.int64)
    batches += [(e, zero), (zero, e)]
    cur = cur.apply_batch(e, zero).apply_batch(zero, e)

    finals = {}
    for driver in ("pull", "push"):
        sess = PageRankSession.from_graph(hg, config=_cfg(driver=driver))
        for dels, ins in batches:
            assert sess.update(dels, ins).converged, driver
        finals[driver] = np.asarray(sess.R[:hg.n]).copy()
        sess.close()
    gap = float(np.abs(finals["push"] - finals["pull"]).max())
    assert gap < 2 * _bound(hg.n), (family, seed, gap)
