"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config and runs one forward/train step on CPU,
asserting output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch import smoke

ARCHS = [a for a in list_archs() if get_arch(a).family != "pagerank"]


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    spec = get_arch(arch)
    out = smoke.run_smoke_step(spec)
    assert np.isfinite(out["loss"]), f"{arch}: non-finite loss"
    assert out["finite"], f"{arch}: NaN/Inf in updated params"
    assert out["shapes_ok"], f"{arch}: param shapes changed by the update"


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_over_steps(arch):
    """A few steps on a fixed batch must reduce the loss (learns at all)."""
    from repro.optim import adam
    from repro.train import trainer
    spec = get_arch(arch)
    cfg, loss_fn, params, batch = smoke.smoke_setup(spec, seed=1)
    acfg = adam.AdamConfig(lr=3e-3, warmup_steps=1, total_steps=30,
                           schedule="constant")
    step = jax.jit(trainer.build_train_step(loss_fn, acfg))
    opt = adam.init_state(params, acfg)
    first = None
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first, f"{arch}: loss did not decrease ({first}->{last})"


# -- LM-specific serve-path smoke --------------------------------------------

LM_ARCHS = [a for a in ARCHS if get_arch(a).family == "lm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode_consistency(arch):
    """Prefill(prompt) + decode(next) must match full forward logits.

    MoE configs get a no-drop capacity factor: GShard capacity dropping is
    batch-dependent, so dropped-token cells legitimately differ between the
    batched forward and the serve path."""
    import dataclasses
    from repro.models.transformer import model as M
    spec = get_arch(arch)
    cfg = spec.smoke_cfg()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    logits_full, _ = M.forward(params, tokens, cfg)
    logits_pre, cache = M.prefill(params, tokens[:, :-1], cfg,
                                  cache_len=S + 4)
    # prefill's last-token logits == forward logits at position S-2
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, -2]),
                               rtol=2e-2, atol=2e-2)
    logits_dec, cache = M.decode_step(params, cache, tokens[:, -1],
                                      jnp.int32(S - 1), cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_output_shapes(arch):
    from repro.models.transformer import model as M
    spec = get_arch(arch)
    cfg = spec.smoke_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 32), jnp.int32)
    logits, aux = M.forward(params, tokens, cfg)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert jnp.isfinite(logits).all()
    if cfg.moe:
        assert float(aux) >= 0.0


# -- retrieval / sampled-path smoke -------------------------------------------

def test_autoint_retrieval_smoke():
    from repro.models.recsys import autoint as A
    spec = get_arch("autoint")
    cfg = spec.smoke_cfg()
    params = A.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.integers(0, cfg.total_rows,
                                 (1, cfg.n_user_fields)), jnp.int32)
    c = jnp.asarray(rng.integers(0, cfg.total_rows, (512, 3)), jnp.int32)
    scores, idx = jax.jit(
        lambda p, u, c: A.retrieval_scores(p, cfg, u, c, top_k=10)
    )(params, u, c)
    assert scores.shape == (10,) and idx.shape == (10,)
    assert jnp.isfinite(scores).all()
    # top-k really is the k largest
    all_scores = A.item_vectors(params, cfg, c) @ A.user_vector(
        params, cfg, u)[0]
    np.testing.assert_allclose(
        np.sort(np.asarray(scores)),
        np.sort(np.sort(np.asarray(all_scores))[-10:]), rtol=1e-4,
        atol=1e-5)


def test_graphsage_sampled_path():
    from repro.models.gnn import graphsage
    spec = get_arch("graphsage-reddit")
    cfg = spec.smoke_cfg()
    params = graphsage.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, f1, f2 = 8, 3, 2
    feats = [jnp.asarray(rng.normal(size=(B, cfg.d_feat)), jnp.float32),
             jnp.asarray(rng.normal(size=(B, f1, cfg.d_feat)), jnp.float32),
             jnp.asarray(rng.normal(size=(B, f1, f2, cfg.d_feat)),
                         jnp.float32)]
    logits = graphsage.forward_sampled(params, cfg, feats)
    assert logits.shape == (B, cfg.n_out)
    assert jnp.isfinite(logits).all()


def test_mixtral_sliding_window_masks_history():
    """SWA: tokens beyond the window must not affect the current logits."""
    from repro.models.transformer import model as M
    spec = get_arch("mixtral-8x22b")
    cfg = spec.smoke_cfg()        # window 32
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    S = 3 * cfg.sliding_window
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (1, S), 0, cfg.vocab)
    logits, _ = M.forward(params, tokens, cfg)
    # perturb a token far outside the last window
    tokens2 = tokens.at[0, 2].set((tokens[0, 2] + 1) % cfg.vocab)
    logits2, _ = M.forward(params, tokens2, cfg)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-5)
    # ...but it must affect logits inside its own window
    assert not np.allclose(np.asarray(logits[0, 3]),
                           np.asarray(logits2[0, 3]), atol=1e-5)
