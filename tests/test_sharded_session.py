"""Sharded-session parity under a real 8-device mesh.

The acceptance bar for the topology-aware API: a
``PageRankSession(topology="sharded")`` must match the single-device
blocked oracle to tolerance on the static solve **and** along a 20-batch
DF stream, for all three partitioners, with zero post-warmup retraces
reported through ``session.report()``.

Runs in a subprocess with 8 forced host devices (the XLA device count is
locked at first jax init, so the main test process must keep seeing one
device) — hence the ``multidevice`` marker (wired in pytest.ini).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.api import EngineConfig, PageRankSession
    from repro.core import pagerank as pr
    from repro.core.delta import random_batch
    from repro.graphs.generators import rmat

    assert len(jax.devices()) == 8
    hg0 = rmat(10, avg_degree=6, seed=3)
    g0 = hg0.snapshot(block_size=64)
    ref0 = pr.numpy_reference(g0, iterations=300)
    r0 = jnp.asarray(ref0)

    batches = []
    cur = hg0
    for i in range(20):
        dels, ins = random_batch(cur, 2e-3, seed=900 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)

    # single-device blocked oracle, per-batch ranks
    oracle = PageRankSession.from_graph(
        hg0, config=EngineConfig(engine="blocked"), r0=r0)
    oracle_ranks = []
    for dels, ins in batches:
        res = oracle.update(dels, ins)
        assert res.stats.converged
        oracle_ranks.append(oracle.ranks[:oracle.n].copy())

    cuts = {}
    for part in ("contiguous", "hash", "bfs_blocks"):
        cfg = EngineConfig(topology="sharded", n_shards=8,
                           partitioner=part)
        # static solve parity
        s0 = PageRankSession.from_graph(hg0, config=cfg)
        err0 = float(np.max(np.abs(s0.ranks[:hg0.n] - ref0[:hg0.n])))
        assert err0 < 1e-8, (part, err0)
        s0.close()

        # 20-batch DF stream parity, zero post-warmup retraces
        sess = PageRankSession.from_graph(hg0, config=cfg, r0=r0)
        assert sess.device_footprint == tuple(range(8))
        sess.warmup()
        for i, (dels, ins) in enumerate(batches):
            res = sess.update(dels, ins)
            assert res.stats.converged, (part, i)
            err = float(np.max(np.abs(sess.ranks[:sess.n]
                                      - oracle_ranks[i])))
            assert err < 1e-9, (part, i, err)
        rep = sess.report()
        assert rep.retraces_post_warmup == 0, (part, rep)
        assert rep.n_updates == 20
        assert rep.topology == "sharded" and rep.n_shards == 8
        assert rep.partitioner == part
        assert 0.0 <= rep.edge_cut <= 1.0
        assert rep.collective_bytes_per_sweep > 0
        cuts[part] = rep.edge_cut
        # the O(batch)-maintained cut matches a from-scratch recount of
        # the realized owner assignment on the final graph
        from repro.graphs.partition import edge_cut
        expect = edge_cut(sess.hg, sess._inv // sess.runtime.n_loc)
        assert abs(rep.edge_cut - expect) < 1e-12, (part, rep.edge_cut,
                                                    expect)

        # topology-transparent reads on the final graph
        ranks = sess.ranks
        ids = [0, 7, sess.n - 1]
        np.testing.assert_allclose(sess.query(ids), ranks[ids])
        vals, idx = sess.top_k(5)
        np.testing.assert_allclose(vals, ranks[idx])
        order = np.argsort(ranks[:sess.n])[::-1][:5]
        np.testing.assert_allclose(vals, ranks[order])
        sess.close()

    # locality-recovering partition beats the worst-case hash cut on
    # this power-law graph
    assert cuts["bfs_blocks"] < cuts["hash"], cuts

    # shard-aware service placement: sharded sessions declare their mesh
    # footprint; the queue still runs one batch per slot per tick
    from repro.api import PageRankService
    s_a = PageRankSession.from_graph(
        hg0, config=EngineConfig(topology="sharded", n_shards=4), r0=r0)
    s_b = PageRankSession.from_graph(
        hg0, config=EngineConfig(engine="blocked"), r0=r0)
    svc = PageRankService([s_a, s_b], warmup=False)
    assert svc.placements()[0] == (0, 1, 2, 3)
    assert len(svc.placements()[1]) == 1
    d0, i0 = batches[0]
    svc.submit(0, d0, i0); svc.submit(1, d0, i0)
    svc.run_until_drained()
    rep = svc.report()
    assert rep["requests_done"] == 2 and rep["requests_queued"] == 0
    assert rep["sessions"][0]["topology"] == "sharded"
    assert rep["sessions"][0]["n_shards"] == 4
    assert rep["placements"]["0"] == [0, 1, 2, 3]
    err = float(np.max(np.abs(s_a.ranks[:s_a.n] - s_b.ranks[:s_b.n])))
    assert err < 1e-9, err
    print("SHARDED-OK", cuts)
""")


@pytest.mark.multidevice
@pytest.mark.slow
def test_sharded_session_parity_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-OK" in out.stdout
