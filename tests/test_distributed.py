"""Distributed PageRank tests — run in a subprocess with 8 forced host
devices (XLA device count is locked at first jax init, so the main test
process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.graphs.generators import rmat
    from repro.core import numpy_reference
    from repro.core.delta import random_batch
    from repro.core.distributed import run_distributed
    from repro.core.frontier import batch_to_device, initial_affected

    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    assert len(jax.devices()) == 8
    hg0 = rmat(10, avg_degree=8, seed=3)
    g0 = hg0.snapshot(block_size=64)
    ref0 = numpy_reference(g0, iterations=300)
    dels, ins = random_batch(hg0, 1e-3, seed=11)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    ref1 = numpy_reference(g1, iterations=300)
    b = batch_to_device(g1, dels, ins)
    aff0 = initial_affected(g0, g1, b)
    rp = jnp.asarray(ref0)

    def err(R):
        return float(np.max(np.abs(np.asarray(R)[:hg1.n] - ref1[:hg1.n])))

    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="full")
    assert st.converged and err(R) < 1e-8, (st, err(R))

    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="delta",
                            delta_capacity=4096)
    assert st.converged and err(R) < 1e-8, (st, err(R))
    assert st.delta_exchanges > 0

    # wire-compressed variants must converge to the same answer
    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="bf16", tau=1e-7,
                            dtype=jnp.float32)
    assert st.converged and err(R) < 1e-4, (st, err(R))
    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="delta",
                            delta_capacity=4096,
                            marks_dtype=jnp.int8)
    assert st.converged and err(R) < 1e-8, (st, err(R))

    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="full", local_gs_sweeps=3)
    assert st.converged and err(R) < 1e-8, (st, err(R))

    # ring exchange (overlappable collective_permute schedule)
    R, st = run_distributed(hg1, mesh, r_prev=rp, affected0=aff0,
                            expand=True, exchange="ring")
    assert st.converged and err(R) < 1e-8, (st, err(R))

    R, st = run_distributed(hg1, mesh, expand=False)   # static from scratch
    assert st.converged and err(R) < 1e-8, (st, err(R))
    print("DIST-OK")
""")


@pytest.mark.multidevice
@pytest.mark.slow
def test_distributed_pagerank_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DIST-OK" in out.stdout
