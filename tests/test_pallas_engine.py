"""Parity of the fused Pallas frontier engine vs the oracles.

Covers the acceptance matrix of the fused-engine work: static + dynamic
batches (insertions + deletions) in f32 and f64, the OR-semiring expansion
kernel vs the dense frontier marking, fault-plan runs (delays + crashes),
the incremental tile builder, and the zero-host-sync driver contract.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.core import blocked as blk
from repro.core import frontier as fr
from repro.core import pallas_engine as pe
from repro.core.delta import random_batch, signed_edge_delta
from repro.core.faults import FaultPlan
from repro.core.graph import HostGraph, out_neighbor_or
from repro.core.incremental import IncrementalPullMatrix
from repro.graphs.generators import rmat, grid_road
from repro.kernels.block_spmv import ops

TAU64 = 1e-10
TAU32 = 1e-7
BAND64 = 1e-8      # paper: error within [0, 1e-9) at τ=1e-10 (f64)
BAND32 = 1e-6      # acceptance: L∞ ≤ 1e-6 for f32 runs


@pytest.fixture(scope="module")
def dyn():
    hg0 = rmat(9, avg_degree=6, seed=3)
    g0 = hg0.snapshot(block_size=64)
    r_prev = jnp.asarray(pr.numpy_reference(g0, iterations=300))
    dels, ins = random_batch(hg0, 5e-3, seed=11)
    hg1 = hg0.apply_batch(dels, ins)
    g1 = hg1.snapshot(block_size=64)
    ref1 = pr.numpy_reference(g1, iterations=300)
    batch = fr.batch_to_device(g1, dels, ins)
    return hg0, g0, g1, batch, r_prev, ref1, dels, ins


@pytest.mark.parametrize("mode", ["bb", "lf"])
def test_static_matches_numpy_reference(mode):
    hg = rmat(9, avg_degree=6, seed=1)
    g = hg.snapshot(block_size=64)
    ref = pr.numpy_reference(g, iterations=300)
    res = pr.static_pagerank(g, mode=mode, engine="pallas", tau=TAU64)
    assert res.converged
    assert pr.linf(res.ranks, ref) < BAND64


@pytest.mark.slow
def test_static_pallas_kernel_backend_full_convergence():
    """Full convergence through the *Pallas kernels* in interpret mode —
    validates the kernel semantics end-to-end (the un-marked engine tests
    run the platform default backend, i.e. the fast XLA tile path on CPU
    containers)."""
    hg = rmat(9, avg_degree=6, seed=1)
    g = hg.snapshot(block_size=64)
    ref = pr.numpy_reference(g, iterations=300)
    res = pr.static_pagerank(g, mode="lf", engine="pallas", tau=TAU64,
                             pallas_backend="pallas")
    assert res.converged
    assert pr.linf(res.ranks, ref) < BAND64


@pytest.mark.slow
def test_df_dynamic_pallas_kernel_backend(dyn):
    """DF_LF dynamic batch through the Pallas kernels in interpret mode
    must agree bitwise-tightly with the XLA tile path."""
    _, g0, g1, batch, r_prev, ref1, _, _ = dyn
    res_k = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                           engine="pallas", pallas_backend="pallas")
    res_x = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                           engine="pallas", pallas_backend="xla")
    assert res_k.converged and res_x.converged
    assert pr.linf(res_k.ranks[:g1.n], ref1[:g1.n]) < BAND64
    assert res_k.stats.sweeps == res_x.stats.sweeps
    assert pr.linf(res_k.ranks, res_x.ranks) < 1e-12


@pytest.mark.parametrize("mode", ["bb", "lf"])
def test_df_dynamic_matches_oracles_f64(dyn, mode):
    _, g0, g1, batch, r_prev, ref1, _, _ = dyn
    res = pr.df_pagerank(g0, g1, batch, r_prev, mode=mode, engine="pallas")
    assert res.converged
    assert pr.linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND64
    # vs the blocked (Gauss–Seidel) engine on the same run
    blkres = pr.df_pagerank(g0, g1, batch, r_prev, mode=mode,
                            engine="blocked")
    assert pr.linf(res.ranks, blkres.ranks) < BAND64


def test_df_dynamic_f32(dyn):
    _, g0, g1, batch, r_prev, ref1, _, _ = dyn
    res = pr.df_pagerank(g0, g1, batch, r_prev.astype(jnp.float32),
                         mode="lf", engine="pallas", tau=TAU32)
    assert res.converged
    assert pr.linf(res.ranks.astype(jnp.float64)[:g1.n],
                   ref1[:g1.n]) < BAND32


def test_work_accounting_matches_blocked(dyn):
    """In BB mode both engines run the same Jacobi recurrence, so the fused
    driver's device-side counters must agree exactly with the blocked
    engine's host-side ones: same sweeps, same frontier-proportional edge
    count (the frontier_work_ratio ≪ 1 demonstration itself lives in the
    k-mer smoke benchmark — tests/test_bench_smoke.py)."""
    _, g0, g1, batch, r_prev, _, _, _ = dyn
    res_p = pr.df_pagerank(g0, g1, batch, r_prev, mode="bb",
                           engine="pallas")
    res_b = pr.df_pagerank(g0, g1, batch, r_prev, mode="bb",
                           engine="blocked")
    assert res_p.stats.sweeps == res_b.stats.sweeps
    assert res_p.stats.edges_processed == res_b.stats.edges_processed
    assert res_p.stats.blocks_processed == res_b.stats.blocks_processed


def test_nd_and_rc_policy(dyn):
    _, g0, g1, batch, r_prev, ref1, _, _ = dyn
    res = pr.nd_pagerank(g1, r_prev, mode="lf", engine="pallas")
    assert res.converged and pr.linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND64
    res_rc = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                            engine="pallas", active_policy="rc")
    assert res_rc.converged
    assert pr.linf(res_rc.ranks[:g1.n], ref1[:g1.n]) < BAND64


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_expand_op_matches_dense_frontier(backend):
    """OR-semiring tile expansion == fr.expand_frontier's dense marking,
    on both SpMV backends."""
    rng = np.random.default_rng(10)
    n = 256
    hg = HostGraph(n, np.stack([rng.integers(0, n, 1500),
                                rng.integers(0, n, 1500)], 1))
    g = hg.snapshot(block_size=64)
    mat = pe.build_pull_matrix(g, dtype=np.float32)
    changed = jnp.asarray(rng.random(g.n_pad) < 0.05) & g.vertex_valid
    affected0 = jnp.zeros(g.n_pad, bool)
    rc0 = jnp.zeros(g.n_pad, bool)
    aff, rc = fr.expand_frontier(g, changed, affected0, rc0)
    hit = ops.frontier_expand_op(mat, changed, interpret=True,
                                 backend=backend) > 0
    assert bool(jnp.all(hit == aff))
    assert bool(jnp.all(hit == rc))
    # active-ids variant restricted to candidate blocks agrees too
    ch_cb = fr.block_any(changed, g.n_blocks, g.block_size)
    cand = (ops.block_adjacency(mat) & ch_cb[None, :]).any(axis=1)
    cids = fr.compact_block_ids(cand, g.n_blocks)
    y = ops.block_spmv_active(mat, changed.astype(jnp.float32), cids,
                              semiring="or", interpret=True, backend=backend)
    hit2 = (y > 0) & jnp.repeat(cand, g.block_size) & g.vertex_valid
    assert bool(jnp.all(hit2 == aff))
    # bucketed dispatch (the fused driver's launch path) agrees as well
    yb = ops.block_spmv_active_bucketed(
        mat, changed.astype(jnp.float32), cids, cand.sum(), semiring="or",
        interpret=True, backend=backend)
    hit3 = (yb > 0) & jnp.repeat(cand, g.block_size) & g.vertex_valid
    assert bool(jnp.all(hit3 == aff))


class TestFaults:
    def _setup(self):
        hg0 = rmat(9, avg_degree=6, seed=7)
        g0 = hg0.snapshot(block_size=64)
        r_prev = jnp.asarray(pr.numpy_reference(g0, iterations=300))
        dels, ins = random_batch(hg0, 5e-3, seed=1)
        hg1 = hg0.apply_batch(dels, ins)
        g1 = hg1.snapshot(block_size=64)
        ref1 = pr.numpy_reference(g1, iterations=300)
        return g0, g1, fr.batch_to_device(g1, dels, ins), r_prev, ref1

    def test_lf_survives_crashes_same_bound(self):
        g0, g1, batch, r_prev, ref1 = self._setup()
        plan = FaultPlan(n_threads=8, n_crashed=6, crash_window=4, seed=3)
        res = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                             engine="pallas", faults=plan)
        assert res.converged and not res.stats.dnf
        assert pr.linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND64

    def test_lf_survives_delays_same_bound(self):
        g0, g1, batch, r_prev, ref1 = self._setup()
        plan = FaultPlan(n_threads=8, delay_prob=0.4, delay_ms=100, seed=5)
        res = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                             engine="pallas", faults=plan)
        assert res.converged
        assert pr.linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND64
        assert res.stats.sim_time_ms > 0

    def test_bb_stalls_on_crash(self):
        g0, g1, batch, r_prev, _ = self._setup()
        plan = FaultPlan(n_threads=8, n_crashed=1, crash_window=1, seed=3)
        res = pr.df_pagerank(g0, g1, batch, r_prev, mode="bb",
                             engine="pallas", faults=plan)
        assert res.stats.dnf and not res.converged


class TestIncrementalBuilder:
    def test_apply_delta_matches_rebuild(self, dyn):
        hg0, g0, g1, _, _, _, dels, ins = dyn
        inc = IncrementalPullMatrix.from_snapshot(g0)
        mat1 = inc.advance(hg0, g1, dels, ins)
        fresh = pe.build_pull_matrix(g1)
        x = jnp.asarray(np.random.default_rng(0).random(g1.n_pad))
        y_inc = ops.block_spmv(mat1, x, interpret=True)
        y_new = ops.block_spmv(fresh, x, interpret=True)
        assert pr.linf(y_inc, y_new) < 1e-12

    def test_incremental_matrix_drives_engine(self, dyn):
        hg0, g0, g1, batch, r_prev, ref1, dels, ins = dyn
        inc = IncrementalPullMatrix.from_snapshot(g0)
        mat1 = inc.advance(hg0, g1, dels, ins)
        res = pr.df_pagerank(g0, g1, batch, r_prev, mode="lf",
                             engine="pallas", pallas_mat=mat1)
        assert res.converged
        assert pr.linf(res.ranks[:g1.n], ref1[:g1.n]) < BAND64

    def test_delete_reinsert_roundtrip_exact(self):
        hg = grid_road(24, seed=0)
        g = hg.snapshot(block_size=64)
        inc = IncrementalPullMatrix.from_snapshot(g)
        dense0 = np.asarray(inc.mat.tiles).copy()
        dels = hg.edges[::7]
        hg1 = hg.apply_batch(dels, np.zeros((0, 2)))
        inc.advance(hg, hg1.snapshot(block_size=64), dels, np.zeros((0, 2)))
        hg2 = hg1.apply_batch(np.zeros((0, 2)), dels)
        inc.advance(hg1, hg2.snapshot(block_size=64), np.zeros((0, 2)), dels)
        assert np.array_equal(np.asarray(inc.mat.tiles), dense0)

    def test_signed_edge_delta_layout(self):
        rows, cols, vals = signed_edge_delta(np.array([[1, 2]]),
                                             np.array([[3, 4]]))
        # pull layout: A[dst, src]
        assert rows.tolist() == [2, 4] and cols.tolist() == [1, 3]
        assert vals.tolist() == [-1.0, 1.0]


def test_driver_has_no_per_sweep_host_syncs():
    """The fused loop must be free of host transfers: int()/float()/
    np.asarray/bool() inside the convergence loop would appear as source
    calls in pallas_engine._driver — the driver is one jitted while_loop,
    so tracing it must succeed and nothing inside may force concretization.
    """
    import inspect
    import re
    src = inspect.getsource(getattr(pe._driver, "__wrapped__", pe._driver))
    for pattern in (r"(?<![\w.])int\(", r"(?<![\w.])float\(",
                    r"(?<![\w.])bool\(", r"(?<![\w.j])np\.asarray"):
        assert not re.search(pattern, src), \
            f"host sync '{pattern}' in fused driver"
    # and the abstract trace goes through without ConcretizationError
    hg = rmat(8, avg_degree=4, seed=0)
    g = hg.snapshot(block_size=64)
    mat = pe.build_pull_matrix(g)
    plan = pr.flt.NO_FAULTS
    part, alive, delay, crashed = plan.device_tables(50)
    f = jnp.asarray
    for backend in ("pallas", "xla"):
        jax.eval_shape(
            lambda *a, b=backend: pe._driver(
                *a, n=g.n, block_size=g.block_size, mode="lf", expand=True,
                active_policy="affected", max_iterations=50,
                interpret=True, backend=b),
            mat, pr.initial_ranks(g), g.vertex_valid, g.vertex_valid,
            g.out_deg, g.block_in_edges(), g.block_out_edges(),
            ops.block_adjacency(mat), jnp.ones((mat.n_rb,), bool),
            f(0.85), f(1e-10), f(1e-13),
            f(part), f(alive), f(delay), f(crashed))
