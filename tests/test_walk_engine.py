"""Walk-engine subsystem tests (PR-8): config/capability gating, the
device-resident walk store's delta-localized regeneration, the session's
walk mode (``ppr_query``, zero post-warmup retraces, localization
accounting), the dense personalized oracle, and the service's per-user
personalized serving path."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.api import (CapabilityError, EngineConfig, PageRankService,
                       PageRankSession, registry)
from repro.core import pagerank as pr
from repro.core.delta import random_batch
from repro.core.graph import HostGraph
from repro.core.incremental import effective_batch
from repro.core.walk_engine import WalkState
from repro.graphs.generators import powerlaw


def _graph(n=96, m=420, seed=0) -> HostGraph:
    rng = np.random.default_rng(seed)
    e = np.stack([rng.integers(0, n, m), rng.integers(0, n, m)], 1)
    hg = HostGraph(n, e)
    e = hg.edges
    return HostGraph(n, e[e[:, 0] != e[:, 1]])


# ---------------------------------------------------------------------------
# registry + config gating
# ---------------------------------------------------------------------------

def test_walk_engine_registered_with_capability():
    assert "walk" in registry.names()
    eng = registry.resolve("walk")
    assert registry.supports_of(eng) == frozenset({"ppr"})
    assert registry.fault_domains_of(eng) == ("process",)
    # sweep engines declare no capabilities
    assert registry.supports_of(registry.resolve("pallas")) == frozenset()


@pytest.mark.parametrize("field,bad,match", [
    ("walks_per_vertex", 0, "must be >= 1"),
    ("walks_per_vertex", -3, "must be >= 1"),
    ("walk_length", 1, "must be >= 2"),
    ("walk_seed", -1, "must be >= 0"),
    ("walks_per_vertex", 2.5, "integer"),
    ("walk_length", True, "integer"),
])
def test_config_validates_walk_fields_eagerly(field, bad, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(engine="walk", **{field: bad})


@pytest.mark.parametrize("engine", ["dense", "blocked", "pallas"])
def test_sweep_engines_reject_personalization_fields(engine):
    with pytest.raises(CapabilityError, match="'ppr' capability"):
        EngineConfig(engine=engine, walks_per_vertex=8)
    with pytest.raises(CapabilityError, match="engine='walk'"):
        EngineConfig(engine=engine, walk_length=16, walk_seed=1)


def test_walk_engine_rejects_sweep_fault_and_integrity_knobs():
    from repro.core.faults import FaultPlan
    with pytest.raises(ValueError, match="sweep"):
        EngineConfig(engine="walk", faults=FaultPlan(n_threads=2))
    from repro.core.integrity import IntegrityConfig
    with pytest.raises(ValueError, match="integrity"):
        EngineConfig(engine="walk", integrity=IntegrityConfig())


def test_ppr_query_on_sweep_engine_raises_capability_error():
    hg = _graph()
    with PageRankSession.from_graph(
            hg, config=EngineConfig(engine="blocked")) as sess:
        with pytest.raises(CapabilityError, match="ppr_query"):
            sess.ppr_query([0, 1], 5)


# ---------------------------------------------------------------------------
# walk store: determinism + localization
# ---------------------------------------------------------------------------

def test_delta_regeneration_equals_full_rebuild():
    hg = _graph(seed=3)
    ws = WalkState(hg, R=6, L=16, seed=9)
    dels, ins = random_batch(hg, 0.15, seed=4)
    ins = np.asarray(ins)
    ins = ins[ins[:, 0] != ins[:, 1]]
    stats = ws.apply_batch(*effective_batch(hg, dels, ins))
    full = WalkState(hg.apply_batch(dels, ins), R=6, L=16, seed=9)
    assert np.array_equal(np.asarray(ws.walks), np.asarray(full.walks))
    assert np.array_equal(np.asarray(ws.counts), np.asarray(full.counts))
    # localization: regenerated ≤ touched mass, strictly below global
    assert 0 < stats.regenerated_walks <= stats.touched_walk_mass
    assert stats.regenerated_walks < stats.total_walks


def test_delete_reinsert_is_noop_on_walk_buffers():
    hg = _graph(seed=5)
    ws = WalkState(hg, R=5, L=14, seed=2)
    w0 = np.asarray(ws.walks).copy()
    c0 = np.asarray(ws.counts).copy()
    edges = hg.edges[:5]
    none = np.zeros((0, 2), np.int64)
    ws.apply_batch(*effective_batch(hg, edges, none))
    assert not np.array_equal(np.asarray(ws.walks), w0)  # delta took effect
    hg2 = hg.apply_batch(edges, none)
    ws.apply_batch(*effective_batch(hg2, none, edges))
    assert np.array_equal(np.asarray(ws.walks), w0)
    assert np.array_equal(np.asarray(ws.counts), c0)


def test_estimates_track_oracles():
    hg = powerlaw(128, 5, seed=11)
    g = hg.snapshot(block_size=64)
    ws = WalkState(hg, R=128, L=48, seed=1)
    # global estimate vs the exact numpy oracle
    ref = pr.numpy_reference(g, iterations=300)[:hg.n]
    est = np.asarray(ws.pagerank())
    assert float(np.abs(est - ref).sum()) < 0.35
    # personalized estimate vs the personalized numpy oracle
    seeds = np.array([3, 17, 40])
    pref = pr.ppr_numpy_reference(g, seeds, iterations=300)[:hg.n]
    pest = np.asarray(ws.ppr(seeds))
    assert float(np.abs(pest - pref).sum()) < 0.8
    vals, idx = ws.ppr_top_k(seeds, 5)
    order = np.argsort(pest)[::-1][:5]
    np.testing.assert_allclose(np.asarray(vals), pest[order])


def test_accuracy_improves_with_R():
    hg = powerlaw(96, 5, seed=7)
    g = hg.snapshot(block_size=64)
    seeds = np.array([1, 2, 5])
    ref = pr.ppr_numpy_reference(g, seeds, iterations=300)[:hg.n]
    errs = []
    for R in (4, 32, 256):
        ws = WalkState(hg, R=R, L=48, seed=3)
        errs.append(float(np.abs(np.asarray(ws.ppr(seeds)) - ref).sum()))
    assert errs[-1] < errs[0]


# ---------------------------------------------------------------------------
# dense personalized oracle (satellite: exact PPR on small graphs)
# ---------------------------------------------------------------------------

def test_dense_jacobi_personalization_matches_numpy_ppr():
    hg = _graph(seed=13)
    g = hg.snapshot(block_size=64)
    seeds = np.array([0, 7, 31])
    p = pr.restart_vector(g, seeds)
    R0 = jnp.asarray(p)
    R, iters, conv = pr.dense_jacobi(
        g, R0, g.vertex_valid, expand=False, tau=1e-12,
        personalization=p)
    assert conv
    ref = pr.ppr_numpy_reference(g, seeds, iterations=400)
    assert float(np.abs(np.asarray(R) - ref).max()) < 1e-9
    # degenerate restart vectors are rejected eagerly
    with pytest.raises(ValueError, match="at least one seed"):
        pr.restart_vector(g, [])
    with pytest.raises(ValueError, match="out of range"):
        pr.restart_vector(g, [g.n + 4])


def test_powerlaw_generator_seeded_and_heavy_tailed():
    a = powerlaw(256, 6, seed=3)
    b = powerlaw(256, 6, seed=3)
    c = powerlaw(256, 6, seed=4)
    assert np.array_equal(a.edges, b.edges)     # deterministic per seed
    assert not np.array_equal(a.edges, c.edges)
    deg = np.bincount(a.edges[:, 0], minlength=256)
    assert deg.max() >= 4 * max(np.median(deg), 1)      # hubs exist
    assert (a.edges[:, 0] != a.edges[:, 1]).all()       # simple digraph
    with pytest.raises(ValueError, match="exponent"):
        powerlaw(64, 4, exponent=1.0)


# ---------------------------------------------------------------------------
# session walk mode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def walk_session():
    hg = _graph(seed=21)
    cfg = EngineConfig(engine="walk", walks_per_vertex=8, walk_length=24,
                       walk_seed=2)
    sess = PageRankSession.from_graph(hg, config=cfg)
    yield hg, sess
    sess.close()


def test_session_update_localized_and_retrace_free(walk_session):
    hg, sess = walk_session
    sess.warmup()
    cur = hg
    for j in range(3):
        dels, ins = random_batch(cur, 0.05, seed=60 + j)
        res = sess.update(dels, ins)
        cur = cur.apply_batch(dels, ins)
        assert res.stats.converged
        assert 0 < res.regenerated_walks <= res.touched_walks
        assert res.regenerated_walks < res.total_walks
    rep = sess.report()
    assert rep.engine == "walk"
    assert rep.retraces_post_warmup == 0
    assert rep.n_updates >= 3
    # session buffers must equal a cold walk store on the final graph
    fresh = WalkState(cur, R=8, L=24, seed=2)
    assert np.array_equal(np.asarray(sess.walks.walks),
                          np.asarray(fresh.walks))


def test_session_ppr_query_validation(walk_session):
    hg, sess = walk_session
    vals, idx = sess.ppr_query([0, 1], 5)
    assert len(vals) == len(idx) == 5
    assert (np.diff(vals) <= 0).all()
    with pytest.raises(ValueError, match="at least one seed"):
        sess.ppr_query([], 5)
    with pytest.raises(ValueError, match="out of range"):
        sess.ppr_query([sess.n + 2], 5)
    with pytest.raises(ValueError, match="k must be an integer"):
        sess.ppr_query([0], 2.5)
    with pytest.raises(ValueError, match="must be >= 1"):
        sess.ppr_query([0], 0)


def test_session_fork_diverges_independently(walk_session):
    hg, sess = walk_session
    twin = sess.fork()
    before = np.asarray(sess.walks.walks).copy()
    dels = np.zeros((0, 2), np.int64)
    twin.update(dels, np.array([[0, 5]]))
    assert np.array_equal(np.asarray(sess.walks.walks), before)
    twin.close()
    assert not sess.closed


def test_session_recompute_semantics(walk_session):
    hg, sess = walk_session
    res = sess.recompute("static")
    assert res.stats.converged
    for variant in ("dt", "df"):
        with pytest.raises(ValueError, match="marking"):
            sess.recompute(variant)


def test_walk_engine_snapshot_run_via_registry():
    hg = _graph(n=48, m=180, seed=8)
    g = hg.snapshot(block_size=64)
    sess = PageRankSession.from_snapshot(
        g, config=EngineConfig(engine="walk", walks_per_vertex=64,
                               walk_length=32))
    ref = pr.numpy_reference(g, iterations=300)[:g.n]
    assert float(np.abs(sess.ranks[:g.n] - ref).sum()) < 0.5
    sess.ppr_query([0], 3)
    sess.close()


def test_walk_session_wal_restore_bit_identical(tmp_path):
    """Process-domain durability on the sweep-free engine: regeneration is
    deterministic in (graph, seed), so checkpoint + WAL replay must
    reproduce the walk buffers bit-for-bit."""
    hg = _graph(n=50, m=220, seed=40)
    cfg = EngineConfig(engine="walk", walks_per_vertex=6, walk_length=20,
                       walk_seed=4, durability="wal")
    sess = PageRankSession.from_graph(hg, config=cfg,
                                      store_dir=str(tmp_path))
    none = np.zeros((0, 2), np.int64)
    for j in range(3):
        sess.update(none, np.array([[j, (j * 7 + 3) % 50]]))
    walks_live = np.asarray(sess.walks.walks).copy()
    ranks_live = np.asarray(sess.ranks).copy()
    sess.close()
    restored = PageRankSession.restore(str(tmp_path))
    try:
        assert np.array_equal(np.asarray(restored.walks.walks), walks_live)
        np.testing.assert_allclose(np.asarray(restored.ranks), ranks_live)
        vals, idx = restored.ppr_query([0, 1], 4)
        assert len(vals) == 4
    finally:
        restored.close()


# ---------------------------------------------------------------------------
# service: per-user personalized serving
# ---------------------------------------------------------------------------

def test_service_serves_personalized_rankings():
    graphs = [_graph(seed=31), _graph(seed=32)]
    cfg = EngineConfig(engine="walk", walks_per_vertex=8, walk_length=24)
    svc = PageRankService(graphs, config=cfg)
    try:
        r = svc.ppr_query(0, [3, 4], 4)
        assert r.degraded             # snapshot (degraded-mode) read
        assert len(r.values) == 4 and len(r.vertices) == 4
        # updates drain while personalized reads keep serving
        svc.submit(0, np.zeros((0, 2), np.int64), np.array([[0, 9]]))
        while svc.step():
            pass
        r2 = svc.ppr_query(0, [3, 4], 4)
        assert r2.lag_updates == 0    # snapshot refreshed to committed
        r3 = svc.ppr_query(1, [7], 2)
        assert len(r3.values) == 2
        rep = svc.report()
        assert rep["queries"]["served"] >= 3
    finally:
        svc.stop()
