"""Streaming DF_LF runtime: recompile-free hot path + capacity ladder.

Covers the acceptance matrix of the streaming work: zero retraces of the
fused driver across a multi-batch stream, stream results matching the
from-scratch rebuild path on insertion+deletion batches, and the
capacity-padded ``apply_delta`` edge cases (emptied tiles stay inert,
bucket-overflow growth rewidens correctly, grid changes are rejected).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import pagerank as pr
from repro.core import frontier as fr
from repro.core import pallas_engine as pe
from repro.core.delta import random_batch
from repro.core.graph import HostGraph
from repro.core.incremental import IncrementalPullMatrix, MatrixAux
from repro.core.stream import StreamRunner, run_stream
from repro.graphs.generators import rmat, grid_road
from repro.kernels.block_spmv import ops


# ---------------------------------------------------------------------------
# apply_delta edge cases (capacity ladder semantics)
# ---------------------------------------------------------------------------

def _rand_mat(n=300, m=2000, block=64, seed=0, padded=True):
    rng = np.random.default_rng(seed)
    rows, cols = rng.integers(0, n, m), rng.integers(0, n, m)
    mat = ops.build_block_sparse(rows, cols, n, n, block=block,
                                 dtype=np.float64, padded=padded)
    return mat, rows, cols, rng


class TestApplyDeltaEdgeCases:
    def test_deletion_emptied_tiles_stay_inert(self):
        """Deleting every edge of a tile leaves an all-zero tile that is
        still referenced (structure is monotone) but contributes nothing."""
        mat, rows, cols, rng = _rand_mat()
        B = mat.block
        # empty the (0, 0) tile completely
        in_tile = (rows // B == 0) & (cols // B == 0)
        assert in_tile.sum() > 0
        mat1 = ops.apply_delta(mat, rows[in_tile], cols[in_tile],
                               -np.ones(int(in_tile.sum())))
        # slot tables unchanged: the emptied tile is still present
        assert jnp.array_equal(mat1.tile_cols, mat.tile_cols)
        assert mat1.tiles.shape == mat.tiles.shape
        x = jnp.asarray(rng.random(mat.n_cols))
        y = ops.block_spmv(mat1, x, backend="xla")
        keep = ~in_tile
        fresh = ops.build_block_sparse(rows[keep], cols[keep], mat.n_rows,
                                       mat.n_cols, block=B, dtype=np.float64)
        assert pr.linf(y, ops.block_spmv(fresh, x, backend="xla")) < 1e-12

    def test_growth_past_capacity_bucket_rewidens(self):
        """Adding more tiles than the preallocated pool / slot bucket grows
        both to the next bucket and stays numerically exact."""
        n, B = 256, 32
        rows0 = np.arange(0, n, B)          # one diagonal tile per row-block
        mat = ops.build_block_sparse(rows0, rows0, n, n, block=B,
                                     dtype=np.float64, padded=True)
        cap0, mt0 = mat.tile_capacity, mat.max_tiles
        # flood row-block 0 with a tile in every column-block → must exceed
        # the slot bucket; enough distinct tiles to overflow the pool too
        rr, cc = np.meshgrid(np.arange(0, n, B), np.arange(0, n, B))
        dr, dc = rr.reshape(-1), cc.reshape(-1)
        mat1 = ops.apply_delta(mat, dr, dc, np.ones(len(dr)))
        assert mat1.max_tiles > mt0
        assert mat1.tile_capacity >= mat1.n_tiles()
        assert mat1.tile_capacity > cap0
        # buckets stay on the doubling ladder
        assert mat1.tile_capacity == ops.capacity_bucket(mat1.tile_capacity)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.random(n))
        fresh = ops.build_block_sparse(
            np.concatenate([rows0, dr]), np.concatenate([rows0, dc]), n, n,
            block=B, dtype=np.float64,
            values=np.ones(len(rows0) + len(dr)))
        assert pr.linf(ops.block_spmv(mat1, x, backend="xla"),
                       ops.block_spmv(fresh, x, backend="xla")) < 1e-12

    def test_within_bucket_growth_keeps_shapes(self):
        """New tiles inside the preallocated capacity leave tiles.shape and
        max_tiles untouched — the recompile-free invariant."""
        mat, rows, cols, rng = _rand_mat(m=40)  # block-sparse structure
        free = mat.tile_capacity - mat.n_tiles()
        assert free > 0, "padded build must leave headroom"
        # one new tile in an existing row (slot headroom from the ladder)
        occ = np.asarray(mat.tile_cols)
        rb = int(np.argmin((occ >= 0).sum(1)))
        cb_free = int(np.where(~np.isin(np.arange(mat.n_cb),
                                        occ[rb][occ[rb] >= 0]))[0][0])
        mat1 = ops.apply_delta(mat, np.array([rb * mat.block]),
                               np.array([cb_free * mat.block]), np.ones(1))
        assert mat1.tiles.shape == mat.tiles.shape
        assert mat1.max_tiles == mat.max_tiles

    def test_grid_size_change_rejected(self):
        mat, _, _, _ = _rand_mat(n=300)
        with pytest.raises(ValueError, match="grid"):
            ops.apply_delta(mat, np.array([mat.n_rows]), np.array([0]),
                            np.ones(1))
        with pytest.raises(ValueError, match="grid"):
            ops.apply_delta(mat, np.array([0]), np.array([-1]), np.ones(1))
        hg = grid_road(16, seed=0)
        g_small = hg.snapshot(block_size=64)
        inc = IncrementalPullMatrix.from_snapshot(g_small)
        g_big = grid_road(48, seed=0).snapshot(block_size=64)
        with pytest.raises(ValueError, match="rebuild"):
            inc.advance(hg, g_big, np.zeros((0, 2)), np.zeros((0, 2)))


# ---------------------------------------------------------------------------
# cached MatrixAux (block_adjacency + rb_in/rb_out maintained per delta)
# ---------------------------------------------------------------------------

def test_matrix_aux_tracks_fresh_recompute():
    hg = rmat(9, avg_degree=6, seed=5)
    g = hg.snapshot(block_size=64)
    inc = IncrementalPullMatrix.from_snapshot(g)
    cur = hg
    for i in range(3):
        dels, ins = random_batch(cur, 1e-2, seed=20 + i)
        nxt = cur.apply_batch(dels, ins)
        g_new = nxt.snapshot(block_size=64)
        inc.advance(cur, g_new, dels, ins)
        cur = nxt
    fresh = MatrixAux.from_parts(inc.mat, cur.snapshot(block_size=64))
    np.testing.assert_array_equal(inc.aux.rb_in, fresh.rb_in)
    np.testing.assert_array_equal(inc.aux.rb_out, fresh.rb_out)
    # cached presence is monotone ⊇ the recomputed one and covers it
    assert bool(np.all(inc.aux.bmat >= fresh.bmat))
    res = pr.df_pagerank(
        cur.snapshot(block_size=64), cur.snapshot(block_size=64),
        fr.batch_to_device(cur.snapshot(block_size=64), np.zeros((0, 2)),
                           np.zeros((0, 2))),
        jnp.asarray(pr.numpy_reference(cur.snapshot(block_size=64),
                                       iterations=300)),
        mode="lf", engine="pallas", pallas_mat=inc.mat, pallas_aux=inc.aux)
    assert res.converged


# ---------------------------------------------------------------------------
# streaming runtime
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_setup():
    hg = rmat(9, avg_degree=6, seed=3)
    g = hg.snapshot(block_size=64)
    r0 = jnp.asarray(pr.numpy_reference(g, iterations=300))
    batches = []
    cur = hg
    for i in range(4):
        dels, ins = random_batch(cur, 5e-3, seed=100 + i)
        batches.append((dels, ins))
        cur = cur.apply_batch(dels, ins)
    return hg, g, r0, batches


def test_zero_retraces_across_stream(stream_setup):
    """≥3-batch stream: after the warmup batch the fused driver must not
    retrace — the capacity-padded matrix and the snapshot-free operand set
    keep every jit cache key stable."""
    hg, g, r0, batches = stream_setup
    runner = StreamRunner(hg, block_size=64, r0=r0)
    sizes = []
    for dels, ins in batches:
        sizes.append(runner.step(dels, ins).driver_cache_size)
    assert len(sizes) >= 3
    assert sizes[0] >= 0, "jit cache stats unavailable"
    assert sizes[-1] == sizes[0], f"driver retraced during stream: {sizes}"
    # run_stream's aggregate agrees
    rep = run_stream(hg, batches, block_size=64, r0=r0)
    assert rep.retraces_post_warmup == 0


def test_stream_matches_from_scratch_rebuild(stream_setup):
    """Streaming results must match the rebuild-everything path on
    insertion+deletion batches (same engine, same hyperparameters)."""
    hg, g, r0, batches = stream_setup
    runner = StreamRunner(hg, block_size=64, r0=r0)
    cur, r_ref = hg, r0
    for dels, ins in batches:
        res = runner.step(dels, ins)
        g_prev = cur.snapshot(block_size=64)
        cur = cur.apply_batch(dels, ins)
        g_new = cur.snapshot(block_size=64)
        oracle = pr.df_pagerank(
            g_prev, g_new, fr.batch_to_device(g_new, dels, ins), r_ref,
            mode="lf", engine="pallas")
        r_ref = oracle.ranks
        assert res.stats.converged
        assert pr.linf(res.ranks, oracle.ranks) < 1e-12
    # and against the independent oracle on the final graph
    ref = pr.numpy_reference(cur.snapshot(block_size=64), iterations=300)
    assert pr.linf(runner.R[:cur.n], jnp.asarray(ref[:cur.n])) < 1e-9


def test_stream_seed_matches_initial_affected(stream_setup):
    """The tile-matrix frontier seed equals the snapshot-based marking of
    paper Alg. 1 lines 4-6."""
    from repro.core.stream import _seed_affected
    hg, g, r0, batches = stream_setup
    runner = StreamRunner(hg, block_size=64, r0=r0)
    cur = hg
    for dels, ins in batches[:2]:
        mat_prev = runner.inc.mat
        g_prev = cur.snapshot(block_size=64)
        res = runner.step(dels, ins)  # noqa: F841 (advances runner state)
        cur = cur.apply_batch(dels, ins)
        g_new = cur.snapshot(block_size=64)
        batch = fr.batch_to_device(g_new, dels, ins)
        want = fr.initial_affected(g_prev, g_new, batch)
        got = _seed_affected(
            mat_prev, runner.inc.mat, jnp.asarray(runner.inc.aux.bmat),
            batch, runner.valid, block_size=64,
            interpret=runner.interpret, backend=runner.backend)
        assert bool(jnp.all(got == want))


def test_stream_device_mirrors_track_ground_truth(stream_setup):
    """The device-resident operand mirrors (out_deg / rb_in / rb_out /
    bmat), patched per batch by one O(batch) scatter, must equal the values
    a fresh snapshot of the final graph would produce."""
    hg, g, r0, batches = stream_setup
    runner = StreamRunner(hg, block_size=64, r0=r0)
    cur = hg
    for dels, ins in batches:
        runner.step(dels, ins)
        cur = cur.apply_batch(dels, ins)
    g_fin = cur.snapshot(block_size=64)
    np.testing.assert_array_equal(np.asarray(runner._out_deg),
                                  np.asarray(g_fin.out_deg))
    np.testing.assert_array_equal(np.asarray(runner._rb_in),
                                  np.asarray(g_fin.block_in_edges()))
    np.testing.assert_array_equal(np.asarray(runner._rb_out),
                                  np.asarray(g_fin.block_out_edges()))
    # presence mirror: monotone superset covering the true structure, and
    # in sync with the numpy twin maintained by IncrementalPullMatrix
    fresh_bmat = np.asarray(ops.block_adjacency(
        pe.build_pull_matrix(g_fin)))
    got = np.asarray(runner._bmat)
    assert bool(np.all(got >= fresh_bmat))
    np.testing.assert_array_equal(got, runner.inc.aux.bmat)
    np.testing.assert_array_equal(np.asarray(runner._rb_in),
                                  runner.inc.aux.rb_in)
    np.testing.assert_array_equal(np.asarray(runner._rb_out),
                                  runner.inc.aux.rb_out)


def test_stream_rejects_unknown_mode():
    hg = rmat(8, avg_degree=4, seed=0)
    with pytest.raises(ValueError):
        StreamRunner(hg, mode="nope")
