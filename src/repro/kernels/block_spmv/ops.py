"""Host-side block-sparse builder + jit'd SpMV wrappers + PageRank step op.

The builder is fully vectorized (one flat ``np.add.at`` scatter for tile
values, one argsort-free slot assignment for the per-row tile lists) and has
an incremental sibling: :func:`apply_delta` patches only the tiles an edge
batch touches, so a dynamic-graph stream pays O(batch) per snapshot instead
of O(m) rebuilds.

Streaming runtime additions (docs/ENGINES.md §Streaming):

* **capacity padding** — the tile pool and the per-row slot tables can be
  preallocated on a doubling *growth ladder* (:func:`capacity_bucket`), so
  ``tiles.shape`` / ``max_tiles`` stay stable while a dynamic stream patches
  the matrix.  Stable shapes + stable pytree aux = the fused driver is never
  retraced by a delta batch (zero post-warmup recompiles).
* **device-side delta scatter** — :func:`apply_delta` applies the values of
  an edge batch with one jitted per-edge scatter-add whose operand shapes
  are bucketed, so the hot part of a stream step runs on-device with a
  bounded jit cache.  Only the tiny slot-table bookkeeping stays on host.
* **two SpMV backends** — the Pallas kernels (``backend="pallas"``: MXU path
  on TPU, interpreter-validated elsewhere) and an XLA tile path
  (``backend="xla"``: gather + ``einsum`` over the *same* tile layout) that
  gives CPU containers real engine-relative performance instead of the
  ~200× interpret-mode penalty.  :func:`default_backend` picks per platform.
* **frontier-proportional dispatch** — :func:`block_spmv_active_bucketed`
  launches the active-row-block SpMV through a ``lax.switch`` over a static
  ladder of grid sizes, so the Pallas grid (and the interpret-mode loop, and
  the XLA gather) scales with the *actual* frontier instead of ``n_rb``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.block_spmv.block_spmv import (block_spmv_pallas,
                                                 block_spmv_active_pallas,
                                                 _acc_dtype)


TILE_CAP_BASE = 8        # minimum tile-pool capacity bucket
SLOT_CAP_BASE = 4        # minimum per-row slot-table width bucket
DELTA_BATCH_BUCKET = 64  # minimum padded edge-batch length for the scatter
ACTIVE_LADDER_BASE = 8   # smallest active-block grid bucket

I32_MAX = np.iinfo(np.int32).max


def check_i32(count: int, what: str) -> None:
    """Guard for the int32 index diet: slot tables, tile ids and block
    indices are stored 32-bit (half the slot-table footprint of int64),
    which is sufficient below 2^31 entries.  Past that the narrow layout
    would silently alias — fail loudly at the boundary instead."""
    if count > I32_MAX:
        raise OverflowError(
            f"{what} count {count} exceeds the int32 index range "
            f"({I32_MAX}); the 32-bit slot-table/index layout cannot "
            "address it — shard the graph (topology='sharded') or raise "
            "block_size so per-structure counts stay below 2^31")


def capacity_bucket(n: int, base: int = TILE_CAP_BASE) -> int:
    """Smallest power-of-two multiple of ``base`` ≥ n (doubling ladder).
    Growth through buckets bounds reallocation *and* the jit cache: a
    streamed matrix only ever exposes O(log) distinct shapes."""
    cap = base
    while cap < n:
        cap *= 2
    return cap


def active_ladder(n_rb: int, base: int = ACTIVE_LADDER_BASE
                  ) -> Tuple[int, ...]:
    """Static ladder of active-block grid sizes for bucketed SpMV dispatch:
    (base, 2·base, …, n_rb).  O(log n_rb) entries → O(log n_rb) compiled
    branches, each with a grid proportional to its bucket."""
    out = []
    K = base
    while K < n_rb:
        out.append(K)
        K *= 2
    out.append(n_rb)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BlockSparse:
    """Block-sparse matrix A [n_rows_pad, n_cols_pad] in B×B dense tiles.

    ``tiles[k]`` is the dense tile for the k-th stored (row-block, col-block)
    pair; ``tile_cols[i, j]`` is the column-block of the j-th tile of
    row-block i (or -1 padding); ``tile_idx`` flat-indexes into ``tiles``.

    ``tiles.shape[0]`` is a *capacity*, not a count: trailing tiles that no
    slot references are zero padding from the growth ladder.  The live tile
    count is recoverable from the slot tables (every allocated tile stays
    referenced even when deletions empty it).

    Registered as a pytree so it can flow through ``jax.jit`` / ``lax``
    control flow (the fused Pallas engine carries one through its driver).
    """
    n_rows: int
    n_cols: int
    block: int
    max_tiles: int
    tiles: jnp.ndarray       # [tile_capacity, B, B]
    tile_cols: jnp.ndarray   # [n_rb, max_tiles] i32
    tile_idx: jnp.ndarray    # [n_rb * max_tiles] i32

    @property
    def n_rb(self) -> int:
        return (self.n_rows + self.block - 1) // self.block

    @property
    def n_cb(self) -> int:
        return (self.n_cols + self.block - 1) // self.block

    @property
    def tile_capacity(self) -> int:
        return int(self.tiles.shape[0])

    def n_tiles(self) -> int:
        """Live tile count (host sync on the small index table only)."""
        occ = np.asarray(self.tile_cols) >= 0
        if not occ.any():
            return 0
        return int(np.asarray(self.tile_idx).reshape(
            occ.shape)[occ].max()) + 1

    def tree_flatten(self):
        children = (self.tiles, self.tile_cols, self.tile_idx)
        aux = (self.n_rows, self.n_cols, self.block, self.max_tiles)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_rows, n_cols, block, max_tiles = aux
        tiles, tile_cols, tile_idx = children
        return cls(n_rows=n_rows, n_cols=n_cols, block=block,
                   max_tiles=max_tiles, tiles=tiles, tile_cols=tile_cols,
                   tile_idx=tile_idx)


jax.tree_util.register_pytree_node(
    BlockSparse, BlockSparse.tree_flatten, BlockSparse.tree_unflatten)


def _slot_tables(tiles_rb: np.ndarray, tiles_cb: np.ndarray, n_rb: int,
                 min_max_tiles: int = 1) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-row tile lists from sorted-by-(rb, cb) tile coordinates.

    Tiles of one row-block are contiguous (the caller sorts by the flat key
    rb * n_cb + cb), so the slot of tile t within its row is just
    ``t - row_start[rb(t)]`` — no Python loop.
    """
    n_tiles = len(tiles_rb)
    check_i32(n_tiles, "tile")
    per_row = np.bincount(tiles_rb, minlength=n_rb)
    max_tiles = max(min_max_tiles, int(per_row.max(initial=1)))
    row_start = np.zeros(n_rb + 1, dtype=np.int64)
    np.cumsum(per_row, out=row_start[1:])
    # int32 diet: tile ids and in-row slots are < 2^31 (guarded above), so
    # the O(n_tiles) bookkeeping intermediates stay 32-bit like the tables
    slot = (np.arange(n_tiles, dtype=np.int32)
            - row_start[tiles_rb].astype(np.int32))
    tile_cols = np.full((n_rb, max_tiles), -1, dtype=np.int32)
    tile_idx = np.zeros((n_rb, max_tiles), dtype=np.int32)
    tile_cols[tiles_rb, slot] = tiles_cb
    tile_idx[tiles_rb, slot] = np.arange(n_tiles, dtype=np.int32)
    return tile_cols, tile_idx, max_tiles


def build_block_sparse(rows: np.ndarray, cols: np.ndarray, n_rows: int,
                       n_cols: int, *, block: int = 128,
                       values: Optional[np.ndarray] = None,
                       dtype=np.float32, padded: bool = False,
                       to_device: bool = True) -> BlockSparse:
    """Build tiles from an edge list: A[rows[k], cols[k]] = values[k] (or 1).

    ``padded=True`` preallocates the tile pool and the slot tables on the
    growth ladder (:func:`capacity_bucket`), the layout a dynamic stream
    should use: :func:`apply_delta` can then add tiles without changing
    ``tiles.shape`` / ``max_tiles`` until a bucket overflows.

    ``to_device=False`` keeps the tile pool and slot tables as numpy
    arrays — the **host tier** layout of :mod:`repro.core.tiering`, where
    the full pool never touches the device and only a bounded hot set of
    row-blocks is gathered into a device slab.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = (np.ones_like(rows, dtype=dtype) if values is None
            else np.asarray(values, dtype))
    n_rb = (n_rows + block - 1) // block
    n_cb = (n_cols + block - 1) // block

    rb, cb = rows // block, cols // block
    key = rb * n_cb + cb
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    uniq = np.unique(key)

    n_tiles = max(1, len(uniq))
    cap = capacity_bucket(n_tiles) if padded else n_tiles
    tiles = np.zeros((cap, block, block), dtype=dtype)
    # one flat scatter for every entry: tile position × B² + local offset
    tpos = np.searchsorted(uniq, key)
    flat = tpos * (block * block) + (rows % block) * block + (cols % block)
    np.add.at(tiles.reshape(-1), flat, vals)

    tiles_rb = (uniq // n_cb).astype(np.int64)
    tiles_cb = (uniq % n_cb).astype(np.int64)
    min_mt = 1
    if padded:
        per_row = np.bincount(tiles_rb, minlength=n_rb) if len(tiles_rb) \
            else np.zeros(n_rb, np.int64)
        min_mt = capacity_bucket(int(per_row.max(initial=1)), SLOT_CAP_BASE)
    tile_cols, tile_idx, max_tiles = _slot_tables(tiles_rb, tiles_cb, n_rb,
                                                  min_max_tiles=min_mt)

    if not to_device:
        return BlockSparse(
            n_rows=n_rows, n_cols=n_cols, block=block, max_tiles=max_tiles,
            tiles=tiles, tile_cols=tile_cols,
            tile_idx=tile_idx.reshape(-1))
    return BlockSparse(
        n_rows=n_rows, n_cols=n_cols, block=block, max_tiles=max_tiles,
        tiles=jnp.asarray(tiles), tile_cols=jnp.asarray(tile_cols),
        tile_idx=jnp.asarray(tile_idx.reshape(-1)))


@dataclasses.dataclass
class DeltaPlan:
    """Host-side bookkeeping for one delta batch against a block-sparse
    structure: where every edge lands (``tid``) plus the rebuilt slot
    tables when the batch opened new (row-block, col-block) pairs.

    The plan is *scatter-agnostic*: :func:`apply_delta` feeds it to the
    jitted device scatter, the host tier
    (:class:`repro.core.tiering.HostTilePool`) to a numpy ``add.at`` —
    the two tiers share one bookkeeping path so they cannot diverge."""
    tid: np.ndarray                    # [b] target tile id per edge
    n_old: int                         # live tiles before the batch
    n_new: int                         # tiles the batch appends
    tile_cols: Optional[np.ndarray]    # rebuilt [n_rb, mt'] (None: unchanged)
    tile_idx: Optional[np.ndarray]     # rebuilt [n_rb, mt'] (None: unchanged)
    max_tiles: int                     # post-batch slot width
    touched_rb: np.ndarray             # unique row-blocks the batch lands in

    @property
    def n_live(self) -> int:
        return self.n_old + self.n_new


def plan_delta(tile_cols_h: np.ndarray, tile_idx_h: np.ndarray,
               rows: np.ndarray, cols: np.ndarray, *, n_cb: int,
               block: int, max_tiles: int) -> DeltaPlan:
    """Resolve a delta batch against host copies of the slot tables:
    per-edge target tile ids, appended-tile count, and (when new tiles
    appear) merged slot tables on the :data:`SLOT_CAP_BASE` width ladder.
    Index-sized work only — never touches tile data."""
    n_rb = tile_cols_h.shape[0]
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    key = (rows // block) * n_cb + (cols // block)

    occ = tile_cols_h >= 0
    ex_rb, ex_slot = np.nonzero(occ)
    ex_key = ex_rb * n_cb + tile_cols_h[ex_rb, ex_slot]
    ex_tid = tile_idx_h[ex_rb, ex_slot]
    order = np.argsort(ex_key)
    sk, st = ex_key[order], ex_tid[order]

    pos = np.searchsorted(sk, key)
    pos_c = np.clip(pos, 0, max(len(sk) - 1, 0))
    found = (sk[pos_c] == key) if len(sk) else np.zeros(len(key), bool)

    # live tile count: capacity padding means tiles.shape[0] is an upper
    # bound, but every live tile is referenced by some slot
    n_old = int(ex_tid.max()) + 1 if len(ex_tid) else 0
    new_keys = np.unique(key[~found])
    check_i32(n_old + len(new_keys), "tile")
    tid = np.where(found, st[pos_c] if len(sk) else 0,
                   n_old + np.searchsorted(new_keys, key))

    tile_cols_np = tile_idx_np = None
    out_mt = max_tiles
    if len(new_keys):
        # merge old + new coordinates, re-deriving slots (cheap: index-sized)
        all_key = np.concatenate([ex_key, new_keys])
        all_tid = np.concatenate([ex_tid, n_old + np.arange(len(new_keys))])
        order = np.argsort(all_key)
        all_key, all_tid = all_key[order], all_tid[order]
        t_rb = (all_key // n_cb).astype(np.int32)
        t_cb = (all_key % n_cb).astype(np.int32)
        per_row_max = int(np.bincount(t_rb, minlength=n_rb).max(initial=1))
        min_mt = max_tiles if per_row_max <= max_tiles else \
            capacity_bucket(per_row_max, SLOT_CAP_BASE)
        tile_cols_np, idx_pos, out_mt = _slot_tables(
            t_rb, t_cb, n_rb, min_max_tiles=min_mt)
        # _slot_tables numbers tiles 0..n-1 in sorted order; map to real ids
        tile_idx_np = np.zeros_like(idx_pos)
        occ2 = tile_cols_np >= 0
        tile_idx_np[occ2] = all_tid[idx_pos[occ2]]

    return DeltaPlan(
        tid=tid, n_old=n_old, n_new=len(new_keys),
        tile_cols=tile_cols_np, tile_idx=tile_idx_np, max_tiles=out_mt,
        touched_rb=np.unique(rows // block).astype(np.int32))


@functools.partial(jax.jit, static_argnames=("block",))
def _scatter_delta(tiles: jnp.ndarray, tid: jnp.ndarray, rloc: jnp.ndarray,
                   cloc: jnp.ndarray, vals: jnp.ndarray, *, block: int
                   ) -> jnp.ndarray:
    """Jitted per-edge scatter-add of a (bucketed-length) delta batch into
    the tile pool.  Padded entries carry val 0 against tile 0 (inert)."""
    flat = tid * (block * block) + rloc * block + cloc
    return tiles.reshape(-1).at[flat].add(vals).reshape(tiles.shape)


def apply_delta(mat: BlockSparse, rows: np.ndarray, cols: np.ndarray,
                values: np.ndarray) -> BlockSparse:
    """Patch A with A[rows[k], cols[k]] += values[k], touching only the
    tiles the delta lands in.

    Value application is a single jitted device scatter over a
    bucket-padded edge batch (:func:`_scatter_delta`) — no host round-trip
    through the tile pool.  Entirely new (row-block, col-block) pairs are
    appended into the preallocated capacity; the pool / slot tables are
    rewidened (to the next :func:`capacity_bucket`) only when a bucket
    overflows, so shapes are stable across a stream.  Tiles emptied by
    deletions are kept (structure grows monotonically) — their dense B×B
    block is all-zero and contributes nothing.

    Raises ``ValueError`` for coordinates outside the matrix grid: the block
    grid is fixed for the lifetime of a stream (rebuild via
    ``build_block_sparse`` / ``IncrementalPullMatrix.from_snapshot`` when
    the vertex set outgrows it).
    """
    B = mat.block
    n_rb, n_cb = mat.n_rb, mat.n_cb
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(values, dtype=np.dtype(mat.tiles.dtype))
    if len(rows) == 0:
        return mat
    if (rows.min() < 0 or cols.min() < 0 or rows.max() >= mat.n_rows
            or cols.max() >= mat.n_cols):
        raise ValueError(
            f"delta coordinates (rows in [{rows.min()}, {rows.max()}], cols "
            f"in [{cols.min()}, {cols.max()}]) fall outside the fixed "
            f"{mat.n_rows}x{mat.n_cols} block grid ({n_rb}x{n_cb} blocks of "
            f"{B}); a grid-size change requires a rebuild with "
            f"build_block_sparse / IncrementalPullMatrix.from_snapshot")

    # host bookkeeping shared with the host tier (repro.core.tiering)
    plan = plan_delta(
        np.asarray(mat.tile_cols),
        np.asarray(mat.tile_idx).reshape(n_rb, mat.max_tiles),
        rows, cols, n_cb=n_cb, block=B, max_tiles=mat.max_tiles)

    tiles = mat.tiles
    if plan.n_live > tiles.shape[0]:
        # tile-pool bucket overflow → grow to the next capacity bucket
        cap = capacity_bucket(plan.n_live)
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((cap - tiles.shape[0], B, B), tiles.dtype)])

    # one bucketed device scatter applies every delta value
    b_pad = capacity_bucket(len(rows), DELTA_BATCH_BUCKET)
    pad = b_pad - len(rows)
    z = np.zeros(pad, np.int32)
    tiles = _scatter_delta(
        tiles,
        jnp.asarray(np.concatenate([plan.tid.astype(np.int32), z])),
        jnp.asarray(np.concatenate([(rows % B).astype(np.int32), z])),
        jnp.asarray(np.concatenate([(cols % B).astype(np.int32), z])),
        jnp.asarray(np.concatenate([vals, np.zeros(pad, vals.dtype)])),
        block=B)

    tile_cols_out, tile_idx_out = mat.tile_cols, mat.tile_idx
    if plan.tile_cols is not None:
        tile_cols_out = jnp.asarray(plan.tile_cols)
        tile_idx_out = jnp.asarray(plan.tile_idx.reshape(-1))

    return BlockSparse(
        n_rows=mat.n_rows, n_cols=mat.n_cols, block=B,
        max_tiles=plan.max_tiles, tiles=tiles, tile_cols=tile_cols_out,
        tile_idx=tile_idx_out)


# ---------------------------------------------------------------------------
# SpMV backends
# ---------------------------------------------------------------------------

BACKENDS = ("pallas", "xla")


def default_backend() -> str:
    """Tile-SpMV backend when a caller passes ``backend=None``: the Pallas
    kernels on TPU, the XLA gather/einsum path elsewhere (CPU containers
    would otherwise pay the ~200× interpret-mode penalty).  Override with
    ``REPRO_TILE_BACKEND=pallas|xla`` — an invalid override fails here,
    eagerly, with the valid-value list (it is also checked at
    ``repro.api.EngineConfig`` construction) instead of surfacing only when
    a kernel is launched."""
    env = os.environ.get("REPRO_TILE_BACKEND")
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_TILE_BACKEND={env!r} is not a valid tile backend; "
                f"expected one of {list(BACKENDS)}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve_backend(backend: Optional[str]) -> str:
    backend = backend or default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown tile backend {backend!r} "
                         f"(expected one of {list(BACKENDS)})")
    return backend


@functools.partial(jax.jit,
                   static_argnames=("block", "max_tiles", "semiring"))
def _block_spmv_xla(tile_idx: jnp.ndarray, tile_cols: jnp.ndarray,
                    tiles: jnp.ndarray, x: jnp.ndarray, *, block: int,
                    max_tiles: int, semiring: str = "sum") -> jnp.ndarray:
    """XLA tile backend: gather each row-block's tiles and x-slices over the
    same layout the Pallas kernel prefetches, contract with one einsum
    (batched B×B matvecs — dense MXU/AVX-friendly work, no interpreter)."""
    n_rb = tile_cols.shape[0]
    xb = x.reshape(-1, block)                              # [n_cb, B]
    T = tiles[tile_idx.reshape(n_rb, max_tiles)]           # [n_rb, mt, B, B]
    X = xb[jnp.maximum(tile_cols, 0)]                      # [n_rb, mt, B]
    X = jnp.where((tile_cols >= 0)[:, :, None], X, 0)
    y = jnp.einsum("rmab,rmb->ra", T, X,
                   preferred_element_type=_acc_dtype(x.dtype))
    if semiring == "or":
        y = (y > 0)
    elif semiring != "sum":
        raise ValueError(semiring)
    return y.astype(x.dtype).reshape(-1)


@functools.partial(jax.jit,
                   static_argnames=("block", "max_tiles", "semiring"))
def _block_spmv_active_xla(active_ids: jnp.ndarray, tile_idx: jnp.ndarray,
                           tile_cols: jnp.ndarray, tiles: jnp.ndarray,
                           x: jnp.ndarray, *, block: int, max_tiles: int,
                           semiring: str = "sum") -> jnp.ndarray:
    """Active-row-block XLA tile SpMV: work ∝ len(active_ids) · max_tiles.
    Same contract as the Pallas kernel: rows of inactive blocks are
    *defined as zero* here but callers must still mask (the Pallas backend
    leaves them undefined)."""
    n_rb = tile_cols.shape[0]
    rb = jnp.maximum(active_ids, 0)
    cols = tile_cols[rb]                                   # [k, mt]
    T = tiles[tile_idx.reshape(n_rb, max_tiles)[rb]]       # [k, mt, B, B]
    xb = x.reshape(-1, block)
    X = xb[jnp.maximum(cols, 0)]                           # [k, mt, B]
    live = (active_ids >= 0)[:, None] & (cols >= 0)
    X = jnp.where(live[:, :, None], X, 0)
    y_act = jnp.einsum("kmab,kmb->ka", T, X,
                       preferred_element_type=_acc_dtype(x.dtype))
    if semiring == "or":
        y_act = (y_act > 0)
    elif semiring != "sum":
        raise ValueError(semiring)
    y_act = y_act.astype(x.dtype)
    # padded slots write the trash row n_rb (mirrors the Pallas kernel)
    out = jnp.zeros((n_rb + 1, block), x.dtype)
    out = out.at[jnp.where(active_ids >= 0, active_ids, n_rb)].set(y_act)
    return out[:n_rb].reshape(-1)


def block_spmv(mat: BlockSparse, x: jnp.ndarray, *, semiring: str = "sum",
               interpret: bool = True,
               backend: Optional[str] = None) -> jnp.ndarray:
    """y = A @ x over the requested semiring; x is zero-padded to block size.

    ``backend`` selects the Pallas kernels or the XLA tile path
    (:func:`default_backend` when None).  ``interpret`` applies to the
    Pallas backend only: True executes the kernel body under the
    interpreter (CPU validation), False compiles for TPU.
    """
    backend = _resolve_backend(backend)
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    if backend == "xla":
        y = _block_spmv_xla(mat.tile_idx, mat.tile_cols, mat.tiles, xp,
                            block=mat.block, max_tiles=mat.max_tiles,
                            semiring=semiring)
    else:
        y = block_spmv_pallas(mat.tile_idx, mat.tile_cols, mat.tiles, xp,
                              block=mat.block, max_tiles=mat.max_tiles,
                              semiring=semiring, interpret=interpret)
    return y[:mat.n_rows]


def block_spmv_active(mat: BlockSparse, x: jnp.ndarray,
                      active_ids: jnp.ndarray, *, semiring: str = "sum",
                      interpret: bool = True,
                      backend: Optional[str] = None) -> jnp.ndarray:
    """Frontier-compacted y = A @ x restricted to the row-blocks in
    ``active_ids`` (compacted, -1-padded).  Rows of inactive blocks are
    UNDEFINED — mask with the active-block indicator before consuming."""
    backend = _resolve_backend(backend)
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    if backend == "xla":
        y = _block_spmv_active_xla(active_ids.astype(jnp.int32),
                                   mat.tile_idx, mat.tile_cols, mat.tiles,
                                   xp, block=mat.block,
                                   max_tiles=mat.max_tiles, semiring=semiring)
    else:
        y = block_spmv_active_pallas(active_ids.astype(jnp.int32),
                                     mat.tile_idx, mat.tile_cols, mat.tiles,
                                     xp, block=mat.block,
                                     max_tiles=mat.max_tiles,
                                     semiring=semiring, interpret=interpret)
    return y[:mat.n_rows]


def block_spmv_active_bucketed(mat: BlockSparse, x: jnp.ndarray,
                               active_ids: jnp.ndarray, n_active: jnp.ndarray,
                               *, semiring: str = "sum",
                               interpret: bool = True,
                               backend: Optional[str] = None,
                               ladder: Optional[Sequence[int]] = None
                               ) -> jnp.ndarray:
    """Frontier-proportional active SpMV dispatch.

    ``active_ids`` is the full compacted slot list ([n_rb], -1-padded) and
    ``n_active`` the (traced) count of real entries.  The call selects the
    smallest ladder bucket K ≥ n_active with a ``lax.switch`` and launches
    the K-slot kernel on ``active_ids[:K]`` — so the Pallas grid / the XLA
    gather scales with the actual frontier, not ``n_rb``.  Trace-safe inside
    the fused driver's ``while_loop`` (the switch index is a traced scalar;
    every branch has static shapes).  O(log n_rb) branches are compiled once.
    """
    backend = _resolve_backend(backend)
    n_rb = mat.n_rb
    lad = tuple(ladder) if ladder is not None else active_ladder(n_rb)
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    ids32 = active_ids.astype(jnp.int32)

    def run(ids_k):
        if backend == "xla":
            return _block_spmv_active_xla(
                ids_k, mat.tile_idx, mat.tile_cols, mat.tiles, xp,
                block=mat.block, max_tiles=mat.max_tiles, semiring=semiring)
        return block_spmv_active_pallas(
            ids_k, mat.tile_idx, mat.tile_cols, mat.tiles, xp,
            block=mat.block, max_tiles=mat.max_tiles, semiring=semiring,
            interpret=interpret)

    if len(lad) == 1:
        y = run(ids32[:lad[0]])
    else:
        branches = [functools.partial(lambda K: run(ids32[:K]), K)
                    for K in lad]
        bidx = sum((n_active > K).astype(jnp.int32) for K in lad[:-1])
        y = lax.switch(bidx, branches)
    return y[:mat.n_rows]


def block_spmv_push_bucketed(mat: BlockSparse, x: jnp.ndarray,
                             src_cb: jnp.ndarray,
                             active_ids: jnp.ndarray, n_active: jnp.ndarray,
                             *, interpret: bool = True,
                             backend: Optional[str] = None,
                             ladder: Optional[Sequence[int]] = None
                             ) -> jnp.ndarray:
    """Scatter-semiring push step on the pull tile layout.

    Forward push moves each selected source's residual along its
    *out*-edges: ``y[v] = Σ_{u→v, u ∈ S} x[u]``.  On the pull layout
    (``A[v, u] = 1`` iff edge u→v) that scatter is exactly ``A @ (x ⊙ 1_S)``
    — so the push reuses the same tiles, slot tables and bucketed dispatch
    as the pull, with the operand masked to the selected source
    column-blocks (``src_cb``, a [n_cb] indicator) and the launch restricted
    to the candidate *destination* row-blocks (``active_ids`` compacted,
    -1-padded; ``n_active`` traced — the tile-presence adjacency gives the
    exact candidate set, so no destination outside it can receive mass).

    Same output contract as :func:`block_spmv_active_bucketed`: rows of
    blocks outside ``active_ids`` are UNDEFINED on the Pallas backend —
    mask with the candidate indicator before consuming."""
    xm = jnp.where(jnp.repeat(src_cb, mat.block)[:x.shape[0]], x, 0)
    return block_spmv_active_bucketed(
        mat, xm, active_ids, n_active, semiring="sum",
        interpret=interpret, backend=backend, ladder=ladder)


def block_adjacency(mat: BlockSparse) -> jnp.ndarray:
    """Boolean [n_rb, n_cb] tile-presence matrix: which row-blocks own a tile
    in each column-block.  Drives candidate-block selection for the OR-pass
    (a changed column-block can only mark rows of these row-blocks).

    A dynamic stream should *maintain* this incrementally
    (:class:`repro.core.incremental.IncrementalPullMatrix` caches it and
    ORs in each batch's touched blocks) instead of recomputing per run."""
    occ = mat.tile_cols >= 0
    rb = jnp.arange(mat.n_rb, dtype=jnp.int32)[:, None]
    cb = jnp.where(occ, mat.tile_cols, mat.n_cb)
    out = jnp.zeros((mat.n_rb, mat.n_cb + 1), bool)
    out = out.at[jnp.broadcast_to(rb, cb.shape), cb].set(True)
    return out[:, :mat.n_cb]


def pagerank_pull_step(mat: BlockSparse, ranks: jnp.ndarray,
                       inv_out_deg: jnp.ndarray, n: int, *,
                       alpha: float = 0.85, interpret: bool = True,
                       backend: Optional[str] = None) -> jnp.ndarray:
    """One PageRank pull iteration with the tile SpMV:
    r' = (1-α)/n + α · A @ (r ⊙ 1/outdeg).  A[v,u] = 1 iff edge u→v."""
    contrib = ranks * inv_out_deg
    pulled = block_spmv(mat, contrib, semiring="sum", interpret=interpret,
                        backend=backend)
    return (1.0 - alpha) / n + alpha * pulled


def frontier_expand_op(mat_t: BlockSparse, changed: jnp.ndarray, *,
                       interpret: bool = True,
                       backend: Optional[str] = None) -> jnp.ndarray:
    """DF expansion: indicator of out-neighbors of ``changed`` vertices.
    ``mat_t`` must hold A[v,u]=1 iff edge u→v (same layout as the pull)."""
    return block_spmv(mat_t, changed.astype(jnp.float32), semiring="or",
                      interpret=interpret, backend=backend)
