"""Host-side block-sparse builder + jit'd SpMV wrapper + PageRank step op."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.block_spmv.block_spmv import block_spmv_pallas


@dataclasses.dataclass(frozen=True)
class BlockSparse:
    """Block-sparse matrix A [n_rows_pad, n_cols_pad] in B×B dense tiles.

    ``tiles[k]`` is the dense tile for the k-th stored (row-block, col-block)
    pair; ``tile_cols[i, j]`` is the column-block of the j-th tile of
    row-block i (or -1 padding); ``tile_idx`` flat-indexes into ``tiles``.
    """
    n_rows: int
    n_cols: int
    block: int
    max_tiles: int
    tiles: jnp.ndarray       # [n_tiles, B, B]
    tile_cols: jnp.ndarray   # [n_rb, max_tiles] i32
    tile_idx: jnp.ndarray    # [n_rb * max_tiles] i32

    @property
    def n_rb(self) -> int:
        return self.tile_cols.shape[0]

    @property
    def n_cb(self) -> int:
        return (self.n_cols + self.block - 1) // self.block


def build_block_sparse(rows: np.ndarray, cols: np.ndarray, n_rows: int,
                       n_cols: int, *, block: int = 128,
                       values: Optional[np.ndarray] = None,
                       dtype=np.float32) -> BlockSparse:
    """Build tiles from an edge list: A[rows[k], cols[k]] = values[k] (or 1)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = (np.ones_like(rows, dtype=dtype) if values is None
            else np.asarray(values, dtype))
    n_rb = (n_rows + block - 1) // block
    n_cb = (n_cols + block - 1) // block

    rb, cb = rows // block, cols // block
    key = rb * n_cb + cb
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    uniq, start = np.unique(key, return_index=True)
    counts = np.diff(np.append(start, len(key)))

    n_tiles = max(1, len(uniq))
    tiles = np.zeros((n_tiles, block, block), dtype=dtype)
    for t, (k, s, c) in enumerate(zip(uniq, start, counts)):
        r = rows[s:s + c] % block
        cc = cols[s:s + c] % block
        np.add.at(tiles[t], (r, cc), vals[s:s + c])

    tiles_rb = (uniq // n_cb).astype(np.int64)
    tiles_cb = (uniq % n_cb).astype(np.int64)
    per_row = np.bincount(tiles_rb, minlength=n_rb)
    max_tiles = max(1, int(per_row.max(initial=1)))

    tile_cols = np.full((n_rb, max_tiles), -1, dtype=np.int32)
    tile_idx = np.zeros((n_rb, max_tiles), dtype=np.int32)
    slot = np.zeros(n_rb, dtype=np.int64)
    for t, (r, c) in enumerate(zip(tiles_rb, tiles_cb)):
        tile_cols[r, slot[r]] = c
        tile_idx[r, slot[r]] = t
        slot[r] += 1

    return BlockSparse(
        n_rows=n_rows, n_cols=n_cols, block=block, max_tiles=max_tiles,
        tiles=jnp.asarray(tiles), tile_cols=jnp.asarray(tile_cols),
        tile_idx=jnp.asarray(tile_idx.reshape(-1)))


def block_spmv(mat: BlockSparse, x: jnp.ndarray, *, semiring: str = "sum",
               interpret: bool = True) -> jnp.ndarray:
    """y = A @ x over the requested semiring; x is zero-padded to block size.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on TPU pass ``interpret=False``.
    """
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    y = block_spmv_pallas(mat.tile_idx, mat.tile_cols, mat.tiles, xp,
                          block=mat.block, max_tiles=mat.max_tiles,
                          semiring=semiring, interpret=interpret)
    return y[:mat.n_rows]


def pagerank_pull_step(mat: BlockSparse, ranks: jnp.ndarray,
                       inv_out_deg: jnp.ndarray, n: int, *,
                       alpha: float = 0.85, interpret: bool = True
                       ) -> jnp.ndarray:
    """One PageRank pull iteration with the Pallas SpMV:
    r' = (1-α)/n + α · A @ (r ⊙ 1/outdeg).  A[v,u] = 1 iff edge u→v."""
    contrib = ranks * inv_out_deg
    pulled = block_spmv(mat, contrib, semiring="sum", interpret=interpret)
    return (1.0 - alpha) / n + alpha * pulled


def frontier_expand_op(mat_t: BlockSparse, changed: jnp.ndarray, *,
                       interpret: bool = True) -> jnp.ndarray:
    """DF expansion: indicator of out-neighbors of ``changed`` vertices.
    ``mat_t`` must hold A[v,u]=1 iff edge u→v (same layout as the pull)."""
    return block_spmv(mat_t, changed.astype(jnp.float32), semiring="or",
                      interpret=interpret)
