"""Host-side block-sparse builder + jit'd SpMV wrapper + PageRank step op.

The builder is fully vectorized (one flat ``np.add.at`` scatter for tile
values, one argsort-free slot assignment for the per-row tile lists) and has
an incremental sibling: :func:`apply_delta` patches only the tiles an edge
batch touches, so a dynamic-graph stream pays O(batch) per snapshot instead
of O(m) rebuilds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.block_spmv.block_spmv import (block_spmv_pallas,
                                                 block_spmv_active_pallas)


@dataclasses.dataclass(frozen=True)
class BlockSparse:
    """Block-sparse matrix A [n_rows_pad, n_cols_pad] in B×B dense tiles.

    ``tiles[k]`` is the dense tile for the k-th stored (row-block, col-block)
    pair; ``tile_cols[i, j]`` is the column-block of the j-th tile of
    row-block i (or -1 padding); ``tile_idx`` flat-indexes into ``tiles``.

    Registered as a pytree so it can flow through ``jax.jit`` / ``lax``
    control flow (the fused Pallas engine carries one through its driver).
    """
    n_rows: int
    n_cols: int
    block: int
    max_tiles: int
    tiles: jnp.ndarray       # [n_tiles, B, B]
    tile_cols: jnp.ndarray   # [n_rb, max_tiles] i32
    tile_idx: jnp.ndarray    # [n_rb * max_tiles] i32

    @property
    def n_rb(self) -> int:
        return (self.n_rows + self.block - 1) // self.block

    @property
    def n_cb(self) -> int:
        return (self.n_cols + self.block - 1) // self.block

    def tree_flatten(self):
        children = (self.tiles, self.tile_cols, self.tile_idx)
        aux = (self.n_rows, self.n_cols, self.block, self.max_tiles)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_rows, n_cols, block, max_tiles = aux
        tiles, tile_cols, tile_idx = children
        return cls(n_rows=n_rows, n_cols=n_cols, block=block,
                   max_tiles=max_tiles, tiles=tiles, tile_cols=tile_cols,
                   tile_idx=tile_idx)


jax.tree_util.register_pytree_node(
    BlockSparse, BlockSparse.tree_flatten, BlockSparse.tree_unflatten)


def _slot_tables(tiles_rb: np.ndarray, tiles_cb: np.ndarray, n_rb: int,
                 min_max_tiles: int = 1) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-row tile lists from sorted-by-(rb, cb) tile coordinates.

    Tiles of one row-block are contiguous (the caller sorts by the flat key
    rb * n_cb + cb), so the slot of tile t within its row is just
    ``t - row_start[rb(t)]`` — no Python loop.
    """
    n_tiles = len(tiles_rb)
    per_row = np.bincount(tiles_rb, minlength=n_rb)
    max_tiles = max(min_max_tiles, int(per_row.max(initial=1)))
    row_start = np.zeros(n_rb + 1, dtype=np.int64)
    np.cumsum(per_row, out=row_start[1:])
    slot = np.arange(n_tiles, dtype=np.int64) - row_start[tiles_rb]
    tile_cols = np.full((n_rb, max_tiles), -1, dtype=np.int32)
    tile_idx = np.zeros((n_rb, max_tiles), dtype=np.int32)
    tile_cols[tiles_rb, slot] = tiles_cb
    tile_idx[tiles_rb, slot] = np.arange(n_tiles, dtype=np.int64)
    return tile_cols, tile_idx, max_tiles


def build_block_sparse(rows: np.ndarray, cols: np.ndarray, n_rows: int,
                       n_cols: int, *, block: int = 128,
                       values: Optional[np.ndarray] = None,
                       dtype=np.float32) -> BlockSparse:
    """Build tiles from an edge list: A[rows[k], cols[k]] = values[k] (or 1)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = (np.ones_like(rows, dtype=dtype) if values is None
            else np.asarray(values, dtype))
    n_rb = (n_rows + block - 1) // block
    n_cb = (n_cols + block - 1) // block

    rb, cb = rows // block, cols // block
    key = rb * n_cb + cb
    order = np.argsort(key, kind="stable")
    rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
    uniq = np.unique(key)

    n_tiles = max(1, len(uniq))
    tiles = np.zeros((n_tiles, block, block), dtype=dtype)
    # one flat scatter for every entry: tile position × B² + local offset
    tpos = np.searchsorted(uniq, key)
    flat = tpos * (block * block) + (rows % block) * block + (cols % block)
    np.add.at(tiles.reshape(-1), flat, vals)

    tiles_rb = (uniq // n_cb).astype(np.int64)
    tiles_cb = (uniq % n_cb).astype(np.int64)
    tile_cols, tile_idx, max_tiles = _slot_tables(tiles_rb, tiles_cb, n_rb)

    return BlockSparse(
        n_rows=n_rows, n_cols=n_cols, block=block, max_tiles=max_tiles,
        tiles=jnp.asarray(tiles), tile_cols=jnp.asarray(tile_cols),
        tile_idx=jnp.asarray(tile_idx.reshape(-1)))


def apply_delta(mat: BlockSparse, rows: np.ndarray, cols: np.ndarray,
                values: np.ndarray) -> BlockSparse:
    """Patch A with A[rows[k], cols[k]] += values[k], touching only the
    tiles the delta lands in.

    Existing tiles are updated with one scattered ``.at[touched].add``;
    entirely new (row-block, col-block) pairs are appended and the per-row
    tile lists widened only if needed.  Tiles emptied by deletions are kept
    (structure grows monotonically across a stream) — their dense B×B block
    is all-zero and contributes nothing.
    """
    B = mat.block
    n_rb, n_cb = mat.n_rb, mat.n_cb
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(values, dtype=np.dtype(mat.tiles.dtype))
    if len(rows) == 0:
        return mat

    key = (rows // B) * n_cb + (cols // B)

    # current tile table (host copies of the small index arrays only)
    tile_cols_h = np.asarray(mat.tile_cols)
    tile_idx_h = np.asarray(mat.tile_idx).reshape(n_rb, mat.max_tiles)
    occ = tile_cols_h >= 0
    ex_rb, ex_slot = np.nonzero(occ)
    ex_key = ex_rb * n_cb + tile_cols_h[ex_rb, ex_slot]
    ex_tid = tile_idx_h[ex_rb, ex_slot]
    order = np.argsort(ex_key)
    sk, st = ex_key[order], ex_tid[order]

    pos = np.searchsorted(sk, key)
    pos_c = np.clip(pos, 0, max(len(sk) - 1, 0))
    found = (sk[pos_c] == key) if len(sk) else np.zeros(len(key), bool)

    n_old = int(mat.tiles.shape[0])
    new_keys = np.unique(key[~found])
    tid = np.where(found, st[pos_c] if len(sk) else 0,
                   n_old + np.searchsorted(new_keys, key))

    touched = np.unique(tid)
    tmap = np.searchsorted(touched, tid)
    patch = np.zeros((len(touched), B, B), dtype=vals.dtype)
    np.add.at(patch.reshape(-1),
              tmap * (B * B) + (rows % B) * B + (cols % B), vals)

    tiles = mat.tiles
    if len(new_keys):
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((len(new_keys), B, B), tiles.dtype)])
    tiles = tiles.at[jnp.asarray(touched)].add(jnp.asarray(patch))

    tile_cols_out, tile_idx_out = mat.tile_cols, mat.tile_idx
    max_tiles = mat.max_tiles
    if len(new_keys):
        # merge old + new coordinates, re-deriving slots (cheap: index-sized)
        all_key = np.concatenate([ex_key, new_keys])
        all_tid = np.concatenate([ex_tid, n_old + np.arange(len(new_keys))])
        order = np.argsort(all_key)
        all_key, all_tid = all_key[order], all_tid[order]
        t_rb = (all_key // n_cb).astype(np.int64)
        t_cb = (all_key % n_cb).astype(np.int64)
        tile_cols_np, idx_pos, max_tiles = _slot_tables(
            t_rb, t_cb, n_rb, min_max_tiles=mat.max_tiles)
        # _slot_tables numbers tiles 0..n-1 in sorted order; map to real ids
        tile_idx_np = np.zeros_like(idx_pos)
        occ2 = tile_cols_np >= 0
        tile_idx_np[occ2] = all_tid[idx_pos[occ2]]
        tile_cols_out = jnp.asarray(tile_cols_np)
        tile_idx_out = jnp.asarray(tile_idx_np.reshape(-1))

    return BlockSparse(
        n_rows=mat.n_rows, n_cols=mat.n_cols, block=B, max_tiles=max_tiles,
        tiles=tiles, tile_cols=tile_cols_out, tile_idx=tile_idx_out)


def block_spmv(mat: BlockSparse, x: jnp.ndarray, *, semiring: str = "sum",
               interpret: bool = True) -> jnp.ndarray:
    """y = A @ x over the requested semiring; x is zero-padded to block size.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on TPU pass ``interpret=False``.
    """
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    y = block_spmv_pallas(mat.tile_idx, mat.tile_cols, mat.tiles, xp,
                          block=mat.block, max_tiles=mat.max_tiles,
                          semiring=semiring, interpret=interpret)
    return y[:mat.n_rows]


def block_spmv_active(mat: BlockSparse, x: jnp.ndarray,
                      active_ids: jnp.ndarray, *, semiring: str = "sum",
                      interpret: bool = True) -> jnp.ndarray:
    """Frontier-compacted y = A @ x restricted to the row-blocks in
    ``active_ids`` (compacted, -1-padded).  Rows of inactive blocks are
    UNDEFINED — mask with the active-block indicator before consuming."""
    n_cb_pad = mat.n_cb * mat.block
    xp = jnp.zeros((n_cb_pad,), x.dtype).at[:x.shape[0]].set(x)
    y = block_spmv_active_pallas(active_ids.astype(jnp.int32), mat.tile_idx,
                                 mat.tile_cols, mat.tiles, xp,
                                 block=mat.block, max_tiles=mat.max_tiles,
                                 semiring=semiring, interpret=interpret)
    return y[:mat.n_rows]


def block_adjacency(mat: BlockSparse) -> jnp.ndarray:
    """Boolean [n_rb, n_cb] tile-presence matrix: which row-blocks own a tile
    in each column-block.  Drives candidate-block selection for the OR-pass
    (a changed column-block can only mark rows of these row-blocks)."""
    occ = mat.tile_cols >= 0
    rb = jnp.arange(mat.n_rb, dtype=jnp.int32)[:, None]
    cb = jnp.where(occ, mat.tile_cols, mat.n_cb)
    out = jnp.zeros((mat.n_rb, mat.n_cb + 1), bool)
    out = out.at[jnp.broadcast_to(rb, cb.shape), cb].set(True)
    return out[:, :mat.n_cb]


def pagerank_pull_step(mat: BlockSparse, ranks: jnp.ndarray,
                       inv_out_deg: jnp.ndarray, n: int, *,
                       alpha: float = 0.85, interpret: bool = True
                       ) -> jnp.ndarray:
    """One PageRank pull iteration with the Pallas SpMV:
    r' = (1-α)/n + α · A @ (r ⊙ 1/outdeg).  A[v,u] = 1 iff edge u→v."""
    contrib = ranks * inv_out_deg
    pulled = block_spmv(mat, contrib, semiring="sum", interpret=interpret)
    return (1.0 - alpha) / n + alpha * pulled


def frontier_expand_op(mat_t: BlockSparse, changed: jnp.ndarray, *,
                       interpret: bool = True) -> jnp.ndarray:
    """DF expansion: indicator of out-neighbors of ``changed`` vertices.
    ``mat_t`` must hold A[v,u]=1 iff edge u→v (same layout as the pull)."""
    return block_spmv(mat_t, changed.astype(jnp.float32), semiring="or",
                      interpret=interpret)
