"""Pure-jnp oracle for the block-sparse SpMV kernel."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def spmv_ref(rows: np.ndarray, cols: np.ndarray, n_rows: int,
             x: jnp.ndarray, *, values=None, semiring: str = "sum"
             ) -> jnp.ndarray:
    """Edge-list oracle: y[r] = Σ_{k: rows[k]=r} values[k] · x[cols[k]]."""
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    v = (jnp.ones(rows.shape, x.dtype) if values is None
         else jnp.asarray(values, x.dtype))
    import jax
    y = jax.ops.segment_sum(v * x[cols], rows, num_segments=n_rows)
    if semiring == "or":
        y = (y > 0).astype(x.dtype)
    return y


def pagerank_pull_step_ref(rows, cols, n_rows, ranks, inv_out_deg, n, *,
                           alpha=0.85):
    contrib = ranks * inv_out_deg
    pulled = spmv_ref(rows, cols, n_rows, contrib)
    return (1.0 - alpha) / n + alpha * pulled
