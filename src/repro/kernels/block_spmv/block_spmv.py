"""Block-sparse SpMV Pallas TPU kernel — the PageRank pull hot-spot on the MXU.

Hardware adaptation (DESIGN.md §2): a CPU/GPU CSR gather loop has no MXU
mapping.  Instead the adjacency is partitioned into dense B×B tiles and only
non-empty tiles are stored.  Per destination row-block, the kernel walks its
(padded) tile list via *scalar-prefetched* indices and accumulates

    acc[rows of i] += A_tile(i, j) @ c[cols of tile j]

entirely in VMEM, writing each output block exactly once.  The same kernel in
the OR-semiring (saturating accumulation) implements the Dynamic Frontier
expansion ("mark out-neighbors of changed vertices") on the transposed tiles.

Grid = (n_row_blocks, max_tiles_per_row); the tile loop is innermost so the
output block stays resident in VMEM across the accumulation (standard Pallas
revisiting pattern).  Padded slots carry column id -1 and are masked.

VMEM working set per grid step: one B×B tile + one B×1 slice of x + one B×1
accumulator ≈ (B² + 2B)·4 bytes → B=256 ⇒ ~260 KiB, far below the ~16 MiB
VMEM budget; B is kept a parameter (tests sweep 8..128) and must be a
multiple of 8×128 lanes for peak MXU utilisation on real hardware (B=128/256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _accumulate(o_ref, part, j, *, semiring: str):
    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if semiring == "sum":
        o_ref[...] += part
    elif semiring == "or":
        # saturating OR: any positive contribution marks the row
        o_ref[...] = jnp.maximum(o_ref[...], jnp.minimum(part, 1.0))
    else:
        raise ValueError(semiring)


def _acc_dtype(dtype) -> jnp.dtype:
    """MXU accumulation dtype: f32 for f32/bf16 inputs, f64 for f64 ranks
    (f64 is the CPU/interpret validation path — TPU MXU has no f64)."""
    return jnp.dtype(jnp.float64) if dtype == jnp.float64 else jnp.float32


def _kernel(idx_ref, cols_ref, tiles_ref, x_ref, o_ref, *, semiring: str):
    j = pl.program_id(1)
    valid = cols_ref[pl.program_id(0), j] >= 0
    tile = tiles_ref[0]                       # [B, B]
    x = x_ref[...]                            # [B, 1]
    part = jnp.dot(tile, x, preferred_element_type=_acc_dtype(x.dtype))
    part = jnp.where(valid, part, 0.0).astype(o_ref.dtype)
    _accumulate(o_ref, part, j, semiring=semiring)


def _active_kernel(act_ref, idx_ref, cols_ref, tiles_ref, x_ref, o_ref, *,
                   semiring: str):
    """Same body as :func:`_kernel` but row-blocks come from the compacted
    ``act_ref`` slot list (-1 = padded slot → contributes nothing)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    rb = act_ref[i]
    valid = (rb >= 0) & (cols_ref[jnp.maximum(rb, 0), j] >= 0)
    tile = tiles_ref[0]                       # [B, B]
    x = x_ref[...]                            # [B, 1]
    part = jnp.dot(tile, x, preferred_element_type=_acc_dtype(x.dtype))
    part = jnp.where(valid, part, 0.0).astype(o_ref.dtype)
    _accumulate(o_ref, part, j, semiring=semiring)


@functools.partial(jax.jit, static_argnames=("block", "max_tiles",
                                             "semiring", "interpret"))
def block_spmv_pallas(tile_idx: jnp.ndarray,    # [n_rb * max_tiles] i32
                      tile_cols: jnp.ndarray,   # [n_rb, max_tiles]  i32 (-1 pad)
                      tiles: jnp.ndarray,       # [n_tiles, B, B]    f32
                      x: jnp.ndarray,           # [n_cb * B]         f32
                      *, block: int, max_tiles: int, semiring: str = "sum",
                      interpret: bool = False) -> jnp.ndarray:
    """Returns y [n_rb * B] with y = A @ x (sum) or y = (A @ x > 0) (or)."""
    n_rb = tile_cols.shape[0]
    x2 = x.reshape(-1, 1)

    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_rb, max_tiles),
        in_specs=[
            pl.BlockSpec((1, tiles.shape[1], tiles.shape[2]),
                         lambda i, j, idx, cols: (idx[i * max_tiles + j], 0,
                                                  0)),
            pl.BlockSpec((block, 1),
                         lambda i, j, idx, cols: (
                             jnp.maximum(cols[i, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, j, idx, cols: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, semiring=semiring),
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((n_rb * block, 1), x.dtype),
        interpret=interpret,
    )(tile_idx, tile_cols, tiles, x2)
    y = out[:, 0]
    if semiring == "or":
        y = (y > 0).astype(x.dtype)
    return y


@functools.partial(jax.jit, static_argnames=("block", "max_tiles",
                                             "semiring", "interpret"))
def block_spmv_active_pallas(active_ids: jnp.ndarray,  # [n_rb] i32, -1 pad
                             tile_idx: jnp.ndarray,    # [n_rb * max_tiles] i32
                             tile_cols: jnp.ndarray,   # [n_rb, max_tiles] i32
                             tiles: jnp.ndarray,       # [n_tiles, B, B]
                             x: jnp.ndarray,           # [n_cb * B]
                             *, block: int, max_tiles: int,
                             semiring: str = "sum",
                             interpret: bool = False) -> jnp.ndarray:
    """Frontier-compacted SpMV: only the row-blocks named in ``active_ids``
    are computed.  ``active_ids`` is a compacted slot list (active block ids
    first, then -1 padding) so the grid walks frontier blocks only; padded
    slots alias a trash output block and tile 0 — after the first padded step
    their block indices stop changing, so the pipeline re-fetches nothing and
    `pl.when` skips the compute (frontier-proportional work on hardware).

    Rows in *inactive* blocks are left undefined — callers must mask with the
    active-block indicator before use (the fused engine does).
    """
    n_rb = tile_cols.shape[0]
    x2 = x.reshape(-1, 1)

    def tile_map(i, j, act, idx, cols):
        rb = jnp.maximum(act[i], 0)
        return (idx[rb * max_tiles + j], 0, 0)

    def x_map(i, j, act, idx, cols):
        rb = jnp.maximum(act[i], 0)
        return (jnp.maximum(cols[rb, j], 0), 0)

    def o_map(i, j, act, idx, cols):
        # padded slot → trash block n_rb (output is padded by one block)
        return (jnp.where(act[i] >= 0, act[i], n_rb), 0)

    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(active_ids.shape[0], max_tiles),
        in_specs=[
            pl.BlockSpec((1, tiles.shape[1], tiles.shape[2]), tile_map),
            pl.BlockSpec((block, 1), x_map),
        ],
        out_specs=pl.BlockSpec((block, 1), o_map),
    )
    out = pl.pallas_call(
        functools.partial(_active_kernel, semiring=semiring),
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct(((n_rb + 1) * block, 1), x.dtype),
        interpret=interpret,
    )(active_ids, tile_idx, tile_cols, tiles, x2)
    y = out[:n_rb * block, 0]
    if semiring == "or":
        # normalize to a 0/1 indicator like block_spmv_pallas (and the XLA
        # tile path) — weighted matrices would otherwise leak tile values
        y = (y > 0).astype(x.dtype)
    return y
