"""Adam / AdamW built from scratch (no optax), with ZeRO-1-compatible state.

State is a pytree mirroring params: {"m": ..., "v": ..., "step": scalar}.
Gradient clipping (global L2 norm) and a cosine/linear-warmup schedule are
included; the trainer shards ``m``/``v`` per dist.sharding.zero1_logical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"      # "cosine" | "linear" | "constant"
    state_dtype: Any = jnp.float32


def schedule_lr(cfg: AdamConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                     0.0, 1.0)
        decay = (0.5 * (1 + jnp.cos(jnp.pi * t)) if cfg.schedule == "cosine"
                 else 1.0 - t)
    return cfg.lr * warm * decay


def init_state(params, cfg: AdamConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state, cfg: AdamConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(cfg.state_dtype)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(cfg.state_dtype)
        return (p - (lr * delta).astype(p.dtype)), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    state = {"m": new_m, "v": new_v, "step": step}
    return new_p, state, {"lr": lr, "grad_norm": gnorm}
