"""Standalone LR schedules (the AdamConfig embeds the common ones; these are
for custom training loops and the examples)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, warmup_steps))
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(1, total_steps - warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return peak_lr * warm * (final_frac + (1 - final_frac) * cos)


def warmup_linear(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, warmup_steps))
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(1, total_steps - warmup_steps), 0.0, 1.0)
    return peak_lr * warm * (1.0 - t)


def inverse_sqrt(step, *, peak_lr: float, warmup_steps: int):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, warmup_steps))
    decay = jnp.sqrt(jnp.maximum(1.0, warmup_steps)
                     / jnp.maximum(step, 1.0))
    return peak_lr * warm * jnp.minimum(1.0, decay)
