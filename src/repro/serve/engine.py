"""Batched LM serving engine: prefill + decode with a slot-based batch.

A production-shaped (if compact) engine:
  * fixed decode batch of ``slots`` — each slot holds one request's KV cache
    row; finished slots are refilled from the queue (continuous batching);
  * prefill runs per admitted request (padded to ``prefill_buckets`` so the
    jit cache stays small), then its KV is packed into the slot cache;
  * decode is one fused step over all live slots;
  * deterministic greedy sampling by default (argmax), temperature optional.

The engine is mesh-agnostic: under a mesh + rules context the same code path
serves the sharded model (launch/serve.py wires that up).

The slot pattern here (fixed slots, shared queue, one unit of work per live
slot per tick) is reused by the PageRank serving layer:
:class:`repro.api.service.PageRankService` drives N dynamic-graph sessions
the same way a decode batch drives N requests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import model as M
from repro.models.transformer.config import TransformerConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] i32
    max_new_tokens: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServeEngine:
    def __init__(self, cfg: TransformerConfig, params, *, slots: int = 8,
                 cache_len: int = 512,
                 prefill_buckets: Tuple[int, ...] = (64, 128, 256, 512),
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.buckets = prefill_buckets
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)

        self.cache = M.init_cache(cfg, slots, cache_len)
        self.positions = np.zeros(slots, np.int64)      # next position
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.last_token = np.zeros(slots, np.int64)

        self._prefill = jax.jit(
            lambda p, t: M.prefill(p, t, cfg, cache_len=cache_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_batch_step(p, c, t, pos, cfg))

    # -- queue management -----------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.live[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            S = len(req.prompt)
            # exact-length prefill: causal attention makes right-padding
            # corrupt the last-token logits, so each admitted prompt runs at
            # its true length (buckets only bound the jit-cache variety for
            # callers that pre-pad prompts themselves)
            tok = np.asarray(req.prompt, np.int64)[None, :]
            logits, cache1 = self._prefill(self.params,
                                           jnp.asarray(tok, jnp.int32))
            for k in ("k", "v"):
                upd = cache1[k][:, 0]
                self.cache[k] = self.cache[k].at[:, s, :upd.shape[1]].set(
                    upd[:, :self.cache_len])
            nxt = self._sample(logits[0])
            req.out.append(int(nxt))
            self.live[s] = req
            self.positions[s] = S
            self.last_token[s] = int(nxt)

    def _sample(self, logits: jnp.ndarray) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    # -- decode ---------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one fused decode; returns #live slots."""
        self._admit()
        live_idx = [s for s in range(self.slots) if self.live[s] is not None]
        if not live_idx:
            return 0
        tokens = jnp.asarray(self.last_token, jnp.int32)
        positions = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          positions)
        for s in live_idx:
            req = self.live[s]
            nxt = self._sample(logits[s])
            req.out.append(int(nxt))
            self.positions[s] += 1
            self.last_token[s] = int(nxt)
            if (len(req.out) >= req.max_new_tokens
                    or self.positions[s] >= self.cache_len - 1):
                req.done = True
                self.live[s] = None
        return len(live_idx)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            before = [r for r in self.live if r is not None]
            n = self.step()
            finished.extend(r for r in before
                            if r.done and r not in finished)
            if n == 0 and not self.queue:
                break
        return finished
