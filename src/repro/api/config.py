"""`EngineConfig` — one frozen, validated home for every engine knob.

The legacy variant functions threaded nine loose kwargs through
``_defaults()``, which silently forwarded typos into the engine stack
(surfacing as an opaque ``TypeError`` deep inside ``_run``).  The config
object replaces that: every knob is a declared field, validation happens at
*construction* (including the ``REPRO_ENGINE`` / ``REPRO_TILE_BACKEND``
environment overrides, resolved eagerly through the engine registry), and
unknown keys are rejected with the valid-key list in the message.

A config is immutable and reusable: build one, hand it to any number of
:class:`repro.api.PageRankSession` instances (or ``replace()`` a variant of
it for a what-if fork).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

MODES = ("lf", "bb")
ACTIVE_POLICIES = ("affected", "rc")
# convergence drivers of the streaming pallas engine (docs/ENGINES.md):
#   "pull" — the fused frontier pull loop (re-pull active blocks to tau);
#   "push" — the residual forward-push loop (repro.core.push_engine):
#            work ∝ residual mass, convergence on the L1 residual bound
DRIVERS = ("pull", "push")
TOPOLOGIES = ("single", "sharded")
# contribution-exchange variants the sharded session runtime supports
EXCHANGES = ("full", "bf16", "delta")
# process-fault durability levels (docs/FAULTS.md):
#   "none" — session state is device-only, a process crash loses it;
#   "wal"  — every update batch is durably logged before it touches device
#            state, with periodic atomic rank checkpoints; restore =
#            checkpoint + WAL replay through the normal hot path
DURABILITIES = ("none", "wal")
# load-shedding policies of a full serving queue (ServingConfig):
#   "reject"      — refuse the NEW submit (caller sees AdmissionRejected);
#   "drop_oldest" — shed the oldest queued request to admit the new one
#                   (recency wins: the freshest deltas are the ones worth
#                   converging under overload)
SHED_POLICIES = ("reject", "drop_oldest")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Validated, immutable engine configuration.

    Fields
    ------
    alpha:          damping factor, in (0, 1) (paper uses 0.85).
    tau:            per-vertex convergence threshold, > 0.
    tau_f:          frontier-expansion threshold; ``None`` resolves to
                    ``tau / 1000`` where expansion is on (paper §5.1.2).
    mode:           ``"lf"`` (lock-free) or ``"bb"`` (barrier-based).
    engine:         engine name resolved through :mod:`repro.api.registry`
                    (``None`` → platform default, ``REPRO_ENGINE`` override).
    backend:        tile-SpMV backend for the pallas engine
                    (``None`` → platform default, ``REPRO_TILE_BACKEND``
                    override; rejected at run time by other engines).
    tile:           edge-tile size of the blocked engine's pull loop.
    block_size:     vertices per block — the session's block grid (sessions
                    built ``from_snapshot`` take the snapshot's grid).
    active_policy:  ``"affected"`` (paper Alg. 2 line 19) or ``"rc"``
                    (per-chunk converged flag, §4.3).
    max_iterations: sweep budget before declaring non-convergence.
    faults:         optional :class:`repro.core.faults.FaultPlan`.
    dtype:          rank dtype (``None`` → f64 when x64 is enabled else f32).
    topology:       ``"single"`` (one device — every engine) or
                    ``"sharded"`` (vertex-partitioned over a device mesh;
                    resolves the ``distributed`` engine).
    n_shards:       mesh size under ``topology="sharded"`` (``None`` → all
                    visible devices); rejected under ``"single"``.
    partitioner:    vertex→shard map: ``"contiguous"`` / ``"hash"`` /
                    ``"bfs_blocks"`` (:mod:`repro.graphs.partition`);
                    observable via ``session.report().edge_cut``.
    exchange:       per-sweep contribution collective: ``"full"`` /
                    ``"bf16"`` (half wire bytes) / ``"delta"`` (sparse
                    frontier-sized gather with full fallback).
    fault_domain:   optional :class:`repro.core.fault_domain.FaultDomain`:
                    ``ThreadFaultDomain`` (equivalent to ``faults=``, the
                    paper's pseudo-thread model), ``ShardFaultDomain``
                    (sharded topologies; deterministic shard-crash
                    injection), or ``CorruptionFaultDomain`` (streaming
                    sessions; deterministic silent-corruption injection).
                    Validated against the resolved engine's declared
                    domains.
    durability:     ``"none"`` or ``"wal"`` (process fault domain): under
                    ``"wal"`` the session requires a ``store_dir`` and
                    durably logs every update batch *before* applying it,
                    plus atomic rank checkpoints every
                    ``checkpoint_interval`` batches.
    checkpoint_interval: batches between atomic rank checkpoints of a
                    durable session (bounds WAL replay length).
    integrity:      optional :class:`repro.core.integrity.IntegrityConfig`
                    (or a kwargs dict — the form the durable-store meta
                    round-trips): enables the corruption fault domain's
                    detection machinery — fused invariant checks on every
                    drive, checksum scrubbing via ``session.verify()`` /
                    the service scrubber, and the automatic repair ladder.
    walks_per_vertex: walk-engine ``R`` — Monte Carlo walk segments per
                    vertex (``None`` → 16).  Estimation error shrinks as
                    ``1/sqrt(R)``; update work grows linearly in it.
                    Rejected (:class:`repro.api.registry.CapabilityError`)
                    when the resolved engine does not declare the
                    ``"ppr"`` capability.
    walk_length:    walk-engine ``L`` — hard cap on a walk segment's
                    length, ≥ 2 (``None`` → 48; truncation bias is
                    O(alpha^L)).  Same capability gate.
    walk_seed:      base PRNG seed of the walk store; every walk's draws
                    are a pure function of (seed, walk id), which is what
                    makes delta-localized regeneration bit-exact
                    (``None`` → 0).  Same capability gate.
    device_budget_bytes: cap on device-resident tile-pool bytes for a
                    streaming session (``None`` → untiered: the whole pool
                    lives on device).  When set, the session runs the
                    two-tier storage of :mod:`repro.core.tiering`: host
                    truth + a frontier-biased hot slab of row-blocks sized
                    to this budget (docs/SCALE.md has the sizing rule).
                    Single-topology streaming sessions only.
    driver:         convergence driver of the streaming pallas engine:
                    ``"pull"`` (fused frontier pull, the default) or
                    ``"push"`` (residual forward-push,
                    :mod:`repro.core.push_engine` — per-batch work
                    proportional to seeded residual mass instead of
                    frontier × sweeps; docs/ENGINES.md §Drivers).
                    ``"push"`` requires the pallas engine in stream mode
                    (``from_graph``), topology ``"single"``, ``mode="lf"``
                    and no fault/integrity instrumentation.
    """

    alpha: float = 0.85
    tau: float = 1e-10
    tau_f: Optional[float] = None
    mode: str = "lf"
    engine: Optional[str] = None
    backend: Optional[str] = None
    tile: int = 512
    block_size: int = 64
    active_policy: str = "affected"
    max_iterations: int = 500
    faults: Optional[Any] = None
    dtype: Optional[Any] = None
    topology: str = "single"
    n_shards: Optional[int] = None
    partitioner: str = "contiguous"
    exchange: str = "full"
    fault_domain: Optional[Any] = None
    durability: str = "none"
    checkpoint_interval: int = 16
    integrity: Optional[Any] = None
    walks_per_vertex: Optional[int] = None
    walk_length: Optional[int] = None
    walk_seed: Optional[int] = None
    device_budget_bytes: Optional[int] = None
    driver: str = "pull"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"mode={self.mode!r} invalid; expected one of {MODES}")
        if self.active_policy not in ACTIVE_POLICIES:
            raise ValueError(f"active_policy={self.active_policy!r} invalid; "
                             f"expected one of {ACTIVE_POLICIES}")
        if not (0.0 < float(self.alpha) < 1.0):
            raise ValueError(f"alpha={self.alpha} outside (0, 1)")
        if float(self.tau) <= 0:
            raise ValueError(f"tau={self.tau} must be > 0")
        if self.tau_f is not None and float(self.tau_f) <= 0:
            raise ValueError(f"tau_f={self.tau_f} must be > 0 (or None)")
        for name in ("tile", "block_size", "max_iterations"):
            if int(getattr(self, name)) <= 0:
                raise ValueError(f"{name}={getattr(self, name)} must be > 0")
        if self.faults is not None and not hasattr(self.faults,
                                                  "device_tables"):
            raise ValueError(
                "faults must be a FaultPlan (needs .device_tables())")
        # -- topology axis ----------------------------------------------------
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"topology={self.topology!r} invalid; "
                             f"expected one of {TOPOLOGIES}")
        from repro.graphs.partition import PARTITIONERS
        if self.partitioner not in PARTITIONERS:
            raise ValueError(f"partitioner={self.partitioner!r} invalid; "
                             f"expected one of {PARTITIONERS}")
        if self.exchange not in EXCHANGES:
            raise ValueError(f"exchange={self.exchange!r} invalid; "
                             f"expected one of {EXCHANGES}")
        if self.n_shards is not None and int(self.n_shards) <= 0:
            raise ValueError(f"n_shards={self.n_shards} must be > 0 "
                             "(or None for all visible devices)")
        if self.topology == "single":
            if self.n_shards is not None:
                raise ValueError(
                    "n_shards is only meaningful with topology='sharded' "
                    f"(got topology='single', n_shards={self.n_shards})")
            if self.engine == "distributed":
                raise ValueError(
                    "engine='distributed' requires topology='sharded' — "
                    "topology is the config axis that selects it")
        else:
            if self.engine not in (None, "distributed"):
                raise ValueError(
                    f"topology='sharded' resolves engine='distributed'; "
                    f"engine={self.engine!r} cannot run sharded (leave "
                    "engine=None)")
            if self.faults is not None:
                raise ValueError(
                    "fault simulation is not supported with "
                    "topology='sharded' (stragglers are the model: stale "
                    "contributions, no crash tables) — use a single-device "
                    "engine with a FaultPlan")
            import jax
            avail = len(jax.devices())
            ns = int(self.n_shards) if self.n_shards else avail
            if ns > avail:
                raise ValueError(
                    f"n_shards={ns} exceeds the {avail} visible device(s) — "
                    "for host testing set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N")
        # -- fault-domain / durability axis -----------------------------------
        if self.durability not in DURABILITIES:
            raise ValueError(f"durability={self.durability!r} invalid; "
                             f"expected one of {DURABILITIES}")
        if int(self.checkpoint_interval) <= 0:
            raise ValueError(f"checkpoint_interval={self.checkpoint_interval}"
                             " must be > 0")
        if self.integrity is not None:
            from repro.core.integrity import IntegrityConfig
            # accept the kwargs-dict form (the shape SessionStore meta
            # round-trips through restore()) by coercing in place
            object.__setattr__(self, "integrity",
                               IntegrityConfig.coerce(self.integrity))
        if self.fault_domain is not None:
            from repro.core.fault_domain import FaultDomain
            if not isinstance(self.fault_domain, FaultDomain):
                raise ValueError(
                    "fault_domain must be a repro.core.fault_domain."
                    "FaultDomain (ThreadFaultDomain / ShardFaultDomain / "
                    "CorruptionFaultDomain), "
                    f"got {type(self.fault_domain).__name__}")
            if self.faults is not None:
                raise ValueError(
                    "faults= and fault_domain= are mutually exclusive — "
                    "faults=plan is shorthand for "
                    "fault_domain=ThreadFaultDomain(plan)")
            self.fault_domain.validate_for(topology=self.topology)
        # -- walk-engine / personalization axis -------------------------------
        for name, lo in (("walks_per_vertex", 1), ("walk_length", 2),
                         ("walk_seed", 0)):
            v = getattr(self, name)
            if v is None:
                continue
            if isinstance(v, bool) or not isinstance(v, int):
                raise ValueError(
                    f"{name} must be an integer (or None), got "
                    f"{type(v).__name__} ({v!r})")
            if v < lo:
                raise ValueError(f"{name}={v} must be >= {lo}")
        # -- tiered-storage axis ----------------------------------------------
        if self.device_budget_bytes is not None:
            v = self.device_budget_bytes
            if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"device_budget_bytes={v!r} must be a positive integer "
                    "(or None for untiered storage)")
            if self.topology != "single":
                raise ValueError(
                    "device_budget_bytes tiers a single device's tile pool; "
                    "topology='sharded' already partitions state across "
                    "devices — the two cannot compose")
            if self.engine not in (None, "pallas"):
                raise ValueError(
                    "device_budget_bytes requires the streaming pallas "
                    f"engine (got engine={self.engine!r})")
        # resolve engine + tile backend now: this validates explicit values
        # AND the REPRO_ENGINE / REPRO_TILE_BACKEND env overrides eagerly —
        # a bad value fails at construction, not mid-run
        from repro.api import registry
        eng = registry.resolve(self._engine_for_resolution())
        registry.resolve_backend(self.backend)
        # -- driver axis (pull vs residual forward-push; docs/ENGINES.md) ----
        if self.driver not in DRIVERS:
            raise ValueError(
                f"driver={self.driver!r} invalid; expected one of {DRIVERS}")
        if self.driver == "push":
            if eng.name != "pallas":
                raise ValueError(
                    "driver='push' is the residual forward-push mode of the "
                    f"streaming pallas engine; engine resolves to "
                    f"{eng.name!r} — pass engine='pallas' (or leave the "
                    "default) to select it")
            if self.mode != "lf":
                raise ValueError(
                    "driver='push' has no blocked-barrier analogue; "
                    f"mode must be 'lf' (got {self.mode!r})")
            if self.faults is not None:
                raise ValueError(
                    "driver='push' does not host thread fault tables; "
                    "run fault experiments on driver='pull'")
            if self.fault_domain is not None:
                raise ValueError(
                    "driver='push' does not host fault domains on the drive "
                    "path (durability='wal' still composes); use "
                    "driver='pull' for fault-domain experiments")
            if self.integrity is not None:
                raise ValueError(
                    "integrity invariants instrument the pull iterate; "
                    "driver='push' does not support integrity=")
        if (self.fault_domain is not None
                and self.fault_domain.name
                not in registry.fault_domains_of(eng)):
            raise ValueError(
                f"engine {eng.name!r} does not host the "
                f"{self.fault_domain.name!r} fault domain (declares "
                f"{registry.fault_domains_of(eng)}) — see docs/FAULTS.md")
        # capability gate: personalization fields only reach engines that
        # declare "ppr"; everything else rejects them at construction
        registry.reject_personalization(
            eng, {name: getattr(self, name)
                  for name in ("walks_per_vertex", "walk_length",
                               "walk_seed")})
        if "ppr" in registry.supports_of(eng):
            if self.faults is not None:
                raise ValueError(
                    f"engine {eng.name!r} is sweep-free and hosts no "
                    "thread fault domain; faults must be None")
            if self.integrity is not None:
                raise ValueError(
                    "integrity checks instrument the stream-mode "
                    f"pull-matrix state; engine {eng.name!r} does not "
                    "host them (integrity must be None)")

    def _engine_for_resolution(self) -> Optional[str]:
        """Topology-aware engine name: sharded configs always resolve the
        ``distributed`` engine (env/platform defaults apply to ``single``)."""
        if self.topology == "sharded":
            return self.engine or "distributed"
        return self.engine

    # -- resolution helpers --------------------------------------------------
    @property
    def resolved_engine(self) -> str:
        """Engine name after topology/default/env resolution
        (registry-validated)."""
        from repro.api import registry
        return registry.resolve(self._engine_for_resolution()).name

    @property
    def resolved_n_shards(self) -> Optional[int]:
        """Mesh size under ``topology="sharded"`` (``None`` → all visible
        devices); ``None`` for single-device configs."""
        if self.topology != "sharded":
            return None
        if self.n_shards is not None:
            return int(self.n_shards)
        import jax
        return len(jax.devices())

    @property
    def resolved_backend(self) -> str:
        """Tile-SpMV backend after default/env resolution."""
        from repro.api import registry
        return registry.resolve_backend(self.backend)

    def resolved_tau_f(self, *, expand: bool) -> float:
        if not expand:
            return float("inf")
        return float(self.tau_f) if self.tau_f is not None \
            else float(self.tau) / 1000.0

    def resolved_dtype(self):
        import jax
        import jax.numpy as jnp
        if self.dtype is not None:
            return jnp.dtype(self.dtype)
        return jnp.dtype(jnp.float64 if jax.config.jax_enable_x64
                         else jnp.float32)

    @property
    def resolved_walks_per_vertex(self) -> int:
        """Walk-engine ``R`` after default resolution."""
        from repro.core import walk_engine
        return int(self.walks_per_vertex
                   if self.walks_per_vertex is not None
                   else walk_engine.DEFAULT_WALKS_PER_VERTEX)

    @property
    def resolved_walk_length(self) -> int:
        """Walk-engine ``L`` after default resolution."""
        from repro.core import walk_engine
        return int(self.walk_length if self.walk_length is not None
                   else walk_engine.DEFAULT_WALK_LENGTH)

    @property
    def resolved_walk_seed(self) -> int:
        """Walk-store base seed after default resolution."""
        from repro.core import walk_engine
        return int(self.walk_seed if self.walk_seed is not None
                   else walk_engine.DEFAULT_WALK_SEED)

    # -- strict construction -------------------------------------------------
    @classmethod
    def valid_keys(cls) -> tuple:
        return tuple(f.name for f in dataclasses.fields(cls))

    @classmethod
    def from_kwargs(cls, **kw) -> "EngineConfig":
        """Build a config, rejecting unknown keys with the valid-key list
        (the fix for ``_defaults()`` silently forwarding typos)."""
        unknown = sorted(set(kw) - set(cls.valid_keys()))
        if unknown:
            raise TypeError(
                f"unknown EngineConfig key(s) {unknown}; "
                f"valid keys: {sorted(cls.valid_keys())}")
        return cls(**kw)

    def replace(self, **kw) -> "EngineConfig":
        """``dataclasses.replace`` with the same strict key check."""
        unknown = sorted(set(kw) - set(self.valid_keys()))
        if unknown:
            raise TypeError(
                f"unknown EngineConfig key(s) {unknown}; "
                f"valid keys: {sorted(self.valid_keys())}")
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Validated, immutable serving policy of a
    :class:`~repro.api.service.PageRankService` — the overload-resilience
    axis (queueing, admission, deadlines, degraded reads, watchdog),
    orthogonal to the per-session :class:`EngineConfig`.

    Fields
    ------
    max_queue_depth:   admission bound per stream: a submit that would
                       queue deeper than this is shed per ``shed_policy``.
    shed_policy:       ``"reject"`` (refuse the new submit with a
                       machine-readable reason) or ``"drop_oldest"``
                       (shed the oldest queued request instead — the
                       freshest deltas win under overload).
    deadline_s:        default per-request deadline, measured from submit;
                       a request still queued past it is shed
                       (``deadline_expired``), one completing late counts
                       as a deadline miss.  ``None`` → no deadline.
    max_retries:       dispatch attempts beyond the first on a transient
                       update failure (a closed/dead session is permanent
                       and not retried).
    retry_backoff_s:   base of the exponential backoff between retries
                       (attempt k sleeps ``retry_backoff_s * 2**k``).
    coalesce:          fold a stream's whole queued run of batches into
                       ONE equivalent batch per dispatch (one scatter, no
                       per-tick barrier).  ``False`` keeps strictly
                       per-batch dispatch (bit-for-bit with a sequential
                       session — the durability tests' mode).
    degraded_reads:    serve ``query``/``top_k`` from a per-slot read
                       snapshot (refreshed after every dispatch) instead
                       of the live session, so reads never wait on
                       updates; every read reports its staleness.
    staleness_budget_s: the staleness bound reads are held to: a read
                       finding its snapshot older than
                       ``snapshot_refresh_frac`` of this budget refreshes
                       it first (fork is non-blocking, so refresh works
                       even while the slot is mid-dispatch), keeping the
                       reported ``staleness_s``/``lag_updates`` inside
                       the budget rather than merely observable.
    snapshot_refresh_frac: fraction of ``staleness_budget_s`` at which a
                       read proactively refreshes its snapshot — the
                       headroom that absorbs the refresh wall time itself
                       plus read-arrival jitter before the budget expires.
    heartbeat_timeout_s: watchdog threshold: a BUSY slot whose dispatcher
                       heartbeat goes stale past this is declared stuck
                       and failed over (idle slots never trip it).
    watchdog:          enable stuck/dead-slot detection + failover-drain.
    scrub:             run the background integrity scrubber thread over
                       slots whose sessions carry an
                       ``EngineConfig(integrity=…)`` (each slot is paced
                       by its own ``IntegrityConfig.scrub_interval_s``;
                       busy slots are skipped, never blocked).
    """

    max_queue_depth: int = 64
    shed_policy: str = "reject"
    deadline_s: Optional[float] = None
    max_retries: int = 1
    retry_backoff_s: float = 0.02
    coalesce: bool = True
    degraded_reads: bool = True
    staleness_budget_s: float = 0.5
    snapshot_refresh_frac: float = 0.5
    heartbeat_timeout_s: float = 30.0
    watchdog: bool = True
    scrub: bool = True

    def __post_init__(self):
        if int(self.max_queue_depth) < 1:
            raise ValueError(f"max_queue_depth={self.max_queue_depth} "
                             "must be >= 1")
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy={self.shed_policy!r} invalid; "
                             f"expected one of {SHED_POLICIES}")
        if self.deadline_s is not None and float(self.deadline_s) < 0:
            raise ValueError(f"deadline_s={self.deadline_s} must be >= 0 "
                             "(or None for no deadline)")
        if int(self.max_retries) < 0:
            raise ValueError(f"max_retries={self.max_retries} must be >= 0")
        if float(self.retry_backoff_s) < 0:
            raise ValueError(f"retry_backoff_s={self.retry_backoff_s} "
                             "must be >= 0")
        if float(self.staleness_budget_s) < 0:
            raise ValueError(f"staleness_budget_s={self.staleness_budget_s}"
                             " must be >= 0")
        if not (0.0 < float(self.snapshot_refresh_frac) <= 1.0):
            raise ValueError(
                f"snapshot_refresh_frac={self.snapshot_refresh_frac} "
                "outside (0, 1] — it is the fraction of the staleness "
                "budget at which reads refresh their snapshot")
        if float(self.heartbeat_timeout_s) <= 0:
            raise ValueError(f"heartbeat_timeout_s="
                             f"{self.heartbeat_timeout_s} must be > 0")

    @classmethod
    def valid_keys(cls) -> tuple:
        return tuple(f.name for f in dataclasses.fields(cls))

    def replace(self, **kw) -> "ServingConfig":
        unknown = sorted(set(kw) - set(self.valid_keys()))
        if unknown:
            raise TypeError(
                f"unknown ServingConfig key(s) {unknown}; "
                f"valid keys: {sorted(self.valid_keys())}")
        return dataclasses.replace(self, **kw)
