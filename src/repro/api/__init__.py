"""repro.api — the session-centric public surface of the reproduction.

One stateful handle (:class:`PageRankSession`) owns graph state, the
resolved engine and the incremental operands; :class:`EngineConfig` is the
single validated home for every knob; :mod:`repro.api.registry` maps engine
names to engine code; :class:`PageRankService` drives N sessions from one
shared batch queue.  The legacy ``repro.core.pagerank`` variant functions
are deprecated shims over this surface (see docs/API.md for the migration
table).

The public surface below is snapshot-tested (``tests/test_api_surface.py``)
— changes to it are deliberate.
"""
from repro.api.config import EngineConfig
from repro.api import registry
from repro.api.registry import Engine, register
from repro.api.session import (PageRankSession, SessionReport,
                               StreamBatchResult)
from repro.api.service import PageRankService, UpdateRequest
from repro.ckpt.checkpoint import SessionStore
from repro.core.fault_domain import (RecoveryRecord, ShardFault,
                                     ShardFaultDomain, ThreadFaultDomain)

__all__ = [
    "EngineConfig",
    "Engine",
    "PageRankService",
    "PageRankSession",
    "RecoveryRecord",
    "SessionReport",
    "SessionStore",
    "ShardFault",
    "ShardFaultDomain",
    "StreamBatchResult",
    "ThreadFaultDomain",
    "UpdateRequest",
    "register",
    "registry",
]
