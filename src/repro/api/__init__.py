"""repro.api — the session-centric public surface of the reproduction.

One stateful handle (:class:`PageRankSession`) owns graph state, the
resolved engine and the incremental operands; :class:`EngineConfig` is the
single validated home for every engine knob and :class:`ServingConfig` for
every serving/overload knob; :mod:`repro.api.registry` maps engine names to
engine code; :class:`PageRankService` drives N sessions as an
overload-resilient serving fleet (bounded per-stream queues, coalescing
dispatch, deadlines, degraded-mode reads, watchdog failover).  The legacy
``repro.core.pagerank`` variant functions are deprecated shims over this
surface (see docs/API.md for the migration table).

The public surface below is snapshot-tested (``tests/test_api_surface.py``)
— changes to it are deliberate.
"""
from repro.api.config import EngineConfig, ServingConfig
from repro.api import registry
from repro.api.registry import CapabilityError, Engine, register
from repro.api.session import (PageRankSession, SessionReport,
                               StreamBatchResult, SweepCapWarning)
from repro.api.service import (AdmissionRejected, PageRankService,
                               ReadResult, UpdateRequest)
from repro.ckpt.checkpoint import SessionStore
from repro.core.chaos import ChaosEvent, ChaosPlan
from repro.core.fault_domain import (CorruptionFault, CorruptionFaultDomain,
                                     RecoveryRecord, SessionFault,
                                     ShardFault, ShardFaultDomain,
                                     ThreadFaultDomain)
from repro.core.integrity import IntegrityConfig, IntegrityReport

__all__ = [
    "AdmissionRejected",
    "CapabilityError",
    "ChaosEvent",
    "ChaosPlan",
    "CorruptionFault",
    "CorruptionFaultDomain",
    "EngineConfig",
    "Engine",
    "IntegrityConfig",
    "IntegrityReport",
    "PageRankService",
    "PageRankSession",
    "ReadResult",
    "RecoveryRecord",
    "ServingConfig",
    "SessionFault",
    "SessionReport",
    "SessionStore",
    "ShardFault",
    "ShardFaultDomain",
    "StreamBatchResult",
    "SweepCapWarning",
    "ThreadFaultDomain",
    "UpdateRequest",
    "register",
    "registry",
]
