"""Engine registry — the one place engine names resolve to engine code.

Each core engine module owns its adapter (``as_engine()`` in
:mod:`repro.core.pagerank` (dense), :mod:`repro.core.blocked` and
:mod:`repro.core.pallas_engine`); the registry imports and registers them
lazily on first resolve, so the core modules stay import-cycle-free.
External code can plug in additional engines with :func:`register`.

``resolve(None)`` applies :func:`default_engine` — pallas on TPU, blocked
elsewhere — and validates a ``REPRO_ENGINE`` environment override *through
the registry*, failing with the registered-name list instead of the bare
``ValueError(engine)`` the legacy ``_run`` raised mid-call.
:func:`resolve_backend` does the same for the pallas engine's tile-SpMV
backend and ``REPRO_TILE_BACKEND``.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import jax


@runtime_checkable
class Engine(Protocol):
    """One engine = a name plus a snapshot-level solve.

    ``run`` converges one (R0, affected0) problem on a snapshot and returns
    ``(ranks [n_pad], SweepStats)`` with the ranks already materialized
    (``block_until_ready``).  ``mat`` / ``aux`` / ``backend`` carry the
    pallas engine's incremental operands (engines that do not consume them
    must reject non-None values); ``interpret`` is the pallas engine's
    kernel-interpreter flag (``None`` → platform default; other engines
    ignore it); ``shards`` carries the distributed engine's topology
    request (a :class:`repro.core.distributed.ShardSpec` — engines that do
    not consume it must reject non-None values via
    :func:`reject_shard_spec`).  Callers only pass the operand kwargs they
    actually set, so adapters predating a kwarg keep working.
    """

    name: str

    def run(self, g, R0, affected0, *, mode: str, expand: bool,
            alpha: float, tau: float, tau_f: Optional[float],
            max_iterations: int, faults, tile: int, active_policy: str,
            mat=None, aux=None, backend: Optional[str] = None,
            interpret: Optional[bool] = None, shards=None):
        ...


class CapabilityError(ValueError):
    """An engine was configured with a capability it does not declare
    (e.g. personalization fields on an engine without ``"ppr"`` in its
    ``supports`` set).  Raised at config construction, never mid-query."""


_REGISTRY: Dict[str, Engine] = {}
_BUILTINS = ("repro.core.pagerank",        # dense
             "repro.core.blocked",         # blocked
             "repro.core.pallas_engine",   # pallas
             "repro.core.distributed",     # distributed (sharded)
             "repro.core.walk_engine")     # walk (Monte Carlo PPR)
_builtins_loaded = False


def register(engine: Engine, *, overwrite: bool = False) -> Engine:
    """Register an engine adapter under ``engine.name``."""
    name = getattr(engine, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError("engine must carry a non-empty string .name")
    if not callable(getattr(engine, "run", None)):
        raise ValueError(f"engine {name!r} must define a callable .run")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"engine {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = engine
    return engine


def _ensure_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    import importlib
    for modname in _BUILTINS:
        mod = importlib.import_module(modname)
        eng = mod.as_engine()
        if eng.name not in _REGISTRY:
            register(eng)


def names() -> Tuple[str, ...]:
    """Registered engine names (builtin engines are loaded first)."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def default_engine() -> str:
    """Engine used when a caller passes ``engine=None``: pallas on TPU
    (the fused production path), blocked elsewhere.  A ``REPRO_ENGINE``
    override is validated against the registry *here* — eagerly, with the
    valid-name list — rather than surfacing as a bare error mid-run."""
    env = os.environ.get("REPRO_ENGINE")
    if env:
        _ensure_builtins()
        if env not in _REGISTRY:
            raise ValueError(
                f"REPRO_ENGINE={env!r} is not a registered engine; "
                f"registered engines: {sorted(_REGISTRY)}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


def resolve(name: Optional[str] = None) -> Engine:
    """Resolve an engine name (``None`` → :func:`default_engine`) to its
    registered adapter, with a clear error on unknown names."""
    _ensure_builtins()
    name = name or default_engine()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{sorted(_REGISTRY)} (register custom engines via "
            "repro.api.registry.register)") from None


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve the pallas engine's tile-SpMV backend (``None`` → platform
    default), validating an explicit value or a ``REPRO_TILE_BACKEND``
    override eagerly with a clear message.  Delegates to the kernel layer
    (:func:`repro.kernels.block_spmv.ops._resolve_backend`) so there is one
    source of truth for the backend set."""
    from repro.kernels.block_spmv import ops
    return ops._resolve_backend(backend)


def fault_domains_of(engine: Engine) -> Tuple[str, ...]:
    """Fault domains an engine can host (docs/FAULTS.md): ``"thread"``
    (pseudo-thread delay/crash tables inside one sweep), ``"shard"``
    (crash/stall of one mesh shard), ``"process"`` (crash-stop of the job,
    recovered through the session WAL — engine-agnostic, so every engine
    declares it).  Engines advertise the tuple as a ``fault_domains``
    class attribute; adapters predating the attribute default to
    thread+process (the single-device model)."""
    return tuple(getattr(engine, "fault_domains", ("thread", "process")))


def supports_of(engine: Engine) -> frozenset:
    """Optional capabilities an engine declares beyond the core
    snapshot-level solve (a ``supports`` class attribute; adapters
    predating it declare nothing).  Currently the only capability is
    ``"ppr"`` — seed-set-personalized queries, declared by the walk
    engine."""
    return frozenset(getattr(engine, "supports", ()))


def reject_personalization(engine: Engine, fields: dict) -> None:
    """Shared config-time guard: engines without the ``"ppr"`` capability
    reject the walk/personalization fields (``fields`` maps field name →
    configured value; ``None`` = unset)."""
    if "ppr" in supports_of(engine):
        return
    set_fields = sorted(k for k, v in fields.items() if v is not None)
    if set_fields:
        raise CapabilityError(
            f"{set_fields} are personalization fields consumed only by "
            f"engines declaring the 'ppr' capability; engine "
            f"{engine.name!r} declares supports="
            f"{sorted(supports_of(engine))} — use "
            "EngineConfig(engine='walk') for personalized queries")


def reject_tile_operands(engine_name: str, mat, aux,
                         backend: Optional[str]) -> None:
    """Shared guard for engines that do not consume the pallas engine's
    incremental operands (prebuilt pull matrix / cached aux / tile
    backend)."""
    for name, val in (("pallas_mat", mat), ("pallas_aux", aux),
                      ("pallas_backend", backend)):
        if val is not None:
            raise ValueError(
                f"{name} is only consumed by engine='pallas' "
                f"(resolved engine: {engine_name!r})")


def reject_shard_spec(engine_name: str, shards) -> None:
    """Shared guard for engines that do not consume the distributed
    engine's topology operand (``ShardSpec``)."""
    if shards is not None:
        raise ValueError(
            "shards is only consumed by engine='distributed' "
            f"(resolved engine: {engine_name!r}) — set "
            "EngineConfig(topology='sharded') to route through it")
