"""`PageRankService` — N concurrent sessions behind one shared batch queue.

The serve-while-updating setting (Bahmani et al., arXiv:1006.2880): many
independent dynamic graphs (tenants / shards / what-if branches), each with
its own :class:`~repro.api.session.PageRankSession`, fed from one queue of
edge-update batches while rank queries are served between ticks.

The slot design mirrors :class:`repro.serve.engine.ServeEngine`: each
session is a slot; a tick admits at most one queued batch per slot
(continuous batching — a busy stream never starves the others), runs the
admitted updates, and retires them with their wait/exec latency split.
All sessions share the jit caches: after the first session warms the fused
driver, the remaining sessions' updates at the same operand shapes re-enter
the compiled trace with zero additional retraces (asserted in
``tests/test_api_session.py``; recorded per session in the smoke bench's
``service`` scenario).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.api.config import EngineConfig
from repro.api.session import PageRankSession, StreamBatchResult
from repro.core.graph import HostGraph


@dataclasses.dataclass
class UpdateRequest:
    """One queued edge-update batch for one session slot."""
    uid: int
    stream: int                   # session/slot index
    deletions: np.ndarray
    insertions: np.ndarray
    submitted_s: float = 0.0
    started_s: float = 0.0
    done_s: float = 0.0
    result: Optional[StreamBatchResult] = None
    done: bool = False

    @property
    def wait_s(self) -> float:
        return self.started_s - self.submitted_s

    @property
    def latency_s(self) -> float:
        """Queue wait + execution (submit → converged ranks visible)."""
        return self.done_s - self.submitted_s


class PageRankService:
    """Drive N PageRank sessions from one shared update queue.

    ``graphs`` may be host graphs (sessions are opened over them with the
    shared ``config``) or pre-built sessions.  ``warmup=True`` traces each
    session's per-batch pipeline up front so recorded latencies are
    steady-state."""

    def __init__(self, graphs: Sequence[Union[HostGraph, PageRankSession]],
                 *, config: Optional[EngineConfig] = None,
                 warmup: bool = True):
        if not graphs:
            raise ValueError("need at least one graph or session")
        self.sessions: List[Optional[PageRankSession]] = [
            g if isinstance(g, PageRankSession)
            else PageRankSession.from_graph(g, config=config)
            for g in graphs]
        for s in self.sessions:
            s._service = self       # close() unregisters through this
        if warmup:
            for s in self.sessions:
                s.warmup()
        self.queue: List[UpdateRequest] = []
        self.finished: List[UpdateRequest] = []
        self._uid = 0
        # durable-slot registry: a closed-or-dead slot respawns from its
        # store via failover(); the dir outlives the session object
        self._store_dirs: Dict[int, Optional[str]] = {
            i: getattr(s, "store_dir", None)
            for i, s in enumerate(self.sessions)}
        self._failovers: List[dict] = []

    @property
    def slots(self) -> int:
        return len(self.sessions)

    # -- placement -----------------------------------------------------------
    def placements(self) -> Dict[int, Tuple[int, ...]]:
        """Device footprint declared by each live session (sharded sessions
        span their mesh; single-device sessions one device).  The queue
        still schedules one batch per slot per tick — the placement map is
        what an external scheduler packs against."""
        return {i: s.device_footprint
                for i, s in enumerate(self.sessions) if s is not None}

    def _detach(self, sess: PageRankSession) -> None:
        """Unregister a closing session: its slot empties and its queued
        batches are dropped (slot indices of other streams are stable;
        the slot's durable store dir is retained for failover)."""
        for i, s in enumerate(self.sessions):
            if s is sess:
                self.sessions[i] = None
                self.queue = [r for r in self.queue if r.stream != i]
                return

    # -- failover (process fault domain, docs/FAULTS.md) ---------------------
    def failover(self, stream: int, *, warmup: bool = False) -> dict:
        """Respawn a closed-or-dead slot from its durable store: the
        session is restored from its newest valid checkpoint, catches up
        by replaying its WAL, and re-occupies the same slot index (new
        submits flow immediately).  Returns the recovery row also exposed
        by :meth:`report` (recovery wall time, replayed-batch count)."""
        if not (0 <= stream < self.slots):
            raise ValueError(f"stream {stream} out of range "
                             f"(service has {self.slots} sessions)")
        cur = self.sessions[stream]
        if cur is not None and not cur.closed:
            raise ValueError(f"stream {stream} is still live — failover "
                             "replaces closed or dead slots only")
        store_dir = self._store_dirs.get(stream)
        if store_dir is None:
            raise ValueError(
                f"stream {stream} has no durable store to respawn from "
                "(open its session with durability='wal' + store_dir=)")
        t0 = time.perf_counter()
        sess = PageRankSession.restore(store_dir)
        sess._service = self
        self.sessions[stream] = sess
        rep = sess.report()
        row = {"stream": stream,
               "recovery_time_s": round(time.perf_counter() - t0, 6),
               "replayed_batches": rep.replayed_batches,
               "restored_batch_index": sess._batch_index}
        if warmup:
            sess.warmup()
        self._failovers.append(row)
        return row

    # -- queue management ----------------------------------------------------
    def submit(self, stream: int, deletions, insertions) -> int:
        """Enqueue one batch for session ``stream``; returns its uid."""
        if not (0 <= stream < self.slots):
            raise ValueError(f"stream {stream} out of range "
                             f"(service has {self.slots} sessions)")
        if self.sessions[stream] is None:
            raise ValueError(f"stream {stream} is closed (its session was "
                             "close()d and unregistered)")
        self._uid += 1
        self.queue.append(UpdateRequest(
            uid=self._uid, stream=stream,
            deletions=np.asarray(deletions, np.int64).reshape(-1, 2),
            insertions=np.asarray(insertions, np.int64).reshape(-1, 2),
            submitted_s=time.perf_counter()))
        return self._uid

    # -- ticking -------------------------------------------------------------
    def step(self) -> int:
        """One service tick: admit at most one queued batch per slot (FIFO
        within a stream), run the admitted updates, retire them.  Returns
        the number of batches processed."""
        admitted: Dict[int, UpdateRequest] = {}
        for req in self.queue:
            if req.stream not in admitted:
                admitted[req.stream] = req
        taken = set(r.uid for r in admitted.values())
        self.queue = [r for r in self.queue if r.uid not in taken]
        for req in admitted.values():
            req.started_s = time.perf_counter()
            req.result = self.sessions[req.stream].update(
                req.deletions, req.insertions)
            req.done_s = time.perf_counter()
            req.done = True
            self.finished.append(req)
        return len(admitted)

    def run_until_drained(self, max_ticks: int = 10_000
                          ) -> List[UpdateRequest]:
        """Tick until the queue is empty; returns the retired requests."""
        for _ in range(max_ticks):
            if not self.queue:
                break
            self.step()
        return self.finished

    # -- serving reads -------------------------------------------------------
    def query(self, stream: int, vertices) -> np.ndarray:
        return self.sessions[stream].query(vertices)

    def top_k(self, stream: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
        return self.sessions[stream].top_k(k)

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        """Per-session p50/p95 update latency + retrace counts, plus the
        service-level request latency (queue wait included).  Dict-shaped
        so the smoke bench can serialize it directly."""
        per_session = []
        for i, s in enumerate(self.sessions):
            if s is None:
                per_session.append({"stream": i, "closed": True})
                continue
            rep = s.report()
            row = {
                "stream": i,
                "n": s.n,
                "engine": rep.engine,
                "devices": list(s.device_footprint),
                "n_updates": rep.n_updates,
                "p50_ms": round(rep.p50_s * 1e3, 3),
                "p95_ms": round(rep.p95_s * 1e3, 3),
                "retraces_post_warmup": rep.retraces_post_warmup,
                "total_sweeps": rep.total_sweeps,
                "queries_served": rep.queries_served,
            }
            if rep.topology == "sharded":
                row["topology"] = rep.topology
                row["n_shards"] = rep.n_shards
                row["partitioner"] = rep.partitioner
                row["edge_cut"] = rep.edge_cut
            if rep.durability != "none" or rep.recoveries:
                row["durability"] = rep.durability
                row["recoveries"] = rep.recoveries
                row["recovery_time_s"] = round(rep.recovery_time_s, 6)
                row["replayed_batches"] = rep.replayed_batches
            per_session.append(row)
        lat = [r.latency_s for r in self.finished]
        waits = [r.wait_s for r in self.finished]
        return {
            "n_sessions": self.slots,
            "placements": {str(i): list(fp)
                           for i, fp in self.placements().items()},
            "requests_done": len(self.finished),
            "requests_queued": len(self.queue),
            "request_p50_ms": (round(float(np.percentile(lat, 50)) * 1e3, 3)
                               if lat else 0.0),
            "request_p95_ms": (round(float(np.percentile(lat, 95)) * 1e3, 3)
                               if lat else 0.0),
            "queue_wait_p50_ms": (round(float(np.percentile(waits, 50))
                                        * 1e3, 3) if waits else 0.0),
            "failovers": list(self._failovers),
            "sessions": per_session,
        }
