"""`PageRankService` — overload-resilient serving of N dynamic streams.

The serve-while-updating setting (Bahmani et al., arXiv:1006.2880): many
independent dynamic graphs (tenants / shards / what-if branches), each with
its own :class:`~repro.api.session.PageRankSession`, fed from per-stream
update queues while rank queries are served continuously.

The old design ticked a global barrier — one batch per slot per tick — so
one slow or stuck session blocked every stream behind it and queue wait
dominated request latency.  This service is a *continuous dispatcher*
built for overload (policy in :class:`~repro.api.config.ServingConfig`):

* **continuous dispatch + coalescing** — each slot drains independently
  (its own worker thread under :meth:`start`, or per-slot passes of the
  synchronous :meth:`step`); a dispatch folds the stream's whole queued
  run of batches into ONE equivalent batch (last write per edge wins,
  :func:`repro.core.delta.coalesce_batches`) — one scatter, no per-tick
  barrier, queue wait bounded by a single dispatch.
* **admission control** — per-stream queues are bounded; a submit past
  ``max_queue_depth`` is shed with a machine-readable reason
  (:class:`AdmissionRejected`, or the oldest queued request under
  ``shed_policy="drop_oldest"``).
* **deadlines / retry / backoff** — requests carry deadlines; one still
  queued past its deadline is shed (``deadline_expired``), one finishing
  late counts as a deadline miss; transient dispatch failures retry with
  exponential backoff.
* **degraded-mode reads** — :meth:`query` / :meth:`top_k` serve from a
  per-slot read snapshot (a :meth:`~PageRankSession.fork` sharing the
  device arrays, refreshed after every dispatch), so reads never wait on
  updates; every read reports its staleness (seconds + update lag).
* **watchdog** — dispatches heartbeat (:class:`SlotHeartbeat`); a dead or
  stuck slot is failed over through the durable-store path
  (:meth:`failover`) and its queued batches drain to the respawned
  session, recorded as a session-domain
  :class:`~repro.core.fault_domain.RecoveryRecord` (docs/FAULTS.md).

All sessions share the jit caches: after the first session warms the fused
driver, the remaining sessions' updates at the same operand shapes re-enter
the compiled trace with zero additional retraces (asserted in
``tests/test_api_session.py``; recorded per session in the smoke bench's
``service`` / ``serve_load`` scenarios).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.api.config import EngineConfig, ServingConfig
from repro.api.session import PageRankSession, StreamBatchResult
from repro.core import fault_domain as fd
from repro.core import integrity as ig
from repro.core.delta import coalesce_batches, validate_edge_batch
from repro.core.graph import HostGraph


class AdmissionRejected(RuntimeError):
    """A submit was refused by admission control.  ``reason`` is the
    machine-readable dict (``code``, ``stream``, ``queue_depth``,
    ``max_queue_depth``, ``shed_policy``, ``message``) — the same shape a
    shed queued request carries in ``request.shed_reason``."""

    def __init__(self, reason: dict):
        super().__init__(reason.get("message", str(reason)))
        self.reason = reason


@dataclasses.dataclass
class UpdateRequest:
    """One queued edge-update batch for one session slot."""
    uid: int
    stream: int                   # session/slot index
    deletions: np.ndarray
    insertions: np.ndarray
    submitted_s: float = 0.0
    started_s: float = 0.0
    done_s: float = 0.0
    deadline_at_s: Optional[float] = None  # absolute (perf_counter) deadline
    result: Optional[StreamBatchResult] = None
    done: bool = False
    attempts: int = 0             # dispatch attempts consumed (retries + 1)
    deadline_missed: bool = False  # completed after its deadline
    shed: bool = False
    shed_reason: Optional[dict] = None
    error: Optional[str] = None

    @property
    def wait_s(self) -> float:
        return self.started_s - self.submitted_s

    @property
    def exec_s(self) -> float:
        """Dispatch execution time (started → done), excluding queue wait."""
        return self.done_s - self.started_s

    @property
    def latency_s(self) -> float:
        """Queue wait + execution (submit → converged ranks visible)."""
        return self.done_s - self.submitted_s


@dataclasses.dataclass
class ReadResult:
    """One degraded-mode read: the values plus their staleness bound.

    ``staleness_s`` is the age of the read snapshot the values came from,
    counted only while the snapshot diverges from committed state (0 when
    served from live state OR when the snapshot is at the live batch
    index — current data is not stale however long ago it was forked);
    ``lag_updates`` the number of update
    dispatches the live session has completed past the snapshot.  Unpacks
    like the session-level tuple (``values, vertices = svc.top_k(...)``)
    and casts to an array (``np.asarray(result)`` → values)."""
    values: np.ndarray
    vertices: Optional[np.ndarray]  # top_k only; None for query
    stream: int
    staleness_s: float
    lag_updates: int
    degraded: bool                  # served from a snapshot, not live state

    def __array__(self, dtype=None, copy=None):
        a = np.asarray(self.values)
        return a.astype(dtype) if dtype is not None else a

    def __iter__(self):
        return iter((self.values, self.vertices))


@dataclasses.dataclass
class _ReadSnapshot:
    """Per-slot read replica: a fork sharing the parent's device arrays."""
    sess: PageRankSession
    taken_s: float
    batch_index: int


class PageRankService:
    """Drive N PageRank sessions as an overload-resilient serving fleet.

    ``graphs`` may be host graphs (sessions are opened over them with the
    shared ``config``) or pre-built sessions.  ``serving`` is the
    :class:`~repro.api.config.ServingConfig` overload policy (admission,
    deadlines, shedding, degraded reads, watchdog).  ``warmup=True``
    traces each session's per-batch pipeline up front so recorded
    latencies are steady-state.

    Two dispatch modes share every policy: the synchronous :meth:`step` /
    :meth:`run_until_drained` (tests, benchmarks, single-threaded callers)
    and the background mode (:meth:`start` / :meth:`stop`) where each slot
    drains on its own worker thread and a watchdog thread polls slot
    health — no per-tick barrier in either mode."""

    def __init__(self, graphs: Sequence[Union[HostGraph, PageRankSession]],
                 *, config: Optional[EngineConfig] = None,
                 serving: Optional[ServingConfig] = None,
                 warmup: bool = True):
        if not graphs:
            raise ValueError("need at least one graph or session")
        self.serving = serving if serving is not None else ServingConfig()
        if not isinstance(self.serving, ServingConfig):
            raise TypeError(
                "serving must be a ServingConfig, got "
                f"{type(self.serving).__name__} — build one with "
                "repro.api.ServingConfig(...)")
        self.sessions: List[Optional[PageRankSession]] = [
            g if isinstance(g, PageRankSession)
            else PageRankSession.from_graph(g, config=config)
            for g in graphs]
        for s in self.sessions:
            s._service = self       # close() unregisters through this
        if warmup:
            for s in self.sessions:
                s.warmup()
        self._lock = threading.RLock()
        self._queues: Dict[int, Deque[UpdateRequest]] = {
            i: deque() for i in range(len(self.sessions))}
        self._inflight: Dict[int, List[UpdateRequest]] = {}
        self.finished: List[UpdateRequest] = []
        self.shed_requests: List[UpdateRequest] = []
        self._uid = 0
        self._deadline_misses = 0
        self._retries = 0
        # durable-slot registry: a closed-or-dead slot respawns from its
        # store via failover(); the dir outlives the session object
        self._store_dirs: Dict[int, Optional[str]] = {
            i: getattr(s, "store_dir", None)
            for i, s in enumerate(self.sessions)}
        self._failovers: List[dict] = []
        # -- watchdog / session fault domain (docs/FAULTS.md) ----------------
        self._heartbeat = fd.SlotHeartbeat()
        self._dead: Dict[int, str] = {}          # slot → why it died
        self._dispatches: Dict[int, int] = {
            i: 0 for i in range(len(self.sessions))}
        self._session_faults: List[fd.SessionFault] = []
        self._watchdog_events: List[dict] = []
        self._recovering: set = set()   # slots mid-failover-drain
        self._slot_gen: Dict[int, int] = {
            i: 0 for i in range(len(self.sessions))}
        # -- degraded reads ---------------------------------------------------
        self._snapshots: Dict[int, _ReadSnapshot] = {}
        self._query_walls: List[float] = []
        self._query_staleness: List[float] = []
        self._query_lags: List[int] = []
        self._snapshot_refreshes = 0    # proactive (budget-driven) refreshes
        if self.serving.degraded_reads:
            for i in range(len(self.sessions)):
                self._refresh_snapshot(i)
        # -- integrity scrubber (corruption fault domain) ---------------------
        # per-slot dispatch locks: held for the update portion of a
        # dispatch, tried non-blocking by the scrubber so a scrub never
        # delays serving (a busy slot is simply scrubbed next pass)
        self._slot_locks: Dict[int, threading.Lock] = {
            i: threading.Lock() for i in range(len(self.sessions))}
        self._scrubs_run = 0
        self._last_scrub: Dict[int, float] = {}
        self._scrub_thread: Optional[threading.Thread] = None
        # -- background dispatch ----------------------------------------------
        self._running = False
        self._wake: Dict[int, threading.Event] = {
            i: threading.Event() for i in range(len(self.sessions))}
        self._workers: Dict[int, threading.Thread] = {}
        self._watchdog_thread: Optional[threading.Thread] = None

    @property
    def slots(self) -> int:
        return len(self.sessions)

    @property
    def queue(self) -> List[UpdateRequest]:
        """Flat uid-ordered view over every stream's queued requests
        (compat with the pre-dispatcher single-queue surface)."""
        with self._lock:
            reqs = [r for q in self._queues.values() for r in q]
        return sorted(reqs, key=lambda r: r.uid)

    def queue_depth(self, stream: int) -> int:
        with self._lock:
            return len(self._queues[stream])

    # -- placement -----------------------------------------------------------
    def placements(self) -> Dict[int, Tuple[int, ...]]:
        """Device footprint declared by each live session (sharded sessions
        span their mesh; single-device sessions one device)."""
        return {i: s.device_footprint
                for i, s in enumerate(self.sessions)
                if s is not None and not s.closed}

    def _detach(self, sess: PageRankSession) -> None:
        """Unregister a closing session: its slot empties and its queued
        batches are dropped (slot indices of other streams are stable;
        the slot's durable store dir is retained for failover)."""
        for i, s in enumerate(self.sessions):
            if s is sess:
                self.sessions[i] = None
                with self._lock:
                    self._queues[i].clear()
                    self._snapshots.pop(i, None)
                return

    # -- failover (process + session fault domains, docs/FAULTS.md) ----------
    def failover(self, stream: int, *, warmup: bool = False) -> dict:
        """Respawn a closed-or-dead slot from its durable store: the
        session is restored from its newest valid checkpoint, catches up
        by replaying its WAL, and re-occupies the same slot index (new
        submits flow immediately).  Returns the recovery row also exposed
        by :meth:`report` (recovery wall time, replayed-batch count)."""
        self._check_stream(stream)
        cur = self.sessions[stream]
        if cur is not None and not cur.closed:
            raise ValueError(f"stream {stream} is still live — failover "
                             "replaces closed or dead slots only")
        store_dir = self._store_dirs.get(stream)
        if store_dir is None:
            raise ValueError(
                f"stream {stream} has no durable store to respawn from "
                "(open its session with durability='wal' + store_dir=)")
        t0 = time.perf_counter()
        sess = PageRankSession.restore(store_dir)
        sess._service = self
        self.sessions[stream] = sess
        self._dead.pop(stream, None)
        rep = sess.report()
        row = {"stream": stream,
               "recovery_time_s": round(time.perf_counter() - t0, 6),
               "replayed_batches": rep.replayed_batches,
               "restored_batch_index": sess._batch_index}
        if warmup:
            sess.warmup()
        if self.serving.degraded_reads:
            self._refresh_snapshot(stream)
        self._failovers.append(row)
        return row

    # -- queue management ----------------------------------------------------
    def _check_stream(self, stream: int) -> None:
        if not (0 <= stream < self.slots):
            raise ValueError(f"stream {stream} out of range "
                             f"(service has {self.slots} sessions)")

    def _shed(self, req: UpdateRequest, code: str, message: str) -> dict:
        reason = {"code": code, "stream": req.stream, "uid": req.uid,
                  "queue_depth": len(self._queues[req.stream]),
                  "max_queue_depth": self.serving.max_queue_depth,
                  "shed_policy": self.serving.shed_policy,
                  "message": message}
        req.shed = True
        req.shed_reason = reason
        self.shed_requests.append(req)
        return reason

    def _expire_deadlines(self, stream: int, now: float) -> None:
        """Shed queued requests whose deadline already passed — the
        'timeout' half of the deadline contract (caller holds the lock)."""
        q = self._queues[stream]
        kept: Deque[UpdateRequest] = deque()
        for req in q:
            if req.deadline_at_s is not None and now > req.deadline_at_s:
                self._deadline_misses += 1
                self._shed(req, "deadline_expired",
                           f"request {req.uid} spent "
                           f"{now - req.submitted_s:.3f}s queued, past its "
                           "deadline — shed before dispatch")
            else:
                kept.append(req)
        self._queues[stream] = kept

    def submit(self, stream: int, deletions, insertions, *,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one batch for session ``stream``; returns its uid.

        The batch is validated at admission (malformed batches raise
        ``ValueError`` and never enter a queue).  A full queue sheds per
        ``serving.shed_policy``: ``"reject"`` raises
        :class:`AdmissionRejected` (machine-readable ``.reason``),
        ``"drop_oldest"`` sheds the oldest queued request instead.
        ``deadline_s`` overrides ``serving.deadline_s`` for this request
        (measured from now)."""
        self._check_stream(stream)
        sess = self.sessions[stream]
        recoverable = (self.serving.watchdog
                       and self._store_dirs.get(stream) is not None)
        if sess is None or (sess.closed and not recoverable):
            raise ValueError(f"stream {stream} is closed (its session was "
                             "close()d or died; failover() respawns "
                             "durable slots)")
        # a died-but-durable slot keeps accepting (bounded) submits while
        # the watchdog respawns it — the drain delivers them to the respawn
        deletions, insertions = validate_edge_batch(deletions, insertions,
                                                    sess.n)
        now = time.perf_counter()
        dl = deadline_s if deadline_s is not None else self.serving.deadline_s
        with self._lock:
            self._expire_deadlines(stream, now)
            q = self._queues[stream]
            self._uid += 1
            req = UpdateRequest(
                uid=self._uid, stream=stream,
                deletions=deletions, insertions=insertions,
                submitted_s=now,
                deadline_at_s=(now + float(dl)) if dl is not None else None)
            if len(q) >= self.serving.max_queue_depth:
                if self.serving.shed_policy == "reject":
                    reason = self._shed(
                        req, "queue_full",
                        f"stream {stream} queue at depth {len(q)} >= "
                        f"max_queue_depth={self.serving.max_queue_depth}; "
                        "rejecting new submit (shed_policy='reject')")
                    raise AdmissionRejected(reason)
                oldest = q.popleft()        # drop_oldest: recency wins
                self._shed(oldest, "queue_full_dropped_oldest",
                           f"stream {stream} queue full; request "
                           f"{oldest.uid} shed to admit {req.uid} "
                           "(shed_policy='drop_oldest')")
            q.append(req)
        if self._running:
            self._wake[stream].set()
        return req.uid

    def inject_session_fault(self, stream: int, *,
                             after_dispatches: int = 0, kind: str = "dead",
                             stall_s: float = 0.0) -> None:
        """Schedule one serving-slot failure (session fault domain,
        docs/FAULTS.md), consumed by the slot's dispatcher: after
        ``after_dispatches`` completed dispatches the next dispatch kills
        the slot's session (``kind="dead"``) or stalls its worker for
        ``stall_s`` seconds (``kind="stuck"``, tripping the heartbeat
        watchdog).  Recovery — failover + queue drain — is automatic and
        recorded in :meth:`report`."""
        self._check_stream(stream)
        self._session_faults.append(fd.SessionFault(
            stream=int(stream), after_dispatches=int(after_dispatches),
            kind=kind, stall_s=float(stall_s)))

    def _consume_fault(self, stream: int) -> Optional[fd.SessionFault]:
        with self._lock:
            for i, f in enumerate(self._session_faults):
                if (f.stream == stream
                        and self._dispatches[stream] >= f.after_dispatches):
                    return self._session_faults.pop(i)
        return None

    # -- dispatch ------------------------------------------------------------
    def _take(self, stream: int) -> List[UpdateRequest]:
        """Claim this stream's next dispatch: the whole queued run when
        coalescing, else the single head request (FIFO)."""
        with self._lock:
            self._expire_deadlines(stream, time.perf_counter())
            q = self._queues[stream]
            if not q:
                return []
            if self.serving.coalesce:
                reqs = list(q)
                q.clear()
            else:
                reqs = [q.popleft()]
            self._inflight[stream] = reqs
        return reqs

    def _requeue(self, stream: int, reqs: List[UpdateRequest],
                 gen: int) -> None:
        with self._lock:
            if gen != self._slot_gen[stream]:
                return  # failed over while we held them: the respawn's
                        # drain owns these requests now — do not duplicate
            self._queues[stream].extendleft(reversed(reqs))
            self._inflight.pop(stream, None)

    def _dispatch(self, stream: int, reqs: List[UpdateRequest],
                  gen: int) -> bool:
        """Run one dispatch for ``stream``: coalesce the claimed requests
        into one batch, update with retry/backoff, retire.  Returns False
        when the slot died (requests re-queued for the failover drain)."""
        sv = self.serving
        self._heartbeat.busy(stream)
        try:
            fault = self._consume_fault(stream)
            if fault is not None and fault.kind == "stuck":
                # the stall sits BEFORE the update: the slot holds work,
                # the heartbeat goes stale, and nothing has touched session
                # or WAL state — so the watchdog may safely re-drain
                time.sleep(fault.stall_s)
            if fault is not None and fault.kind == "dead":
                sess = self.sessions[stream]
                if sess is not None:
                    # crash-stop, not a clean close(): drop the service
                    # backref first so _detach doesn't run — the slot stays
                    # registered (dead) and its queue survives for the drain
                    sess._service = None
                    sess.close()
            if gen != self._slot_gen[stream]:
                # the watchdog failed this slot over while we stalled: the
                # respawned slot owns these requests now — abandon them
                # without touching the zombie session
                with self._lock:
                    self._inflight.pop(stream, None)
                return True
            if len(reqs) == 1:
                dels, ins = reqs[0].deletions, reqs[0].insertions
            else:
                sess = self.sessions[stream]
                n = sess.n if sess is not None else 0
                dels, ins = coalesce_batches(
                    [(r.deletions, r.insertions) for r in reqs], n)
            start = time.perf_counter()
            for req in reqs:
                req.started_s = start
            last_err: Optional[BaseException] = None
            result = None
            # the slot lock serializes the session-mutating portion of a
            # dispatch against the integrity scrubber (which only ever
            # try-acquires, so dispatch never waits on a scrub in progress
            # for more than one verify pass)
            with self._slot_locks[stream]:
                for attempt in range(sv.max_retries + 1):
                    sess = self.sessions[stream]
                    if sess is None or sess.closed:
                        last_err = ValueError(
                            f"stream {stream} session is closed")
                        break           # permanent: no retry can help
                    try:
                        result = sess.update(dels, ins)
                        break
                    except ValueError as e:
                        if sess.closed:  # slot died mid-dispatch
                            last_err = e
                            break
                        raise           # rejected batch: caller bug, no retry
                    except Exception as e:  # transient: backoff and retry
                        last_err = e
                        result = None
                        if attempt < sv.max_retries:
                            with self._lock:
                                self._retries += 1
                            time.sleep(sv.retry_backoff_s * (2 ** attempt))
            for req in reqs:
                req.attempts = attempt + 1
            if result is None:
                for req in reqs:
                    req.error = repr(last_err)
                self._requeue(stream, reqs, gen)
                with self._lock:
                    if gen == self._slot_gen[stream]:
                        self._dead.setdefault(stream, repr(last_err))
                return False
            done = time.perf_counter()
            with self._lock:
                if gen != self._slot_gen[stream]:
                    # the watchdog declared us stuck mid-update and drained
                    # these requests to a respawned slot — our result went
                    # to the orphaned pre-failover session; retiring it too
                    # would double-apply, so abandon it
                    return True
                for req in reqs:
                    req.result = result
                    req.done_s = done
                    req.done = True
                    if (req.deadline_at_s is not None
                            and done > req.deadline_at_s):
                        req.deadline_missed = True
                        self._deadline_misses += 1
                self.finished.extend(reqs)
                self._inflight.pop(stream, None)
                self._dispatches[stream] += 1
            if sv.degraded_reads:
                self._refresh_snapshot(stream)
            return True
        finally:
            self._heartbeat.idle(stream)

    # -- watchdog (session fault domain) -------------------------------------
    def _slot_has_work(self, stream: int) -> bool:
        with self._lock:
            return bool(self._queues[stream]) or stream in self._inflight

    def _poll_watchdog(self) -> int:
        """One health pass over every slot: fail over dead slots and
        heartbeat-stale (stuck) ones, draining their queued batches to the
        respawned session.  Returns the number of recoveries performed."""
        if not self.serving.watchdog:
            return 0
        recovered = 0
        for i in range(self.slots):
            sess = self.sessions[i]
            dead = (i in self._dead
                    or (sess is not None and sess.closed))
            stuck = self._heartbeat.stale(
                i, self.serving.heartbeat_timeout_s)
            if (dead or stuck) and self._slot_has_work(i):
                if self._failover_drain(
                        i, kind="stuck" if stuck and not dead else "dead"):
                    recovered += 1
        return recovered

    def _failover_drain(self, stream: int, *, kind: str) -> bool:
        """Recover one failed slot: respawn its session from the durable
        store (:meth:`failover`) and drain every claimed-or-queued batch to
        the respawn.  Slots with no store shed their queue instead (with a
        machine-readable reason) so the service never grows an undrainable
        queue.  The event lands as a session-domain ``RecoveryRecord`` in
        the respawned session's ``report()`` and under
        ``report()["watchdog"]``."""
        t0 = time.perf_counter()
        with self._lock:
            # mark the slot mid-recovery so run_until_drained() doesn't
            # mistake the held-for-drain window for an idle service
            self._recovering.add(stream)
            stranded = (self._inflight.pop(stream, [])
                        + list(self._queues[stream]))
            self._queues[stream].clear()
            self._slot_gen[stream] += 1     # zombie workers see a stale gen
            gen = self._slot_gen[stream]
        try:
            sess = self.sessions[stream]
            if kind == "stuck" and sess is not None and not sess.closed:
                # close the stuck session: a zombie worker waking later hits
                # "session is closed" before any WAL append — the respawn
                # owns the store exclusively from here (backref dropped
                # first so _detach doesn't unregister the slot)
                sess._service = None
                sess.close()
            if self._store_dirs.get(stream) is None:
                with self._lock:
                    for req in stranded:
                        self._shed(req, "slot_dead",
                                   f"stream {stream} {kind} with no durable "
                                   "store to respawn from — request shed")
                    self._dead[stream] = f"{kind}; no durable store"
                    self._watchdog_events.append(fd.RecoveryRecord(
                        domain="session", batch_index=-1,
                        wall_time_s=time.perf_counter() - t0,
                        stream=stream, kind=kind,
                        drained_requests=0,
                        description=(f"slot {stream} {kind}; no store — "
                                     f"{len(stranded)} request(s) shed")
                    ).to_dict())
                return False
            self.failover(stream)
            with self._lock:
                # prepend (like _requeue): a durable dead slot keeps
                # accepting submits while the respawn restores, and those
                # were admitted AFTER the stranded batches — appending the
                # stranded run behind them would invert the apply order
                # vs the accepted-batch lineage (delta batches are
                # order-sensitive: a later delete can cancel an earlier
                # insert of the same edge, so inversion silently diverges
                # the served ranks from the oracle)
                self._queues[stream].extendleft(reversed(stranded))
            rec = fd.RecoveryRecord(
                domain="session",
                batch_index=self.sessions[stream]._batch_index,
                wall_time_s=time.perf_counter() - t0,
                stream=stream, kind=kind, drained_requests=len(stranded),
                replayed_batches=(self.sessions[stream]
                                  .report().replayed_batches),
                description=(f"slot {stream} {kind} — respawned from "
                             f"store, {len(stranded)} queued batch(es) "
                             "drained to the new session"))
            self.sessions[stream]._recoveries.append(rec)
            with self._lock:
                self._watchdog_events.append(rec.to_dict())
            if self._running:
                self._spawn_worker(stream, gen)
                self._wake[stream].set()
            return True
        finally:
            with self._lock:
                self._recovering.discard(stream)

    # -- integrity scrubber (corruption fault domain, docs/FAULTS.md) --------
    def _scrub_eligible(self, stream: int) -> Optional[PageRankSession]:
        sess = self.sessions[stream]
        if sess is None or sess.closed or sess.config.integrity is None:
            return None
        return sess

    def scrub(self, stream: Optional[int] = None, *, deep: bool = True,
              repair: Optional[bool] = None
              ) -> Dict[int, "ig.IntegrityReport"]:
        """One synchronous integrity pass (:meth:`PageRankSession.verify`)
        over ``stream`` (or every eligible slot) — the deterministic form
        of the background scrubber, which the chaos harness uses so every
        detection is attributable to exactly one injection.  Slots whose
        sessions carry no ``EngineConfig(integrity=…)`` are skipped.
        Returns the per-slot :class:`~repro.core.integrity.IntegrityReport`
        map; repairs refresh the slot's read snapshot so repaired state
        serves immediately."""
        streams = range(self.slots) if stream is None else [stream]
        out: Dict[int, ig.IntegrityReport] = {}
        for i in streams:
            self._check_stream(i)
            sess = self._scrub_eligible(i)
            if sess is None:
                continue
            with self._slot_locks[i]:
                try:
                    rep = sess.verify(deep=deep, repair=repair)
                except ValueError:      # closed between check and acquire
                    continue
            with self._lock:
                self._scrubs_run += 1
                self._last_scrub[i] = time.perf_counter()
            out[i] = rep
            if self.serving.degraded_reads and rep.repairs:
                self._refresh_snapshot(i)
        return out

    def _scrub_pass(self) -> int:
        """One background-scrubber sweep: verify each eligible slot whose
        ``scrub_interval_s`` has elapsed, skipping (never blocking) slots
        mid-dispatch.  Returns the number of slots scrubbed."""
        done = 0
        for i in range(self.slots):
            sess = self._scrub_eligible(i)
            if sess is None:
                continue
            interval = sess.config.integrity.scrub_interval_s
            if (time.perf_counter()
                    - self._last_scrub.get(i, 0.0)) < interval:
                continue
            lock = self._slot_locks[i]
            if not lock.acquire(blocking=False):
                continue                # busy slot: next pass gets it
            rep = None
            try:
                rep = sess.verify(deep=True)
            except ValueError:          # closed mid-scrub
                pass
            finally:
                lock.release()
            if rep is None:
                continue
            with self._lock:
                self._scrubs_run += 1
                self._last_scrub[i] = time.perf_counter()
            done += 1
            if self.serving.degraded_reads and rep.repairs:
                self._refresh_snapshot(i)
        return done

    def _scrub_loop(self) -> None:
        intervals = [s.config.integrity.scrub_interval_s
                     for s in self.sessions
                     if s is not None and s.config.integrity is not None]
        poll = min(0.25, max(0.01, min(intervals, default=0.25) / 4))
        while self._running:
            self._scrub_pass()
            time.sleep(poll)

    # -- synchronous dispatch -------------------------------------------------
    def step(self) -> int:
        """One synchronous dispatch pass: every slot with queued work runs
        one dispatch (the whole coalesced run per slot), then the watchdog
        polls slot health.  Returns the number of requests retired."""
        if self._running:
            raise RuntimeError("service is running in background mode — "
                               "stop() it before stepping synchronously")
        before = len(self.finished)
        for i in range(self.slots):
            reqs = self._take(i) if self.sessions[i] is not None else []
            if reqs:
                self._dispatch(i, reqs, self._slot_gen[i])
        self._poll_watchdog()
        return len(self.finished) - before

    def run_until_drained(self, max_ticks: int = 10_000
                          ) -> List[UpdateRequest]:
        """Dispatch until every queue is empty; returns the retired
        requests.  In background mode this just waits for the workers."""
        if self._running:
            deadline = time.time() + 600
            while time.time() < deadline:
                with self._lock:
                    busy = (any(self._queues[i] for i in self._queues)
                            or bool(self._inflight)
                            or bool(self._recovering))
                if not busy:
                    break
                time.sleep(0.01)
            return self.finished
        for _ in range(max_ticks):
            if not self.queue:
                break
            self.step()
        return self.finished

    # -- background dispatch --------------------------------------------------
    def _worker_loop(self, stream: int, gen: int) -> None:
        ev = self._wake[stream]
        while self._running and gen == self._slot_gen[stream]:
            reqs = (self._take(stream)
                    if self.sessions[stream] is not None else [])
            if reqs:
                if not self._dispatch(stream, reqs, gen):
                    return          # slot died; the watchdog takes over
                continue            # drain continuously while work exists
            ev.clear()
            ev.wait(timeout=0.05)

    def _watchdog_loop(self) -> None:
        interval = min(0.1, self.serving.heartbeat_timeout_s / 4)
        while self._running:
            self._poll_watchdog()
            time.sleep(interval)

    def _spawn_worker(self, stream: int, gen: int) -> None:
        t = threading.Thread(target=self._worker_loop, args=(stream, gen),
                             name=f"pagerank-slot-{stream}", daemon=True)
        self._workers[stream] = t
        t.start()

    def start(self) -> "PageRankService":
        """Enter background mode: one dispatcher thread per slot (each
        drains its own stream continuously — a slow stream never blocks
        the others) plus a watchdog thread.  Safe to submit/query from any
        thread while running."""
        if self._running:
            return self
        self._running = True
        for i in range(self.slots):
            self._spawn_worker(i, self._slot_gen[i])
        if self.serving.watchdog:
            self._watchdog_thread = threading.Thread(
                target=self._watchdog_loop, name="pagerank-watchdog",
                daemon=True)
            self._watchdog_thread.start()
        if self.serving.scrub and any(
                self._scrub_eligible(i) is not None
                for i in range(self.slots)):
            self._scrub_thread = threading.Thread(
                target=self._scrub_loop, name="pagerank-scrubber",
                daemon=True)
            self._scrub_thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Leave background mode.  ``drain=True`` waits for the queues to
        empty first (shed/expired requests are not waited on)."""
        if not self._running:
            return
        if drain:
            self.run_until_drained()
        self._running = False
        for ev in self._wake.values():
            ev.set()
        for t in self._workers.values():
            t.join(timeout=10)
        if self._watchdog_thread is not None:
            self._watchdog_thread.join(timeout=10)
            self._watchdog_thread = None
        if self._scrub_thread is not None:
            self._scrub_thread.join(timeout=10)
            self._scrub_thread = None
        self._workers.clear()

    def __enter__(self) -> "PageRankService":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop(drain=exc_type is None)
        return False

    # -- degraded-mode reads --------------------------------------------------
    def _refresh_snapshot(self, stream: int) -> None:
        sess = self.sessions[stream]
        if sess is None or sess.closed:
            return
        snap = _ReadSnapshot(sess.fork(), time.perf_counter(),
                             sess._batch_index)
        with self._lock:
            self._snapshots[stream] = snap

    def _read(self, stream: int, op) -> ReadResult:
        self._check_stream(stream)
        t0 = time.perf_counter()
        snap = self._snapshots.get(stream) if self.serving.degraded_reads \
            else None
        live = self.sessions[stream]
        if snap is not None:
            # refresh proactively at a fraction of the budget so served
            # staleness stays under budget even under sustained update
            # load — fork() only rebinds immutable device arrays, so
            # refreshing while the dispatcher drives is safe and cheap
            refresh_at = (self.serving.staleness_budget_s
                          * self.serving.snapshot_refresh_frac)
            if (t0 - snap.taken_s > refresh_at
                    and live is not None and not live.closed):
                self._refresh_snapshot(stream)
                with self._lock:
                    self._snapshot_refreshes += 1
                snap = self._snapshots[stream]
            op_start = time.perf_counter()
            values, vertices = op(snap.sess)
            lag = 0
            if live is not None:
                # a closed (mid-failover) session's batch index is still
                # the committed high-water mark for the stream
                lag = max(0, live._batch_index - snap.batch_index)
                if not live.closed:
                    live._queries += 1  # degraded reads count for the slot
            # staleness = the age of the served data when the read began
            # (the read's own wall time is latency, not staleness) — and
            # only while the snapshot actually DIVERGES from committed
            # state (lag > 0).  A snapshot at the live batch index IS the
            # newest committed state no matter how long ago it was taken:
            # an idle slot, or one mid-failover (nothing commits anywhere
            # until the respawn replays), serves current data
            stale = (max(0.0, op_start - snap.taken_s) if lag > 0 else 0.0)
            res = ReadResult(values=values, vertices=vertices,
                             stream=stream, staleness_s=stale,
                             lag_updates=lag, degraded=True)
        else:
            if live is None or live.closed:
                raise ValueError(f"stream {stream} is closed and "
                                 "degraded reads are disabled")
            values, vertices = op(live)
            res = ReadResult(values=values, vertices=vertices,
                             stream=stream, staleness_s=0.0,
                             lag_updates=0, degraded=False)
        with self._lock:
            self._query_walls.append(time.perf_counter() - t0)
            self._query_staleness.append(res.staleness_s)
            self._query_lags.append(res.lag_updates)
        return res

    def query(self, stream: int, vertices) -> ReadResult:
        """Ranks of the given vertices, served degraded-mode (from the
        slot's read snapshot — never waiting on an in-flight update) with
        the staleness bound reported on the result."""
        return self._read(stream, lambda s: (s.query(vertices), None))

    def top_k(self, stream: int, k: int) -> ReadResult:
        """(values, vertex ids) of the k highest-ranked vertices, served
        degraded-mode with the staleness bound reported on the result."""
        return self._read(stream, lambda s: tuple(s.top_k(k)))

    def ppr_query(self, stream: int, seeds, k: int) -> ReadResult:
        """(values, vertex ids) of the k highest **personalized** PageRank
        estimates for the caller's seed set — the per-user ranking read.
        Served degraded-mode exactly like :meth:`top_k` (snapshot forks
        share the immutable walk buffers, so a degraded read costs one
        gather).  Streams whose engine lacks the ``"ppr"`` capability
        raise :class:`repro.api.CapabilityError`."""
        return self._read(stream, lambda s: tuple(s.ppr_query(seeds, k)))

    # -- reporting -----------------------------------------------------------
    @staticmethod
    def _pct(vals, q) -> float:
        return round(float(np.percentile(vals, q)) * 1e3, 3) if vals else 0.0

    def report(self) -> dict:
        """Per-session p50/p95 update latency + retrace counts, plus the
        service-level serving health: request/queue-wait/execution
        percentiles, shed + deadline-miss + retry counters, degraded-read
        latency and staleness, and the watchdog event log.  Dict-shaped so
        the smoke bench can serialize it directly."""
        per_session = []
        for i, s in enumerate(self.sessions):
            if s is None or s.closed:
                per_session.append({"stream": i, "closed": True})
                continue
            rep = s.report()
            row = {
                "stream": i,
                "n": s.n,
                "engine": rep.engine,
                "devices": list(s.device_footprint),
                "n_updates": rep.n_updates,
                "p50_ms": round(rep.p50_s * 1e3, 3),
                "p95_ms": round(rep.p95_s * 1e3, 3),
                "retraces_post_warmup": rep.retraces_post_warmup,
                "bucket_retraces_post_warmup": rep.bucket_retraces_post_warmup,
                "total_sweeps": rep.total_sweeps,
                "total_edges_processed": rep.total_edges_processed,
                "queries_served": rep.queries_served,
                "batches_converged": rep.batches_converged,
                "sweep_cap_hits": rep.sweep_cap_hits,
                # per-batch work history: pull-vs-push comparable from one
                # record (ISSUE 10 work accounting)
                "driver": rep.driver,
                "sweeps_history": rep.sweeps_history,
                "edges_processed_history": rep.edges_processed_history,
            }
            if rep.driver == "push":
                row["residual_mass_last"] = rep.residual_mass_last
                row["pushed_blocks"] = rep.pushed_blocks
            if rep.topology == "sharded":
                row["topology"] = rep.topology
                row["n_shards"] = rep.n_shards
                row["partitioner"] = rep.partitioner
                row["edge_cut"] = rep.edge_cut
            if rep.durability != "none" or rep.recoveries:
                row["durability"] = rep.durability
                row["recoveries"] = rep.recoveries
                row["recovery_time_s"] = round(rep.recovery_time_s, 6)
                row["replayed_batches"] = rep.replayed_batches
            if rep.integrity is not None:
                row["integrity"] = rep.integrity
            per_session.append(row)
        with self._lock:
            fin = list(self.finished)
            shed = list(self.shed_requests)
            q_walls = list(self._query_walls)
            q_stale = list(self._query_staleness)
            q_lags = list(self._query_lags)
            queued = sum(len(q) for q in self._queues.values()) \
                + sum(len(v) for v in self._inflight.values())
            watchdog = list(self._watchdog_events)
            deadline_misses = self._deadline_misses
            retries = self._retries
        lat = [r.latency_s for r in fin]
        waits = [r.wait_s for r in fin]
        execs = [r.exec_s for r in fin]
        out = {
            "n_sessions": self.slots,
            "serving": {f.name: getattr(self.serving, f.name)
                        for f in dataclasses.fields(self.serving)},
            "placements": {str(i): list(fp)
                           for i, fp in self.placements().items()},
            "requests_done": len(fin),
            "requests_queued": queued,
            "requests_shed": len(shed),
            "shed_reasons": dict(Counter(
                r.shed_reason["code"] for r in shed if r.shed_reason)),
            "deadline_misses": deadline_misses,
            "retries": retries,
            "request_p50_ms": self._pct(lat, 50),
            "request_p95_ms": self._pct(lat, 95),
            "queue_wait_p50_ms": self._pct(waits, 50),
            "queue_wait_p95_ms": self._pct(waits, 95),
            "exec_p50_ms": self._pct(execs, 50),
            "queries": {
                "served": len(q_walls),
                "p50_ms": self._pct(q_walls, 50),
                "p95_ms": self._pct(q_walls, 95),
                # 9 decimals (ns resolution), not 6: divergence-based
                # staleness is frequently in the microseconds (a read
                # catching a lagging snapshot refreshed moments earlier),
                # and 6-decimal rounding collapses those measurements
                # into bare powers of ten that read as placeholders
                "staleness_p95_s": (round(float(np.percentile(q_stale, 95)),
                                          9) if q_stale else 0.0),
                "staleness_max_s": (round(max(q_stale), 9)
                                    if q_stale else 0.0),
                "lag_updates_max": max(q_lags) if q_lags else 0,
                "snapshot_refreshes": self._snapshot_refreshes,
            },
            "failovers": list(self._failovers),
            "watchdog": watchdog,
            "sessions": per_session,
        }
        rows = [r.get("integrity") for r in per_session
                if r.get("integrity") is not None]
        if rows or self._scrubs_run:
            repairs: Counter = Counter()
            for r in rows:
                repairs.update(r.get("repairs", {}))
            out["integrity"] = {
                "scrubs_run": self._scrubs_run,
                "checks_run": sum(r["checks_run"] for r in rows),
                "corruption_detected": sum(r["corruption_detected"]
                                           for r in rows),
                "repairs": dict(repairs),
            }
        return out
