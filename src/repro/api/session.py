"""`PageRankSession` — one stateful handle for snapshots, streams, serving.

The paper's DF_LF algorithm is stateful: ranks, the affected frontier and
the incremental pull matrix persist across update batches.  The session
owns all of that behind one object::

    from repro.api import PageRankSession, EngineConfig

    sess = PageRankSession.from_graph(hg, config=EngineConfig(tau=1e-10))
    sess.update(dels, ins)          # DF_LF step: recompile-free, O(batch)
    sess.query([3, 17, 42])         # device-resident partial read
    sess.top_k(10)                  # device-side top-k, k values transferred
    sess.recompute(variant="nd")    # re-solve the current graph
    twin = sess.fork()              # what-if branch sharing the tile pool
    sess.report()                   # latency / retrace / work statistics

Three operating modes, picked at construction:

* **stream mode** (``from_graph`` + the pallas engine): the PR-2 streaming
  machinery lives here — the graph is snapshotted **once**, the
  capacity-padded pull matrix and the per-vertex/per-block engine operands
  are maintained as device-resident mirrors patched in O(batch), and
  ``update`` re-enters the fused driver with zero post-warmup retraces
  (asserted in ``tests/test_api_surface.py``).

* **snapshot mode** (``from_snapshot``, or any non-pallas engine): the
  session holds a :class:`~repro.core.graph.GraphSnapshot` and converges
  through the engine adapter resolved from :mod:`repro.api.registry`.
  The legacy ``static/nd/dt/df_pagerank`` functions are deprecated shims
  over exactly this path (bit-for-bit parity,
  ``tests/test_api_session.py``).

* **sharded mode** (``EngineConfig(topology="sharded")``): the vertex set
  is partitioned over an ``n_shards`` device mesh
  (:mod:`repro.graphs.partition`) and updates route each delta batch to
  its owning shards through the incremental
  :class:`~repro.core.distributed.DistRuntime` — same O(batch),
  recompile-free contract as stream mode, with ranks sharded across
  devices.  The topology is invisible through the public surface:
  ``update``/``query``/``top_k``/``fork``/``report`` behave identically
  (``report`` additionally exposes ``edge_cut`` and the per-sweep
  collective-bytes model).

* **walk mode** (``EngineConfig(engine="walk")``): the sweep-free Monte
  Carlo engine (:mod:`repro.core.walk_engine`) — R walk segments per
  vertex in device-resident capacity-padded buffers, regenerated
  delta-locally per ``update`` (only walks through touched vertices) and
  serving global estimates **plus** :meth:`ppr_query` (seed-set
  personalized top-k), the capability no sweep engine declares.

Faults in any domain (docs/FAULTS.md) recover behind the same surface:
thread-domain plans ride on ``EngineConfig(faults=…)``/``fault_domain=``,
sharded sessions survive shard crashes via helping + elastic re-partition
(:meth:`inject_shard_fault` schedules one deterministically), and
``durability="wal"`` + ``store_dir=`` makes the session crash-stop-proof
— :meth:`save` / :meth:`restore` round-trip through an atomic checkpoint
plus a write-ahead log replayed on the zero-retrace hot path, with every
recovery's cost visible in :meth:`report`.

The vertex set (and hence the block grid) is fixed for the lifetime of a
session; growing past it requires a new session.  ``close()`` (or the
context-manager form) releases device buffers and unregisters from any
service.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
import zlib
from functools import partial
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import registry
from repro.api.config import EngineConfig
from repro.ckpt.checkpoint import SessionStore
from repro.core import distributed as dist
from repro.core import fault_domain as fd
from repro.core import faults as flt
from repro.core import frontier as fr
from repro.core import integrity as ig
from repro.core import pallas_engine as pe
from repro.core import push_engine as pshe
from repro.core.blocked import SweepStats
from repro.core.delta import signed_edge_delta, validate_edge_batch
from repro.core.graph import (GraphSnapshot, HostGraph, initial_ranks,
                              pad_ranks)
from repro.core.incremental import (IncrementalPullMatrix, MatrixAux,
                                    effective_batch)
from repro.core.pagerank import PagerankResult
from repro.core import tiering
from repro.core import walk_engine as we
from repro.graphs import partition as gpart
from repro.kernels.block_spmv import ops

VARIANTS = ("static", "nd", "dt", "df")


class SweepCapWarning(RuntimeWarning):
    """An update batch hit ``max_iterations`` without converging — the
    served ranks are the best iterate, not a ``tau``-converged solution.
    Raised as a warning (not an error) because bounded-staleness serving
    legitimately runs with tight sweep budgets; ``report()`` counts every
    occurrence in ``sweep_cap_hits``."""


# ---------------------------------------------------------------------------
# streaming machinery (moved here from repro.core.stream in PR 3; the
# per-batch hot path is session state now — core.stream re-exports these
# for compatibility)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block_size", "interpret", "backend"))
def _seed_affected(mat_prev: ops.BlockSparse, mat_new: ops.BlockSparse,
                   bmat, batch, valid, *, block_size: int, interpret: bool,
                   backend: str) -> jnp.ndarray:
    """Initial DF frontier for one batch (paper Alg. 1 lines 4-6): mark the
    out-neighbors of every update source in G^{t-1} *and* G^t.

    Both graphs are queried through their pull matrices (A[v,u] ≥ 1 iff
    edge u→v, self-loops included — the same edge set a snapshot's
    ``out_neighbor_or`` walks), so the stream needs no snapshot edge
    arrays.  Launches are restricted to the candidate row-blocks that own a
    tile in a source's column-block; ``mat_new``'s structure is a superset
    of ``mat_prev``'s (growth is monotone), so one candidate set covers
    both passes."""
    n_pad = valid.shape[0]
    n_rb = n_pad // block_size
    ind = jnp.zeros((n_pad + 1,), bool)
    ind = ind.at[jnp.minimum(batch[:, 0], n_pad)].set(True)
    f = ind[:n_pad] & valid
    sb = fr.block_any(f, n_rb, block_size)
    cand = (bmat & sb[None, :]).any(axis=1)
    n_cand = cand.sum()
    cids = fr.compact_block_ids(cand, n_rb)
    fx = f.astype(mat_new.tiles.dtype)
    h_prev = ops.block_spmv_active_bucketed(
        mat_prev, fx, cids, n_cand, semiring="or", interpret=interpret,
        backend=backend)
    h_new = ops.block_spmv_active_bucketed(
        mat_new, fx, cids, n_cand, semiring="or", interpret=interpret,
        backend=backend)
    return (((h_prev > 0) | (h_new > 0))
            & jnp.repeat(cand, block_size) & valid)


@partial(jax.jit, static_argnames=("block",))
def _apply_operand_delta(out_deg, rb_in, rb_out, bmat,
                         rows, cols, vals, *, block: int):
    """O(batch) device-side update of the engine-operand mirrors from the
    signed pull-layout delta (rows = dst, cols = src, vals = ±1; padded
    entries carry val 0 and are inert).  Mirrors
    :meth:`repro.core.incremental.MatrixAux.apply_delta` plus the
    out-degree update, so a stream never re-uploads the graph-sized
    operand vectors — only the bucketed batch crosses to the device."""
    n_pad = out_deg.shape[0]
    n_rb = rb_in.shape[0]
    real = vals != 0
    v = jnp.where(real, vals, 0).astype(rb_in.dtype)
    rb = jnp.minimum(rows // block, n_rb - 1)
    cb = jnp.minimum(cols // block, n_rb - 1)
    out_deg = out_deg.at[jnp.minimum(cols, n_pad - 1)].add(
        v.astype(out_deg.dtype))
    rb_in = rb_in.at[rb].add(v)
    rb_out = rb_out.at[cb].add(v)
    # OR-scatter: padded entries contribute max(existing, False) == existing
    bmat = bmat.at[rb, cb].max(real)
    return out_deg, rb_in, rb_out, bmat


def _driver_cache_size() -> int:
    try:
        return int(pe._driver._cache_size())
    except Exception:           # pragma: no cover - older jax fallback
        return -1


# Cross-session retrace attribution.  The fused driver's jit cache is
# process-wide, so a step's cache-size delta can observe ANOTHER session's
# legitimate first-visit bucket compile (concurrent service dispatch) and
# misreport it as an unexpected retrace.  Every stream-mode drive entered
# at a first-visit operand bucket registers here for its duration; a step
# whose measurement window overlaps any registered drive (its own or a
# concurrent session's) attributes the window's cache growth to the
# capacity ladder, keeping ``driver_retraces`` an assertable
# zero-invariant under concurrency.  Sequential callers are unaffected:
# with no overlap, only the step's own first-visit can explain growth —
# exactly the previous behavior.
_RETRACE_LOCK = threading.Lock()
_NEW_BUCKET_STARTED = 0         # monotone count of first-visit drives begun
_NEW_BUCKET_ACTIVE = 0          # of those, currently mid-drive


@dataclasses.dataclass
class StreamBatchResult:
    """Outcome of one update step."""
    ranks: jnp.ndarray            # [n_pad] post-batch converged ranks
    stats: SweepStats
    wall_time_s: float            # full step: delta + seed + converge
    batch_edges: int              # raw batch size (before no-op filtering)
    driver_cache_size: int        # jit cache entries of the fused driver
    driver_retraces: int = 0      # cache growth DURING this step (-1 n/a) —
    #                               unlike the global cache size, immune to
    #                               other sessions/forks compiling variants
    bucket_retraces: int = 0      # cache growth explained by a FIRST visit
    #                               to a (tile capacity, max_tiles, expand)
    #                               operand bucket — the expected once-per-
    #                               bucket compile of the doubling ladder,
    #                               split out so driver_retraces stays an
    #                               assertable zero-invariant
    # -- walk-mode localization accounting (None on sweep engines) ----------
    regenerated_walks: Optional[int] = None   # walks rebuilt this batch
    touched_walks: Optional[int] = None       # touched-walk mass (bound)
    total_walks: Optional[int] = None         # n * R (the "global" yardstick)
    # -- push-driver accounting (None on the pull driver) --------------------
    residual_mass: Optional[float] = None     # ‖r‖₁ at drive exit
    pushed_blocks: Optional[int] = None       # source blocks pushed (summed
    #                                           over sweeps/refill rounds)

    @property
    def converged(self) -> bool:
        """Whether this batch reached ``tau`` within the sweep budget
        (``False`` = the sweep cap was hit; see :class:`SweepCapWarning`)."""
        return bool(self.stats.converged)


@dataclasses.dataclass
class SessionReport:
    """Aggregate latency / retrace / work statistics of a session."""
    engine: str
    backend: Optional[str]        # tile backend (pallas engine), else None
    mode: str
    n_updates: int
    p50_s: float
    p95_s: float
    retraces_post_warmup: int     # driver cache growth after warmup (-1 n/a)
    total_sweeps: int
    total_edges_processed: int
    queries_served: int
    wall_times_s: List[float]
    # -- convergence accounting (no silent sweep-capping) --------------------
    batches_converged: int = 0    # updates that reached tau in budget
    sweep_cap_hits: int = 0       # updates that hit max_iterations instead
    # -- topology (sharded sessions; None/"single" otherwise) ---------------
    topology: str = "single"
    n_shards: Optional[int] = None
    partitioner: Optional[str] = None
    edge_cut: Optional[float] = None          # realized cross-shard edges
    collective_bytes_per_sweep: Optional[float] = None  # analytic wire model
    # -- retrace decomposition (stream mode) ---------------------------------
    bucket_retraces_post_warmup: int = 0      # first-visit bucket compiles
    # -- fault domains / durability (docs/FAULTS.md) -------------------------
    durability: str = "none"
    recoveries: int = 0                       # completed, any domain
    recovery_time_s: float = 0.0              # summed detection→recovered
    replayed_batches: int = 0                 # WAL batches replayed (process)
    recovery_events: List[dict] = dataclasses.field(default_factory=list)
    # -- corruption domain (core/integrity.py; None = integrity disabled) ----
    integrity: Optional[dict] = None
    # -- tiered storage / memory audit (docs/SCALE.md) -----------------------
    tiering: Optional[dict] = None            # HotSetManager counters
    device_bytes: Optional[dict] = None       # per-component device bytes
    bytes_per_vertex: Optional[float] = None  # sum(device_bytes) / n
    # -- work accounting (per-batch history; pull-vs-push comparable) --------
    driver: str = "pull"                      # EngineConfig.driver
    sweeps_history: List[int] = dataclasses.field(default_factory=list)
    edges_processed_history: List[int] = dataclasses.field(
        default_factory=list)
    residual_mass_last: Optional[float] = None  # push: ‖r‖₁ at last exit
    pushed_blocks: Optional[int] = None         # push: total source blocks


class PageRankSession:
    """Stateful PageRank handle owning graph state, the resolved engine and
    the incremental operands.  Construct via :meth:`from_graph` (dynamic
    streams + serving) or :meth:`from_snapshot` (one-shot solves over an
    existing device snapshot)."""

    def __init__(self, *, hg: Optional[HostGraph] = None,
                 g: Optional[GraphSnapshot] = None,
                 config: Optional[EngineConfig] = None,
                 r0=None, interpret: Optional[bool] = None,
                 store_dir: Optional[str] = None,
                 _restore_attach: bool = False):
        if config is None:
            config = EngineConfig()
        if not isinstance(config, EngineConfig):
            raise TypeError(
                f"config must be an EngineConfig, got {type(config).__name__}"
                " — build one with repro.api.EngineConfig(...)")
        if hg is None and g is None:
            raise ValueError("need a HostGraph (from_graph) or a "
                             "GraphSnapshot (from_snapshot)")
        self.config = config
        self._sharded = config.topology == "sharded"
        self.engine = registry.resolve(config._engine_for_resolution())
        self.engine_name = self.engine.name
        self.hg = hg
        self._dtype = config.resolved_dtype()
        self.interpret = (pe.default_interpret() if interpret is None
                          else interpret)
        self.backend = (config.resolved_backend
                        if self.engine_name == "pallas" else config.backend)
        self._stream = (self.engine_name == "pallas" and hg is not None
                        and g is None)
        self._walk = "ppr" in registry.supports_of(self.engine)
        # tiered storage (docs/SCALE.md): host-truth tile pool + bounded
        # device hot set; stream mode only — everything else keeps its
        # state fully device-resident
        self._tiered = config.device_budget_bytes is not None
        if self._tiered and not self._stream:
            raise ValueError(
                "device_budget_bytes tiers the streaming tile pool — open "
                "the session with from_graph and the pallas engine")
        self.pool: Optional[tiering.HostTilePool] = None
        self.hot: Optional[tiering.HotSetManager] = None
        self._deferred_rb: Optional[np.ndarray] = None
        # residual forward-push driver (docs/ENGINES.md): the session keeps
        # a device-resident residual vector next to the ranks, seeded in
        # O(batch) per update; config validation already pinned the engine
        # to pallas — here we additionally need the *stream* machinery
        self._push = config.driver == "push"
        if self._push and not self._stream:
            raise ValueError(
                "driver='push' runs the residual forward-push stream — "
                "open the session with from_graph and the pallas engine "
                "(from_snapshot has no operand mirrors to seed)")
        self._residual = None
        self._closed = False
        self._service = None          # backref set by PageRankService
        self._shard_spec: Optional[dist.ShardSpec] = None
        self._history: List[StreamBatchResult] = []
        self._warm_idx: Optional[int] = None
        self._queries = 0
        # replay state for recompute("dt"/"df"): the last applied batch,
        # the pre-batch host graph / snapshot, and the pre-batch ranks
        self._last_batch: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._hg_prev: Optional[HostGraph] = None
        self._g_prev: Optional[GraphSnapshot] = None
        self._r_prev = None

        # -- fault domains / durability (docs/FAULTS.md) ---------------------
        self._fault_plan = fd.resolve_thread_plan(config.faults,
                                                  config.fault_domain)
        self._shard_faults: Optional[fd.ShardFaultDomain] = None
        if self._sharded:
            # each session consumes its OWN schedule: the domain object
            # lives on a frozen, shareable config, so adopt a clone
            self._shard_faults = (
                config.fault_domain.clone()
                if isinstance(config.fault_domain, fd.ShardFaultDomain)
                else fd.ShardFaultDomain())
        self._recoveries: List[fd.RecoveryRecord] = []
        # -- corruption domain (core/integrity.py) ---------------------------
        self._corruption_faults: Optional[fd.CorruptionFaultDomain] = None
        if isinstance(config.fault_domain, fd.CorruptionFaultDomain):
            config.fault_domain.validate_for(topology=config.topology)
            # same contract as the shard domain: consume a private clone of
            # the schedule riding the (shareable) frozen config
            self._corruption_faults = config.fault_domain.clone()
        self._integrity_checks = 0      # invariant/digest checks evaluated
        self._corruption_detected = 0   # verify() passes that found damage
        self._integrity_alert: Optional[dict] = None  # fused-drive detection
        self._scatter_fault: Optional[str] = None     # pending torn scatter
        self._r_verified = None         # last integrity-clean iterate
        self._hg_digest: Optional[int] = None
        self._driver_keys: set = set()  # operand buckets already compiled
        self._batch_index = 0       # total update batches applied (WAL key)
        self._replaying = False     # True while restore() replays the WAL
        self.store_dir = store_dir
        self.store: Optional[SessionStore] = None
        self._process_domain: Optional[fd.ProcessFaultDomain] = None
        if config.durability == "wal":
            if hg is None:
                raise ValueError(
                    "durability='wal' needs a host graph (from_graph, or "
                    "from_snapshot with hg=) — the WAL replays edge "
                    "batches against it")
            if store_dir is None:
                raise ValueError(
                    "durability='wal' needs a store_dir= (the directory "
                    "holding the checkpoint + WAL)")
            self.store = SessionStore(store_dir)
            if not _restore_attach and (
                    self.store.read_meta() is not None
                    or self.store.latest_checkpoint_index is not None):
                raise ValueError(
                    f"store_dir {store_dir!r} already holds a session — "
                    "reopen it with PageRankSession.restore(dir) (replays "
                    "its WAL), or give a new session a fresh directory; "
                    "mixing two sessions' logs would corrupt both")
            self._process_domain = fd.ProcessFaultDomain(
                self.store, checkpoint_interval=config.checkpoint_interval)

        if self._sharded:
            self._init_sharded(g, r0)
        elif self._walk:
            self._init_walk(g, r0)
        elif self._stream:
            self._init_stream(r0)
        else:
            self._init_snapshot(g, r0)

        # a config-carried fault schedule is validated against the REAL
        # mesh now that it exists — never mid-update (see
        # inject_shard_fault)
        if self._shard_faults is not None:
            bad = [f.shard for f in self._shard_faults.pending_faults
                   if not 0 <= f.shard < self.runtime.n_dev]
            if bad:
                raise ValueError(
                    f"ShardFaultDomain schedules shard(s) {bad} outside "
                    f"the {self.runtime.n_dev}-shard mesh")

        # durable bootstrap: a FRESH store gets the session meta + one
        # atomic checkpoint of the born state (batch index 0), so a crash
        # before the first update already restores; restore() re-attaches
        # to a populated store and must not clobber it
        if (self.store is not None
                and self.store.latest_checkpoint_index is None):
            self._checkpoint_now()          # writes meta on a fresh store

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_graph(cls, hg: HostGraph, *,
                   config: Optional[EngineConfig] = None, r0=None,
                   interpret: Optional[bool] = None,
                   store_dir: Optional[str] = None) -> "PageRankSession":
        """Open a session over a host graph.  With the pallas engine this is
        **stream mode**: the graph is snapshotted once and every engine
        operand is maintained incrementally (O(batch) per update, zero
        post-warmup driver retraces).  ``r0=None`` runs one initial solve
        (``variant="static"`` semantics) so the session is born serving.
        ``store_dir`` attaches the durable store a
        ``config.durability="wal"`` session checkpoints and logs through."""
        return cls(hg=hg, config=config, r0=r0, interpret=interpret,
                   store_dir=store_dir)

    @classmethod
    def from_snapshot(cls, g: GraphSnapshot, *,
                      config: Optional[EngineConfig] = None, r0=None,
                      hg: Optional[HostGraph] = None,
                      interpret: Optional[bool] = None,
                      store_dir: Optional[str] = None) -> "PageRankSession":
        """Wrap an existing device snapshot (snapshot mode; the block grid
        comes from the snapshot, not ``config.block_size``).  Pass ``hg``
        as well to enable ``update``."""
        return cls(hg=hg, g=g, config=config, r0=r0, interpret=interpret,
                   store_dir=store_dir)

    # -- init paths ----------------------------------------------------------
    def _init_stream(self, r0) -> None:
        cfg = self.config
        # the ONLY snapshot stream mode ever builds; not retained — the
        # scalars + operand mirrors below carry everything the hot path needs
        g0 = self.hg.snapshot(block_size=cfg.block_size)
        self.g = None
        self.n, self.n_pad = g0.n, g0.n_pad
        self.block_size, self.n_rb = g0.block_size, g0.n_blocks
        dt = self._dtype
        # traced hyperparameter operands, created once so dtypes (and the
        # jit cache key) are identical across every step
        self._alpha = jnp.asarray(cfg.alpha, dt)
        self._tau = jnp.asarray(cfg.tau, dt)
        self._tau_f = jnp.asarray(cfg.resolved_tau_f(expand=True), dt)
        plan = self._fault_plan or flt.NO_FAULTS
        t = plan.device_tables(cfg.max_iterations)
        self._fault_tables = tuple(jnp.asarray(a) for a in t)

        if self._tiered:
            # host tier: the full tile pool + slot tables never land on the
            # device — only the HotSetManager's budget-bounded slab does.
            # The device "matrix" is the slab VIEW (same BlockSparse slot-
            # table indirection), rebound after every admission.
            src, dst = g0.in_edges_host()
            self.pool = tiering.HostTilePool.from_edges(
                dst, src, g0.n_pad, g0.n_pad, block=g0.block_size,
                dtype=np.dtype(dt))
            self.hot = tiering.HotSetManager(self.pool,
                                             cfg.device_budget_bytes)
            aux = MatrixAux(
                bmat=tiering.host_block_adjacency(self.pool.tile_cols,
                                                  self.pool.mat.n_cb),
                rb_in=np.asarray(g0.block_in_edges()).copy(),
                rb_out=np.asarray(g0.block_out_edges()).copy())
            self.inc = IncrementalPullMatrix(self.hot.view(), aux)
        else:
            self.inc = IncrementalPullMatrix.from_snapshot(
                g0, dtype=np.dtype(dt), padded=True)
        self._rb_res_full = jnp.ones((self.n_rb,), bool)
        self.valid = g0.vertex_valid
        # device-resident engine operands, patched in place per batch by
        # _apply_operand_delta (the host-side numpy twins live in inc.aux
        # for non-stream callers)
        self._out_deg = jnp.asarray(g0.out_deg)
        self._rb_in = jnp.asarray(self.inc.aux.rb_in)
        self._rb_out = jnp.asarray(self.inc.aux.rb_out)
        self._bmat = jnp.asarray(self.inc.aux.bmat)
        # host-truth twin of the out-degree mirror (rb_in/rb_out/bmat have
        # theirs in inc.aux), maintained in O(batch) — what the integrity
        # scrubber digests the device mirror against
        self._out_deg_host = np.asarray(g0.out_deg).copy()
        self._hg_digest = self._graph_digest()
        if r0 is None:
            if self._push:
                # cold push solve: p = 0, r = b — the invariant
                # r = b + M·p − p holds trivially, and the drive pushes the
                # whole teleport mass to the fixed point (tiered sessions
                # refill through the same admit → re-drive loop as pull)
                self._residual = jnp.where(
                    self.valid, (1.0 - cfg.alpha) / self.n, 0).astype(dt)
                r0, _, _ = self._drive_push_refill(
                    jnp.zeros((self.n_pad,), dt),
                    want_rb=(np.arange(self.n_rb) if self._tiered
                             else None))
                m = self.inc.mat
                self._driver_keys.add((int(m.tiles.shape[0]),
                                       int(m.tile_cols.shape[1]), "push"))
            elif self._tiered:
                # cold solve through the refill loop: admit what fits,
                # converge resident blocks, defer the rest — block-Jacobi
                # over residency partitions (expand=True propagates
                # corrections across rounds; docs/SCALE.md §Miss semantics)
                r0, _ = self._drive_refill(
                    jnp.asarray(initial_ranks(g0, dt)), g0.vertex_valid,
                    expand=True, want_rb=np.arange(self.n_rb))
                m = self.inc.mat
                self._driver_keys.add((int(m.tiles.shape[0]),
                                       int(m.tile_cols.shape[1]), True))
            else:
                r0, _ = pe.run_pallas(
                    g0, initial_ranks(g0, dt), g0.vertex_valid,
                    mode=cfg.mode,
                    expand=False, alpha=cfg.alpha, tau=cfg.tau,
                    max_iterations=cfg.max_iterations,
                    active_policy=cfg.active_policy,
                    mat=self.inc.mat, aux=self.inc.aux,
                    interpret=self.interpret, backend=self.backend)
        r0 = jnp.asarray(r0, dt)
        if r0.shape[0] < self.n_pad:       # e.g. length-n restore state
            r0 = jnp.zeros((self.n_pad,), dt).at[:r0.shape[0]].set(r0)
        self.R = r0[:self.n_pad]
        self._r_verified = self.R       # drift baseline for integrity checks
        if self._push and self._residual is None:
            # restored / caller-provided ranks: rebuild the exact residual
            # invariant before the first update seeds against it
            self._residual = self._residual_recompute(self.R)

    def _init_snapshot(self, g: Optional[GraphSnapshot], r0) -> None:
        cfg = self.config
        if g is None:
            g = self.hg.snapshot(block_size=cfg.block_size)
        self.g = g
        self.n, self.n_pad = g.n, g.n_pad
        self.block_size, self.n_rb = g.block_size, g.n_blocks
        self.valid = g.vertex_valid
        self.inc = None
        if r0 is None:
            res = self._converge(initial_ranks(g, self._dtype),
                                 g.vertex_valid, expand=False)
            self.R = res.ranks
        else:
            # keep the caller's dtype: engines key their compute dtype off
            # R0.dtype (an f32 rank vector must stay f32)
            self.R = pad_ranks(g, jnp.asarray(r0))

    def _init_sharded(self, g: Optional[GraphSnapshot], r0) -> None:
        """Sharded mode (``topology="sharded"``): partition the vertex set
        over an ``n_shards`` device mesh with the configured partitioner
        and hand the graph to the incremental
        :class:`repro.core.distributed.DistRuntime`.  Ranks live
        device-resident in the partitioner-relabeled vertex space; every
        public read (``query``/``top_k``/``ranks``) translates back, so the
        topology is invisible to callers."""
        cfg = self.config
        if self.hg is None:
            # from_snapshot without hg: recover the host edge set (the
            # sharded runtime is host-graph-based; self-loops re-added by it)
            src, dst = g.in_edges_host()
            self.hg = HostGraph(g.n, np.stack([src, dst], 1))
        self.g = None
        self.inc = None
        n_shards = cfg.resolved_n_shards
        self._shard_spec = dist.ShardSpec(
            n_shards=n_shards, partitioner=cfg.partitioner,
            exchange=cfg.exchange)
        order, inv, _ = gpart.make_partition(self.hg, n_shards,
                                             cfg.partitioner)
        self._order, self._inv = order, inv
        self._hg_rel, _ = gpart.relabel(self.hg, order)
        self._hg_rel_prev: Optional[HostGraph] = None
        self._last_batch_rel = None
        self._x_full = self._x_delta = self._x_sweeps = 0
        devices = np.asarray(jax.devices()[:n_shards])
        self._mesh = dist.Mesh(devices, ("shards",))
        self.runtime = dist.DistRuntime(
            self._hg_rel, self._mesh, axis="shards", alpha=cfg.alpha,
            tau=cfg.tau, tau_f=cfg.resolved_tau_f(expand=True),
            exchange=cfg.exchange, dtype=self._dtype)
        self.n, self.n_pad = self.hg.n, self.runtime.n_pad
        self.block_size, self.n_rb = cfg.block_size, 0
        self.valid = self.runtime.valid
        # realized shard of vertex v is its relabeled position's contiguous
        # share — the edge-cut this layout actually pays.  Counted once
        # here (O(m)), then maintained in O(batch) per update.
        self._cut_edges = int(self._crossing(self._hg_rel.edges))
        if r0 is None:
            R0 = jnp.where(self.valid, 1.0 / self.n, 0).astype(self._dtype)
            R, _ = self.runtime.drive(R0, self.valid, expand=False,
                                      max_sweeps=cfg.max_iterations)
            self.R = R
        else:
            r0h = np.asarray(r0)
            r_rel = np.zeros(self.n_pad, r0h.dtype)
            r_rel[:self.n] = r0h[order]
            self.R = jnp.asarray(r_rel, self._dtype)

    def _init_walk(self, g: Optional[GraphSnapshot], r0) -> None:
        """Walk mode (``engine="walk"``): no sweeps, no pull operands — the
        session owns a :class:`repro.core.walk_engine.WalkState` (R walk
        segments per vertex, device-resident) and every rank read derives
        from its visit counters.  ``r0`` is accepted for constructor parity
        (and WAL restore) but ignored: regeneration is deterministic in
        (graph, seed), so replaying the WAL reproduces the counters exactly
        — there is no separate rank state to seed."""
        cfg = self.config
        if self.hg is None:
            # from_snapshot without hg: recover the host edge set (walks run
            # over the host-graph adjacency; the snapshot's implicit
            # self-loops are re-added by the walk kernel's sampling)
            src, dst = g.in_edges_host()
            keep = src != dst
            self.hg = HostGraph(g.n, np.stack([src[keep], dst[keep]], 1))
        self.g = None
        self.inc = None
        self.n = self.n_pad = self.hg.n
        self.block_size, self.n_rb = cfg.block_size, 0
        self.valid = jnp.ones((self.n,), bool)
        self.walks = we.WalkState(
            self.hg, R=cfg.resolved_walks_per_vertex,
            L=cfg.resolved_walk_length, seed=cfg.resolved_walk_seed,
            alpha=cfg.alpha, dtype=self._dtype)
        self._hg_digest = self._graph_digest()
        self.R = self.walks.pagerank()
        self._r_verified = self.R

    # -- the snapshot-level solve (registry-dispatched) ----------------------
    def _converge(self, R0, affected0, *, expand: bool,
                  mode: Optional[str] = None, mat=None, aux=None,
                  g: Optional[GraphSnapshot] = None) -> PagerankResult:
        """Converge one (R0, affected0) problem through the resolved engine
        adapter and adopt the result as the session's ranks.  This is the
        exact path the deprecated ``*_pagerank`` functions shim onto."""
        cfg = self.config
        g = g if g is not None else self.g
        if g is None:
            raise ValueError("snapshot-level solve needs a GraphSnapshot "
                             "(stream-mode sessions use update/recompute)")
        t0 = time.perf_counter()
        R, stats = self.engine.run(
            g, R0, affected0, mode=mode or cfg.mode, expand=expand,
            alpha=cfg.alpha, tau=cfg.tau, tau_f=cfg.tau_f,
            max_iterations=cfg.max_iterations, faults=self._fault_plan,
            tile=cfg.tile, active_policy=cfg.active_policy,
            mat=mat, aux=aux, backend=cfg.backend,
            interpret=self.interpret)
        self.R = R
        return PagerankResult(ranks=R, stats=stats,
                              wall_time_s=time.perf_counter() - t0)

    # -- the stream-mode fused solve ----------------------------------------
    def _drive(self, R0, affected, *, expand: bool
               ) -> Tuple[jnp.ndarray, SweepStats]:
        """Run the fused driver over the device-resident operand mirrors
        (stream mode; one host sync for the stats vector).

        With ``EngineConfig(integrity=…)`` the corruption-domain invariant
        vector (mass error / negativity / finiteness / drift,
        :func:`repro.core.integrity.invariant_vec`) is concatenated onto
        the stats vector and fetched in the SAME ``block_until_ready`` —
        the per-drive checks cost device FLOPs, never an extra host sync.
        A violated invariant raises no error here (the batch is already
        applied); it posts ``_integrity_alert`` for :meth:`update` /
        :meth:`verify` to repair."""
        cfg = self.config
        part, alive, delay, crashed = self._fault_tables
        tiered = self._tiered
        rb_res = self.hot.rb_res if tiered else self._rb_res_full
        R, stats_vec, deferred = pe._driver(
            self.inc.mat, R0, affected, self.valid, self._out_deg,
            self._rb_in, self._rb_out, self._bmat, rb_res,
            self._alpha, self._tau, self._tau_f,
            part, alive, delay, crashed,
            n=self.n, block_size=self.block_size, mode=cfg.mode,
            expand=expand, active_policy=cfg.active_policy,
            max_iterations=cfg.max_iterations, interpret=self.interpret,
            backend=self.backend, tiered=tiered)
        icfg = cfg.integrity
        fused = (icfg is not None and icfg.fused
                 and self._r_verified is not None)
        # everything riding the drive — invariants AND the tiered deferral
        # indicator — is fetched in the SAME block_until_ready: one sync
        tail = []
        if fused:
            inv = ig.invariant_vec(R, self._r_verified, self.valid)
            tail.append(inv.astype(stats_vec.dtype))
        if tiered:
            tail.append(deferred.astype(stats_vec.dtype))
        sv = np.asarray(jax.block_until_ready(       # the single sync
            jnp.concatenate([stats_vec] + tail) if tail else stats_vec))
        def_pending = False
        if tiered:
            self._deferred_rb = sv[-self.n_rb:] != 0
            def_pending = bool(self._deferred_rb.any())
            sv = sv[:-self.n_rb]
        else:
            self._deferred_rb = None
        if fused:
            stats = pe._stats_from_vec(sv[:-ig.N_INVARIANTS])
            mass_err, neg, nonfinite, _drift = (
                float(x) for x in sv[-ig.N_INVARIANTS:])
            # the drift term is informational here: a drive legitimately
            # moves ranks arbitrarily far from the pre-batch baseline, so
            # only verify() (between drives, where drift must be 0) gates
            # on it.  Mass is gated on converged iterates only — a sweep-
            # capped iterate's residual legitimately carries ≤ n·tau —
            # and only once no deferred (non-resident) blocks are pending:
            # mid-refill iterates carry those blocks' stale mass.
            self._integrity_checks += 3
            alert = None
            if nonfinite > 0:
                alert = {"check": "rank_finite", "count": int(nonfinite)}
            elif neg > 0:
                alert = {"check": "rank_negativity", "count": int(neg)}
            elif (stats.converged and not def_pending
                    and mass_err > icfg.mass_tol):
                alert = {"check": "rank_mass", "mass_error": mass_err}
            if alert is None:
                self._r_verified = R
            else:
                self._integrity_alert = alert
            return R, stats
        self._r_verified = R
        return R, pe._stats_from_vec(sv)

    def _admit(self, want_rb) -> None:
        """Admit row-blocks into the hot slab and rebind the device view
        (tiered streams only)."""
        self.hot.admit(want_rb)
        self.inc.mat = self.hot.view()

    def _mask_from_indices(self, idx: np.ndarray) -> jnp.ndarray:
        """Device indicator from a host index list: only the bucket-padded
        list crosses host→device (pad slots target the guard row), so the
        per-step transfer is O(batch·deg), never O(n)."""
        idx = np.asarray(idx, np.int64).reshape(-1)
        k_pad = ops.capacity_bucket(max(len(idx), 1), 1024)
        buf = np.full(k_pad, self.n_pad, np.int64)
        buf[:len(idx)] = np.minimum(idx, self.n_pad)
        ind = jnp.zeros((self.n_pad + 1,), bool).at[jnp.asarray(buf)].set(
            True)
        return ind[:self.n_pad] & self.valid

    def _drive_refill(self, R0, affected, *, expand: bool,
                      want_rb=None) -> Tuple[jnp.ndarray, SweepStats]:
        """Admission + fused drive + deferred-refill loop.

        Untiered sessions fall through to one plain :meth:`_drive`.
        Tiered: admit the frontier-biased want set in one batched gather,
        drive, and while the driver deferred non-resident blocks, admit
        those and re-drive with exactly the deferred blocks re-marked
        affected — the paper's helping mechanism applied to residency
        misses (a miss inside a sweep never syncs; the block is helped on
        the next drive).  Each round makes the previous rounds' blocks
        evictable, so the loop progresses whenever one row-block fits the
        slab; ``max_iterations`` rounds is the safety cap.

        Drain criterion: the loop stops once every currently-deferred
        block has been re-driven during an unbroken run of *quiet* rounds
        — rounds whose max rank movement stayed at or below ``tau`` (or
        the float ulp floor when ``tau`` sits under machine precision, a
        limit-cycle regime counted in ``refill_stalls``).  Abandoning the
        expansion marks of a quiet round is exactly what the untiered
        driver does when a sweep's max change falls to ``tau``, so tiered
        and untiered share one convergence semantics; the quiet *window*
        (rather than a single round) is what makes the criterion reachable
        when the deferred set is larger than the slab and each round can
        only re-drive a slice of it."""
        if not self._tiered:
            return self._drive(R0, affected, expand=expand)
        if want_rb is not None:
            self._admit(want_rb)
        R, agg = self._drive(R0, affected, expand=expand)
        rounds = 0
        eps = float(np.finfo(np.dtype(R.dtype)).eps)
        quiet_driven = np.zeros(self.n_rb, bool)
        B = self.block_size
        while self._deferred_rb is not None and self._deferred_rb.any():
            if rounds >= int(self.config.max_iterations):
                warnings.warn(
                    f"tiered refill loop did not drain in {rounds} rounds "
                    "— serving the best iterate (raise "
                    "device_budget_bytes)", SweepCapWarning, stacklevel=3)
                agg = SweepStats(
                    sweeps=agg.sweeps, iterations=agg.iterations,
                    blocks_processed=agg.blocks_processed,
                    edges_processed=agg.edges_processed,
                    sim_time_ms=agg.sim_time_ms, converged=False,
                    dnf=agg.dnf)
                break
            rounds += 1
            deferred = self._deferred_rb
            pending = np.nonzero(deferred)[0]
            self._admit(pending)
            aff = jnp.repeat(jnp.asarray(deferred), B) & self.valid
            R_prev = R
            R, st = self._drive(R, aff, expand=expand)
            agg = SweepStats(
                sweeps=agg.sweeps + st.sweeps,
                iterations=agg.iterations + st.iterations,
                blocks_processed=agg.blocks_processed + st.blocks_processed,
                edges_processed=agg.edges_processed + st.edges_processed,
                sim_time_ms=agg.sim_time_ms + st.sim_time_ms,
                converged=bool(st.converged), dnf=bool(agg.dnf or st.dnf))
            # drain check: a quiet round extends the window with the
            # blocks it actually re-drove; a loud round (or an unconverged
            # drive) resets it
            driven = pending[self.hot.resident[pending]]
            quiet = False
            at_floor = False
            if st.converged and len(driven):
                delta = float(jnp.max(jnp.abs(R - R_prev)))
                if delta <= float(self.config.tau):
                    quiet = True
                else:
                    rmax = float(jnp.max(jnp.abs(R)))
                    at_floor = delta <= 16.0 * eps * max(rmax, eps)
                    quiet = at_floor
            if quiet:
                quiet_driven[driven] = True
                cur = np.nonzero(self._deferred_rb)[0]
                if quiet_driven[cur].all():
                    if at_floor:
                        self.hot.counters["refill_stalls"] += 1
                    self._deferred_rb = np.zeros_like(deferred)
                    break
            else:
                quiet_driven[:] = False
        self.hot.counters["refill_drives"] += rounds
        return R, agg

    # -- the stream-mode residual forward-push solve -------------------------
    def _drv_cache_size(self) -> int:
        """Jit-cache size of THIS session's fused driver (push sessions
        measure the push driver's cache, pull sessions the pull driver's —
        the retrace yardsticks are per-driver)."""
        return (pshe.push_cache_size() if getattr(self, "_push", False)
                else _driver_cache_size())

    def _drive_push(self, P0) -> Tuple[jnp.ndarray, SweepStats, dict]:
        """One fused push drive over the device-resident operand mirrors:
        ranks + carried residual in, ranks + shrunk residual out, one host
        sync for the stats vector (the tiered deferral indicator rides the
        same ``block_until_ready``, exactly like :meth:`_drive`)."""
        cfg = self.config
        tiered = self._tiered
        rb_res = self.hot.rb_res if tiered else self._rb_res_full
        P, Rr, stats_vec, deferred = pshe._push_driver(
            self.inc.mat, P0, self._residual, self.valid, self._out_deg,
            self._rb_out, self._bmat, rb_res, self._alpha, self._tau,
            n=self.n, block_size=self.block_size,
            max_iterations=cfg.max_iterations, interpret=self.interpret,
            backend=self.backend, tiered=tiered)
        tail = [deferred.astype(stats_vec.dtype)] if tiered else []
        sv = np.asarray(jax.block_until_ready(       # the single sync
            jnp.concatenate([stats_vec] + tail) if tail else stats_vec))
        if tiered:
            self._deferred_rb = sv[-self.n_rb:] != 0
            sv = sv[:-self.n_rb]
        else:
            self._deferred_rb = None
        self._residual = Rr
        self._r_verified = P
        stats, extras = pshe.push_stats_from_vec(sv)
        return P, stats, extras

    def _drive_push_refill(self, P0, *, want_rb=None
                           ) -> Tuple[jnp.ndarray, SweepStats, dict]:
        """Admission + push drive + stale-refresh refill loop (the push
        twin of :meth:`_drive_refill`).  A drive delivers pushes to
        resident destination rows only; rows it pushed to while
        non-resident are *stale* and sit in the deferred bitmap.  Each
        round admits the pending blocks, rebuilds the admitted ones'
        residuals exactly from the invariant (``r = b + M·p − p`` needs
        only the row's own — now resident — tiles;
        :func:`repro.core.push_engine.residual_refresh_blocks`) and
        re-drives, until the bitmap drains or the rounds cap trips.  No
        quiet-window drain is needed: ``p`` is globally exact at all
        times, so draining the bitmap IS convergence."""
        if not self._tiered:
            return self._drive_push(P0)
        if want_rb is not None:
            self._admit(want_rb)
        P, agg, extras = self._drive_push(P0)
        pushed = extras["pushed_blocks"]
        rounds = 0
        while self._deferred_rb is not None and self._deferred_rb.any():
            if rounds >= int(self.config.max_iterations):
                warnings.warn(
                    f"tiered push refill loop did not drain in {rounds} "
                    "rounds — serving the best iterate (raise "
                    "device_budget_bytes)", SweepCapWarning, stacklevel=3)
                agg = SweepStats(
                    sweeps=agg.sweeps, iterations=agg.iterations,
                    blocks_processed=agg.blocks_processed,
                    edges_processed=agg.edges_processed,
                    sim_time_ms=agg.sim_time_ms, converged=False,
                    dnf=agg.dnf)
                break
            rounds += 1
            pending = np.nonzero(self._deferred_rb)[0]
            self._admit(pending)
            got = pending[self.hot.resident[pending]]
            if len(got):
                ids = np.full(self.n_rb, -1, np.int32)
                ids[:len(got)] = got
                self._residual = pshe.residual_refresh_blocks(
                    self.inc.mat, P, self._residual, self.valid,
                    self._out_deg, self._alpha, jnp.asarray(ids),
                    jnp.asarray(np.int32(len(got))),
                    n=self.n, block_size=self.block_size,
                    interpret=self.interpret, backend=self.backend)
            # blocks the slab could not take this round stay deferred
            leftover = np.zeros(self.n_rb, bool)
            leftover[pending] = ~self.hot.resident[pending]
            P, st, extras = self._drive_push(P)
            pushed += extras["pushed_blocks"]
            agg = SweepStats(
                sweeps=agg.sweeps + st.sweeps,
                iterations=agg.iterations + st.iterations,
                blocks_processed=agg.blocks_processed + st.blocks_processed,
                edges_processed=agg.edges_processed + st.edges_processed,
                sim_time_ms=agg.sim_time_ms + st.sim_time_ms,
                converged=bool(st.converged), dnf=bool(agg.dnf or st.dnf))
            if leftover.any():
                self._deferred_rb = (leftover if self._deferred_rb is None
                                     else self._deferred_rb | leftover)
        self.hot.counters["refill_drives"] += rounds
        return P, agg, {**extras, "pushed_blocks": pushed}

    def _residual_recompute(self, P) -> jnp.ndarray:
        """Exact O(m) residual rebuild ``r = b + M·p − p`` for the current
        graph (nd / restore / static-repair path).  Tiered sessions hold
        only a partial device view, so they walk host truth instead."""
        if self._tiered:
            return jnp.asarray(pshe.residual_from_host(
                self.hg, self._out_deg_host, np.asarray(P),
                float(self.config.alpha)))
        return pshe.residual_full(
            self.inc.mat, P, self.valid, self._out_deg, self._alpha,
            n=self.n, interpret=self.interpret, backend=self.backend)

    def _seed_push(self, variant: str, dels_eff, ins_eff, deg_old_host
                   ) -> Tuple[jnp.ndarray, Optional[np.ndarray]]:
        """Set the session residual for one applied batch and return
        ``(P0, seed_idx)``.  ``df`` is the O(batch·deg) hot path: the batch
        changes the pull matrix only in its effective source columns, so
        ``Δr = (M' − M)·p`` is enumerated host-side
        (:func:`repro.core.push_engine.residual_seed_host`) and applied
        with one bucketed device scatter — the operand-mirror scatter
        discipline.  ``nd`` keeps ``p`` and rebuilds the exact residual
        (O(m)); ``static`` restarts cold (p = 0, r = b)."""
        cfg = self.config
        if variant == "df":
            dels_a = np.asarray(dels_eff, np.int64).reshape(-1, 2)
            ins_a = np.asarray(ins_eff, np.int64).reshape(-1, 2)
            sources = np.unique(np.concatenate([dels_a[:, 0],
                                                ins_a[:, 0]]))
            if len(sources):
                p_src = np.asarray(self.R[jnp.asarray(sources)])
                sidx, svals = pshe.residual_seed_host(
                    self._hg_prev, self.hg, sources, p_src,
                    deg_old_host[sources], self._out_deg_host[sources],
                    float(cfg.alpha))
            else:
                sidx = np.zeros(0, np.int64)
                svals = np.zeros(0, self._dtype)
            # the scatter runs even for an empty batch so warmup() traces
            # it at the base bucket, like the operand scatter
            self._residual = pshe.scatter_residual(self._residual, sidx,
                                                   svals)
            return self.R, sidx
        if variant == "nd":
            self._residual = self._residual_recompute(self.R)
            return self.R, None
        # static: cold restart — invariant holds trivially at p=0, r=b
        self._residual = jnp.where(
            self.valid, (1.0 - cfg.alpha) / self.n, 0).astype(self._dtype)
        return jnp.zeros((self.n_pad,), self._dtype), None

    # -- updates -------------------------------------------------------------
    def update(self, deletions, insertions, *, variant: str = "df"
               ) -> StreamBatchResult:
        """Apply one edge batch and reconverge.

        ``variant`` selects the dynamic marking: ``"df"`` (Dynamic Frontier,
        the paper's algorithm — the default and the recompile-free hot
        path), ``"dt"`` (reachability marking), ``"nd"`` (warm start, all
        affected) or ``"static"`` (cold start, all affected).  In stream
        mode everything except the ``dt`` marking stays snapshot-free."""
        self._ensure_open()
        if variant not in VARIANTS:
            raise ValueError(f"variant={variant!r} invalid; "
                             f"expected one of {VARIANTS}")
        if self.hg is None:
            raise ValueError(
                "this session wraps a bare snapshot (from_snapshot without "
                "hg=); build it with PageRankSession.from_graph to stream "
                "updates")
        # validate BEFORE the WAL append and before any device scatter: a
        # NaN-weighted, duplicate, out-of-range or ambiguous batch raises
        # here, is never durably logged, and never replays after a restore
        deletions, insertions = validate_edge_batch(deletions, insertions,
                                                    self.n)
        # a scheduled silent corruption lands on live state BEFORE the
        # batch, so this drive's fused invariants (or the next scrub) must
        # be what detects it — the domain's whole point
        if self._corruption_faults is not None and not self._replaying:
            cfault = self._corruption_faults.pop_pending()
            if cfault is not None:
                self._apply_corruption(cfault)
        bidx = self._batch_index + 1
        wal_undo = None
        if self.store is not None and not self._replaying:
            wal_undo = self.store.wal_size()
        try:
            if wal_undo is not None:
                # write-ahead: the batch is durable BEFORE any device
                # scatter, so a crash-stop at any instant restores to
                # either fully-before or (via replay) fully-after this
                # batch.  Inside the try: a failed append (torn frame on
                # ENOSPC) must also roll back, or the broken tail would
                # hide every later acknowledged record from read_wal
                self.store.append_wal(
                    batch_index=bidx, variant=variant,
                    deletions=np.asarray(deletions,
                                         np.int64).reshape(-1, 2),
                    insertions=np.asarray(insertions,
                                          np.int64).reshape(-1, 2))
            if self._sharded:
                res = self._update_sharded(deletions, insertions, variant)
            elif self._walk:
                res = self._update_walk(deletions, insertions, variant)
            elif self._stream:
                res = self._update_stream(deletions, insertions, variant)
            else:
                res = self._update_snapshot(deletions, insertions, variant)
        except BaseException:
            # the batch was REJECTED in-process (it never became session
            # state): revoke its record so a later restore does not replay
            # a batch the live session refused
            if wal_undo is not None:
                self.store.truncate_wal(wal_undo)
            raise
        self._batch_index = bidx
        self._history.append(res)
        if not res.stats.converged:
            warnings.warn(
                f"update batch {bidx} hit the sweep cap "
                f"(max_iterations={self.config.max_iterations}) without "
                f"reaching tau={self.config.tau} — serving the best "
                "iterate; raise max_iterations or loosen tau "
                "(report().sweep_cap_hits counts these)",
                SweepCapWarning, stacklevel=2)
        if (self._process_domain is not None and not self._replaying
                and bidx % self._process_domain.checkpoint_interval == 0):
            self._checkpoint_now()
        # fused detection → repair ladder, inside the same update call (the
        # batch itself was applied; only the iterate needs repairing)
        if self._integrity_alert is not None and not self._replaying:
            icfg = self.config.integrity
            if icfg is not None and icfg.auto_repair:
                self.verify(repair=True, deep=False)
            # else: leave the alert posted; the next verify() handles it
        return res

    def _crossing(self, edges_rel: np.ndarray) -> int:
        """Count edges (in relabeled coordinates) whose endpoints land on
        different shards under the contiguous 1-D layout."""
        if len(edges_rel) == 0:
            return 0
        n_loc = self.runtime.n_loc
        return int((edges_rel[:, 0] // n_loc
                    != edges_rel[:, 1] // n_loc).sum())

    def _sharded_affected(self, variant: str, hg_rel_prev: HostGraph,
                          dels_rel: np.ndarray, ins_rel: np.ndarray
                          ) -> jnp.ndarray:
        """Initial affected marking for one sharded batch, in relabeled
        space.  ``df`` seeds from the host adjacency in O(batch · deg) and
        uploads only the bucketed index list; ``dt`` walks reachability on
        throwaway snapshots (the what-if path, O(m))."""
        if variant == "df":
            sources = np.concatenate([dels_rel[:, 0], ins_rel[:, 0]])
            idx = dist.df_seed_indices(hg_rel_prev, self._hg_rel, sources)
            return self.runtime.mask_from_indices(idx)
        if variant == "dt":
            bs = self.config.block_size
            g_prev = hg_rel_prev.snapshot(block_size=bs)
            g_new = self._hg_rel.snapshot(block_size=bs)
            batch_dev = fr.batch_to_device(g_new, dels_rel, ins_rel)
            aff = np.asarray(fr.dt_affected(g_prev, g_new, batch_dev))
            return self.runtime.mask_from_indices(np.nonzero(
                aff[:self.n])[0])
        return self.valid        # nd / static

    def _update_sharded(self, deletions, insertions, variant: str = "df"
                        ) -> StreamBatchResult:
        """Sharded step: translate the batch into the partitioner-relabeled
        space, route it to its owning shards (O(batch) slab/degree
        scatters), seed the frontier, and re-enter the cached compiled
        sweep.  Ranks never leave the devices."""
        t0 = time.perf_counter()
        cfg = self.config
        cache0 = self.runtime.cache_size()
        dels = np.asarray(deletions, np.int64).reshape(-1, 2)
        ins = np.asarray(insertions, np.int64).reshape(-1, 2)
        dels_rel = (self._inv[dels] if len(dels)
                    else np.zeros((0, 2), np.int64))
        ins_rel = (self._inv[ins] if len(ins)
                   else np.zeros((0, 2), np.int64))
        hg_rel_prev = self._hg_rel
        dels_eff, ins_eff = effective_batch(hg_rel_prev, dels_rel, ins_rel)
        self._hg_prev, self._g_prev = self.hg, None
        self._hg_rel_prev = hg_rel_prev
        self._last_batch = (dels, ins)
        self._last_batch_rel = (dels_rel, ins_rel)
        self._r_prev = self.R
        self.hg = self.hg.apply_batch(dels, ins)
        self._hg_rel = hg_rel_prev.apply_batch(dels_rel, ins_rel)
        self.runtime.apply_batch(dels_eff, ins_eff)
        self._cut_edges += int(self._crossing(ins_eff)
                               - self._crossing(dels_eff))

        affected = self._sharded_affected(variant, hg_rel_prev,
                                          dels_rel, ins_rel)
        if variant == "static":
            R0 = jnp.where(self.valid, 1.0 / self.n, 0).astype(self._dtype)
        else:
            R0 = self.R
        fault = (self._shard_faults.pop_pending()
                 if self._shard_faults is not None else None)
        if fault is None:
            R, dstats = self.runtime.drive(
                R0, affected, expand=(variant == "df"),
                max_sweeps=cfg.max_iterations)
        else:
            R, dstats = self._drive_with_shard_fault(
                R0, affected, expand=(variant == "df"), fault=fault)
        self.R = R
        self._x_full += dstats.full_exchanges
        self._x_delta += dstats.delta_exchanges
        self._x_sweeps += dstats.sweeps
        stats = SweepStats(sweeps=dstats.sweeps, iterations=dstats.sweeps,
                           edges_processed=dstats.edges_processed,
                           converged=dstats.converged)
        cache1 = self.runtime.cache_size()
        retraces = (cache1 - cache0 if cache0 >= 0 and cache1 >= 0 else -1)
        if fault is not None:
            # a consumed shard fault legitimately (re)compiles — on a new
            # mesh after a permanent loss — accounted through
            # report().recovery_events, not the streaming retrace counter
            retraces = 0
        return StreamBatchResult(
            ranks=R, stats=stats,
            wall_time_s=time.perf_counter() - t0,
            batch_edges=len(dels) + len(ins),
            driver_cache_size=cache1,
            driver_retraces=retraces)

    # -- shard fault domain (docs/FAULTS.md) ---------------------------------
    def inject_shard_fault(self, shard: int, *, at_sweep: int = 1,
                           permanent: bool = True) -> None:
        """Schedule one shard failure, consumed by the next :meth:`update`:
        the drive runs normally for ``at_sweep`` sweeps, then shard
        ``shard`` crash-stops (``permanent=True``, the mesh shrinks around
        it) or stalls and later rejoins (``permanent=False``).  Recovery —
        the paper's helping mechanism generalized to shards — happens
        inside the same update call; :meth:`report` records it."""
        self._ensure_open()
        if not self._sharded:
            raise ValueError(
                "shard faults require topology='sharded' (single-device "
                "sessions take a thread-domain FaultPlan instead)")
        # validate HERE, not mid-update: a fault consumed after the batch
        # has already mutated graph state must never be the thing that
        # raises (the update would be half-applied)
        if not (0 <= int(shard) < self.runtime.n_dev):
            raise ValueError(f"shard {shard} out of range (mesh has "
                             f"{self.runtime.n_dev} shards)")
        self._shard_faults.inject(shard, at_sweep=at_sweep,
                                  permanent=permanent)

    # -- corruption fault domain (core/integrity.py, docs/FAULTS.md) ---------
    def _graph_digest(self) -> int:
        """CRC32 of the host edge set — the host-truth identity the deep
        scrub's ``graph_digest`` check compares against."""
        return zlib.crc32(
            np.ascontiguousarray(self.hg.edges).tobytes()) & 0xFFFFFFFF

    def _integrity_cfg(self) -> ig.IntegrityConfig:
        icfg = self.config.integrity
        return icfg if icfg is not None else ig.IntegrityConfig()

    def _integrity_check(self, icfg: ig.IntegrityConfig, *, deep: bool
                         ) -> Tuple[List[dict], int, float, float]:
        """One detection pass, NO repair: ``(failures, checks_run,
        mass_error, drift)``.  Rank invariants always run; stream mode adds
        the mirror digests, the tile-pool sum check and the slot-table
        structural check; ``deep`` adds the host-graph digest."""
        failures: List[dict] = []
        checks = 0
        ref = self._r_verified if self._r_verified is not None else self.R
        inv = np.asarray(ig.invariant_vec(self.R, ref, self.valid))
        mass_err, neg, nonfinite, drift = (float(x) for x in inv)
        checks += 4
        if nonfinite > 0:
            failures.append({"check": "rank_finite",
                             "count": int(nonfinite)})
        if neg > 0:
            failures.append({"check": "rank_negativity", "count": int(neg)})
        # a sweep-capped iterate legitimately carries residual mass ≤ n·tau,
        # so the mass gate applies to converged iterates only
        converged = (not self._history
                     or bool(self._history[-1].stats.converged))
        if converged and mass_err > icfg.mass_tol:
            failures.append({"check": "rank_mass", "mass_error": mass_err})
        # between drives the ranks are bit-identical to the last verified
        # iterate (queries never write), so ANY drift is corruption
        if drift > icfg.drift_tol:
            failures.append({"check": "rank_drift", "drift": drift})
        if self._stream:
            aux = self.inc.aux
            mirrors = (("out_deg", self._out_deg, self._out_deg_host),
                       ("rb_in", self._rb_in, aux.rb_in),
                       ("rb_out", self._rb_out, aux.rb_out),
                       ("bmat", self._bmat, aux.bmat))
            for name, dev, host in mirrors:
                checks += 1
                bad = ig.compare_digests(
                    dev, host, chunk_bytes=icfg.scrub_chunk_bytes)
                if bad:
                    failures.append({"check": "mirror_digest",
                                     "mirror": name, "chunks": bad[:8]})
            # aggregate tile-pool checksum: every stored pull-matrix entry
            # is 1.0 (one per in-edge incl. self-loop), so the live tiles
            # of row-block i must sum to exactly rb_in[i]; 0.25 tolerates
            # nothing but float noise on integer counts
            checks += 1
            # tiered: host truth is the twin everything checks against —
            # the pool's live tiles carry the sums, its slot tables the
            # structure, and the slab scrub CRCs every resident device
            # tile against its host original
            sums = (self.pool.row_sums() if self._tiered
                    else ig.tile_row_sums(self.inc.mat))
            bad_rb = np.nonzero(np.abs(sums - aux.rb_in) > 0.25)[0]
            if len(bad_rb):
                failures.append({"check": "tile_sums",
                                 "row_blocks": bad_rb[:8].tolist()})
            checks += 1
            if self._tiered:
                failures.extend(ig.check_slot_tables(
                    self.pool.tile_cols, self.pool.mat.tile_idx,
                    aux.bmat, int(self.pool.mat.tiles.shape[0])))
                checks += 1
                failures.extend(self.hot.scrub(
                    np.asarray(self.inc.mat.tiles)))
            else:
                failures.extend(ig.check_slot_tables(
                    np.asarray(self.inc.mat.tile_cols),
                    np.asarray(self.inc.mat.tile_idx),
                    aux.bmat, int(self.inc.mat.tiles.shape[0])))
            if deep and self._hg_digest is not None:
                checks += 1
                if self._graph_digest() != self._hg_digest:
                    failures.append({"check": "graph_digest"})
        return failures, checks, mass_err, drift

    def verify(self, *, repair: Optional[bool] = None,
               deep: bool = True) -> ig.IntegrityReport:
        """Run the corruption-domain integrity checks on the live state
        and (by default, per ``IntegrityConfig.auto_repair``) climb the
        repair ladder on any failure.

        Checks: the rank invariants (mass conservation, non-negativity,
        finiteness, exact inter-drive drift vs the last verified iterate),
        and in stream mode the chunked digests of the operand mirrors
        against their host-truth twins, the tile-pool sum check and the
        slot-table structural check; ``deep=True`` adds the host-graph
        digest.  The ladder (``"frontier"`` → ``"rebuild"`` →
        ``"restore"``) re-marks corrupted rows into the DF frontier and
        helps them to convergence, rebuilds the device operands from host
        truth, or restores from the durable checkpoint+WAL store — each
        rung re-verifies and escalates on failure, emitting a
        ``RecoveryRecord(domain="corruption")`` visible in
        :meth:`report`.  This is also what the
        :class:`~repro.api.PageRankService` background scrubber calls on
        idle slots."""
        self._ensure_open()
        t0 = time.perf_counter()
        icfg = self._integrity_cfg()
        if repair is None:
            repair = icfg.auto_repair
        alert, self._integrity_alert = self._integrity_alert, None
        failures, checks, mass_err, drift = self._integrity_check(
            icfg, deep=deep)
        self._integrity_checks += checks
        if alert is not None and not any(f["check"] == alert["check"]
                                         for f in failures):
            # the fused drive flagged it even if the state has since moved
            failures = [dict(alert, fused=True)] + failures
        repairs: List[str] = []
        ok = not failures
        if failures:
            self._corruption_detected += 1
            if repair:
                ok, repairs, mass_err, drift = self._repair_corruption(
                    failures, icfg, deep=deep)
        if ok:
            self._r_verified = self.R
            # a repair rung's own drive may have re-posted a fused alert
            # against the pre-repair baseline; the clean re-check above
            # supersedes it
            self._integrity_alert = None
        return ig.IntegrityReport(
            ok=ok, checks_run=checks, failures=failures, repairs=repairs,
            mass_error=mass_err, drift=drift,
            wall_time_s=time.perf_counter() - t0)

    def _repair_corruption(self, failures: List[dict],
                           icfg: ig.IntegrityConfig, *, deep: bool
                           ) -> Tuple[bool, List[str], float, float]:
        """Climb the repair ladder from the cheapest rung the failure set
        allows, re-verifying after each rung and escalating while damage
        remains.  Returns ``(ok, rungs_applied, mass_error, drift)``."""
        checks = {f["check"] for f in failures}
        if "graph_digest" in checks:
            start = "restore"       # the host truth itself is damaged
        elif checks & {"mirror_digest", "tile_sums", "slot_tables",
                       "hot_slab"}:
            start = "rebuild"
        else:
            start = "frontier"
        detected = failures[0]["check"]
        repairs: List[str] = []
        mass_err = drift = float("nan")
        for rung in ig.REPAIR_RUNGS[ig.REPAIR_RUNGS.index(start):]:
            t0 = time.perf_counter()
            applied = self._apply_repair_rung(rung, icfg)
            if applied is None:     # rung unavailable (e.g. no store)
                continue
            desc, reconverged = applied
            left, checks_run, mass_err, drift = self._integrity_check(
                icfg, deep=deep or rung == "restore")
            self._integrity_checks += checks_run
            self._recoveries.append(fd.RecoveryRecord(
                domain="corruption", batch_index=self._batch_index,
                wall_time_s=time.perf_counter() - t0, rung=rung,
                check=detected, description=desc))
            repairs.append(rung)
            # a sweep-capped repair drive is NOT a repair even when the
            # checks pass (the mass gate is suspended on capped iterates):
            # escalate until a rung actually reconverges
            if not left and reconverged:
                return True, repairs, mass_err, drift
        return False, repairs, mass_err, drift

    def _apply_repair_rung(self, rung: str, icfg: ig.IntegrityConfig
                           ) -> Optional[Tuple[str, bool]]:
        """Execute one ladder rung; returns ``(description, reconverged)``
        or ``None`` when the rung does not apply to this session (skipped,
        not failed)."""
        if rung == "frontier":
            # the paper's helping mechanism aimed at corruption instead of
            # crashes: corrupted rows are reset to the last verified
            # iterate, re-marked affected, and the DF expansion propagates
            # any correction outward
            ref = (self._r_verified if self._r_verified is not None else
                   jnp.where(self.valid, 1.0 / self.n,
                             0.0).astype(self._dtype))
            bad = self.valid & (~jnp.isfinite(self.R) | (self.R < 0)
                                | (jnp.abs(self.R - ref) > icfg.drift_tol))
            n_bad = int(jnp.sum(bad))
            if n_bad:
                R0, affected = jnp.where(bad, ref, self.R), bad
            else:
                # aggregate-only symptom (mass off, nothing localizable):
                # fall back to the verified iterate wholesale
                R0 = jnp.where(self.valid, ref, jnp.zeros_like(ref))
                affected = self.valid
            if self._stream:
                R, st = self._drive_refill(R0, affected, expand=True)
                self.R, reconverged = R, bool(st.converged)
            else:
                self._converge(R0, affected, expand=True)
                reconverged = True
            return (f"{n_bad} corrupted rank(s) re-marked into the DF "
                    "frontier and helped back to convergence", reconverged)
        if rung == "rebuild":
            if not self._stream:
                return None         # nothing mirrored to rebuild
            g = self.hg.snapshot(block_size=self.block_size)
            if self._tiered:
                # both tiers rebuild from the host edge set: fresh pool,
                # fresh (empty) hot set — the re-converge below re-admits
                src, dst = g.in_edges_host()
                self.pool = tiering.HostTilePool.from_edges(
                    dst, src, g.n_pad, g.n_pad, block=self.block_size,
                    dtype=np.dtype(self._dtype))
                self.hot = tiering.HotSetManager(
                    self.pool, self.config.device_budget_bytes)
                aux = MatrixAux(
                    bmat=tiering.host_block_adjacency(
                        self.pool.tile_cols, self.pool.mat.n_cb),
                    rb_in=np.asarray(g.block_in_edges()).copy(),
                    rb_out=np.asarray(g.block_out_edges()).copy())
                self.inc = IncrementalPullMatrix(self.hot.view(), aux)
            else:
                self.inc = IncrementalPullMatrix.from_snapshot(
                    g, dtype=np.dtype(self._dtype), padded=True)
            self._out_deg = jnp.asarray(g.out_deg)
            self._out_deg_host = np.asarray(g.out_deg).copy()
            self._rb_in = jnp.asarray(self.inc.aux.rb_in)
            self._rb_out = jnp.asarray(self.inc.aux.rb_out)
            self._bmat = jnp.asarray(self.inc.aux.bmat)
            self._scatter_fault = None
            # a rebuilt pool restarts the capacity ladder at its own
            # bucket; compiles it causes are recovery cost, not retraces
            cap = int(self.inc.mat.tiles.shape[0])
            mt = int(self.inc.mat.tile_cols.shape[1])
            self._driver_keys.update({(cap, mt, False), (cap, mt, True)})
            # cold uniform restart, NOT a warm start: both the current
            # iterate and the drift baseline may have converged (or sweep-
            # capped) against the torn operands, and a structured-garbage
            # warm start can need more sweeps than the cap — the cold
            # start's sweep count depends only on alpha/tau.  expand=True
            # so frontier expansion sweeps corrections through chunks that
            # look locally converged.
            R0 = jnp.where(self.valid, 1.0 / self.n, 0.0).astype(self._dtype)
            R, st = self._drive_refill(
                R0, self.valid, expand=True,
                want_rb=np.arange(self.n_rb) if self._tiered else None)
            self.R = R
            return ("operand mirrors + tile pool rebuilt from host truth; "
                    "full re-converge from the verified iterate",
                    bool(st.converged))
        if rung == "restore":
            if self.store is None:
                return None         # no durable store to fall back to
            svc, history = self._service, self._history
            warm, queries = self._warm_idx, self._queries
            recov = self._recoveries
            counters = (self._integrity_checks, self._corruption_detected)
            keys, store_dir = self._driver_keys, self.store.dir
            fresh = type(self).restore(store_dir, interpret=self.interpret)
            replayed = sum(r.replayed_batches for r in fresh._recoveries)
            # adopt the restored state in place, keeping this session's
            # identity (service registration, history, counters)
            self.__dict__.update(fresh.__dict__)
            self._service = svc
            self._history = history
            self._warm_idx = warm
            self._queries = queries
            self._recoveries = recov + fresh._recoveries
            self._integrity_checks, self._corruption_detected = counters
            self._driver_keys = keys | fresh._driver_keys
            return (f"checkpoint+WAL restore from {store_dir!r} "
                    f"({replayed} batch(es) replayed)", True)
        raise ValueError(f"unknown repair rung {rung!r}")

    def inject_corruption(self, kind: Union[str, "fd.CorruptionFault"], *,
                          index: Optional[int] = None, seed: int = 0,
                          defer: bool = False) -> "fd.CorruptionFault":
        """Silently corrupt live session state (chaos harness / tests —
        see ``fd.CORRUPTION_KINDS``).  Nothing is raised and nothing is
        recorded: detection is the integrity subsystem's job (the fused
        per-drive invariants, a scrub, or an explicit :meth:`verify`).
        ``defer=True`` queues the fault on the session's corruption domain
        instead, to be consumed by the NEXT :meth:`update` right before
        the batch applies."""
        self._ensure_open()
        if isinstance(kind, fd.CorruptionFault):
            fault = kind
        else:
            fault = fd.CorruptionFault(kind=str(kind), index=index,
                                       seed=int(seed))
        if defer:
            if self._corruption_faults is None:
                self._corruption_faults = fd.CorruptionFaultDomain()
            self._corruption_faults.inject(fault.kind, index=fault.index,
                                           seed=fault.seed)
        else:
            self._apply_corruption(fault)
        return fault

    def _apply_corruption(self, fault: "fd.CorruptionFault") -> None:
        kind = fault.kind
        rng = np.random.default_rng(fault.seed)
        if kind in ("scatter_drop", "scatter_dup"):
            # consumed by the next _update_stream: the device operand
            # scatter is dropped / double-applied while the host twins
            # record the truth — a torn scatter
            self._scatter_fault = kind
            return
        if kind == "rank":
            i = (int(fault.index) if fault.index is not None
                 else int(rng.integers(self.n)))
            bit = ig.exponent_bit(self._dtype, rng)
            val = np.asarray(self.R[i], self._dtype)
            self.R = self.R.at[i].set(ig.flipped_float(val, bit))
            return
        if not self._stream:
            raise ValueError(
                f"corruption kind {kind!r} instruments stream-mode state "
                "(tile pool / slot tables / operand mirrors); only 'rank' "
                "and the scatter kinds apply elsewhere")
        if kind == "graph":
            keys = self.hg._keys      # hg.edges is DERIVED from the key set
            if len(keys) == 0:
                raise ValueError("graph corruption needs at least one edge")
            i = (int(fault.index) if fault.index is not None
                 else int(rng.integers(len(keys))))
            keys[i] ^= 1              # in-place host-truth bit flip (dst±1)
            return
        if kind == "mirror":
            rb = (int(fault.index) if fault.index is not None
                  else int(rng.integers(self._rb_in.shape[0])))
            self._rb_in = self._rb_in.at[rb].add(
                jnp.asarray(3, self._rb_in.dtype))
            return
        mat = self.inc.mat
        tc = (self.pool.tile_cols.copy() if self._tiered
              else np.asarray(mat.tile_cols))
        occ = np.argwhere(tc >= 0)
        if kind == "slot":
            r, c = (occ[int(fault.index) % len(occ)]
                    if fault.index is not None
                    else occ[int(rng.integers(len(occ)))])
            n_cb = int(self.inc.aux.bmat.shape[1])
            if self._tiered:
                # the slot tables' truth is the HOST tier — corrupt it
                # there (the structural check scrubs host tables)
                self.pool.mat.tile_cols[int(r), int(c)] = np.int32(n_cb + 5)
            else:
                self.inc.mat = dataclasses.replace(
                    mat, tile_cols=mat.tile_cols.at[int(r), int(c)].set(
                        np.int32(n_cb + 5)))
            return
        # kind == "tile": flip an exponent bit of a LIVE (1.0) entry so the
        # perturbation clears the sum check's 0.25 count tolerance
        if self._tiered:
            # corrupt the DEVICE slab copy of a resident tile; host truth
            # stays clean — exactly the divergence hot.scrub() CRCs for
            tid_tbl = self.pool.tile_idx2d
            for rb in rng.permutation(sorted(self.hot._rb_slots)):
                rb = int(rb)
                slots = self.hot._rb_slots[rb]
                tids = tid_tbl[rb][self.pool.tile_cols[rb] >= 0]
                for tid, slot in zip(tids.tolist(), slots):
                    t = self.pool.mat.tiles[tid]
                    nz = np.argwhere(t != 0)
                    if len(nz):
                        bi, bj = (int(x) for x in
                                  nz[int(rng.integers(len(nz)))])
                        bit = ig.exponent_bit(t.dtype, rng)
                        new = ig.flipped_float(
                            np.asarray(t[bi, bj], t.dtype), bit)
                        self.inc.mat = dataclasses.replace(
                            mat, tiles=mat.tiles.at[slot, bi, bj].set(new))
                        self.hot.adopt_view(self.inc.mat)
                        return
            raise ValueError("no resident live tile entry to corrupt")
        tid_tbl = np.asarray(mat.tile_idx).reshape(tc.shape)
        for oi in rng.permutation(len(occ)):
            r, c = occ[oi]
            tid = int(tid_tbl[r, c])
            t = np.asarray(mat.tiles[tid])
            nz = np.argwhere(t != 0)
            if len(nz):
                bi, bj = (int(x) for x in nz[int(rng.integers(len(nz)))])
                bit = ig.exponent_bit(t.dtype, rng)
                new = ig.flipped_float(np.asarray(t[bi, bj], t.dtype), bit)
                self.inc.mat = dataclasses.replace(
                    mat, tiles=mat.tiles.at[tid, bi, bj].set(new))
                return
        raise ValueError("no live tile entry to corrupt")

    def _drive_with_shard_fault(self, R0, affected, *, expand: bool,
                                fault: "fd.ShardFault"
                                ) -> Tuple[jnp.ndarray, dist.DistStats]:
        """One sharded drive interrupted by a shard failure at
        ``fault.at_sweep`` sweeps, then recovered by **shard helping**:

        1. suspend the drive at the crash point, keeping the per-vertex
           (affected, still-unconverged) state;
        2. the dead shard's un-converged row-blocks — identified through
           the runtime's slot tables / ownership ranges — are re-marked as
           affected-and-unconverged (their last writes may be torn);
        3. permanent loss: elastically re-partition onto the surviving
           shards (:meth:`~repro.core.distributed.DistRuntime.shrink`),
           which re-homes every row-block the dead shard owned;
        4. resume the drive from the mid-crash ranks — the surviving
           shards pick up the re-marked rows and the DF expansion
           propagates their corrections, exactly the paper's recovery
           argument one level up."""
        cfg = self.config
        rt = self.runtime
        # a consumed fault must NEVER raise: the batch is already applied
        # to graph state when the drive runs.  A fault made stale by an
        # earlier shrink (its shard no longer exists) is dropped; a
        # permanent loss of the only remaining shard cannot re-partition
        # and degrades to a transient stall
        if not (0 <= fault.shard < rt.n_dev):
            return rt.drive(R0, affected, expand=expand,
                            max_sweeps=cfg.max_iterations)
        if fault.permanent and rt.n_dev == 1:
            fault = dataclasses.replace(fault, permanent=False)
        phase1 = max(1, min(int(fault.at_sweep), cfg.max_iterations))
        R_mid, st1, (aff_mid, rc_mid) = rt.drive(
            R0, affected, expand=expand, max_sweeps=phase1,
            collect_state=True)
        if st1.converged:           # crash scheduled past convergence
            return R_mid, st1
        t0 = time.perf_counter()
        n = self.n
        aff_h = np.asarray(aff_mid)[:n]
        rc_h = np.asarray(rc_mid)[:n]
        R_h = np.asarray(R_mid)
        lo, hi = rt.owned_range(fault.shard)
        dead_rows = np.zeros(n, bool)
        dead_rows[lo:min(hi, n)] = True
        # rows the survivors must help: everything still unconverged plus
        # every affected row the dead shard owned (its last sweep's writes
        # cannot be trusted)
        help_mask = rc_h | (dead_rows & aff_h)
        helped = int((help_mask & dead_rows).sum())
        if fault.permanent:
            rt2 = rt.shrink(fault.shard)
            self.runtime = rt2
            self._mesh = rt2.mesh
            self._shard_spec = dataclasses.replace(
                self._shard_spec, n_shards=rt2.n_dev)
            self.n_pad = rt2.n_pad
            self.valid = rt2.valid
            # ownership boundaries moved: recount the realized edge cut
            self._cut_edges = int(self._crossing(self._hg_rel.edges))
        else:
            rt2 = rt
        r2 = np.zeros(rt2.n_pad, R_h.dtype)
        r2[:n] = R_h[:n]
        aff2 = rt2.mask_from_indices(np.nonzero(aff_h | help_mask)[0])
        rc2 = rt2.mask_from_indices(np.nonzero(help_mask)[0])
        R, st2 = rt2.drive(jnp.asarray(r2), aff2, expand=True, rc0=rc2,
                           max_sweeps=cfg.max_iterations)
        wall = time.perf_counter() - t0
        self._recoveries.append(fd.RecoveryRecord(
            domain="shard", batch_index=self._batch_index + 1,
            wall_time_s=wall, shard=fault.shard, permanent=fault.permanent,
            helped_vertices=helped, recovery_sweeps=st2.sweeps,
            description=(
                f"shard {fault.shard} "
                f"{'lost — elastic re-partition to' if fault.permanent else 'stalled — rejoined,'} "
                f"{rt2.n_dev} shards; {helped} un-converged rows helped")))
        stats = dist.DistStats(
            sweeps=st1.sweeps + st2.sweeps, converged=st2.converged,
            full_exchanges=st1.full_exchanges + st2.full_exchanges,
            delta_exchanges=st1.delta_exchanges + st2.delta_exchanges,
            edges_processed=st1.edges_processed + st2.edges_processed)
        return R, stats

    def _update_stream(self, deletions, insertions, variant: str = "df"
                       ) -> StreamBatchResult:
        """Stream-mode step: delta scatter → frontier seed → fused
        convergence loop, all device-side after the O(batch) host
        bookkeeping."""
        global _NEW_BUCKET_STARTED, _NEW_BUCKET_ACTIVE
        if self._push and variant == "dt":
            raise ValueError(
                "driver='push' does not implement the dt reachability "
                "marking (it walks throwaway snapshots of the pull "
                "iterate); use variant='df' or 'nd', or a driver='pull' "
                "session")
        t0 = time.perf_counter()
        cache0 = self._drv_cache_size()
        with _RETRACE_LOCK:     # open the attribution window with cache0
            nb_started0 = _NEW_BUCKET_STARTED
            nb_active0 = _NEW_BUCKET_ACTIVE
        g_prev_snap = (self.hg.snapshot(block_size=self.block_size)
                       if variant == "dt" else None)
        dels_eff, ins_eff = effective_batch(self.hg, deletions, insertions)
        rows, cols, vals = signed_edge_delta(dels_eff, ins_eff)
        if self._tiered:
            # host tier first: patch host truth, drop residency of the
            # touched blocks (their slab copies are stale — the admission
            # below re-gathers them fresh), update the host aux twins.
            # mat_prev/mat_new stay None: tiered seeding is host-side.
            plan = self.pool.apply_delta(rows, cols, vals)
            self.inc.aux.apply_delta(self.block_size, rows, cols, vals)
            self.hot.invalidate(
                plan.touched_rb,
                structure_changed=(plan.tile_cols is not None
                                   or plan.n_new > plan.n_old))
            mat_prev = mat_new = None
        else:
            mat_prev = self.inc.mat
            mat_new = self.inc.advance(self.hg, None, deletions, insertions,
                                       effective=(dels_eff, ins_eff))
        self._hg_prev, self._g_prev = self.hg, None
        self._last_batch = (np.asarray(deletions, np.int64).reshape(-1, 2),
                            np.asarray(insertions, np.int64).reshape(-1, 2))
        self._r_prev = self.R
        self.hg = self.hg.apply_batch(deletions, insertions)
        if self.config.integrity is not None:
            # the host-truth digest tracks every legitimate rebinding of
            # the host graph; anything mutating hg.edges WITHOUT passing
            # here is what the deep scrub's graph_digest check catches
            self._hg_digest = self._graph_digest()

        # push seeding divides by the PRE-batch degrees: capture the host
        # twin before the mirror patch below rebinds it
        deg_old_host = self._out_deg_host if self._push else None
        # patch the device-resident operand mirrors in O(batch): only the
        # bucketed signed delta crosses host→device, never the graph-sized
        # vectors
        scatter_fault, self._scatter_fault = self._scatter_fault, None
        if len(rows):
            b_pad = ops.capacity_bucket(len(rows), ops.DELTA_BATCH_BUCKET)
            z = np.zeros(b_pad - len(rows), np.int32)
            dev_args = (jnp.asarray(np.concatenate(
                            [rows.astype(np.int32), z])),
                        jnp.asarray(np.concatenate(
                            [cols.astype(np.int32), z])),
                        jnp.asarray(np.concatenate(
                            [vals.astype(np.int32), z])))
            # a pending torn-scatter corruption (scatter_drop/scatter_dup)
            # silently skips or double-applies the DEVICE patch only — the
            # host twins below stay truth, which is exactly how the scrub's
            # mirror digests detect the tear
            reps = {"scatter_drop": 0, "scatter_dup": 2}.get(scatter_fault, 1)
            for _ in range(reps):
                self._out_deg, self._rb_in, self._rb_out, self._bmat = \
                    _apply_operand_delta(
                        self._out_deg, self._rb_in, self._rb_out,
                        self._bmat, *dev_args, block=self.block_size)
            self._out_deg_host = self._out_deg_host + np.bincount(
                cols, weights=vals, minlength=self.n_pad
            ).astype(self._out_deg_host.dtype)

        batch_dev = fr.pack_batch(self.n_pad, deletions, insertions)
        seed_idx = None
        pextras = None
        if self._push:
            # residual seeding replaces the frontier marking: the residual
            # IS the frontier (work ∝ its mass).  seed_idx feeds the same
            # tiered admission want-set as the pull df seed.
            R0, seed_idx = self._seed_push(variant, dels_eff, ins_eff,
                                           deg_old_host)
            affected, expand = None, True
        elif variant == "df":
            if self._tiered:
                # host-side DF seed (paper Alg. 1 lines 4-6) through the
                # sorted host key sets — needs no device pull matrices, and
                # only the bucketed index list crosses to the device
                dels_a = np.asarray(deletions, np.int64).reshape(-1, 2)
                ins_a = np.asarray(insertions, np.int64).reshape(-1, 2)
                sources = np.concatenate([dels_a[:, 0], ins_a[:, 0]])
                seed_idx = dist.df_seed_indices(self._hg_prev, self.hg,
                                                sources)
                affected = self._mask_from_indices(seed_idx)
            else:
                affected = _seed_affected(
                    mat_prev, mat_new, self._bmat, batch_dev, self.valid,
                    block_size=self.block_size, interpret=self.interpret,
                    backend=self.backend)
            R0, expand = self.R, True
        elif variant == "dt":
            g_new_snap = self.hg.snapshot(block_size=self.block_size)
            affected = fr.dt_affected(g_prev_snap, g_new_snap, batch_dev)
            R0, expand = self.R, False
        elif variant == "nd":
            affected, R0, expand = self.valid, self.R, False
        else:   # static
            affected = self.valid
            R0 = jnp.where(self.valid, 1.0 / self.n, 0).astype(self._dtype)
            expand = False
        if self._tiered:
            # tiered drives always expand: the refill loop is block-Jacobi
            # over residency partitions, and only frontier expansion
            # re-marks a resident block whose non-resident inputs moved in
            # a later round (docs/SCALE.md §Miss semantics)
            expand = True
            # frontier-biased admission BEFORE the drive: delta-touched
            # blocks ∪ seed blocks ∪ their tile-adjacent candidates (the
            # first expansion wave) — ONE batched gather per step
            want = [np.asarray(plan.touched_rb, np.int64)]
            if seed_idx is not None and len(seed_idx):
                srb = np.unique(np.asarray(seed_idx, np.int64)
                                // self.block_size)
                want += [srb, np.nonzero(
                    self.inc.aux.bmat[:, srb].any(axis=1))[0]]
            self._admit(np.concatenate(want))
            key_mat = self.inc.mat
        else:
            key_mat = mat_new

        # first visit to an operand bucket (tile capacity × slot width ×
        # expand flag) legitimately compiles once — the doubling ladder's
        # documented cost.  Record the visit BEFORE driving so the growth
        # observed below can be attributed to it.
        dkey = (int(key_mat.tiles.shape[0]),
                int(key_mat.tile_cols.shape[1]),
                "push" if self._push else bool(expand))
        new_bucket = dkey not in self._driver_keys
        self._driver_keys.add(dkey)

        if new_bucket:
            with _RETRACE_LOCK:
                _NEW_BUCKET_STARTED += 1
                _NEW_BUCKET_ACTIVE += 1
        try:
            if self._push:
                R, stats, pextras = self._drive_push_refill(R0)
            else:
                R, stats = self._drive_refill(R0, affected, expand=expand)
        finally:
            if new_bucket:
                with _RETRACE_LOCK:
                    _NEW_BUCKET_ACTIVE -= 1
        self.R = R
        raw = (np.asarray(deletions).reshape(-1, 2).shape[0]
               + np.asarray(insertions).reshape(-1, 2).shape[0])
        cache1 = self._drv_cache_size()
        with _RETRACE_LOCK:
            nb_started1 = _NEW_BUCKET_STARTED
        retraces = (cache1 - cache0
                    if cache0 >= 0 and cache1 >= 0 else -1)
        # first-visit drives overlapping this window: ones already active
        # at cache0 plus ones begun since — any of their compiles may land
        # in this window's cache delta (shared process-wide jit cache)
        overlapping = nb_active0 + (nb_started1 - nb_started0)
        bucket = 0
        if retraces > 0 and (new_bucket or overlapping > 0):
            bucket, retraces = retraces, 0
        return StreamBatchResult(
            ranks=R, stats=stats,
            wall_time_s=time.perf_counter() - t0, batch_edges=raw,
            driver_cache_size=cache1,
            driver_retraces=retraces, bucket_retraces=bucket,
            residual_mass=(pextras["residual_l1"]
                           if pextras is not None else None),
            pushed_blocks=(pextras["pushed_blocks"]
                           if pextras is not None else None))

    def _update_walk(self, deletions, insertions, variant: str = "df"
                     ) -> StreamBatchResult:
        """Walk-mode step: patch the adjacency slabs and regenerate ONLY
        the walk segments passing through touched vertices (O(batch ·
        walks-per-touched-vertex), never O(n·R)).  The ``variant`` is
        accepted for surface parity but does not change the marking — walk
        invalidation IS the frontier."""
        t0 = time.perf_counter()
        cache0 = we.cache_size()
        dels_eff, ins_eff = effective_batch(self.hg, deletions, insertions)
        self._hg_prev, self._g_prev = self.hg, None
        self._last_batch = (np.asarray(deletions, np.int64).reshape(-1, 2),
                            np.asarray(insertions, np.int64).reshape(-1, 2))
        self._r_prev = self.R
        self.hg = self.hg.apply_batch(deletions, insertions)
        wstats = self.walks.apply_batch(dels_eff, ins_eff)
        self.R = self.walks.pagerank()
        self._r_verified = self.R
        raw = (np.asarray(deletions).reshape(-1, 2).shape[0]
               + np.asarray(insertions).reshape(-1, 2).shape[0])
        cache1 = we.cache_size()
        retraces = (cache1 - cache0
                    if cache0 >= 0 and cache1 >= 0 else -1)
        bucket = 0
        if retraces > 0 and wstats.new_bucket:
            bucket, retraces = retraces, 0
        stats = SweepStats(
            sweeps=1, iterations=1, blocks_processed=0,
            edges_processed=wstats.steps, sim_time_ms=0.0,
            converged=True, dnf=False)
        return StreamBatchResult(
            ranks=self.R, stats=stats,
            wall_time_s=time.perf_counter() - t0, batch_edges=raw,
            driver_cache_size=cache1,
            driver_retraces=retraces, bucket_retraces=bucket,
            regenerated_walks=wstats.regenerated_walks,
            touched_walks=wstats.touched_walk_mass,
            total_walks=wstats.total_walks)

    def _update_snapshot(self, deletions, insertions, variant: str
                         ) -> StreamBatchResult:
        """Snapshot-mode step: rebuild the snapshot (O(m) host work — the
        legacy path, kept for the oracle engines) and converge through the
        engine adapter."""
        t0 = time.perf_counter()
        cache0 = _driver_cache_size() if self.engine_name == "pallas" else -1
        g_prev = self.g
        hg_new = self.hg.apply_batch(deletions, insertions)
        g_new = hg_new.snapshot(block_size=self.block_size)
        batch_dev = fr.batch_to_device(g_new, deletions, insertions)
        if variant == "df":
            affected = fr.initial_affected(g_prev, g_new, batch_dev)
            R0, expand = pad_ranks(g_new, self.R), True
        elif variant == "dt":
            affected = fr.dt_affected(g_prev, g_new, batch_dev)
            R0, expand = pad_ranks(g_new, self.R), False
        elif variant == "nd":
            affected, expand = g_new.vertex_valid, False
            R0 = pad_ranks(g_new, self.R)
        else:   # static
            affected, expand = g_new.vertex_valid, False
            R0 = initial_ranks(g_new, self._dtype)
        self._hg_prev, self._g_prev = self.hg, g_prev
        self._last_batch = (np.asarray(deletions, np.int64).reshape(-1, 2),
                            np.asarray(insertions, np.int64).reshape(-1, 2))
        self._r_prev = self.R
        self.hg, self.g = hg_new, g_new
        self.n, self.n_pad = g_new.n, g_new.n_pad
        self.valid = g_new.vertex_valid
        res = self._converge(R0, affected, expand=expand, g=g_new)
        raw = (np.asarray(deletions).reshape(-1, 2).shape[0]
               + np.asarray(insertions).reshape(-1, 2).shape[0])
        cache1 = _driver_cache_size() if self.engine_name == "pallas" else -1
        return StreamBatchResult(
            ranks=res.ranks, stats=res.stats,
            wall_time_s=time.perf_counter() - t0, batch_edges=raw,
            driver_cache_size=cache1,
            driver_retraces=(cache1 - cache0
                             if cache0 >= 0 and cache1 >= 0 else -1))

    # -- recompute -----------------------------------------------------------
    def recompute(self, variant: str = "static") -> PagerankResult:
        """Re-solve the session's **current** graph.

        ``"static"`` starts from uniform ranks, ``"nd"`` warm-starts from
        the session's ranks (both with every vertex affected).  ``"dt"`` /
        ``"df"`` *replay the last update batch* with that variant's marking
        from the pre-batch ranks — the what-if tool for comparing variants
        on the same step (requires at least one prior ``update``)."""
        self._ensure_open()
        if variant not in VARIANTS:
            raise ValueError(f"variant={variant!r} invalid; "
                             f"expected one of {VARIANTS}")
        res = self._recompute(variant)
        if self._process_domain is not None and not self._replaying:
            # recompute changes served state OUTSIDE the WAL's batch
            # stream — persist a checkpoint so restore() matches what the
            # live session was serving
            self._checkpoint_now()
        return res

    def _recompute(self, variant: str) -> PagerankResult:
        if self._sharded:
            return self._recompute_sharded(variant)
        if self._walk:
            return self._recompute_walk(variant)
        if self._push:
            return self._recompute_push(variant)
        if variant in ("static", "nd"):
            R0 = (self.R if variant == "nd" else
                  jnp.where(self.valid, 1.0 / self.n, 0).astype(self._dtype))
            if self._stream:
                t0 = time.perf_counter()
                R, stats = self._drive_refill(
                    R0, self.valid, expand=self._tiered,
                    want_rb=(np.arange(self.n_rb) if self._tiered
                             else None))
                self.R = R
                return PagerankResult(ranks=R, stats=stats,
                                      wall_time_s=time.perf_counter() - t0)
            return self._converge(R0, self.valid, expand=False)

        # dt / df: replay the last batch's marking from the pre-batch state
        if self._last_batch is None:
            raise ValueError(
                f"recompute({variant!r}) replays the last update batch, but "
                "no batch has been applied yet — call update() first or use "
                "variant='static'/'nd'")
        g_prev = (self._g_prev if self._g_prev is not None
                  else self._hg_prev.snapshot(block_size=self.block_size))
        g_cur = (self.g if self.g is not None
                 else self.hg.snapshot(block_size=self.block_size))
        batch_dev = fr.batch_to_device(g_cur, *self._last_batch)
        if variant == "df":
            affected = fr.initial_affected(g_prev, g_cur, batch_dev)
        else:
            affected = fr.dt_affected(g_prev, g_cur, batch_dev)
        R0 = pad_ranks(g_cur, self._r_prev)
        mat = aux = None
        if self._stream and not self._tiered:
            # reuse the incrementally maintained operands; tiered sessions
            # hold only a partial device view, so their dt/df replay (an
            # explicitly O(m) what-if path) rebuilds a full throwaway
            # matrix from the snapshot instead
            mat, aux = self.inc.mat, self.inc.aux
        return self._converge(R0, affected, expand=(variant == "df"),
                              g=g_cur, mat=mat, aux=aux)

    def _recompute_push(self, variant: str) -> PagerankResult:
        """Push-session re-solve.  ``nd`` keeps the rank estimate and
        rebuilds the exact residual (O(m)); ``static`` restarts cold.
        ``dt``/``df`` replay the *pull* marking machinery and have no push
        analogue (same contract as the walk engine's recompute)."""
        if variant not in ("static", "nd"):
            raise ValueError(
                f"recompute({variant!r}) replays the pull driver's "
                "frontier marking; a driver='push' session re-solves via "
                "variant='static' or 'nd'")
        t0 = time.perf_counter()
        if variant == "nd":
            P0 = self.R
            self._residual = self._residual_recompute(P0)
        else:
            P0 = jnp.zeros((self.n_pad,), self._dtype)
            self._residual = jnp.where(
                self.valid, (1.0 - self.config.alpha) / self.n,
                0).astype(self._dtype)
        R, stats, _ = self._drive_push_refill(
            P0, want_rb=(np.arange(self.n_rb) if self._tiered else None))
        self.R = R
        return PagerankResult(ranks=R, stats=stats,
                              wall_time_s=time.perf_counter() - t0)

    def _recompute_sharded(self, variant: str) -> PagerankResult:
        """Sharded re-solve through the cached compiled sweep — same
        variant semantics as single-device recompute."""
        cfg = self.config
        t0 = time.perf_counter()
        if variant in ("static", "nd"):
            R0 = (self.R if variant == "nd" else
                  jnp.where(self.valid, 1.0 / self.n, 0).astype(self._dtype))
            affected, expand = self.valid, False
        else:
            if self._last_batch_rel is None:
                raise ValueError(
                    f"recompute({variant!r}) replays the last update batch, "
                    "but no batch has been applied yet — call update() "
                    "first or use variant='static'/'nd'")
            dels_rel, ins_rel = self._last_batch_rel
            affected = self._sharded_affected(variant, self._hg_rel_prev,
                                              dels_rel, ins_rel)
            R0, expand = self._r_prev, (variant == "df")
        R, dstats = self.runtime.drive(R0, affected, expand=expand,
                                       max_sweeps=cfg.max_iterations)
        self.R = R
        self._x_full += dstats.full_exchanges
        self._x_delta += dstats.delta_exchanges
        self._x_sweeps += dstats.sweeps
        stats = SweepStats(sweeps=dstats.sweeps, iterations=dstats.sweeps,
                           edges_processed=dstats.edges_processed,
                           converged=dstats.converged)
        return PagerankResult(ranks=R, stats=stats,
                              wall_time_s=time.perf_counter() - t0)

    def _recompute_walk(self, variant: str) -> PagerankResult:
        """Walk-mode re-solve: regenerate EVERY walk segment from the
        current graph (``static``/``nd`` — both cold-start here, there is
        no warm iterate to reuse).  The marking replays (``dt``/``df``)
        have no walk analogue: walk invalidation is already the frontier,
        so they raise rather than silently aliasing ``static``."""
        if variant not in ("static", "nd"):
            raise ValueError(
                f"recompute({variant!r}) replays a sweep-engine affected "
                "marking, which the walk engine does not have — walk "
                "sessions regenerate globally via variant='static'/'nd' "
                "(per-delta localization happens inside update())")
        t0 = time.perf_counter()
        cfg = self.config
        self.walks = we.WalkState(
            self.hg, R=cfg.resolved_walks_per_vertex,
            L=cfg.resolved_walk_length, seed=cfg.resolved_walk_seed,
            alpha=cfg.alpha, dtype=self._dtype)
        self.R = self.walks.pagerank()
        self._r_verified = self.R
        stats = SweepStats(sweeps=1, iterations=1,
                           edges_processed=int(self.walks.total_steps),
                           converged=True)
        return PagerankResult(ranks=self.R, stats=stats,
                              wall_time_s=time.perf_counter() - t0)

    # -- serving reads (device-resident, no full-rank host transfer) ---------
    def _vertex_ids(self, vertices) -> np.ndarray:
        """Validate a vertex-id argument (Python int, sequence, or numpy
        array) into a flat int64 array, rejecting non-integer dtypes and
        negative/out-of-range ids with a clear error."""
        arr = np.asarray(vertices)
        if arr.size == 0:       # empty id lists are valid (empty result) —
            return np.zeros(0, np.int64)  # note np.asarray([]) is float64
        if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"vertex ids must be integers, got dtype {arr.dtype} "
                f"(value: {vertices!r})")
        idx = arr.reshape(-1).astype(np.int64)
        bad = (idx < 0) | (idx >= self.n)
        if bad.any():
            raise ValueError(
                f"vertex id(s) {idx[bad][:8].tolist()} out of range for a "
                f"graph with {self.n} vertices (valid ids: 0..{self.n - 1})")
        return idx

    def query(self, vertices: Union[int, Sequence[int], np.ndarray]
              ) -> np.ndarray:
        """Ranks of the given vertices: one device gather, only ``len(
        vertices)`` values cross to the host.  Accepts a Python int, a
        list, or an integer array; negative or out-of-range ids raise
        ``ValueError``.  Topology-transparent: sharded sessions translate
        through the partitioner relabeling."""
        self._ensure_open()
        idx = self._vertex_ids(vertices)
        if self._sharded:
            idx = self._inv[idx]
        vals = self.R[jnp.asarray(idx)]
        self._queries += int(idx.shape[0])
        return np.asarray(vals)

    def top_k(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(values, vertex ids) of the k highest-ranked vertices — computed
        device-side, only 2k scalars transferred."""
        self._ensure_open()
        if not isinstance(k, (int, np.integer)):
            raise ValueError(
                f"k must be an integer, got {type(k).__name__} ({k!r})")
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        k = int(min(k, self.n))
        masked = jnp.where(self.valid, self.R, -jnp.inf)
        vals, idx = jax.lax.top_k(masked, k)
        self._queries += k
        idx = np.asarray(idx)
        if self._sharded:
            idx = self._order[idx]          # back to caller vertex ids
        return np.asarray(vals), idx

    def ppr_query(self, seeds, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(values, vertex ids) of the k highest **personalized** PageRank
        estimates for a uniform restart over ``seeds`` — the per-user read
        the walk engine exists for.  O(read): one device gather over the
        seeds' walk segments plus a top-k; no regeneration, no sweep.
        Engines without the ``"ppr"`` capability raise
        :class:`repro.api.CapabilityError`."""
        self._ensure_open()
        if not self._walk:
            raise registry.CapabilityError(
                f"ppr_query needs an engine declaring the 'ppr' capability; "
                f"engine {self.engine_name!r} declares supports="
                f"{sorted(registry.supports_of(self.engine))} — open the "
                "session with EngineConfig(engine='walk')")
        seeds = self._vertex_ids(seeds)
        if seeds.size == 0:
            raise ValueError("ppr_query needs at least one seed vertex "
                             "(got an empty seed set)")
        if not isinstance(k, (int, np.integer)):
            raise ValueError(
                f"k must be an integer, got {type(k).__name__} ({k!r})")
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        k = int(min(k, self.n))
        vals, idx = self.walks.ppr_top_k(seeds, k)
        self._queries += k
        return np.asarray(vals), np.asarray(idx)

    @property
    def ranks(self) -> np.ndarray:
        """Full host copy of the rank vector in caller vertex order (the
        expensive full read — prefer :meth:`query` / :meth:`top_k` for
        serving)."""
        self._ensure_open()
        r = np.asarray(self.R)
        if self._sharded:
            out = np.zeros(self.n_pad, r.dtype)
            out[self._order] = r[:self.n]
            return out
        return r

    # -- lifecycle end -------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def device_footprint(self) -> Tuple[int, ...]:
        """Ids of the devices this session's state occupies (sharded
        sessions span their mesh; closed sessions hold nothing)."""
        if self._closed:
            return ()
        if self._sharded:
            return tuple(d.id for d in self._mesh.devices.flat)
        try:
            return tuple(sorted(d.id for d in self.R.devices()))
        except Exception:           # pragma: no cover - non-jax R
            return (0,)

    def _ensure_open(self) -> None:
        if self._closed:
            raise ValueError("session is closed — open a new "
                             "PageRankSession")

    def close(self) -> None:
        """End the session: unregister from any :class:`PageRankService`
        and drop every device buffer reference (rank vector, tile pool /
        operand mirrors, sharded slabs) so long-lived multi-session
        processes reclaim device memory.  Idempotent; forked twins keep
        their own references and are unaffected."""
        if self._closed:
            return
        self._closed = True
        svc, self._service = self._service, None
        if svc is not None:
            svc._detach(self)
        for attr in ("R", "inc", "runtime", "g", "valid", "_out_deg",
                     "_rb_in", "_rb_out", "_bmat", "_fault_tables",
                     "_r_prev", "store", "_process_domain", "walks",
                     "_r_verified", "_out_deg_host", "_corruption_faults",
                     "pool", "hot", "_rb_res_full", "_deferred_rb",
                     "_residual"):
            if hasattr(self, attr):
                setattr(self, attr, None)

    def __enter__(self) -> "PageRankSession":
        self._ensure_open()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # -- durability / process fault domain (docs/FAULTS.md) ------------------
    def _meta(self) -> dict:
        """JSON-able store meta: graph identity + a config echo (the
        non-serializable ``faults`` / ``fault_domain`` objects are
        injection schedules, not state — they are not persisted)."""
        cfgd = {}
        for f in dataclasses.fields(self.config):
            if f.name in ("faults", "fault_domain"):
                continue
            v = getattr(self.config, f.name)
            if f.name == "dtype" and v is not None:
                v = str(jnp.dtype(v))
            if f.name == "integrity" and v is not None:
                v = v.to_dict()     # coerced back by EngineConfig
            cfgd[f.name] = v
        return {"format": 1, "kind": "pagerank-session",
                "n": int(self.hg.n), "config": cfgd}

    def _checkpoint_into(self, store: SessionStore) -> str:
        """One atomic checkpoint of the current session state: caller-order
        ranks + the host edge set, keyed by the applied-batch count."""
        if store.read_meta() is None:
            store.write_meta(self._meta())
        return store.checkpoint(
            ranks=np.asarray(self.ranks[:self.n]), edges=self.hg.edges,
            batch_index=self._batch_index)

    def _checkpoint_now(self) -> str:
        return self._checkpoint_into(self.store)

    def save(self, directory: Optional[str] = None) -> str:
        """Force one atomic checkpoint of the current state (ranks +
        edge set, keyed by the applied-batch count).  Durable sessions
        checkpoint into their attached store (also shortening the WAL
        replay a later restore pays); any session may pass ``directory``
        to save into a fresh :class:`~repro.ckpt.checkpoint.SessionStore`.
        Returns the checkpoint path."""
        self._ensure_open()
        if self.hg is None:
            raise ValueError("save() needs a host graph (from_graph, or "
                             "from_snapshot with hg=)")
        store = self.store
        if directory is not None and (
                store is None
                or os.path.abspath(directory) != os.path.abspath(store.dir)):
            store = SessionStore(directory)
        if store is None:
            raise ValueError(
                "save() needs a directory= (this session has no attached "
                "store; open it with durability='wal' + store_dir= for "
                "continuous durability)")
        return self._checkpoint_into(store)

    @classmethod
    def restore(cls, directory: str, *,
                config: Optional[EngineConfig] = None,
                interpret: Optional[bool] = None) -> "PageRankSession":
        """Reopen a session from its durable store: newest valid rank
        checkpoint + WAL replay of every batch logged after it, through
        the normal update hot path (stream mode replays recompile-free).
        ``config`` overrides the stored config — e.g. a different
        ``n_shards`` restores onto a different device count (elastic
        rescale).  The recovery is recorded in ``report()``
        (``replayed_batches``, ``recovery_time_s``)."""
        t0 = time.perf_counter()
        store = SessionStore(directory)
        meta = store.read_meta()
        if meta is None:
            raise ValueError(f"{directory!r} is not a session store "
                             "(missing meta.json)")
        got = store.restore_latest_state()
        if got is None:
            raise ValueError(f"{directory!r} holds no valid checkpoint "
                             "(all steps corrupt or none written)")
        state, ckpt_idx = got
        if config is None:
            config = EngineConfig.from_kwargs(**meta["config"])
        hg = HostGraph(int(meta["n"]), state["edges"])
        sess = cls(hg=hg, config=config, r0=state["ranks"],
                   interpret=interpret,
                   store_dir=directory if config.durability == "wal"
                   else None,
                   _restore_attach=True)
        sess._batch_index = ckpt_idx
        recs = store.read_wal(after=ckpt_idx)
        sess._replaying = True
        try:
            for rec in recs:
                sess.update(rec.deletions, rec.insertions,
                            variant=rec.variant)
        finally:
            sess._replaying = False
        # replay warmed every hot-path cache entry the stream needs; the
        # post-restore retrace counter starts here.  With nothing to
        # replay the session is cold — leave _warm_idx unset so report()
        # excuses the first (compile-bearing) update as usual
        sess._warm_idx = len(sess._history) if recs else None
        sess._recoveries.append(fd.RecoveryRecord(
            domain="process", batch_index=ckpt_idx,
            wall_time_s=time.perf_counter() - t0,
            replayed_batches=len(recs),
            description=(f"restored from checkpoint {ckpt_idx} + "
                         f"{len(recs)} WAL batch(es)")))
        return sess

    # -- warmup / reporting --------------------------------------------------
    def warmup(self) -> None:
        """Trace the full per-batch pipeline at the stream's operand shapes
        without perturbing graph or rank state: a zero-value delta against
        vertex 0's (always present) self-loop tile warms the device scatter
        at the base batch bucket, and an empty-batch step warms the frontier
        seed and the fused driver.  Batches larger than the base bucket
        still pay one compile per new bucket they reach.  Snapshot-mode
        sessions are already warm from their initial solve."""
        self._ensure_open()
        if self._sharded:
            self.runtime.warmup(self.R)
            self._warm_idx = len(self._history)
            return
        if self._walk:
            self.walks.warmup()
            self._warm_idx = len(self._history)
            return
        if self._stream:
            z = np.zeros(1, np.int64)
            if self._tiered:
                # warm the host-tier delta path and the invalidate →
                # re-admit gather at the base bucket (values all zero, so
                # state is unperturbed)
                self.pool.apply_delta(z, z, np.zeros(1))
                self.hot.invalidate(np.zeros(1, np.int64))
                self._admit(np.zeros(1, np.int64))
            else:
                self.inc.mat = ops.apply_delta(self.inc.mat, z, z,
                                               np.zeros(1))
            empty = np.zeros((0, 2), np.int64)
            # not recorded in history, and the dt/df replay state must not
            # see the empty warmup batch as "the last update"
            saved = (self._last_batch, self._hg_prev, self._g_prev,
                     self._r_prev)
            self._update_stream(empty, empty)
            (self._last_batch, self._hg_prev, self._g_prev,
             self._r_prev) = saved
        self._warm_idx = len(self._history)

    def report(self) -> SessionReport:
        """Latency / retrace / work statistics over the update history.

        ``retraces_post_warmup`` sums the driver-cache growth observed
        *during this session's own updates* (after :meth:`warmup`, or after
        the first — expected — trace when warmup was skipped), so sessions
        sharing one process don't count each other's compiles."""
        walls = [r.wall_time_s for r in self._history]
        growth = [r.driver_retraces for r in self._history]
        buckets = 0
        if (self.engine_name not in ("pallas", "distributed", "walk")
                or not growth or any(gr < 0 for gr in growth)):
            retraces = -1
        else:
            start = self._warm_idx if self._warm_idx is not None else 1
            retraces = sum(growth[start:])
            buckets = sum(r.bucket_retraces
                          for r in self._history[start:])
        icfg = self.config.integrity
        integrity = None
        if (icfg is not None or self._integrity_checks
                or self._corruption_detected):
            by_rung = {r: 0 for r in ig.REPAIR_RUNGS}
            for rec in self._recoveries:
                if rec.domain == "corruption" and rec.rung in by_rung:
                    by_rung[rec.rung] += 1
            integrity = {
                "checks_run": int(self._integrity_checks),
                "corruption_detected": int(self._corruption_detected),
                "repairs": by_rung,
                "scrub_interval_s": (float(icfg.scrub_interval_s)
                                     if icfg is not None else None),
            }
        dev_bytes = self._device_bytes()
        spec = self._shard_spec
        wire = None
        if spec is not None:
            frac_full = (self._x_full / max(self._x_sweeps, 1)
                         if spec.exchange == "delta" else 1.0)
            wire = dist.collective_bytes_per_sweep(
                n_pad=self.n_pad, n_dev=spec.n_shards,
                exchange=spec.exchange, rank_bytes=self._dtype.itemsize,
                delta_capacity=spec.delta_capacity, expand=True,
                frac_full=frac_full)
        return SessionReport(
            engine=self.engine_name,
            backend=self.backend if self.engine_name == "pallas" else None,
            mode=self.config.mode,
            n_updates=len(self._history),
            p50_s=float(np.percentile(walls, 50)) if walls else 0.0,
            p95_s=float(np.percentile(walls, 95)) if walls else 0.0,
            retraces_post_warmup=retraces,
            total_sweeps=sum(r.stats.sweeps for r in self._history),
            total_edges_processed=sum(r.stats.edges_processed
                                      for r in self._history),
            queries_served=self._queries,
            wall_times_s=walls,
            batches_converged=sum(1 for r in self._history
                                  if r.stats.converged),
            sweep_cap_hits=sum(1 for r in self._history
                               if not r.stats.converged),
            topology=self.config.topology,
            n_shards=spec.n_shards if spec is not None else None,
            partitioner=spec.partitioner if spec is not None else None,
            edge_cut=(self._cut_edges / max(self.hg.m, 1)
                      if spec is not None else None),
            collective_bytes_per_sweep=wire,
            bucket_retraces_post_warmup=buckets,
            durability=self.config.durability,
            recoveries=len(self._recoveries),
            recovery_time_s=sum(r.wall_time_s for r in self._recoveries),
            replayed_batches=sum(r.replayed_batches
                                 for r in self._recoveries),
            recovery_events=[r.to_dict() for r in self._recoveries],
            integrity=integrity,
            tiering=(self.hot.stats() if self._tiered
                     and self.hot is not None else None),
            device_bytes=dev_bytes,
            bytes_per_vertex=(sum(dev_bytes.values()) / max(self.n, 1)
                              if dev_bytes is not None else None),
            driver=getattr(self.config, "driver", "pull"),
            sweeps_history=[int(r.stats.sweeps) for r in self._history],
            edges_processed_history=[int(r.stats.edges_processed)
                                     for r in self._history],
            residual_mass_last=next(
                (r.residual_mass for r in reversed(self._history)
                 if r.residual_mass is not None), None),
            pushed_blocks=(sum(r.pushed_blocks for r in self._history
                               if r.pushed_blocks is not None)
                           if any(r.pushed_blocks is not None
                                  for r in self._history) else None))

    def _device_bytes(self) -> Optional[dict]:
        """Per-component device-resident bytes (the ``report()`` memory
        audit).  ``None`` for sharded topologies, whose state is accounted
        per device by the wire model instead."""
        if self._sharded or self._closed:
            return None

        def _nb(*arrs):
            return int(sum(a.nbytes for a in arrs
                           if a is not None and hasattr(a, "nbytes")))

        out = {"ranks": _nb(self.R, self.valid,
                            getattr(self, "_residual", None))}
        if self._stream:
            mat = self.inc.mat
            out["tile_pool"] = _nb(mat.tiles)
            out["slot_tables"] = _nb(mat.tile_cols, mat.tile_idx)
            out["operand_mirrors"] = _nb(self._out_deg, self._rb_in,
                                         self._rb_out, self._bmat)
            if self._tiered:
                out["slot_tables"] += _nb(self.hot.rb_res)
        elif self.g is not None:
            out["graph_snapshot"] = _nb(*jax.tree_util.tree_leaves(self.g))
        if self._walk and getattr(self, "walks", None) is not None:
            out["walk_buffers"] = _nb(*(v for v in vars(self.walks).values()
                                        if isinstance(v, jnp.ndarray)))
        return out

    # -- what-if branching ---------------------------------------------------
    def fork(self) -> "PageRankSession":
        """Cheap what-if branch: the new session shares every device array
        with its parent — including the tile pool — until one side's
        updates diverge them (jax arrays are immutable; deltas patch
        functionally).  Host-side mutable state (the aux twins, history,
        replay state) is copied so the branches are fully independent."""
        self._ensure_open()
        new = object.__new__(PageRankSession)
        new.__dict__.update(self.__dict__)
        new._history = []
        new._warm_idx = 0 if self._warm_idx is not None else None
        new._queries = 0
        new._service = None       # forks are not registered with a service
        # a fork is a what-if branch, not a durable replica: two writers
        # on one WAL would interleave corruptingly, so the twin detaches
        # (save(directory=...) gives it its own store when needed)
        new.store = None
        new.store_dir = None
        new._process_domain = None
        new._recoveries = []
        new._replaying = False
        if self._shard_faults is not None:
            new._shard_faults = fd.ShardFaultDomain()
        # integrity state: checks/detections are per-session counters; the
        # bucket set and host twins are mutable and must not be shared
        new._integrity_checks = 0
        new._corruption_detected = 0
        new._integrity_alert = None
        new._scatter_fault = None
        new._driver_keys = set(self._driver_keys)
        if self._corruption_faults is not None:
            new._corruption_faults = fd.CorruptionFaultDomain()
        if getattr(self, "_out_deg_host", None) is not None:
            new._out_deg_host = self._out_deg_host.copy()
        if self.inc is not None:
            aux = self.inc.aux
            new.inc = IncrementalPullMatrix(
                self.inc.mat,
                MatrixAux(bmat=aux.bmat.copy(), rb_in=aux.rb_in.copy(),
                          rb_out=aux.rb_out.copy())
                if aux is not None else None)
        if self._tiered:
            # both tiers branch: the host pool copies (numpy is mutable),
            # the hot set forks over it (the immutable device slab is
            # shared until either side's admissions diverge it)
            new.pool = self.pool.copy()
            new.hot = self.hot.fork(new.pool)
            new._deferred_rb = None
        if self._sharded:
            new.runtime = self.runtime.fork()
        if self._walk:
            new.walks = self.walks.fork()
        return new
