"""Distributed substrate: logical-axis sharding rules, the ``constrain``
annotation API, and gradient-compression primitives.

Model code annotates tensors with *logical* axis names
(:func:`repro.dist.api.constrain`); the launch layer activates a rule
table + mesh (:func:`repro.dist.api.use_rules`) that maps logical axes to
physical mesh axes (:mod:`repro.dist.sharding`).  Outside an active rules
context every annotation is the identity, so model code runs unmodified
on a single host device.
"""
