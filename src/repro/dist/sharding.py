"""Logical-axis → mesh-axis rule tables (DESIGN.md §5).

A *rule table* maps logical axis names (``"batch"``, ``"heads"``, ``"ff"``,
…) to physical mesh axis names (``"pod"`` / ``"data"`` / ``"model"``), a
tuple of them, or ``None`` (replicated).  :func:`logical_to_spec` turns a
tensor's logical tuple into a :class:`~jax.sharding.PartitionSpec` against
a concrete mesh, **dropping** any mapping whose mesh axis is absent or
whose dimension is not divisible by the mesh-axis size — a non-divisible
tensor is simply left unsharded on that axis (the baseline behaviour the
per-arch ``rules_override`` tables tune away from).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# one logical axis maps to a mesh axis, a tuple of mesh axes, or None
Rule = Optional[Union[str, Tuple[str, ...]]]
Rules = Dict[str, Rule]

# -- family base tables (per-arch overrides merge on top; see
#    repro.configs.registry.ArchSpec.rules_override) -------------------------

LM_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,                 # sequence-parallel archs override → "model"
    "cache_seq": "model",        # decode KV cache shards its seq dim (TP)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,               # FSDP archs override → "data"
    "ff": "model",
    "expert_ff": "model",
    "experts": None,             # MoE archs override → "pod"
    "moe_capacity": None,
    "vocab": "model",
    "layers": None,              # scan-over-layers leading dim stays local
    "table_rows": "model",
}

GNN_RULES: Rules = {
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "batch": ("pod", "data"),
    "layers": None,
}

RECSYS_RULES: Rules = {
    "batch": ("pod", "data"),
    "candidates": ("data", "model"),
    "fields": None,
    "embed": None,
    "table_rows": "model",
    "layers": None,
}


def _mesh_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _resolve_rule(rule: Rule, mesh: Mesh, dim: int) -> Rule:
    """One logical axis's physical assignment against a concrete mesh:
    keep only mesh axes that exist, and drop the whole mapping when the
    dimension is not divisible by the combined mesh-axis size."""
    if rule is None:
        return None
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    if dim % _mesh_size(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def logical_to_spec(logical: Sequence[Optional[str]], rules: Rules,
                    mesh: Mesh, shape: Sequence[int]) -> P:
    """Map a logical axis tuple to a PartitionSpec for ``shape`` on
    ``mesh``.  Unknown logical names and non-divisible dims are replicated;
    a mesh axis is consumed at most once (first logical axis wins)."""
    entries = []
    used: set = set()
    for name, dim in zip(logical, shape):
        rule = _resolve_rule(rules.get(name) if name else None, mesh,
                             int(dim))
        if rule is not None:
            axes = (rule,) if isinstance(rule, str) else tuple(rule)
            if any(a in used for a in axes):
                rule = None
            else:
                used.update(axes)
        entries.append(rule)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero1_logical(logical: Sequence[Optional[str]], shape: Sequence[int],
                  mesh: Mesh, rules: Rules) -> Tuple[Optional[str], ...]:
    """ZeRO-1 logical tuple for an optimizer-state tensor: keep the
    parameter's own sharding and additionally assign the first replicated,
    divisible dimension to the ``data`` axis (optimizer state is touched
    once per step — sharding it over the data-parallel axis is free).
    Returns the logical tuple unchanged when no dimension qualifies."""
    if "data" not in mesh.shape:
        return tuple(logical)
    dsz = mesh.shape["data"]
    out = list(logical)
    # a dim already mapped to "data" by the rules means state is covered
    for name in logical:
        rule = rules.get(name) if name else None
        axes = ((rule,) if isinstance(rule, str) else tuple(rule or ()))
        if "data" in axes:
            return tuple(out)
    for i, (name, dim) in enumerate(zip(logical, shape)):
        rule = _resolve_rule(rules.get(name) if name else None, mesh,
                             int(dim))
        if rule is None and int(dim) % dsz == 0 and int(dim) > 0:
            out[i] = "_zero1"
            break
    return tuple(out)


# the internal logical axis zero1_logical introduces; merged into every
# rule lookup by logical_to_spec callers via rule table defaulting
for _t in (LM_RULES, GNN_RULES, RECSYS_RULES):
    _t.setdefault("_zero1", "data")
del _t
