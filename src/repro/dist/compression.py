"""Gradient-compression primitives (wire-byte reduction for the data-
parallel all-reduce): bf16 cast, top-k sparsification with error feedback,
and symmetric 8-bit quantization.  All operate on gradient pytrees and are
exact-accounting: what is not sent this round is carried in the error-
feedback residual and resurfaces next round (mass conservation is tested in
``tests/test_ckpt_and_substrate.py``)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


# -- bf16 wire cast ----------------------------------------------------------

def bf16_compress(grads: Any) -> Any:
    """Cast every leaf to bfloat16 (half the wire bytes of f32)."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def bf16_decompress(compressed: Any, like: Any) -> Any:
    """Cast back to the dtypes of ``like`` (the f32 master copy)."""
    return jax.tree.map(lambda c, g: c.astype(g.dtype), compressed, like)


# -- top-k with error feedback ----------------------------------------------

@dataclasses.dataclass
class ErrorFeedback:
    """Per-leaf residual of un-sent gradient mass."""
    residual: Dict[str, jnp.ndarray]

    @classmethod
    def init(cls, grads: Any) -> "ErrorFeedback":
        return cls(residual=jax.tree.map(jnp.zeros_like, grads))


def topk_compress(grads: Any, ef: ErrorFeedback, *, frac: float
                  ) -> Tuple[Any, ErrorFeedback]:
    """Keep the top ``frac`` fraction (by magnitude) of ``grads + residual``
    per leaf; the rest becomes the next residual.  Exactly conserves mass:
    ``kept + new_residual == grads + old_residual``."""

    def one(g, r):
        acc = g + r
        flat = acc.reshape(-1)
        k = max(1, int(frac * flat.shape[0]))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(acc) >= thresh
        kept = jnp.where(mask, acc, 0)
        return kept, acc - kept

    kept_res = jax.tree.map(one, grads, ef.residual)
    kept = jax.tree.map(lambda kr: kr[0], kept_res,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda kr: kr[1], kept_res,
                       is_leaf=lambda x: isinstance(x, tuple))
    return kept, ErrorFeedback(residual=res)


# -- symmetric 8-bit quantization --------------------------------------------

def quantize_8bit(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric linear quantization to int8: returns (q, scale) with
    ``g ≈ q · scale`` and |error| ≤ scale/2."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_8bit(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
