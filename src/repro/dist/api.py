"""Sharding annotation API: ``constrain`` + the ``use_rules`` context.

Model code marks tensors with logical axis tuples; nothing happens until a
launcher activates a (rules, mesh) pair::

    with use_rules(S.LM_RULES, mesh):
        logits, _ = model.forward(params, tokens, cfg)

Outside the context ``constrain`` is the identity, so the same model code
runs on one host device (tests, examples) and on a production mesh
(dry-run, launch) without branching.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.dist import sharding as S

_ctx = threading.local()


def active_rules() -> Optional[Tuple[S.Rules, Mesh]]:
    """The innermost active (rules, mesh) pair, or None."""
    stack = getattr(_ctx, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_rules(rules: S.Rules, mesh: Mesh):
    """Activate a logical→physical rule table for the dynamic extent of the
    block; nested contexts override (innermost wins)."""
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    stack.append((rules, mesh))
    try:
        yield
    finally:
        stack.pop()


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate ``x`` with a logical axis tuple.  Under an active
    :func:`use_rules` context this lowers to
    ``lax.with_sharding_constraint`` via the rule table; otherwise it is
    the identity (single-device paths pay nothing)."""
    ctx = active_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    if len(logical) != x.ndim:
        raise ValueError(
            f"logical tuple {tuple(logical)} has {len(logical)} axes but "
            f"tensor has shape {x.shape}")
    spec = S.logical_to_spec(logical, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
