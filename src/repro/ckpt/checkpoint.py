"""Atomic, elastic checkpointing (fault tolerance at the framework level).

Layout:  <dir>/step_<n>/manifest.json + one ``.npy`` per leaf.
  * atomic   — written to ``step_<n>.tmp`` then ``os.rename``d; a crash
    mid-save never corrupts the latest valid checkpoint;
  * elastic  — arrays are stored unsharded with their *logical* tree
    structure; ``restore`` re-device_puts onto whatever mesh/sharding the
    restarted job runs with (any divisor device count — elastic rescale);
  * auto-resume — ``restore_latest`` scans for the newest valid manifest
    (validated by per-leaf checksums), so a relaunched job continues where
    the last complete save finished.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, params, opt_state, step: int) -> str:
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": int(step), "leaves": {}}
        for name, tree in (("params", params), ("opt", opt_state)):
            for key, leaf in _flatten_with_paths(tree).items():
                arr = np.asarray(leaf)   # gathers sharded arrays to host
                fname = f"{name}__{key.replace('/', '__')}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][f"{name}/{key}"] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self._list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def _list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d,
                                               "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return out

    # -- restore ----------------------------------------------------------------
    def restore(self, step: int, params_like, opt_like, *,
                shardings=None) -> Tuple[Any, Any, int]:
        """Restore onto the templates' tree structure.  ``shardings`` is an
        optional matching (params, opt) pytree pair of NamedShardings for
        elastic placement onto the current mesh."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def load_tree(name, template, shard_tree):
            flat_t = _flatten_with_paths(template)
            flat_s = (_flatten_with_paths(shard_tree)
                      if shard_tree is not None else None)
            loaded = {}
            for key in flat_t:
                meta = manifest["leaves"][f"{name}/{key}"]
                arr = np.load(os.path.join(d, meta["file"]))
                if (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != meta["crc"]:
                    raise IOError(f"checksum mismatch for {name}/{key}")
                if flat_s is not None:
                    loaded[key] = jax.device_put(arr, flat_s[key])
                else:
                    loaded[key] = jax.numpy.asarray(arr)
            # rebuild via tree structure of the template
            leaves_order = [loaded[key] for key in flat_t]
            treedef = jax.tree_util.tree_structure(template)
            return jax.tree_util.tree_unflatten(treedef, leaves_order)

        p_sh, o_sh = shardings if shardings is not None else (None, None)
        params = load_tree("params", params_like, p_sh)
        opt = load_tree("opt", opt_like, o_sh)
        return params, opt, manifest["step"]

    def restore_latest(self, params_like=None, opt_like=None, *,
                       shardings=None):
        steps = sorted(self._list_steps())
        if not steps:
            return None
        if params_like is None:
            raise ValueError("restore_latest needs template pytrees")
        return self.restore(steps[-1], params_like, opt_like,
                            shardings=shardings)

    @property
    def latest_step(self) -> Optional[int]:
        steps = self._list_steps()
        return max(steps) if steps else None
