"""Atomic, elastic checkpointing + the durable-session store.

Two layers:

* :class:`Checkpointer` — generic pytree checkpoints.
  Layout:  <dir>/step_<n>/manifest.json + one ``.npy`` per leaf.
    - atomic   — written to ``step_<n>.tmp`` then ``os.rename``d; a crash
      mid-save never corrupts the latest valid checkpoint (orphaned
      ``.tmp`` dirs from crashed saves are swept on the next save);
    - elastic  — arrays are stored unsharded with their *logical* tree
      structure; ``restore`` re-device_puts onto whatever mesh/sharding
      the restarted job runs with (any divisor device count);
    - auto-resume — ``restore_latest`` scans newest→oldest and returns the
      first checkpoint that passes validation (readable manifest, every
      per-leaf checksum intact), *skipping* corrupted steps instead of
      raising, so one bad write never strands a relaunched job.

* :class:`SessionStore` — the process-fault-domain backing store of a
  durable :class:`~repro.api.session.PageRankSession` (see
  docs/FAULTS.md).  One directory holds

    - ``meta.json``   — graph identity + config echo (atomic write);
    - ``ckpt/``       — a Checkpointer of {ranks, edges} keyed by the
      batch index the checkpoint captures;
    - ``wal.bin``     — a write-ahead log of applied update batches.

  WAL framing (little-endian):  per record ``b"WR1\\n" | u32 payload_len |
  u32 crc32(payload) | payload``; the payload packs
  ``u64 batch_index | u8 variant | u32 n_dels | u32 n_ins`` followed by the
  two int64 edge arrays.  Appends are flushed + fsync'd **before** the
  batch touches device state, so a crash-stop at any instant loses at most
  work that was never acknowledged.  Readers accept exactly the valid
  prefix: a truncated or checksum-broken tail (the crash case) terminates
  the scan cleanly instead of raising.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, params, opt_state, step: int) -> str:
        self._sweep_tmp()           # also clears any stale tmp for `step`
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp)
        manifest = {"step": int(step), "leaves": {}}
        for name, tree in (("params", params), ("opt", opt_state)):
            for key, leaf in _flatten_with_paths(tree).items():
                arr = np.asarray(leaf)   # gathers sharded arrays to host
                fname = f"{name}__{key.replace('/', '__')}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][f"{name}/{key}"] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()
        return final

    def _sweep_tmp(self) -> None:
        """Remove orphaned ``step_<n>.tmp`` dirs left by crashed saves."""
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def _gc(self) -> None:
        steps = sorted(self._list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def _list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d,
                                               "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return out

    # -- restore ----------------------------------------------------------------
    def restore(self, step: int, params_like, opt_like, *,
                shardings=None) -> Tuple[Any, Any, int]:
        """Restore onto the templates' tree structure.  ``shardings`` is an
        optional matching (params, opt) pytree pair of NamedShardings for
        elastic placement onto the current mesh."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def load_tree(name, template, shard_tree):
            flat_t = _flatten_with_paths(template)
            flat_s = (_flatten_with_paths(shard_tree)
                      if shard_tree is not None else None)
            loaded = {}
            for key in flat_t:
                meta = manifest["leaves"][f"{name}/{key}"]
                arr = np.load(os.path.join(d, meta["file"]))
                if (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != meta["crc"]:
                    raise IOError(f"checksum mismatch for {name}/{key}")
                if flat_s is not None:
                    loaded[key] = jax.device_put(arr, flat_s[key])
                else:
                    loaded[key] = jax.numpy.asarray(arr)
            # rebuild via tree structure of the template
            leaves_order = [loaded[key] for key in flat_t]
            treedef = jax.tree_util.tree_structure(template)
            return jax.tree_util.tree_unflatten(treedef, leaves_order)

        p_sh, o_sh = shardings if shardings is not None else (None, None)
        params = load_tree("params", params_like, p_sh)
        opt = load_tree("opt", opt_like, o_sh)
        return params, opt, manifest["step"]

    def restore_latest(self, params_like=None, opt_like=None, *,
                       shardings=None):
        """Restore the newest checkpoint that passes validation.  A step
        whose manifest is unreadable or whose per-leaf checksum mismatches
        is *skipped* (newest→oldest scan) — one corrupted write must not
        strand the job when an older valid checkpoint exists.  Returns
        ``None`` when no valid checkpoint remains."""
        steps = sorted(self._list_steps())
        if not steps:
            return None
        if params_like is None:
            raise ValueError("restore_latest needs template pytrees")
        for step in reversed(steps):
            try:
                return self.restore(step, params_like, opt_like,
                                    shardings=shardings)
            except (OSError, IOError, KeyError, ValueError,
                    json.JSONDecodeError):
                continue             # corrupted step → fall back to previous
        return None

    @property
    def latest_step(self) -> Optional[int]:
        steps = self._list_steps()
        return max(steps) if steps else None


# ---------------------------------------------------------------------------
# durable-session store (process fault domain)
# ---------------------------------------------------------------------------

_WAL_MAGIC = b"WR1\n"
_WAL_HEAD = struct.Struct("<4sII")          # magic, payload_len, crc32
_WAL_PAYLOAD_HEAD = struct.Struct("<QBII")  # batch_index, variant, nd, ni

# WAL variant codes (order is the on-disk format — append only)
WAL_VARIANTS = ("static", "nd", "dt", "df")


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One durably-logged update batch."""
    batch_index: int
    variant: str
    deletions: np.ndarray      # [k, 2] int64
    insertions: np.ndarray     # [k, 2] int64


class SessionStore:
    """Directory-backed durability for one PageRank session: atomic
    {ranks, edges} checkpoints keyed by batch index + a crash-tolerant WAL
    of the batches applied since.  Restore = newest valid checkpoint +
    replay of every WAL record with a higher batch index (the session
    layer drives the replay through its normal update hot path)."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.ckpt = Checkpointer(os.path.join(directory, "ckpt"), keep=keep)
        self.wal_path = os.path.join(directory, "wal.bin")

    # -- meta ----------------------------------------------------------------
    def write_meta(self, meta: dict) -> None:
        tmp = os.path.join(self.dir, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.dir, "meta.json"))

    def read_meta(self) -> Optional[dict]:
        path = os.path.join(self.dir, "meta.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    # -- checkpoints ----------------------------------------------------------
    @staticmethod
    def _template() -> dict:
        # shapes/dtypes come from the manifest; the template only carries
        # the tree structure (keys)
        return {"ranks": np.zeros(0), "edges": np.zeros((0, 2), np.int64)}

    def checkpoint(self, *, ranks: np.ndarray, edges: np.ndarray,
                   batch_index: int) -> str:
        """Atomically persist the session state *after* ``batch_index``
        batches have been applied, then compact the WAL: records at or
        below the OLDEST retained checkpoint can never be replayed (every
        restore starts from some retained checkpoint), so dropping them
        bounds WAL size and restore cost by the checkpoint window instead
        of the session's lifetime."""
        state = {"ranks": np.asarray(ranks),
                 "edges": np.asarray(edges, np.int64)}
        path = self.ckpt.save(state, {}, batch_index)
        steps = self.ckpt._list_steps()
        if steps:
            self.compact_wal(keep_after=min(steps))
        return path

    def compact_wal(self, *, keep_after: int) -> None:
        """Atomically rewrite the WAL keeping only records with
        ``batch_index > keep_after`` (crash-safe: tmp + rename; a crash
        mid-compaction leaves the old complete log)."""
        if not os.path.exists(self.wal_path):
            return
        recs = self.read_wal(after=keep_after)
        tmp = self.wal_path + ".tmp"
        with open(tmp, "wb") as f:
            for r in recs:
                f.write(self._encode_record(r.batch_index, r.variant,
                                            r.deletions, r.insertions))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.wal_path)

    def restore_latest_state(self) -> Optional[Tuple[dict, int]]:
        """(state, batch_index) of the newest valid checkpoint, skipping
        corrupted steps; None when the store holds no valid checkpoint."""
        got = self.ckpt.restore_latest(self._template(), {})
        if got is None:
            return None
        state, _, step = got
        return ({k: np.asarray(v) for k, v in state.items()}, int(step))

    @property
    def latest_checkpoint_index(self) -> Optional[int]:
        return self.ckpt.latest_step

    # -- write-ahead log ------------------------------------------------------
    @staticmethod
    def _encode_record(batch_index: int, variant: str,
                       deletions: np.ndarray, insertions: np.ndarray
                       ) -> bytes:
        dels = np.ascontiguousarray(
            np.asarray(deletions, np.int64).reshape(-1, 2))
        ins = np.ascontiguousarray(
            np.asarray(insertions, np.int64).reshape(-1, 2))
        payload = (_WAL_PAYLOAD_HEAD.pack(
            int(batch_index), WAL_VARIANTS.index(variant),
            dels.shape[0], ins.shape[0])
            + dels.tobytes() + ins.tobytes())
        return _WAL_HEAD.pack(_WAL_MAGIC, len(payload),
                              zlib.crc32(payload) & 0xFFFFFFFF) + payload

    def append_wal(self, *, batch_index: int, variant: str,
                   deletions: np.ndarray, insertions: np.ndarray) -> None:
        """Durably append one batch BEFORE it is applied to session state
        (flush + fsync): after a crash the record either exists completely
        or is a truncated tail the reader drops — never a half-applied
        batch without a log entry."""
        frame = self._encode_record(batch_index, variant, deletions,
                                    insertions)
        with open(self.wal_path, "ab") as f:
            f.write(frame)
            f.flush()
            os.fsync(f.fileno())

    def read_wal(self, *, after: int = -1) -> List[WalRecord]:
        """Every valid WAL record with ``batch_index > after``, in append
        order.  Scanning stops at the first truncated or checksum-broken
        frame (the crash tail) — the valid prefix is the durable state."""
        if not os.path.exists(self.wal_path):
            return []
        with open(self.wal_path, "rb") as f:
            buf = f.read()
        out: List[WalRecord] = []
        off = 0
        while off + _WAL_HEAD.size <= len(buf):
            magic, plen, crc = _WAL_HEAD.unpack_from(buf, off)
            start = off + _WAL_HEAD.size
            if magic != _WAL_MAGIC or start + plen > len(buf):
                break                          # truncated / corrupt tail
            payload = buf[start:start + plen]
            if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                break
            bidx, var, nd, ni = _WAL_PAYLOAD_HEAD.unpack_from(payload, 0)
            body = payload[_WAL_PAYLOAD_HEAD.size:]
            need = (nd + ni) * 2 * 8
            if len(body) != need or var >= len(WAL_VARIANTS):
                break
            dels = np.frombuffer(body[:nd * 16], np.int64).reshape(-1, 2)
            ins = np.frombuffer(body[nd * 16:], np.int64).reshape(-1, 2)
            if bidx > after:
                out.append(WalRecord(batch_index=int(bidx),
                                     variant=WAL_VARIANTS[var],
                                     deletions=dels.copy(),
                                     insertions=ins.copy()))
            off = start + plen
        return out

    def wal_size(self) -> int:
        """Current WAL length in bytes (0 when no log exists) — capture
        before an append to make it revocable via :meth:`truncate_wal`."""
        return (os.path.getsize(self.wal_path)
                if os.path.exists(self.wal_path) else 0)

    def truncate_wal(self, size: int) -> None:
        """Roll the WAL back to a byte offset.  Used when a batch is
        *rejected in-process* after its record was appended (validation
        errors inside the apply): the record must not survive to be
        replayed by a later restore, since the session never held it."""
        if os.path.exists(self.wal_path):
            with open(self.wal_path, "rb+") as f:
                f.truncate(size)
                f.flush()
                os.fsync(f.fileno())

    def wal_tip(self) -> int:
        """Highest durably-logged batch index (-1 for an empty WAL)."""
        recs = self.read_wal()
        return recs[-1].batch_index if recs else -1
