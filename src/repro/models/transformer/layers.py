"""Transformer building blocks: RMSNorm, RoPE, GQA attention (chunked causal
/ sliding-window / split-KV decode), dense MLPs, and capacity-based MoE."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.api import constrain
from repro.models.transformer.config import TransformerConfig

NEG_INF = -1e9


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(d_head: int, theta: float, dtype=jnp.float32) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=dtype) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [..., S, H, dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _qkv(x, p, cfg: TransformerConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def causal_attention(x, p, cfg: TransformerConfig, positions) -> jnp.ndarray:
    return causal_attention_with_kv(x, p, cfg, positions)[0]


def causal_attention_with_kv(x, p, cfg: TransformerConfig, positions
                             ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                        jnp.ndarray]:
    """Full-sequence GQA attention, q-chunked for O(chunk·S) score memory.
    Applies causal + optional sliding-window masking.  Also returns the
    (roped) K/V for prefill cache construction."""
    B, S, D = x.shape
    KV, rep, dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head
    q, k, v = _qkv(x, p, cfg, positions)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    q = q.reshape(B, S, KV, rep, dh) * (dh ** -0.5)

    Cq = min(cfg.attn_q_chunk, S)
    while S % Cq:                     # largest divisor of S ≤ attn_q_chunk
        Cq -= 1
    n_chunks = S // Cq
    kv_pos = positions  # [S] or [B, S]
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None, :]

    def chunk(qi):
        qc = lax.dynamic_slice_in_dim(q, qi * Cq, Cq, axis=1)
        qp = lax.dynamic_slice_in_dim(kv_pos, qi * Cq, Cq, axis=1)
        s = jnp.einsum("bqkrd,bskd->bkrqs", qc, k,
                       preferred_element_type=jnp.float32)
        mask = qp[:, :, None] >= kv_pos[:, None, :]           # causal
        if cfg.sliding_window:
            mask &= (qp[:, :, None] - kv_pos[:, None, :]) < cfg.sliding_window
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        return jnp.einsum("bkrqs,bskd->bqkrd", pattn, v)

    out = jnp.concatenate([chunk(i) for i in range(n_chunks)], axis=1) \
        if n_chunks > 1 else chunk(0)
    out = out.reshape(B, S, cfg.n_heads, dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), k, v


def decode_attention(x, p, cfg: TransformerConfig, cache_k, cache_v,
                     position: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token decode with a (possibly ring-buffered SWA) KV cache.

    x: [B, 1, D]; cache_k/v: [B, S_cache, KV, dh]; position: scalar i32 —
    current absolute position.  Returns (out, new_cache_k, new_cache_v).
    The cache sequence dim may be sharded over 'model' (split-KV decode);
    XLA inserts the partial-softmax collectives.
    """
    B = x.shape[0]
    KV, rep, dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head
    S_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    pos_arr = jnp.full((1, 1), position, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)

    slot = position % S_cache if cfg.sliding_window else position
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)

    q = q.reshape(B, 1, KV, rep, dh) * (dh ** -0.5)
    s = jnp.einsum("bqkrd,bskd->bkrqs", q, cache_k.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(S_cache)
    if cfg.sliding_window:
        valid = idx < jnp.minimum(position + 1, S_cache)
    else:
        valid = idx <= position
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", pattn, cache_v.astype(x.dtype))
    out = out.reshape(B, 1, cfg.n_heads, dh)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


def decode_attention_batch(x, p, cfg: TransformerConfig, cache_k, cache_v,
                           positions: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-row-position decode (continuous batching): positions [B] i32.

    Identical math to :func:`decode_attention` but every batch row sits at
    its own absolute position (slots admitted at different times).  The
    cache write uses a one-hot mask over the sequence dim instead of
    ``dynamic_update_slice`` (per-row indices).
    """
    B = x.shape[0]
    KV, rep, dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head
    S_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    pos2 = positions[:, None].astype(jnp.int32)            # [B, 1]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)

    slot = (positions % S_cache) if cfg.sliding_window else positions
    iota = jnp.arange(S_cache)
    write = (iota[None, :] == slot[:, None])               # [B, S]
    cache_k = jnp.where(write[:, :, None, None], k.astype(cache_k.dtype),
                        cache_k)
    cache_v = jnp.where(write[:, :, None, None], v.astype(cache_v.dtype),
                        cache_v)

    q = q.reshape(B, 1, KV, rep, dh) * (dh ** -0.5)
    s = jnp.einsum("bqkrd,bskd->bkrqs", q, cache_k.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    if cfg.sliding_window:
        valid = iota[None, :] < jnp.minimum(positions + 1, S_cache)[:, None]
    else:
        valid = iota[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", pattn, cache_v.astype(x.dtype))
    out = out.reshape(B, 1, cfg.n_heads, dh)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def dense_mlp(x, p, cfg: TransformerConfig) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    elif cfg.mlp == "squared_relu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.mlp)
    h = constrain(h, ("batch", "seq", "ff"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo_mlp"].astype(x.dtype))


def moe_mlp(x, p, cfg: TransformerConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k MoE (GShard-style, scatter dispatch).
    Returns (output, aux_load_balancing_loss)."""
    mcfg = cfg.moe
    B, S, D = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    router_logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                               p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, K)              # [T, K]
    if mcfg.renormalize:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch-style aux loss: E · Σ_e fraction_tokens(e) · mean_prob(e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)                           # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              flat_e[:, None], axis=1)[:, 0]
    capacity = max(1, int(mcfg.capacity_factor * T * K / E))
    keep = pos < capacity
    slot_e = jnp.where(keep, flat_e, E)                     # drop bin E
    slot_c = jnp.clip(pos, 0, capacity - 1)

    x_rep = jnp.repeat(xt, K, axis=0)                       # [T*K, D]
    buf = jnp.zeros((E + 1, capacity, D), x.dtype)
    buf = buf.at[slot_e, slot_c].add(x_rep)
    # dispatch buffers shard their capacity dim ("moe_capacity" → data):
    # an unsharded [E, cap, D] buffer turns the token scatter into a
    # full-buffer all-reduce per layer per microbatch (≈27 TB/step at the
    # granite production shape — see EXPERIMENTS.md §Perf)
    buf = constrain(buf[:E], ("experts", "moe_capacity", "embed"))

    if cfg.mlp == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"].astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(h))
    h = constrain(h, ("experts", "moe_capacity", "expert_ff"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_down"].astype(x.dtype))
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((1, capacity, D), x.dtype)], 0)

    y = out_buf[slot_e, slot_c]                             # [T*K, D]
    y = y * (keep * gate_vals.reshape(-1)).astype(x.dtype)[:, None]
    y = y.reshape(T, K, D).sum(axis=1)
    return y.reshape(B, S, D), aux
