"""Transformer configuration (covers all five assigned LM architectures)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    renormalize: bool = True          # Mixtral-style top-k renormalisation


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None              # default d_model // n_heads
    mlp: str = "swiglu"                       # "swiglu" | "squared_relu"
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    sliding_window: Optional[int] = None      # SWA width (Mixtral)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # execution
    dtype: str = "bfloat16"                   # activation dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_q_chunk: int = 1024                  # q-chunked attention (mem bound)
    zero1: bool = True                        # ZeRO-1 optimizer sharding
    # pad the embedding/head vocab dim up to a multiple (restores vocab-axis
    # sharding when the raw vocab is not divisible by the mesh; §Perf knob).
    pad_vocab_to_multiple: Optional[int] = None
    # KV-cache storage dtype (None → activation dtype).  "float8_e4m3fn"
    # halves decode HBM vs bf16 — the §Perf knob that brings the 32k-context
    # decode cells under single-pod HBM.
    cache_dtype: Optional[str] = None

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab_to_multiple:
            return self.vocab
        m = self.pad_vocab_to_multiple
        return ((self.vocab + m - 1) // m) * m

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D roofline term)."""
        D, H, KV, dh, F, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                 self.d_head, self.d_ff, self.vocab,
                                 self.n_layers)
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * dh
        if self.moe:
            ff = self.moe.n_experts * (3 if self.mlp == "swiglu" else 2) \
                * D * F + D * self.moe.n_experts
        else:
            ff = (3 if self.mlp == "swiglu" else 2) * D * F
        norms = 2 * D
        emb = V * D * (1 if self.tie_embeddings else 2)
        return L * (attn + ff + norms) + emb + D

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if not self.moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        per_expert = (3 if self.mlp == "swiglu" else 2) * D * F
        inactive = L * (self.moe.n_experts - self.moe.top_k) * per_expert
        return self.param_count() - inactive
