"""Full LM transformer: param init, scan-over-layers forward (+remat),
prefill / decode serve paths, and loss.  Covers all five assigned LM archs
(dense GQA, QKV-bias, squared-ReLU, capacity MoE, sliding-window attention).

Params are a flat dict; per-layer tensors are stacked on a leading "layers"
dim so the forward is a single ``lax.scan`` (small HLO, fast dry-run compile,
pipeline-friendly).  Every tensor has a logical-axis tuple (``param_logical``)
consumed by :mod:`repro.dist.sharding`.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.api import constrain
from repro.models.transformer import layers as L
from repro.models.transformer.config import TransformerConfig

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    D, H, KV, dh, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                       cfg.d_ff)
    s: Dict[str, Tuple[int, ...]] = {
        "attn_norm": (D,), "mlp_norm": (D,),
        "wq": (D, H, dh), "wk": (D, KV, dh), "wv": (D, KV, dh),
        "wo": (H, dh, D),
    }
    if cfg.qkv_bias:
        s.update(bq=(H, dh), bk=(KV, dh), bv=(KV, dh))
    if cfg.moe:
        E = cfg.moe.n_experts
        s.update(router=(D, E), we_gate=(E, D, F), we_up=(E, D, F),
                 we_down=(E, F, D))
        if cfg.mlp != "swiglu":
            s.pop("we_gate")
    elif cfg.mlp == "swiglu":
        s.update(wi_gate=(D, F), wi_up=(D, F), wo_mlp=(F, D))
    else:
        s.update(wi=(D, F), wo_mlp=(F, D))
    return s


_LOGICAL = {
    "attn_norm": ("embed",), "mlp_norm": ("embed",),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"), "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
    "wi_gate": ("embed", "ff"), "wi_up": ("embed", "ff"),
    "wi": ("embed", "ff"), "wo_mlp": ("ff", "embed"),
    "router": ("embed", "experts"),
    "we_gate": ("experts", "embed", "expert_ff"),
    "we_up": ("experts", "embed", "expert_ff"),
    "we_down": ("experts", "expert_ff", "embed"),
    "emb": ("vocab", "embed"), "final_norm": ("embed",),
    "head": ("embed", "vocab"),
}


def param_shapes(cfg: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    """Flat {name: shape}; per-layer tensors carry the leading L dim."""
    shapes = {f"layers/{k}": (cfg.n_layers,) + v
              for k, v in _layer_shapes(cfg).items()}
    shapes["emb"] = (cfg.vocab_padded, cfg.d_model)
    shapes["final_norm"] = (cfg.d_model,)
    if not cfg.tie_embeddings:
        shapes["head"] = (cfg.d_model, cfg.vocab_padded)
    return shapes


def param_logical(cfg: TransformerConfig) -> Dict[str, Tuple]:
    out = {}
    for name in param_shapes(cfg):
        base = name.split("/")[-1]
        lg = _LOGICAL[base]
        out[name] = (("layers",) + lg) if name.startswith("layers/") else lg
    return out


def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    shapes = param_shapes(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    params: Params = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        base = name.split("/")[-1]
        if "norm" in base:
            params[name] = jnp.ones(shape, dtype)
        elif base.startswith("b"):
            params[name] = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (jax.random.normal(k, shape, dtype)
                            * (fan_in ** -0.5))
    return params


def abstract_params(cfg: TransformerConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    dtype = jnp.dtype(cfg.param_dtype)
    return {k: jax.ShapeDtypeStruct(v, dtype)
            for k, v in param_shapes(cfg).items()}


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _split_layers(params: Params) -> Tuple[Params, Params]:
    stack = {k.split("/", 1)[1]: v for k, v in params.items()
             if k.startswith("layers/")}
    top = {k: v for k, v in params.items() if not k.startswith("layers/")}
    return stack, top


def _layer(x, p, cfg: TransformerConfig, positions):
    h = L.rmsnorm(x, p["attn_norm"].astype(jnp.float32), cfg.norm_eps)
    x = x + L.causal_attention(h, p, cfg, positions)
    h = L.rmsnorm(x, p["mlp_norm"].astype(jnp.float32), cfg.norm_eps)
    if cfg.moe:
        y, aux = L.moe_mlp(h, p, cfg)
    else:
        y, aux = L.dense_mlp(h, p, cfg), jnp.zeros((), jnp.float32)
    x = constrain(x + y, ("batch", "seq", "embed"))
    return x, aux


def forward(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] i32 → (logits [B, S, V] f32, aux_loss scalar)."""
    stack, top = _split_layers(params)
    dtype = jnp.dtype(cfg.dtype)
    x = top["emb"].astype(dtype)[tokens]
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer(x, lp, cfg, positions)
        return (x, aux + a), None

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               stack)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            (x, aux), _ = body_fn((x, aux),
                                  jax.tree.map(lambda a: a[i], stack))
    x = L.rmsnorm(x, top["final_norm"].astype(jnp.float32), cfg.norm_eps)
    head = (top["emb"].T if cfg.tie_embeddings else top["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype),
                        preferred_element_type=jnp.float32)
    return constrain(logits, ("batch", "seq", "vocab")), aux


def loss_fn(params: Params, tokens: jnp.ndarray, labels: jnp.ndarray,
            cfg: TransformerConfig, *, aux_weight: float = 0.01
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross entropy; labels < 0 are masked out.

    The label log-prob is extracted with a one-hot contraction rather than
    ``take_along_axis``: a gather along a model-sharded vocab axis makes
    GSPMD all-gather the full [B, S, V] f32 logits (hundreds of GB at
    production shapes), while compare+select+reduce stays sharded and
    reduces to an all-reduce of [B, S] partials.
    """
    logits, aux = forward(params, tokens, cfg)
    if cfg.vocab_padded != cfg.vocab:
        pad_id = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_id, -1e9, logits)
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = labels[..., None] == jnp.arange(logits.shape[-1],
                                             dtype=labels.dtype)
    ll = jnp.sum(jnp.where(onehot, logits, 0), axis=-1)
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with stacked KV cache
# ---------------------------------------------------------------------------

def cache_shapes(cfg: TransformerConfig, batch: int, cache_len: int
                 ) -> Dict[str, Tuple[int, ...]]:
    eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len
    shp = (cfg.n_layers, batch, eff, cfg.n_kv_heads, cfg.d_head)
    return {"k": shp, "v": shp}


def cache_logical() -> Dict[str, Tuple]:
    lg = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": lg, "v": lg}


def init_cache(cfg: TransformerConfig, batch: int, cache_len: int,
               dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or jnp.dtype(cfg.cache_dtype or cfg.dtype)
    return {k: jnp.zeros(s, dtype)
            for k, s in cache_shapes(cfg, batch, cache_len).items()}


def prefill(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig,
            cache_len: int) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Run the full prompt, return (last-token logits [B, V], filled cache).

    The cache is filled up to S (ring-buffered to the window for SWA) and
    sized ``cache_len`` so decode can continue in place.
    """
    stack, top = _split_layers(params)
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = top["emb"].astype(dtype)[tokens]
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(S, dtype=jnp.int32)
    eff = cache_shapes(cfg, B, cache_len)["k"][2]

    def body(x, lp):
        h = L.rmsnorm(x, lp["attn_norm"].astype(jnp.float32), cfg.norm_eps)
        attn, k, v = L.causal_attention_with_kv(h, lp, cfg, positions)
        x = x + attn
        h = L.rmsnorm(x, lp["mlp_norm"].astype(jnp.float32), cfg.norm_eps)
        y = L.moe_mlp(h, lp, cfg)[0] if cfg.moe else L.dense_mlp(h, lp, cfg)
        x = constrain(x + y, ("batch", "seq", "embed"))
        # place the (window of the) prompt KV into the cache
        cdt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
        k, v = k.astype(cdt), v.astype(cdt)
        if eff >= S:
            ck = jnp.zeros((B, eff) + k.shape[2:], cdt).at[:, :S].set(k)
            cv = jnp.zeros((B, eff) + v.shape[2:], cdt).at[:, :S].set(v)
        else:  # SWA ring buffer: keep the last ``eff`` positions, rolled so
            # that absolute position p lives at slot p % eff
            ck, cv = k[:, S - eff:], v[:, S - eff:]
            shift = S % eff
            ck = jnp.roll(ck, shift, axis=1)
            cv = jnp.roll(cv, shift, axis=1)
        return x, {"k": ck, "v": cv}

    if cfg.scan_layers:
        x, cache = lax.scan(body, x, stack)
    else:                      # unrolled (exact dry-run flop accounting)
        caches = []
        for i in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(lambda a: a[i], stack))
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = L.rmsnorm(x[:, -1:], top["final_norm"].astype(jnp.float32),
                  cfg.norm_eps)
    head = (top["emb"].T if cfg.tie_embeddings else top["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype),
                        preferred_element_type=jnp.float32)[:, 0]
    cache = {k: constrain(v, cache_logical()[k]) for k, v in cache.items()}
    return logits, cache


def decode_step(params: Params, cache: Dict[str, jnp.ndarray],
                token: jnp.ndarray, position: jnp.ndarray,
                cfg: TransformerConfig
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step: token [B] i32, position scalar i32 (absolute).
    Returns (logits [B, V], updated cache)."""
    stack, top = _split_layers(params)
    dtype = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    x = top["emb"].astype(dtype)[token][:, None, :]     # [B, 1, D]

    def body(x, inp):
        lp, ck, cv = inp
        h = L.rmsnorm(x, lp["attn_norm"].astype(jnp.float32), cfg.norm_eps)
        attn, ck, cv = L.decode_attention(h, lp, cfg, ck, cv, position)
        x = x + attn
        h = L.rmsnorm(x, lp["mlp_norm"].astype(jnp.float32), cfg.norm_eps)
        y = L.moe_mlp(h, lp, cfg)[0] if cfg.moe else L.dense_mlp(h, lp, cfg)
        return x + y, (ck, cv)

    if cfg.scan_layers:
        x, (ck, cv) = lax.scan(body, x, (stack, cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            x, (k1, v1) = body(x, (jax.tree.map(lambda a: a[i], stack),
                                   cache["k"][i], cache["v"][i]))
            ks.append(k1)
            vs.append(v1)
        ck, cv = jnp.stack(ks), jnp.stack(vs)
    x = L.rmsnorm(x, top["final_norm"].astype(jnp.float32), cfg.norm_eps)
    head = (top["emb"].T if cfg.tie_embeddings else top["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype),
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"k": ck, "v": cv}


def decode_batch_step(params: Params, cache: Dict[str, jnp.ndarray],
                      tokens: jnp.ndarray, positions: jnp.ndarray,
                      cfg: TransformerConfig
                      ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Continuous-batching decode: tokens [B] i32, positions [B] i32 — each
    slot sits at its own absolute position.  Returns (logits [B, V], cache).
    """
    stack, top = _split_layers(params)
    dtype = jnp.dtype(cfg.dtype)
    x = top["emb"].astype(dtype)[tokens][:, None, :]    # [B, 1, D]

    def body(x, inp):
        lp, ck, cv = inp
        h = L.rmsnorm(x, lp["attn_norm"].astype(jnp.float32), cfg.norm_eps)
        attn, ck, cv = L.decode_attention_batch(h, lp, cfg, ck, cv,
                                                positions)
        x = x + attn
        h = L.rmsnorm(x, lp["mlp_norm"].astype(jnp.float32), cfg.norm_eps)
        y = L.moe_mlp(h, lp, cfg)[0] if cfg.moe else L.dense_mlp(h, lp, cfg)
        return x + y, (ck, cv)

    if cfg.scan_layers:
        x, (ck, cv) = lax.scan(body, x, (stack, cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            x, (k1, v1) = body(x, (jax.tree.map(lambda a: a[i], stack),
                                   cache["k"][i], cache["v"][i]))
            ks.append(k1)
            vs.append(v1)
        ck, cv = jnp.stack(ks), jnp.stack(vs)
    x = L.rmsnorm(x, top["final_norm"].astype(jnp.float32), cfg.norm_eps)
    head = (top["emb"].T if cfg.tie_embeddings else top["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype),
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"k": ck, "v": cv}
