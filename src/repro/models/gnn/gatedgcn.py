"""GatedGCN (Bresson & Laurent; benchmarking-GNNs variant, arXiv:2003.00982).

Edge-gated message passing:
    ê_ij   = E1·h_i + E2·h_j + E3·e_ij
    e_ij'  = e_ij + ReLU(LN(ê_ij))
    η_ij   = σ(ê_ij) / (Σ_{j'→i} σ(ê_ij') + ε)
    h_i'   = h_i + ReLU(LN(U·h_i + Σ_{j→i} η_ij ⊙ V·h_j))
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C

EPS = 1e-6


def shapes(cfg: C.GNNConfig) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_hidden
    s: Dict[str, Tuple[int, ...]] = {
        "enc/w_node": (cfg.d_feat, d), "enc/b_node": (d,),
        "enc/w_edge": (max(cfg.d_edge_feat, 1), d), "enc/b_edge": (d,),
        "dec/w": (d, cfg.n_out), "dec/b": (cfg.n_out,),
    }
    for k in ("U", "V", "E1", "E2", "E3"):
        s[f"layers/{k}"] = (cfg.n_layers, d, d)
    s["layers/ln_h"] = (cfg.n_layers, d)
    s["layers/ln_e"] = (cfg.n_layers, d)
    return s


def init(cfg: C.GNNConfig, key) -> Dict[str, jnp.ndarray]:
    return C.init_from_shapes(shapes(cfg), key, jnp.dtype(cfg.dtype))


def _ln(x, scale):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale


def forward(params, cfg: C.GNNConfig, g: C.GraphBatch) -> jnp.ndarray:
    g = C.shard_edges(g)
    h = g.nodes @ params["enc/w_node"] + params["enc/b_node"]
    ef = (g.edge_feat if g.edge_feat is not None
          else jnp.ones((g.senders.shape[0], 1), h.dtype))
    e = ef @ params["enc/w_edge"] + params["enc/b_edge"]

    stack = {k.split("/", 1)[1]: v for k, v in params.items()
             if k.startswith("layers/")}

    def layer(carry, lp):
        h, e = carry
        hs, hd = C.gather_src(g, h), C.gather_dst(g, h)
        e_hat = hd @ lp["E1"] + hs @ lp["E2"] + e @ lp["E3"]
        e_new = e + jax.nn.relu(_ln(e_hat, lp["ln_e"]))
        sig = jax.nn.sigmoid(e_hat)
        num = C.scatter_sum(g, sig * (hs @ lp["V"]))
        den = C.scatter_sum(g, sig) + EPS
        h_new = h + jax.nn.relu(_ln(h @ lp["U"] + num / den, lp["ln_h"]))
        return (h_new, e_new), None

    h, e = C.scan_or_unroll(layer, (h, e), stack, scan=cfg.scan_layers,
                            remat=cfg.remat)

    if cfg.task == "graph_reg":
        pooled = C.graph_readout(g, h, op="mean")
        return pooled @ params["dec/w"] + params["dec/b"]
    return h @ params["dec/w"] + params["dec/b"]


def loss_fn(params, cfg: C.GNNConfig, g: C.GraphBatch, labels
            ) -> Tuple[jnp.ndarray, Dict]:
    out = forward(params, cfg, g)
    if cfg.task == "node_clf":
        loss = C.node_xent(out, labels, None if g.node_mask is None
                           else g.node_mask.astype(jnp.float32))
    elif cfg.task == "graph_reg":
        loss = C.mse(out, labels, None)
    else:
        loss = C.mse(out, labels, None if g.node_mask is None
                     else g.node_mask.astype(jnp.float32))
    return loss, {"loss": loss}
