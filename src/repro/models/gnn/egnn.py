"""EGNN — E(n)-equivariant GNN (Satorras et al., arXiv:2102.09844).

    m_ij  = φ_e([h_i, h_j, ‖x_i − x_j‖²])
    x_i'  = x_i + (1/(N−1)) Σ_j (x_i − x_j) · φ_x(m_ij)
    h_i'  = h_i + φ_h([h_i, Σ_j m_ij])

φ_e, φ_h: 2-layer MLPs (SiLU); φ_x: 2-layer MLP → scalar, no output bias
(per the reference implementation, keeps equivariance exact).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


def shapes(cfg: C.GNNConfig) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_hidden
    s: Dict[str, Tuple[int, ...]] = {
        "enc/w": (cfg.d_feat, d), "enc/b": (d,),
        "dec/w": (d, cfg.n_out), "dec/b": (cfg.n_out,),
    }
    L = cfg.n_layers
    # φ_e: [h_i, h_j, dist²(+edge_feat)] → d
    d_in_e = 2 * d + 1 + cfg.d_edge_feat
    s["layers/e_w0"] = (L, d_in_e, d)
    s["layers/e_b0"] = (L, d)
    s["layers/e_w1"] = (L, d, d)
    s["layers/e_b1"] = (L, d)
    # φ_x: m → 1 (no final bias)
    s["layers/x_w0"] = (L, d, d)
    s["layers/x_b0"] = (L, d)
    s["layers/x_w1"] = (L, d, 1)
    # φ_h: [h, Σm] → d
    s["layers/h_w0"] = (L, 2 * d, d)
    s["layers/h_b0"] = (L, d)
    s["layers/h_w1"] = (L, d, d)
    s["layers/h_b1"] = (L, d)
    return s


def init(cfg: C.GNNConfig, key) -> Dict[str, jnp.ndarray]:
    return C.init_from_shapes(shapes(cfg), key, jnp.dtype(cfg.dtype))


def forward(params, cfg: C.GNNConfig, g: C.GraphBatch
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (per-node output [N, n_out] or per-graph, final positions)."""
    assert g.pos is not None, "EGNN requires node positions"
    g = C.shard_edges(g)
    h = g.nodes @ params["enc/w"] + params["enc/b"]
    x = g.pos.astype(h.dtype)
    stack = {k.split("/", 1)[1]: v for k, v in params.items()
             if k.startswith("layers/")}
    inv_n = 1.0 / max(g.n_pad - 1, 1)

    def layer(carry, lp):
        h, x = carry
        hs, hd = C.gather_src(g, h), C.gather_dst(g, h)
        xs = C.gather_src(g, x)
        xd = jnp.take(x, jnp.minimum(g.receivers, g.n_pad - 1), axis=0)
        rel = xd - xs                                   # x_i − x_j on edge j→i
        dist2 = jnp.sum(jnp.square(rel), -1, keepdims=True)
        feats = [hd, hs, dist2]
        if g.edge_feat is not None:
            feats.append(g.edge_feat.astype(h.dtype))
        m = jnp.concatenate(feats, -1)
        m = jax.nn.silu(m @ lp["e_w0"] + lp["e_b0"])
        m = jax.nn.silu(m @ lp["e_w1"] + lp["e_b1"])
        if g.edge_mask is not None:
            m = jnp.where(g.edge_mask[:, None], m, 0)
        w = jax.nn.silu(m @ lp["x_w0"] + lp["x_b0"]) @ lp["x_w1"]
        if g.edge_mask is not None:
            w = jnp.where(g.edge_mask[:, None], w, 0)
        x = x + inv_n * C.scatter_sum(g, rel * w)
        agg = C.scatter_sum(g, m)
        dh = jnp.concatenate([h, agg], -1)
        dh = jax.nn.silu(dh @ lp["h_w0"] + lp["h_b0"])
        dh = dh @ lp["h_w1"] + lp["h_b1"]
        return (h + dh, x), None

    h, x = C.scan_or_unroll(layer, (h, x), stack, scan=cfg.scan_layers,
                            remat=cfg.remat)

    out = h @ params["dec/w"] + params["dec/b"]
    if cfg.task == "graph_reg":
        out = C.graph_readout(g, h, op="sum") @ params["dec/w"] \
            + params["dec/b"]
    return out, x


def loss_fn(params, cfg: C.GNNConfig, g: C.GraphBatch, labels
            ) -> Tuple[jnp.ndarray, Dict]:
    out, _ = forward(params, cfg, g)
    if cfg.task == "node_clf":
        loss = C.node_xent(out, labels, None if g.node_mask is None
                           else g.node_mask.astype(jnp.float32))
    elif cfg.task == "graph_reg":
        loss = C.mse(out, labels, None)
    else:
        loss = C.mse(out, labels, None if g.node_mask is None
                     else g.node_mask.astype(jnp.float32))
    return loss, {"loss": loss}
