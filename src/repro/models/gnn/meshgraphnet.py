"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) — encode-process-decode.

    encode:   h = MLP_v(node_feat);  e = MLP_e([rel_pos, |rel_pos|] ⊕ edge_feat)
    process:  ×L:  e' = e + MLP([e, h_s, h_r]);  h' = h + MLP([h, Σ_in e'])
    decode:   out = MLP_d(h)
All MLPs are ``mlp_layers``-deep with LayerNorm (decoder: no LayerNorm).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


def _edge_in_dim(cfg: C.GNNConfig) -> int:
    # relative position (3) + its norm (1) when pos present, else raw features
    return 4 + cfg.d_edge_feat


def shapes(cfg: C.GNNConfig) -> Dict[str, Tuple[int, ...]]:
    d, ml = cfg.d_hidden, cfg.mlp_layers
    s: Dict[str, Tuple[int, ...]] = {}
    for name, d_in in (("enc_v", cfg.d_feat), ("enc_e", _edge_in_dim(cfg))):
        for k, shp in C.mlp_shapes(d_in, d, d, ml).items():
            s[f"{name}/{k}"] = shp
    for k, shp in C.mlp_shapes(d, d, cfg.n_out, ml).items():
        s[f"dec/{k}"] = shp
    L = cfg.n_layers
    for k, shp in C.mlp_shapes(3 * d, d, d, ml).items():
        s[f"layers/e_{k}"] = (L,) + shp
    for k, shp in C.mlp_shapes(2 * d, d, d, ml).items():
        s[f"layers/v_{k}"] = (L,) + shp
    return s


def init(cfg: C.GNNConfig, key) -> Dict[str, jnp.ndarray]:
    return C.init_from_shapes(shapes(cfg), key, jnp.dtype(cfg.dtype))


def forward(params, cfg: C.GNNConfig, g: C.GraphBatch) -> jnp.ndarray:
    g = C.shard_edges(g)
    ml = cfg.mlp_layers
    h = C.mlp_apply(params, g.nodes, prefix="enc_v/", n_layers=ml,
                    layernorm=True)

    if g.pos is not None:
        xs, xd = C.gather_src(g, g.pos), C.gather_dst(g, g.pos)
        rel = (xd - xs).astype(h.dtype)
        ef = jnp.concatenate(
            [rel, jnp.linalg.norm(rel, axis=-1, keepdims=True)], -1)
    else:
        ef = jnp.zeros((g.senders.shape[0], 4), h.dtype)
    if g.edge_feat is not None:
        ef = jnp.concatenate([ef, g.edge_feat.astype(h.dtype)], -1)
    e = C.mlp_apply(params, ef, prefix="enc_e/", n_layers=ml, layernorm=True)

    stack = {k.split("/", 1)[1]: v for k, v in params.items()
             if k.startswith("layers/")}

    def layer(carry, lp):
        h, e = carry
        hs, hd = C.gather_src(g, h), C.gather_dst(g, h)
        e_new = e + C.mlp_apply(lp, jnp.concatenate([e, hs, hd], -1),
                                prefix="e_", n_layers=ml, layernorm=True)
        agg = C.scatter_sum(g, e_new)
        h_new = h + C.mlp_apply(lp, jnp.concatenate([h, agg], -1),
                                prefix="v_", n_layers=ml, layernorm=True)
        return (h_new, e_new), None

    h, e = C.scan_or_unroll(layer, (h, e), stack, scan=cfg.scan_layers,
                            remat=cfg.remat)

    if cfg.task == "graph_reg":
        h = C.graph_readout(g, h, op="mean")
    return C.mlp_apply(params, h, prefix="dec/", n_layers=ml)


def loss_fn(params, cfg: C.GNNConfig, g: C.GraphBatch, labels
            ) -> Tuple[jnp.ndarray, Dict]:
    out = forward(params, cfg, g)
    if cfg.task == "node_clf":
        loss = C.node_xent(out, labels, None if g.node_mask is None
                           else g.node_mask.astype(jnp.float32))
    elif cfg.task == "graph_reg":
        loss = C.mse(out, labels, None)
    else:
        loss = C.mse(out, labels, None if g.node_mask is None
                     else g.node_mask.astype(jnp.float32))
    return loss, {"loss": loss}
