"""GNN model zoo: dispatch by family name."""
from repro.models.gnn.common import GNNConfig, GraphBatch
from repro.models.gnn import gatedgcn, egnn, graphsage, meshgraphnet

FAMILIES = {
    "gatedgcn": gatedgcn,
    "egnn": egnn,
    "graphsage": graphsage,
    "meshgraphnet": meshgraphnet,
}


def get_family(cfg: GNNConfig):
    return FAMILIES[cfg.family]


__all__ = ["GNNConfig", "GraphBatch", "FAMILIES", "get_family",
           "gatedgcn", "egnn", "graphsage", "meshgraphnet"]
