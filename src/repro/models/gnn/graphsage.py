"""GraphSAGE (Hamilton et al., arXiv:1706.02216) — mean aggregator.

Two execution modes:
  * ``forward``          — full-graph layer-wise:  h' = ReLU(W_s·h + W_n·mean_N(h))
  * ``forward_sampled``  — minibatch with dense sampled neighborhoods from
    :mod:`repro.graphs.sampler` (the real neighbor sampler), exactly the
    paper's minibatch algorithm: aggregate hop-2 → hop-1 → seeds.
L2 output normalisation per the paper.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


def shapes(cfg: C.GNNConfig) -> Dict[str, Tuple[int, ...]]:
    d = cfg.d_hidden
    s: Dict[str, Tuple[int, ...]] = {
        "dec/w": (d, cfg.n_out), "dec/b": (cfg.n_out,),
    }
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        s[f"l{i}/w_self"] = (d_in, d)
        s[f"l{i}/w_neigh"] = (d_in, d)
        s[f"l{i}/b"] = (d,)
        d_in = d
    return s


def init(cfg: C.GNNConfig, key) -> Dict[str, jnp.ndarray]:
    return C.init_from_shapes(shapes(cfg), key, jnp.dtype(cfg.dtype))


def _l2norm(h):
    return h * jax.lax.rsqrt(jnp.sum(jnp.square(h), -1, keepdims=True) + 1e-12)


def _layer(params, i, h_self, h_neigh_mean):
    h = h_self @ params[f"l{i}/w_self"] \
        + h_neigh_mean @ params[f"l{i}/w_neigh"] + params[f"l{i}/b"]
    return _l2norm(jax.nn.relu(h))


def forward(params, cfg: C.GNNConfig, g: C.GraphBatch) -> jnp.ndarray:
    g = C.shard_edges(g)
    h = g.nodes
    for i in range(cfg.n_layers):
        neigh = C.scatter_mean(g, C.gather_src(g, h))
        h = _layer(params, i, h, neigh)
    if cfg.task == "graph_reg":
        h = C.graph_readout(g, h, op="mean")
    return h @ params["dec/w"] + params["dec/b"]


def forward_sampled(params, cfg: C.GNNConfig,
                    feats: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
    """feats[k] — features of hop-k nodes, shape [B, f1, …, fk, F].
    len(feats) == n_layers + 1.  Returns seed logits [B, n_out]."""
    assert len(feats) == cfg.n_layers + 1
    from repro.dist.api import constrain
    h = [constrain(f, ("batch",) + (None,) * (f.ndim - 1)) for f in feats]
    # aggregate from the deepest hop inward; after step i, h has one less level
    for i in reversed(range(cfg.n_layers)):
        li = cfg.n_layers - 1 - i          # layer index applied at this step
        new_h = []
        for k in range(i + 1):
            neigh_mean = h[k + 1].mean(axis=-2)
            new_h.append(_layer(params, li, h[k], neigh_mean))
        h = new_h
    return h[0] @ params["dec/w"] + params["dec/b"]


def loss_fn(params, cfg: C.GNNConfig, g: C.GraphBatch, labels
            ) -> Tuple[jnp.ndarray, Dict]:
    out = forward(params, cfg, g)
    if cfg.task == "node_clf":
        loss = C.node_xent(out, labels, None if g.node_mask is None
                           else g.node_mask.astype(jnp.float32))
    elif cfg.task == "graph_reg":
        loss = C.mse(out, labels, None)
    else:
        loss = C.mse(out, labels, None if g.node_mask is None
                     else g.node_mask.astype(jnp.float32))
    return loss, {"loss": loss}


def loss_fn_sampled(params, cfg: C.GNNConfig, feats, labels
                    ) -> Tuple[jnp.ndarray, Dict]:
    logits = forward_sampled(params, cfg, feats)
    loss = C.node_xent(logits, labels, None)
    return loss, {"loss": loss}
