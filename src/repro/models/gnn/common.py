"""Shared GNN substrate: graph batches, segment message passing, MLP blocks.

JAX has no CSR/CSC sparse or EmbeddingBag — message passing is built from
``jnp.take`` (gather along edges) + ``jax.ops.segment_sum`` / ``segment_max``
(scatter-aggregate by destination), per the assignment notes.  Everything is
static-shaped: edge arrays are padded with ``src = dst = n_pad`` (a phantom
node) so padded edges aggregate into a discarded bin.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.api import constrain


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str                    # "gatedgcn" | "egnn" | "graphsage" | "meshgraphnet"
    n_layers: int
    d_hidden: int
    d_feat: int                    # input node feature dim
    n_out: int                     # classes (node_clf) or regression dim
    task: str = "node_clf"         # "node_clf" | "node_reg" | "graph_reg"
    aggregator: str = "sum"        # graphsage: "mean"; gatedgcn: "gated"
    d_edge_feat: int = 0           # input edge feature dim (0 = none)
    mlp_layers: int = 2            # meshgraphnet MLP depth
    sample_sizes: Tuple[int, ...] = ()   # graphsage default fanouts
    dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True       # False: unroll (exact dry-run HLO flops)


class GraphBatch(NamedTuple):
    """One (possibly batched/padded) graph on device.

    ``senders/receivers`` index into the flattened node array; padded edges
    point at node ``n_pad`` (one past the last row — callers allocate +1 row
    in scatter bins, not in ``nodes``).
    """
    nodes: jnp.ndarray                 # [N, F] float
    senders: jnp.ndarray               # [E] i32
    receivers: jnp.ndarray             # [E] i32
    edge_feat: Optional[jnp.ndarray] = None   # [E, Fe]
    pos: Optional[jnp.ndarray] = None  # [N, 3] (egnn / meshgraphnet)
    graph_id: Optional[jnp.ndarray] = None    # [N] i32 (batched small graphs)
    n_graphs: int = 1
    node_mask: Optional[jnp.ndarray] = None   # [N] bool
    edge_mask: Optional[jnp.ndarray] = None   # [E] bool

    @property
    def n_pad(self) -> int:
        return int(self.nodes.shape[0])


# ---------------------------------------------------------------------------
# message passing primitives
# ---------------------------------------------------------------------------

def gather_src(g: GraphBatch, h: jnp.ndarray) -> jnp.ndarray:
    """h[senders] with phantom-safe clamping; padded edges yield zeros."""
    v = jnp.take(h, jnp.minimum(g.senders, g.n_pad - 1), axis=0)
    if g.edge_mask is not None:
        v = jnp.where(g.edge_mask[:, None], v, 0)
    return v


def gather_dst(g: GraphBatch, h: jnp.ndarray) -> jnp.ndarray:
    v = jnp.take(h, jnp.minimum(g.receivers, g.n_pad - 1), axis=0)
    if g.edge_mask is not None:
        v = jnp.where(g.edge_mask[:, None], v, 0)
    return v


def scatter_sum(g: GraphBatch, messages: jnp.ndarray) -> jnp.ndarray:
    """Σ_{e: dst(e)=v} messages[e]  →  [N, d]; padded edges land in bin N."""
    out = jax.ops.segment_sum(messages, g.receivers,
                              num_segments=g.n_pad + 1)
    return out[:g.n_pad]


def scatter_mean(g: GraphBatch, messages: jnp.ndarray) -> jnp.ndarray:
    s = scatter_sum(g, messages)
    ones = jnp.ones((messages.shape[0],), messages.dtype)
    if g.edge_mask is not None:
        ones = ones * g.edge_mask
    cnt = jax.ops.segment_sum(ones, g.receivers,
                              num_segments=g.n_pad + 1)[:g.n_pad]
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(g: GraphBatch, messages: jnp.ndarray) -> jnp.ndarray:
    out = jax.ops.segment_max(messages, g.receivers,
                              num_segments=g.n_pad + 1)
    return jnp.maximum(out[:g.n_pad], 0)  # empty bins → -inf → clamp


def graph_readout(g: GraphBatch, h: jnp.ndarray, *, op: str = "mean"
                  ) -> jnp.ndarray:
    """Per-graph pooling for batched small graphs → [n_graphs, d]."""
    gid = g.graph_id if g.graph_id is not None else jnp.zeros(
        (g.n_pad,), jnp.int32)
    if g.node_mask is not None:
        h = jnp.where(g.node_mask[:, None], h, 0)
        gid = jnp.where(g.node_mask, gid, g.n_graphs)
    s = jax.ops.segment_sum(h, gid, num_segments=g.n_graphs + 1)[:g.n_graphs]
    if op == "sum":
        return s
    ones = jnp.ones((g.n_pad,), h.dtype)
    if g.node_mask is not None:
        ones = ones * g.node_mask
    cnt = jax.ops.segment_sum(ones, gid,
                              num_segments=g.n_graphs + 1)[:g.n_graphs]
    return s / jnp.maximum(cnt, 1.0)[:, None]


# ---------------------------------------------------------------------------
# dense blocks
# ---------------------------------------------------------------------------

def mlp_shapes(d_in: int, d_hidden: int, d_out: int, n_layers: int
               ) -> Dict[str, Tuple[int, ...]]:
    dims = [d_in] + [d_hidden] * (n_layers - 1) + [d_out]
    s = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        s[f"w{i}"] = (a, b)
        s[f"b{i}"] = (b,)
    return s


def mlp_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray, *, prefix: str = "",
              n_layers: int, act=jax.nn.relu, layernorm: bool = False
              ) -> jnp.ndarray:
    for i in range(n_layers):
        x = x @ p[f"{prefix}w{i}"].astype(x.dtype) \
            + p[f"{prefix}b{i}"].astype(x.dtype)
        if i < n_layers - 1:
            x = act(x)
    if layernorm:
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.var(x, -1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
    return x


def dense_init(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    return jax.random.normal(key, shape, dtype) * (fan_in ** -0.5)


def _is_bias(leaf: str) -> bool:
    import re
    return (leaf.startswith("b") and not leaf.startswith("bn")) or \
        any(re.fullmatch(r"b\d*", seg) for seg in leaf.split("_")) or \
        "bias" in leaf


def init_from_shapes(shapes: Dict[str, Tuple[int, ...]], key,
                     dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    params = {}
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        leaf = name.split("/")[-1]
        if "norm" in leaf or leaf.startswith("ln"):
            params[name] = jnp.ones(shape, dtype)
        elif _is_bias(leaf):
            params[name] = jnp.zeros(shape, dtype)
        else:
            params[name] = dense_init(k, shape, dtype)
    return params


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def node_xent(logits: jnp.ndarray, labels: jnp.ndarray,
              mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    nll = lse - ll
    m = (labels >= 0).astype(jnp.float32)
    if mask is not None:
        m = m * mask
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def mse(pred: jnp.ndarray, target: jnp.ndarray,
        mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    err = jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32))
    err = err.mean(axis=-1)
    if mask is not None:
        return (err * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return err.mean()


def scan_or_unroll(layer_fn, carry, stack, *, scan: bool, remat: bool):
    """Run ``layer_fn(carry, per_layer_params) -> (carry, None)`` over a
    stacked param tree, either as ``lax.scan`` (small HLO, production) or
    unrolled (exact compiled-FLOP accounting for the dry-run roofline)."""
    fn = jax.checkpoint(layer_fn) if remat else layer_fn
    if scan:
        carry, _ = jax.lax.scan(fn, carry, stack)
        return carry
    n = jax.tree.leaves(stack)[0].shape[0]
    for i in range(n):
        carry, _ = fn(carry, jax.tree.map(lambda a: a[i], stack))
    return carry


def shard_edges(g: GraphBatch) -> GraphBatch:
    """Apply edge/node sharding constraints (dry-run / production meshes)."""
    return g._replace(
        nodes=constrain(g.nodes, ("nodes", None)),
        senders=constrain(g.senders, ("edges",)),
        receivers=constrain(g.receivers, ("edges",)),
        edge_feat=(None if g.edge_feat is None
                   else constrain(g.edge_feat, ("edges", None))),
        pos=None if g.pos is None else constrain(g.pos, ("nodes", None)),
    )
