"""AutoInt (Song et al., arXiv:1810.11921) — self-attentive feature
interaction over sparse-field embeddings, CTR prediction.

    e_f   = EmbeddingTable_f[id_f]                       (fused table lookup)
    x^0   = [e_1 … e_F]                                  [B, F, D]
    x^l   = ReLU(MultiHeadSelfAttn(x^{l-1}) + W_res x^{l-1})
    ŷ     = σ(w · flatten(x^L) + b)

Also provides a two-tower retrieval scorer for the ``retrieval_cand`` shape:
user tower = the AutoInt interaction stack pooled; item tower = pooled field
embeddings; score = dot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.models.recsys.embedding import fielded_lookup

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39                 # number of sparse fields
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32                   # per-head attention dim
    rows_per_field: int = 1_000_000    # hashed id space per field
    n_user_fields: int = 20            # retrieval: fields 0..u are the query
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.rows_per_field

    @property
    def d_interact(self) -> int:
        return self.n_heads * self.d_attn

    def param_count(self) -> int:
        table = self.total_rows * self.embed_dim
        d_in = [self.embed_dim] + [self.d_interact] * (self.n_attn_layers - 1)
        attn = sum(3 * d * self.d_interact + d * self.d_interact
                   for d in d_in)
        head = self.n_sparse * self.d_interact + 1
        return table + attn + head


def param_shapes(cfg: AutoIntConfig) -> Dict[str, Tuple[int, ...]]:
    s: Dict[str, Tuple[int, ...]] = {
        "table": (cfg.total_rows, cfg.embed_dim),
    }
    d_in = cfg.embed_dim
    for l in range(cfg.n_attn_layers):
        for nm in ("wq", "wk", "wv"):
            s[f"attn{l}/{nm}"] = (d_in, cfg.n_heads, cfg.d_attn)
        s[f"attn{l}/w_res"] = (d_in, cfg.d_interact)
        d_in = cfg.d_interact
    s["head/w"] = (cfg.n_sparse * cfg.d_interact,)
    s["head/b"] = ()
    return s


def param_logical(cfg: AutoIntConfig) -> Dict[str, Tuple]:
    out = {}
    for name, shape in param_shapes(cfg).items():
        if name == "table":
            out[name] = ("table_rows", "embed")
        else:
            out[name] = (None,) * len(shape)
    return out


def init_params(cfg: AutoIntConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    params: Params = {}
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name == "table":
            params[name] = jax.random.normal(k, shape, dtype) * 0.01
        elif name.endswith("/b"):
            params[name] = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(k, shape, dtype) \
                * (fan_in ** -0.5)
    return params


def abstract_params(cfg: AutoIntConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    dtype = jnp.dtype(cfg.dtype)
    return {k: jax.ShapeDtypeStruct(v, dtype)
            for k, v in param_shapes(cfg).items()}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn_layer(params: Params, l: int, x: jnp.ndarray) -> jnp.ndarray:
    """x [B, F, d_in] → [B, F, n_heads·d_attn] (field-axis self-attention)."""
    q = jnp.einsum("bfd,dhk->bfhk", x, params[f"attn{l}/wq"].astype(x.dtype))
    k = jnp.einsum("bfd,dhk->bfhk", x, params[f"attn{l}/wk"].astype(x.dtype))
    v = jnp.einsum("bfd,dhk->bfhk", x, params[f"attn{l}/wv"].astype(x.dtype))
    s = jnp.einsum("bfhk,bghk->bhfg", q, k,
                   preferred_element_type=jnp.float32)
    a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhfg,bghk->bfhk", a, v)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    res = jnp.einsum("bfd,de->bfe", x, params[f"attn{l}/w_res"].astype(
        x.dtype))
    return jax.nn.relu(o + res)


def interact(params: Params, cfg: AutoIntConfig, emb: jnp.ndarray
             ) -> jnp.ndarray:
    """emb [B, F, D] → interaction features [B, F, d_interact]."""
    x = emb
    for l in range(cfg.n_attn_layers):
        x = _attn_layer(params, l, x)
        x = constrain(x, ("batch", "fields", None))
    return x


def forward(params: Params, cfg: AutoIntConfig, ids: jnp.ndarray
            ) -> jnp.ndarray:
    """ids [B, n_sparse] of *global* fused-table row ids → logits [B]."""
    emb = fielded_lookup(params["table"], ids)
    emb = constrain(emb, ("batch", "fields", "embed"))
    x = interact(params, cfg, emb)
    flat = x.reshape(x.shape[0], -1)
    return flat @ params["head/w"].astype(flat.dtype) \
        + params["head/b"].astype(flat.dtype)


def loss_fn(params: Params, cfg: AutoIntConfig, ids: jnp.ndarray,
            labels: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    logits = forward(params, cfg, ids)
    y = labels.astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # numerically-stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(
        z))))
    acc = jnp.mean((z > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": acc}


# ---------------------------------------------------------------------------
# retrieval (two-tower scoring against a large candidate set)
# ---------------------------------------------------------------------------

def user_vector(params: Params, cfg: AutoIntConfig, user_ids: jnp.ndarray
                ) -> jnp.ndarray:
    """user_ids [B, n_user_fields] → [B, d_interact] pooled interaction."""
    B, U = user_ids.shape
    emb = fielded_lookup(params["table"], user_ids)
    # reuse the interaction stack on the user sub-fields
    x = interact(params, cfg, emb)
    return x.mean(axis=1)


def item_vectors(params: Params, cfg: AutoIntConfig, item_ids: jnp.ndarray
                 ) -> jnp.ndarray:
    """item_ids [N, n_item_fields] → [N, d_interact] pooled embeddings,
    projected to the interaction dim with the layer-0 value projection."""
    emb = fielded_lookup(params["table"], item_ids)     # [N, I, D]
    v = jnp.einsum("nfd,dhk->nfhk", emb,
                   params["attn0/wv"].astype(emb.dtype))
    v = v.reshape(emb.shape[0], emb.shape[1], -1)
    return v.mean(axis=1)


def retrieval_scores(params: Params, cfg: AutoIntConfig,
                     user_ids: jnp.ndarray, cand_ids: jnp.ndarray,
                     *, top_k: int = 100
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Score 1 query against N candidates (batched dot, no loop);
    returns (top-k scores, top-k indices)."""
    u = user_vector(params, cfg, user_ids)               # [1, d]
    c = item_vectors(params, cfg, cand_ids)              # [N, d]
    c = constrain(c, ("candidates", None))
    scores = (c @ u[0]).astype(jnp.float32)              # [N]
    return jax.lax.top_k(scores, top_k)
