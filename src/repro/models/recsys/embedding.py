"""EmbeddingBag built from scratch (JAX has none): ``jnp.take`` +
``jax.ops.segment_sum``, per the assignment notes.

Two forms:
  * ``embedding_bag``       — flat variable-length bags (ids + segment ids),
    the general production form;
  * ``fielded_lookup``      — fixed [B, n_fields, bag] layout with a mask,
    the static-shape fast path AutoInt uses (bag=1 ⇒ plain take).

Tables are stored as ONE fused [total_rows, dim] array (row-sharded over the
"model" mesh axis via the ``table_rows`` logical axis); per-field id spaces
are offset into it host-side.  ``sharded_lookup`` is the shard_map masked
local-take + psum variant that avoids materialising the full table on any
device (used when a mesh is active; beyond-paper wire optimization).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dist.api import constrain


def embedding_bag(table: jnp.ndarray, flat_ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, n_segments: int,
                  *, weights: Optional[jnp.ndarray] = None,
                  combiner: str = "sum") -> jnp.ndarray:
    """Σ (or mean of) table[flat_ids] grouped by ``segment_ids``.

    flat_ids/segment_ids: [T] i32; padded entries use segment_id == n_segments.
    """
    rows = jnp.take(table, jnp.minimum(flat_ids, table.shape[0] - 1), axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments + 1)
    out = out[:n_segments]
    if combiner == "mean":
        ones = jnp.ones((flat_ids.shape[0],), rows.dtype)
        if weights is not None:
            ones = weights
        cnt = jax.ops.segment_sum(ones, segment_ids,
                                  num_segments=n_segments + 1)[:n_segments]
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def fielded_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                   mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """ids [B, F] (or [B, F, bag]) of *global* row ids → [B, F, D].

    bag > 1 entries are sum-combined; masked entries contribute 0.
    """
    squeeze = ids.ndim == 2
    if squeeze:
        ids = ids[..., None]
    rows = jnp.take(table, jnp.minimum(ids, table.shape[0] - 1), axis=0)
    if mask is not None:
        m = mask if mask.ndim == ids.ndim else mask[..., None]
        rows = rows * m[..., None].astype(rows.dtype)
    return rows.sum(axis=2)


def sharded_lookup(table: jnp.ndarray, ids: jnp.ndarray, mesh: Mesh,
                   *, axis: str = "model") -> jnp.ndarray:
    """Masked local-take + psum: each device holds a row shard; ids outside
    the local range contribute zero and the psum assembles full rows.  Wire
    cost = |ids|·D instead of |table|·D (no table all-gather)."""
    n_rows = table.shape[0]
    n_shards = mesh.shape[axis]
    rows_loc = n_rows // n_shards

    def local(table_loc, ids):
        d = lax.axis_index(axis)
        lo = d * rows_loc
        local_ids = ids - lo
        ok = (local_ids >= 0) & (local_ids < rows_loc)
        rows = jnp.take(table_loc, jnp.clip(local_ids, 0, rows_loc - 1),
                        axis=0)
        rows = jnp.where(ok[..., None], rows, 0)
        return lax.psum(rows, axis)

    other = tuple(a for a in mesh.axis_names if a != axis)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis, None), P()),
                   out_specs=P(), check_rep=False)
    return fn(table, ids)


def build_field_offsets(rows_per_field: Sequence[int]) -> np.ndarray:
    """Host-side: per-field base offset into the fused table."""
    return np.concatenate([[0], np.cumsum(rows_per_field)[:-1]]).astype(
        np.int64)
