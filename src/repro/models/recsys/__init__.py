from repro.models.recsys.autoint import (AutoIntConfig, init_params,
                                         forward, loss_fn, retrieval_scores)
from repro.models.recsys.embedding import embedding_bag, fielded_lookup

__all__ = ["AutoIntConfig", "init_params", "forward", "loss_fn",
           "retrieval_scores", "embedding_bag", "fielded_lookup"]
