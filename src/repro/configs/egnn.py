"""egnn — E(n)-equivariant GNN, n_layers=4 d_hidden=64.
[arXiv:2102.09844; paper]

EGNN requires node positions; for the non-geometric assigned datasets
(citation / social graphs) the position channel is a synthetic 3-D embedding
supplied by ``input_specs`` — the equivariant update is exercised
structurally, as noted in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.common import GNNConfig


def build_cfg(*, d_feat: int = 1433, n_out: int = 7, task: str = "node_clf",
              **kw) -> GNNConfig:
    base = dict(
        name="egnn", family="egnn", n_layers=4, d_hidden=64,
        aggregator="sum", d_feat=d_feat, n_out=n_out, task=task,
    )
    base.update(kw)
    return GNNConfig(**base)


def smoke_cfg() -> GNNConfig:
    return build_cfg(name="egnn-smoke", n_layers=2, d_hidden=16, d_feat=8,
                     n_out=3)


register(ArchSpec(
    arch_id="egnn",
    family="gnn",
    source="arXiv:2102.09844; paper",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=gnn_shapes(),
    notes="E(n)-equivariant coordinate+feature updates (molecule is the "
          "native fit; other datasets use synthetic positions).",
))
