"""phi4-mini-3.8b — dense LM, 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE + SwiGLU + GQA.  [arXiv:2412.08905; hf]

Embeddings are tied (phi-mini family practice), which is also what lands the
total at ~3.8B; untied would be ~4.4B.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer.config import TransformerConfig


def build_cfg(**kw) -> TransformerConfig:
    base = dict(
        name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=8192, vocab=200064, qkv_bias=False,
        mlp="swiglu", rope_theta=10_000.0, tie_embeddings=True,
        dtype="bfloat16", param_dtype="float32",
    )
    base.update(kw)
    return TransformerConfig(**base)


def smoke_cfg() -> TransformerConfig:
    return build_cfg(name="phi4-mini-smoke", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                     dtype="float32", attn_q_chunk=64)


register(ArchSpec(
    arch_id="phi4-mini-3.8b",
    family="lm",
    source="arXiv:2412.08905; hf",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=lm_shapes(subquadratic=False),
    exec_overrides={
        "train_4k": {"microbatches": 4},
    },
    notes="GQA 24q/8kv, tied embeddings; full attention ⇒ long_500k skipped.",
))
