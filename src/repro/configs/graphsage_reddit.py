"""graphsage-reddit — GNN, n_layers=2 d_hidden=128 mean aggregator,
default sample sizes 25-10.  [arXiv:1706.02216; paper]

``minibatch_lg`` uses the paper's own minibatch algorithm: the host-side
neighbor sampler (:mod:`repro.graphs.sampler`) draws dense fanout blocks
(shape-spec fanout 15-10) and the lowered step consumes the hop tensors.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.common import GNNConfig


def build_cfg(*, d_feat: int = 602, n_out: int = 41, task: str = "node_clf",
              **kw) -> GNNConfig:
    base = dict(
        name="graphsage-reddit", family="graphsage", n_layers=2,
        d_hidden=128, aggregator="mean", sample_sizes=(25, 10),
        d_feat=d_feat, n_out=n_out, task=task,
    )
    base.update(kw)
    return GNNConfig(**base)


def smoke_cfg() -> GNNConfig:
    return build_cfg(name="graphsage-smoke", n_layers=2, d_hidden=16,
                     d_feat=8, n_out=3, sample_sizes=(3, 2))


register(ArchSpec(
    arch_id="graphsage-reddit",
    family="gnn",
    source="arXiv:1706.02216; paper",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=gnn_shapes(),
    notes="mean aggregator + L2-normalized layers; minibatch_lg runs the "
          "true sampled-training path.",
))
