"""granite-moe-3b-a800m — MoE LM, 32L d_model=1536 24H (GQA kv=8) d_ff=512
(per-expert), vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]

Note: the assignment line reads "MoE 40e top-8" with an annotation "32
experts top-8"; we follow the primary spec (40 experts, top-8).  Total
params ≈ 3.4B, active ≈ 0.9B — matching the 3b-a800m name.

vocab = 49155 is not divisible by any mesh-axis size, so the vocab axis is
*unsharded* in the baseline (logical_to_spec drops non-divisible
assignments).  The §Perf log shows the padded-vocab variant
(``pad_vocab_to_multiple``) that restores vocab sharding.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer.config import MoEConfig, TransformerConfig


def build_cfg(**kw) -> TransformerConfig:
    base = dict(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv_heads=8, d_ff=512, vocab=49155, qkv_bias=False,
        mlp="swiglu", rope_theta=10_000.0,
        moe=MoEConfig(n_experts=40, top_k=8),
        dtype="bfloat16", param_dtype="float32",
    )
    base.update(kw)
    return TransformerConfig(**base)


def smoke_cfg() -> TransformerConfig:
    return build_cfg(name="granite-moe-smoke", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=255,
                     moe=MoEConfig(n_experts=4, top_k=2),
                     dtype="float32", attn_q_chunk=64)


register(ArchSpec(
    arch_id="granite-moe-3b-a800m",
    family="lm",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (scaled); hf",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=lm_shapes(subquadratic=False),
    rules_override={
        "experts": "pod",        # expert parallelism on the multi-pod mesh
        "moe_capacity": "data",  # dispatch buffers shard their token dim
    },
    exec_overrides={
        "train_4k": {"microbatches": 4},
    },
    notes="40-expert top-8 MoE; full attention ⇒ long_500k skipped.",
))
