"""qwen1.5-4b — dense LM, 40L d_model=2560 20H (GQA kv=20 ⇒ effectively MHA)
d_ff=6912 vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer.config import TransformerConfig


def build_cfg(**kw) -> TransformerConfig:
    base = dict(
        name="qwen1.5-4b", n_layers=40, d_model=2560, n_heads=20,
        n_kv_heads=20, d_ff=6912, vocab=151936, qkv_bias=True,
        mlp="swiglu", rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="float32",
    )
    base.update(kw)
    return TransformerConfig(**base)


def smoke_cfg() -> TransformerConfig:
    return build_cfg(name="qwen1.5-4b-smoke", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                     dtype="float32", attn_q_chunk=64)


register(ArchSpec(
    arch_id="qwen1.5-4b",
    family="lm",
    source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment); hf",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=lm_shapes(subquadratic=False),
    exec_overrides={
        "train_4k": {"microbatches": 4},
    },
    notes="QKV-bias MHA (kv == heads); full attention ⇒ long_500k skipped.",
))
