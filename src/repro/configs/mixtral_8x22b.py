"""mixtral-8x22b — MoE LM, 56L d_model=6144 48H (GQA kv=8) d_ff=16384
(per-expert), vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

Sliding-window attention (window 4096) bounds the KV cache and makes
attention sub-quadratic in sequence length, so this is the one LM arch that
*runs* the ``long_500k`` cell (524,288-token decode with a ring-buffered
4096-slot cache).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer.config import MoEConfig, TransformerConfig

SLIDING_WINDOW = 4096


def build_cfg(**kw) -> TransformerConfig:
    base = dict(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab=32768, qkv_bias=False,
        mlp="swiglu", rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=8, top_k=2),
        sliding_window=SLIDING_WINDOW,
        dtype="bfloat16", param_dtype="bfloat16",
    )
    base.update(kw)
    return TransformerConfig(**base)


def smoke_cfg() -> TransformerConfig:
    return build_cfg(name="mixtral-smoke", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
                     moe=MoEConfig(n_experts=4, top_k=2),
                     sliding_window=32, dtype="float32",
                     param_dtype="float32", attn_q_chunk=64)


register(ArchSpec(
    arch_id="mixtral-8x22b",
    family="lm",
    source="arXiv:2401.04088; hf",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=lm_shapes(
        subquadratic=True,
        long_note="runs via sliding-window attention: 4096-slot ring-buffer "
                  "KV cache keeps decode O(window) at 524k context"),
    rules_override={
        "embed": "data",         # FSDP for the 141B params
        "experts": "pod",        # expert parallelism on the multi-pod mesh
        "moe_capacity": "data",
    },
    exec_overrides={
        "train_4k": {"microbatches": 8},
    },
    notes="8-expert top-2 MoE with SWA; the only LM arch running long_500k.",
))
