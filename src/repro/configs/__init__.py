"""Config registry — importing this package registers every assigned
architecture (plus the paper's own PageRank workload)."""
from repro.configs.registry import (ArchSpec, ShapeSpec, get_arch,
                                    iter_cells, list_archs)

# one module per assigned architecture; import order = report order
from repro.configs import (  # noqa: F401  (registration side effects)
    qwen1_5_4b,
    phi4_mini_3_8b,
    nemotron_4_340b,
    granite_moe_3b_a800m,
    mixtral_8x22b,
    gatedgcn,
    egnn,
    graphsage_reddit,
    meshgraphnet,
    autoint,
    pagerank_df,
)

__all__ = ["ArchSpec", "ShapeSpec", "get_arch", "iter_cells", "list_archs"]
