"""meshgraphnet — GNN, n_layers=15 d_hidden=128 sum aggregator mlp_layers=2,
encode-process-decode with relative-position edge features.
[arXiv:2010.03409; unverified]"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.common import GNNConfig


def build_cfg(*, d_feat: int = 1433, n_out: int = 7, task: str = "node_reg",
              **kw) -> GNNConfig:
    base = dict(
        name="meshgraphnet", family="meshgraphnet", n_layers=15,
        d_hidden=128, aggregator="sum", mlp_layers=2,
        d_feat=d_feat, n_out=n_out, task=task,
    )
    base.update(kw)
    return GNNConfig(**base)


def smoke_cfg() -> GNNConfig:
    return build_cfg(name="meshgraphnet-smoke", n_layers=2, d_hidden=16,
                     d_feat=8, n_out=3)


register(ArchSpec(
    arch_id="meshgraphnet",
    family="gnn",
    source="arXiv:2010.03409; unverified",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=gnn_shapes(),
    notes="regression head (node_reg) everywhere except full_graph_sm / "
          "ogb_products / minibatch_lg which are classification datasets — "
          "those cells use node_clf heads sized by the shape spec.",
))
