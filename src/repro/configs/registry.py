"""Architecture registry: every assigned architecture (plus the paper's own
PageRank workload) registers an :class:`ArchSpec` here; the launcher, dry-run,
smoke tests and roofline all enumerate cells through this module.

A *cell* is one (architecture × input-shape) pair.  ``ShapeSpec.kind`` selects
which step function the cell lowers (``train_step`` vs ``serve_step`` etc.);
``skip`` carries the rule-based skip reason (e.g. quadratic attention at 524k
tokens) so skipped cells stay visible in every report.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape for an architecture."""
    name: str
    kind: str            # "train" | "prefill" | "decode" | "full_batch" |
    #                      "sampled" | "batched_small" | "serve" | "retrieval"
    dims: Dict[str, int] = dataclasses.field(default_factory=dict)
    note: str = ""
    skip: str = ""       # non-empty → cell excluded by rule (recorded, not run)

    def dim(self, key: str, default: Optional[int] = None) -> int:
        if key in self.dims:
            return self.dims[key]
        if default is None:
            raise KeyError(f"shape {self.name} has no dim {key}")
        return default


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One selectable ``--arch`` entry."""
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys" | "pagerank"
    source: str                       # provenance per the assignment table
    build_cfg: Callable[..., Any]     # full-size config (accepts overrides)
    smoke_cfg: Callable[[], Any]      # reduced config for CPU smoke tests
    shapes: Tuple[ShapeSpec, ...]
    # mesh-rule overrides merged over the family base rules (perf knobs live
    # here so the §Perf loop can iterate without touching model code)
    rules_override: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # per-shape execution overrides, e.g. {"train_4k": {"microbatches": 8}}
    exec_overrides: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}")

    def exec_for(self, shape_name: str) -> Dict[str, Any]:
        return dict(self.exec_overrides.get(shape_name, {}))


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    import repro.configs  # noqa: F401  (triggers registration)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def iter_cells(include_skipped: bool = False):
    """Yield (ArchSpec, ShapeSpec) for every assigned cell."""
    import repro.configs  # noqa: F401
    for arch_id in sorted(_REGISTRY):
        spec = _REGISTRY[arch_id]
        if spec.family == "pagerank":
            continue  # the paper's own workload is reported separately
        for shape in spec.shapes:
            if shape.skip and not include_skipped:
                continue
            yield spec, shape


# ---------------------------------------------------------------------------
# shared shape sets (assignment: one shape set per family)
# ---------------------------------------------------------------------------

def lm_shapes(*, subquadratic: bool, decode: bool = True,
              long_note: str = "") -> Tuple[ShapeSpec, ...]:
    """The LM-family shape set.  ``long_500k`` lowers ``serve_step`` and is
    skipped for pure full-attention archs (O(L²) at 524k tokens)."""
    long_skip = "" if subquadratic else (
        "full quadratic attention at seq 524,288 — O(L²) scores are "
        "infeasible; arch has no sub-quadratic path (see DESIGN.md "
        "§Arch-applicability)")
    return (
        ShapeSpec("train_4k", "train",
                  dict(seq_len=4096, global_batch=256)),
        ShapeSpec("prefill_32k", "prefill",
                  dict(seq_len=32768, global_batch=32)),
        ShapeSpec("decode_32k", "decode",
                  dict(seq_len=32768, global_batch=128)),
        ShapeSpec("long_500k", "decode",
                  dict(seq_len=524288, global_batch=1),
                  note=long_note, skip=long_skip),
    )


def gnn_shapes() -> Tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("full_graph_sm", "full_batch",
                  dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7)),
        ShapeSpec("minibatch_lg", "sampled",
                  dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                       fanout1=15, fanout2=10, d_feat=602, n_out=41),
                  note="sampled-training: the lowered step consumes the "
                       "sampled block; the full graph lives in the host "
                       "sampler (repro.graphs.sampler)"),
        ShapeSpec("ogb_products", "full_batch",
                  dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                       n_out=47)),
        ShapeSpec("molecule", "batched_small",
                  dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                       n_out=1)),
    )


def recsys_shapes() -> Tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_batch", "train", dict(batch=65536)),
        ShapeSpec("serve_p99", "serve", dict(batch=512)),
        ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
        ShapeSpec("retrieval_cand", "retrieval",
                  dict(batch=1, n_candidates=1000000)),
    )
