"""pagerank-df — the paper's own workload: Dynamic-Frontier lock-free
PageRank (Sahu, CS.DC 2024), as a distributed sweep over the production mesh.

Shapes mirror the paper's dataset classes (Table 2) at dry-run scale:
  * web_67m   — power-law web-crawl class (R-MAT-like),   n=2^26, d_avg 16
  * road_64m  — road-network class (near-planar, d_avg 3), n=2^26, d_avg 4
  * social_16m— dense social class,                        n=2^24, d_avg 64
These lower the *distributed DF sweep* (contribution exchange + local pull +
frontier expansion + convergence reduction) — the paper's inner loop — on
the 256/512-chip meshes.  Wall-clock experiments run host-scale graphs via
benchmarks/ (paper Figs 5-9).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, ShapeSpec, register


def build_cfg(**kw):
    base = dict(alpha=0.85, tau=1e-10, tau_f_ratio=1e-3, block_size=256,
                exchange="full")
    base.update(kw)
    return base


def smoke_cfg():
    return build_cfg(tau=1e-9)


def engine_config(cfg=None, **overrides):
    """Bridge an arch cfg dict (from :func:`build_cfg` / the sweep registry)
    into a validated :class:`repro.api.EngineConfig` for session-level runs:
    ``PageRankSession.from_graph(hg, config=engine_config(smoke_cfg()))``.
    ``tau_f_ratio`` is resolved to an absolute ``tau_f``; unknown overrides
    are rejected by ``EngineConfig.from_kwargs``."""
    from repro.api import EngineConfig
    cfg = dict(cfg or build_cfg())
    cfg.update(overrides)
    tau = cfg.pop("tau", 1e-10)
    kw = dict(alpha=cfg.pop("alpha", 0.85), tau=tau,
              tau_f=tau * cfg.pop("tau_f_ratio", 1e-3),
              block_size=cfg.pop("block_size", 256))
    cfg.pop("exchange", None)   # distributed-sweep knob, not a session knob
    kw.update(cfg)              # the rest must be EngineConfig keys
    return EngineConfig.from_kwargs(**kw)


register(ArchSpec(
    arch_id="pagerank-df",
    family="pagerank",
    source="the reproduced paper (Sahu, CS.DC 2024)",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=(
        ShapeSpec("web_67m", "sweep",
                  dict(n_vertices=1 << 26, avg_degree=16)),
        ShapeSpec("road_64m", "sweep",
                  dict(n_vertices=1 << 26, avg_degree=4)),
        ShapeSpec("social_16m", "sweep",
                  dict(n_vertices=1 << 24, avg_degree=64)),
    ),
    notes="the reproduction itself; exchange ∈ {full, bf16, delta} is the "
          "§Perf axis (frontier-aware sparse-delta collective).",
))
