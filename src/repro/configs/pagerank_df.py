"""pagerank-df — the paper's own workload: Dynamic-Frontier lock-free
PageRank (Sahu, CS.DC 2024), as a distributed sweep over the production mesh.

Shapes mirror the paper's dataset classes (Table 2) at dry-run scale:
  * web_67m   — power-law web-crawl class (R-MAT-like),   n=2^26, d_avg 16
  * road_64m  — road-network class (near-planar, d_avg 3), n=2^26, d_avg 4
  * social_16m— dense social class,                        n=2^24, d_avg 64
These lower the *distributed DF sweep* (contribution exchange + local pull +
frontier expansion + convergence reduction) — the paper's inner loop — on
the 256/512-chip meshes.  Wall-clock experiments run host-scale graphs via
benchmarks/ (paper Figs 5-9).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, ShapeSpec, register


def build_cfg(**kw):
    base = dict(alpha=0.85, tau=1e-10, tau_f_ratio=1e-3, block_size=256,
                exchange="full")
    base.update(kw)
    return base


def smoke_cfg():
    return build_cfg(tau=1e-9)


register(ArchSpec(
    arch_id="pagerank-df",
    family="pagerank",
    source="the reproduced paper (Sahu, CS.DC 2024)",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=(
        ShapeSpec("web_67m", "sweep",
                  dict(n_vertices=1 << 26, avg_degree=16)),
        ShapeSpec("road_64m", "sweep",
                  dict(n_vertices=1 << 26, avg_degree=4)),
        ShapeSpec("social_16m", "sweep",
                  dict(n_vertices=1 << 24, avg_degree=64)),
    ),
    notes="the reproduction itself; exchange ∈ {full, bf16, delta} is the "
          "§Perf axis (frontier-aware sparse-delta collective).",
))
