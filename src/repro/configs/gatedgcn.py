"""gatedgcn — GNN, n_layers=16 d_hidden=70, gated edge aggregation.
[arXiv:2003.00982; paper]"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, gnn_shapes, register
from repro.models.gnn.common import GNNConfig


def build_cfg(*, d_feat: int = 1433, n_out: int = 7, task: str = "node_clf",
              **kw) -> GNNConfig:
    base = dict(
        name="gatedgcn", family="gatedgcn", n_layers=16, d_hidden=70,
        aggregator="gated", d_feat=d_feat, n_out=n_out, task=task,
    )
    base.update(kw)
    return GNNConfig(**base)


def smoke_cfg() -> GNNConfig:
    return build_cfg(name="gatedgcn-smoke", n_layers=2, d_hidden=16,
                     d_feat=8, n_out=3)


register(ArchSpec(
    arch_id="gatedgcn",
    family="gnn",
    source="arXiv:2003.00982; paper",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=gnn_shapes(),
    notes="d_hidden=70 is kept exact per the assignment (not lane-aligned); "
          "the §Perf log measures the pad-to-128 variant.",
))
