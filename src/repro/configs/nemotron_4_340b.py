"""nemotron-4-340b — dense LM, 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP.  [arXiv:2402.16819; unverified]

At 340B parameters this is the memory-limit architecture of the assignment:
  * params/grads/optimizer state in bf16 (f32 Adam state alone would be
    21 GB/chip on the single-pod mesh — over the 16 GB v5e HBM);
  * FSDP: the d_model ("embed") param axis shards over "data" in addition to
    the usual tensor-parallel axes, giving full 256/512-way param sharding;
  * sequence parallelism: residual-stream activations shard their seq axis
    over "model" between layers, cutting remat carries 16×;
  * 8 gradient-accumulation microbatches.
All four choices are recorded as hardware-adaptation deltas in DESIGN.md.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, lm_shapes, register
from repro.models.transformer.config import TransformerConfig


def build_cfg(**kw) -> TransformerConfig:
    base = dict(
        name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96,
        n_kv_heads=8, d_ff=73728, vocab=256000, qkv_bias=False,
        mlp="squared_relu", rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
    base.update(kw)
    return TransformerConfig(**base)


def smoke_cfg() -> TransformerConfig:
    return build_cfg(name="nemotron-smoke", n_layers=2, d_model=64,
                     n_heads=8, n_kv_heads=2, d_ff=256, vocab=256,
                     dtype="float32", param_dtype="float32",
                     attn_q_chunk=64)


register(ArchSpec(
    arch_id="nemotron-4-340b",
    family="lm",
    source="arXiv:2402.16819; unverified",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=lm_shapes(subquadratic=False),
    rules_override={
        "embed": "data",        # FSDP / ZeRO-3-style param sharding
        "seq": "model",         # sequence-parallel residual stream
    },
    exec_overrides={
        "train_4k": {"microbatches": 8, "state_dtype": "bfloat16",
                     "accum_dtype": "bfloat16"},
    },
    notes="squared-ReLU GQA; bf16 states + FSDP + SP to fit 16 GB/chip; "
          "full attention ⇒ long_500k skipped.",
))
