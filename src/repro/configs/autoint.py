"""autoint — recsys, n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32, self-attention feature interaction.  [arXiv:1810.11921; paper]

The fused embedding table is 39 fields × 1M hashed rows × 16 dims (the
criteo-scale setting); rows shard over the "model" mesh axis and the lookup
is EmbeddingBag-from-scratch (take + segment_sum, see
repro/models/recsys/embedding.py).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec, recsys_shapes, register
from repro.models.recsys.autoint import AutoIntConfig


def build_cfg(**kw) -> AutoIntConfig:
    base = dict(
        name="autoint", n_sparse=39, embed_dim=16, n_attn_layers=3,
        n_heads=2, d_attn=32, rows_per_field=1_000_000, n_user_fields=20,
    )
    base.update(kw)
    return AutoIntConfig(**base)


def smoke_cfg() -> AutoIntConfig:
    return build_cfg(name="autoint-smoke", n_sparse=6, embed_dim=8,
                     n_attn_layers=2, n_heads=2, d_attn=8,
                     rows_per_field=100, n_user_fields=3)


register(ArchSpec(
    arch_id="autoint",
    family="recsys",
    source="arXiv:1810.11921; paper",
    build_cfg=build_cfg,
    smoke_cfg=smoke_cfg,
    shapes=recsys_shapes(),
    notes="retrieval_cand scores 1 query against 10^6 candidates with a "
          "batched two-tower dot (no loop).",
))
