"""Synthetic graph generators mirroring the paper's dataset families.

The paper evaluates on (a) two real-world temporal networks and (b) twelve
SuiteSparse graphs spanning web crawls (power-law), social networks (dense
power-law), road networks (near-planar, degree ~3) and protein k-mer graphs
(sparse, chain-like).  Offline we generate structurally analogous graphs:

  * ``rmat``           — Kronecker/R-MAT power-law digraphs (web/social class)
  * ``erdos_renyi``    — uniform random digraphs
  * ``grid_road``      — 2-D lattice with random diagonals (road class)
  * ``kmer_chains``    — long weakly-linked chains (k-mer class)
  * ``powerlaw``       — Zipf out-degree digraphs (hub-stress class for the
                         walk engine's visit distributions)
  * ``temporal_stream``— timestamped edge stream (temporal-network class)

All generators are numpy-based (host substrate) and deterministic per seed.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import HostGraph


def _dedupe(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    keys = src.astype(np.int64) * n + dst.astype(np.int64)
    keys = np.unique(keys)
    return np.stack([keys // n, keys % n], axis=1)


def rmat(n_log2: int, avg_degree: int = 16, *, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         chunk_edges: Optional[int] = None) -> HostGraph:
    """R-MAT generator (Chakrabarti et al.); power-law in/out degrees.

    ``chunk_edges`` bounds the build's transient host memory: the edge
    list is generated in chunks of that many edges (~40 bytes/edge of
    transients per chunk instead of per the whole graph — a 100M-edge
    build stays under a flat ceiling instead of peaking at ~4 GB), with
    progressive sorted-unique merging.  Seed-reproducible against the
    monolithic path bit-for-bit: each chunk re-derives the exact slice of
    the monolithic PCG64 random stream it would have consumed, via
    ``PCG64.advance`` (the monolithic build draws ``m`` uniforms per
    level, so chunk ``[lo, lo+k)`` of level ``L`` is the stream advanced
    by ``L*m + lo``)."""
    n = 1 << n_log2
    m = n * avg_degree
    if chunk_edges is not None:
        if chunk_edges <= 0:
            raise ValueError(f"chunk_edges={chunk_edges} must be positive")
        keys = np.empty(0, np.int64)
        for lo in range(0, m, chunk_edges):
            k = min(chunk_edges, m - lo)
            src = np.zeros(k, dtype=np.int64)
            dst = np.zeros(k, dtype=np.int64)
            for level in range(n_log2):
                bg = np.random.PCG64(seed)
                bg.advance(level * m + lo)
                r = np.random.Generator(bg).random(k)
                right = r >= a + b
                down = ((r >= a) & (r < a + b)) | (r >= a + b + c)
                src |= (down.astype(np.int64) << level)
                dst |= (right.astype(np.int64) << level)
            ck = src * np.int64(n) + dst
            keys = np.union1d(keys, ck)     # sorted-unique merge
        return HostGraph(n, np.stack([keys // n, keys % n], axis=1))
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities a,b,c,d
        right = r >= a + b
        down = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= (down.astype(np.int64) << level)
        dst |= (right.astype(np.int64) << level)
    return HostGraph(n, _dedupe(n, src, dst))


def erdos_renyi(n: int, avg_degree: int = 8, *, seed: int = 0) -> HostGraph:
    rng = np.random.default_rng(seed)
    m = n * avg_degree
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return HostGraph(n, _dedupe(n, src, dst))


def grid_road(side: int, *, diag_frac: float = 0.05, seed: int = 0
              ) -> HostGraph:
    """2-D lattice digraph (both directions) + a few random shortcuts.
    Average degree ≈ 3-4, mirroring asia_osm / europe_osm."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    right = vid[(jj < side - 1).ravel()]
    down = vid[(ii < side - 1).ravel()]
    e = [np.stack([right, right + 1], 1), np.stack([right + 1, right], 1),
         np.stack([down, down + side], 1), np.stack([down + side, down], 1)]
    k = int(diag_frac * n)
    if k:
        s = rng.integers(0, n, k)
        d = rng.integers(0, n, k)
        e.append(np.stack([s, d], 1))
    return HostGraph(n, _dedupe(n, *np.concatenate(e).T))


def kmer_chains(n: int, chain_len: int = 64, *, seed: int = 0) -> HostGraph:
    """Disjoint long chains with sparse cross links (protein k-mer class)."""
    rng = np.random.default_rng(seed)
    v = np.arange(n - 1, dtype=np.int64)
    mask = (v + 1) % chain_len != 0
    fwd = np.stack([v[mask], v[mask] + 1], 1)
    bwd = fwd[:, ::-1]
    k = n // 50
    cross = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)], 1)
    return HostGraph(n, _dedupe(n, *np.concatenate([fwd, bwd, cross]).T))


def powerlaw(n: int, avg_degree: int = 8, *, seed: int = 0,
             exponent: float = 2.1) -> HostGraph:
    """Zipf out-degree digraph: vertex out-degrees follow a truncated
    power law with the given ``exponent`` (2.1 ≈ web crawls), rescaled to
    hit ``avg_degree`` on average; destinations are uniform.  Exercises
    hub-heavy walk-length / visit distributions (a hub's walk set is a
    large fraction of the store) without R-MAT's correlated in/out skew."""
    if n < 2:
        raise ValueError(f"n={n} must be >= 2")
    if avg_degree < 1:
        raise ValueError(f"avg_degree={avg_degree} must be >= 1")
    if exponent <= 1.0:
        raise ValueError(f"exponent={exponent} must be > 1 (Zipf)")
    rng = np.random.default_rng(seed)
    deg = rng.zipf(exponent, size=n).astype(np.int64)
    np.minimum(deg, n - 1, out=deg)     # cap: simple digraph, no self-loop
    scale = avg_degree / max(deg.mean(), 1e-12)
    deg = np.maximum((deg * scale).astype(np.int64), 1)
    np.minimum(deg, n - 1, out=deg)
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = rng.integers(0, n, size=src.size)
    keep = src != dst
    return HostGraph(n, _dedupe(n, src[keep], dst[keep]))


def temporal_stream(n: int, m_total: int, *, seed: int = 0,
                    preferential: bool = True
                    ) -> np.ndarray:
    """Timestamped edge insertions [m_total, 2]; later edges prefer recently
    active vertices (mirrors wiki-talk / stackoverflow growth)."""
    rng = np.random.default_rng(seed)
    if not preferential:
        return np.stack([rng.integers(0, n, m_total),
                         rng.integers(0, n, m_total)], 1)
    # preferential attachment-ish: sample dst from a growing popularity table
    src = rng.integers(0, n, m_total)
    pop = rng.integers(0, n, m_total)          # candidate by popularity recency
    uni = rng.integers(0, n, m_total)
    take_pop = rng.random(m_total) < 0.6
    dst = np.where(take_pop, pop * rng.random(m_total), uni).astype(np.int64)
    dst = np.clip(dst, 0, n - 1)
    return np.stack([src, dst], 1)


GENERATORS = {
    "rmat": rmat,
    "erdos_renyi": erdos_renyi,
    "grid_road": grid_road,
    "kmer_chains": kmer_chains,
    "powerlaw": powerlaw,
}
