"""Device partitioners for distributed graph work.

The 1-D contiguous partition in core/distributed.py is the baseline; the
edge-cut-aware partitioners here reduce the cross-device frontier traffic
(the collective term of the roofline) for graphs with locality:

  * ``contiguous``   — vertex v → device v // n_loc (road networks and
    k-mer chains already have index locality → low edge-cut);
  * ``hash``         — vertex v → device hash(v) % n_dev (load-balanced but
    worst-case edge-cut; what you use when the id space is adversarial);
  * ``bfs_blocks``   — BFS-order relabeling then contiguous split: a cheap
    locality-recovering partition for power-law graphs (a lightweight
    stand-in for METIS-class partitioners, which would be overkill here).

``edge_cut`` measures the fraction of edges crossing devices — the direct
driver of the pagerank sweep's all-gather volume under the "delta" exchange.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import HostGraph

PARTITIONERS = ("contiguous", "hash", "bfs_blocks")


def contiguous(n: int, n_dev: int) -> np.ndarray:
    n_loc = -(-n // n_dev)
    return np.arange(n) // n_loc


def hashed(n: int, n_dev: int, *, seed: int = 0x9E3779B9) -> np.ndarray:
    v = np.arange(n, dtype=np.uint64)
    v = (v * np.uint64(seed)) & np.uint64(0xFFFFFFFF)
    return (v % np.uint64(n_dev)).astype(np.int64)


def bfs_order(hg: HostGraph) -> np.ndarray:
    """BFS relabeling: order[new_id] = old_id (undirected view)."""
    e = hg.edges
    n = hg.n
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    order_idx = np.argsort(src, kind="stable")
    src_s, dst_s = src[order_idx], dst[order_idx]
    ptr = np.searchsorted(src_s, np.arange(n + 1))
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    for seed in range(n):
        if visited[seed]:
            continue
        frontier = [seed]
        visited[seed] = True
        while frontier:
            nxt = []
            for u in frontier:
                order[pos] = u
                pos += 1
                nbrs = dst_s[ptr[u]:ptr[u + 1]]
                for w in nbrs[~visited[nbrs]]:
                    if not visited[w]:
                        visited[w] = True
                        nxt.append(w)
            frontier = nxt
    return order


def bfs_blocks(hg: HostGraph, n_dev: int) -> np.ndarray:
    """Vertex → device map via BFS-order contiguous split."""
    order = bfs_order(hg)
    owner = np.empty(hg.n, dtype=np.int64)
    owner[order] = contiguous(hg.n, n_dev)
    return owner


def make_partition(hg: HostGraph, n_dev: int, kind: str = "contiguous",
                   *, seed: int = 0x9E3779B9
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Resolve a partitioner name to ``(order, inv, owner)``.

    ``owner[old_id]`` is the device the partitioner *requests*;
    ``order`` (``order[new_id] = old_id``) relabels vertices so requested
    owners are grouped contiguously (stable within a device), and ``inv``
    is its inverse (``inv[old_id] = new_id``).  The contiguous 1-D layout
    downstream assigns equal ``ceil(n/n_dev)`` shares, so the *realized*
    owner of vertex ``v`` is ``inv[v] // n_loc`` — identical to the request
    for balanced partitioners, spilling a few boundary vertices otherwise
    (e.g. ``hash``).
    """
    if kind == "contiguous":
        owner = contiguous(hg.n, n_dev)
    elif kind == "hash":
        owner = hashed(hg.n, n_dev, seed=seed)
    elif kind == "bfs_blocks":
        owner = bfs_blocks(hg, n_dev)
    else:
        raise ValueError(f"unknown partitioner {kind!r}; "
                         f"expected one of {PARTITIONERS}")
    order = np.argsort(owner, kind="stable")
    inv = np.empty(hg.n, dtype=np.int64)
    inv[order] = np.arange(hg.n)
    return order, inv, owner


def edge_cut(hg: HostGraph, owner: np.ndarray) -> float:
    """Fraction of edges whose endpoints live on different devices."""
    e = hg.edges
    if len(e) == 0:
        return 0.0
    return float(np.mean(owner[e[:, 0]] != owner[e[:, 1]]))


def relabel(hg: HostGraph, order: np.ndarray) -> Tuple[HostGraph, np.ndarray]:
    """Apply a vertex relabeling; returns (new graph, inverse map)."""
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    e = hg.edges
    return HostGraph(hg.n, np.stack([inv[e[:, 0]], inv[e[:, 1]]], 1)), inv
