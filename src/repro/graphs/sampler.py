"""GraphSAGE neighbor sampler (host-side, numpy CSR).

The real minibatch pipeline: build a CSR of out-neighbors once, then per
step sample ``fanouts`` neighbors per hop with replacement (isolated
vertices sample themselves), exactly as in the GraphSAGE paper.  Returns
*global* node-id arrays per hop; the data pipeline gathers features.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.graph import HostGraph


class NeighborSampler:
    def __init__(self, n: int, src: np.ndarray, dst: np.ndarray):
        self.n = int(n)
        order = np.argsort(src, kind="stable")
        self._dst = np.asarray(dst)[order]
        counts = np.bincount(np.asarray(src), minlength=n)
        self._ptr = np.concatenate([[0], np.cumsum(counts)])

    @classmethod
    def from_host_graph(cls, hg: HostGraph) -> "NeighborSampler":
        e = hg.edges
        return cls(hg.n, e[:, 0], e[:, 1])

    def degree(self, v: np.ndarray) -> np.ndarray:
        return self._ptr[v + 1] - self._ptr[v]

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """[len(nodes), fanout] global ids, sampled w/ replacement;
        zero-degree nodes yield themselves (self-loop semantics)."""
        nodes = np.asarray(nodes).reshape(-1)
        deg = self.degree(nodes)
        off = rng.integers(0, 1 << 31, size=(len(nodes), fanout))
        idx = self._ptr[nodes][:, None] + off % np.maximum(deg, 1)[:, None]
        out = self._dst[np.minimum(idx, len(self._dst) - 1)]
        return np.where(deg[:, None] > 0, out, nodes[:, None])

    def sample_block(self, seeds: np.ndarray, fanouts: Sequence[int],
                     rng: np.random.Generator) -> List[np.ndarray]:
        """Multi-hop sample: returns [seeds [B], hop1 [B,f1],
        hop2 [B,f1,f2], ...] of global node ids."""
        out = [np.asarray(seeds).reshape(-1)]
        cur = out[0]
        shape = (len(cur),)
        for f in fanouts:
            nxt = self.sample_neighbors(cur.reshape(-1), f, rng)
            shape = shape + (f,)
            out.append(nxt.reshape(shape))
            cur = nxt
        return out


def minibatch_stream(sampler: NeighborSampler, feats: np.ndarray,
                     labels: np.ndarray, batch_nodes: int,
                     fanouts: Sequence[int], *, seed: int = 0):
    """Yields (hop-feature list, seed labels) minibatches forever."""
    rng = np.random.default_rng(seed)
    while True:
        seeds = rng.integers(0, sampler.n, size=batch_nodes)
        hops = sampler.sample_block(seeds, fanouts, rng)
        yield [feats[h] for h in hops], labels[seeds]
