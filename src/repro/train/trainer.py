"""Training-step builder: grad-accumulation microbatching (lax.scan),
AdamW from :mod:`repro.optim.adam`, optional bf16 gradient compression for
the cross-device reduction, and donation-friendly signatures.

``build_train_step`` is mesh-agnostic — distribution comes from jitting the
returned function with ``in_shardings``/``out_shardings`` (see
:mod:`repro.launch.dryrun` / ``launch/train.py``).  ZeRO-1/FSDP are purely
sharding decisions made there via :mod:`repro.dist.sharding`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # gradient-accumulation steps per update
    grad_dtype: Optional[str] = None   # e.g. "bfloat16": compress the grad
    # all-reduce wire format (fp32 accumulation is kept inside Adam)
    accum_dtype: str = "float32"   # microbatch gradient-accumulator dtype
    # (bf16 halves the accumulator footprint; used by the 340B config)
    scan_microbatches: bool = True  # False: unroll the accumulation loop so
    # the compiled HLO carries exact per-step FLOPs (dry-run roofline)


LossFn = Callable[[Any, Dict[str, jnp.ndarray]],
                  Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_zeros_like(t, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), t)


def build_train_step(loss_fn: LossFn, adam_cfg: adam.AdamConfig,
                     tcfg: TrainConfig = TrainConfig()):
    """Returns ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)``.

    ``batch`` leaves carry the *global* batch on their leading dim; with
    ``microbatches > 1`` they are split and scanned so only one microbatch's
    activations are live at a time (the standard memory/throughput trade).
    """
    mb = tcfg.microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, loss, metrics

    def train_step(params, opt_state, batch):
        if mb <= 1:
            grads, loss, metrics = grads_of(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def body(carry, mb_batch):
                gsum, lsum = carry
                g, l, m = grads_of(params, mb_batch)
                g = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 gsum, g)
                return (g, lsum + l), m

            g0 = _tree_zeros_like(params, jnp.dtype(tcfg.accum_dtype))
            init = (g0, jnp.zeros((), jnp.float32))
            if tcfg.scan_microbatches:
                (grads, loss), ms = jax.lax.scan(body, init, split)
                metrics = jax.tree.map(lambda x: x.mean(0), ms)
            else:
                carry, ms = init, []
                for i in range(mb):
                    carry, m = body(carry, jax.tree.map(
                        lambda x: x[i], split))
                    ms.append(m)
                grads, loss = carry
                metrics = jax.tree.map(
                    lambda *xs: jnp.stack(xs).mean(0), *ms)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb

        if tcfg.grad_dtype is not None:
            wire = jnp.dtype(tcfg.grad_dtype)
            grads = jax.tree.map(lambda g: g.astype(wire), grads)

        params, opt_state, opt_metrics = adam.apply_updates(
            params, grads, opt_state, adam_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# family loss adapters (batch dict → model loss)
# ---------------------------------------------------------------------------

def lm_loss(cfg):
    from repro.models.transformer import model as M

    def fn(params, batch):
        return M.loss_fn(params, batch["tokens"], batch["labels"], cfg)
    return fn


def gnn_loss(cfg):
    from repro.models.gnn import get_family
    mod = get_family(cfg)

    def fn(params, batch):
        return mod.loss_fn(params, cfg, batch["graph"], batch["labels"])
    return fn


def gnn_sampled_loss(cfg):
    from repro.models.gnn import graphsage

    def fn(params, batch):
        feats = [batch[f"hop{i}"] for i in range(cfg.n_layers + 1)]
        return graphsage.loss_fn_sampled(params, cfg, feats, batch["labels"])
    return fn


def recsys_loss(cfg):
    from repro.models.recsys import autoint

    def fn(params, batch):
        return autoint.loss_fn(params, cfg, batch["ids"], batch["labels"])
    return fn


# ---------------------------------------------------------------------------
# simple host training loop (examples / integration tests)
# ---------------------------------------------------------------------------

def fit(loss_fn: LossFn, params, data_iter, *, adam_cfg=None,
        tcfg: TrainConfig = TrainConfig(), steps: int = 100,
        log_every: int = 0, checkpointer=None, ckpt_every: int = 0,
        start_step: int = 0):
    """Single-host training loop used by the examples; returns
    (params, opt_state, history)."""
    adam_cfg = adam_cfg or adam.AdamConfig(total_steps=steps)
    step_fn = jax.jit(build_train_step(loss_fn, adam_cfg, tcfg),
                      donate_argnums=(0, 1))
    opt_state = adam.init_state(params, adam_cfg)
    if checkpointer is not None:
        restored = checkpointer.restore_latest()
        if restored is not None:
            params, opt_state, start_step = restored
    history = []
    for i, batch in enumerate(data_iter):
        step = start_step + i
        if step >= steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if log_every and (step % log_every == 0 or step == steps - 1):
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"step {step:5d}  loss {loss:.4f}")
        if checkpointer is not None and ckpt_every and \
                (step + 1) % ckpt_every == 0:
            checkpointer.save(params, opt_state, step + 1)
    return params, opt_state, history
