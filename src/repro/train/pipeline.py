"""Pipeline parallelism (GPipe wavefront) + in-stage tensor parallelism.

Motivation (§Perf, nemotron-4-340b/train_4k): on a fixed (data=16, model=16)
mesh, 340B params cannot fit replicated (42.5 GB/chip) and FSDP re-gathers
every parameter every microbatch — ~23 TB of all-gather per device per step
(the measured baseline).  Pipelining makes weights STATIONARY:

  * "model" axis = 16 pipeline stages (n_layers/16 layers each);
  * "data" axis  = 16-way Megatron tensor parallelism inside each stage
    (q-heads/ff columns sharded; the 8 GQA KV heads are replicated — kv
    head r//2 serves device r's 6 query heads);
  * microbatches stream through a lax.scan wavefront; stage hand-off is a
    single seq-sharded ``collective_permute`` (residuals travel sharded:
    Megatron-SP all-gather(seq) → compute → reduce-scatter(seq) per block);
  * "pod" axis (multi-pod) = data parallelism over pipeline replicas.

The collective bill becomes activation-sized instead of parameter-sized:
per device ≈ L_loc·mb·4·|x|·(g−1)/g ≈ 0.9 TB vs 23 TB — the hypothesis→
measure log lives in EXPERIMENTS.md §Perf.

The backward pipeline is DERIVED: ``jax.grad`` through the ppermute/scan
forward yields the reverse wavefront automatically; ``jax.checkpoint`` on
the per-tick stage body keeps only seq-sharded carries alive.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.transformer import layers as L
from repro.models.transformer.config import TransformerConfig

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    stage_axis: str = "model"
    tp_axis: str = "data"
    dp_axis: Optional[str] = "pod"      # absent on single-pod meshes
    microbatches: int = 16


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def param_pspecs(cfg: TransformerConfig, pcfg: PipelineConfig, mesh: Mesh
                 ) -> Dict[str, P]:
    """PartitionSpec per flat param name (layer dim → stages, heads/ff → TP,
    emb D-sharded, head V-sharded).  KV tensors replicate over TP."""
    st, tp = pcfg.stage_axis, pcfg.tp_axis
    specs = {
        "layers/attn_norm": P(st, None),
        "layers/mlp_norm": P(st, None),
        "layers/wq": P(st, None, tp, None),
        "layers/wk": P(st, None, None, None),
        "layers/wv": P(st, None, None, None),
        "layers/wo": P(st, tp, None, None),
        "layers/wi": P(st, None, tp),
        "layers/wi_gate": P(st, None, tp),
        "layers/wi_up": P(st, None, tp),
        "layers/wo_mlp": P(st, tp, None),
        "layers/bq": P(st, tp, None),
        "layers/bk": P(st, None, None),
        "layers/bv": P(st, None, None),
        "emb": P(None, tp),
        "head": P(None, tp),
        "final_norm": P(),
    }
    return specs


def validate(cfg: TransformerConfig, pcfg: PipelineConfig, mesh: Mesh):
    st = mesh.shape[pcfg.stage_axis]
    tp = mesh.shape[pcfg.tp_axis]
    assert cfg.n_layers % st == 0, "layers must divide stages"
    assert cfg.n_heads % tp == 0, "q heads must divide TP"
    assert cfg.d_ff % tp == 0, "d_ff must divide TP"
    assert cfg.d_model % tp == 0, "d_model must divide TP (emb shard)"
    h_loc, rep = cfg.n_heads // tp, cfg.q_per_kv
    assert (h_loc <= rep and rep % h_loc == 0) or h_loc % rep == 0, \
        "local q-heads must tile kv groups"
    assert cfg.moe is None, "pipeline path covers dense archs"
    return st, tp


# ---------------------------------------------------------------------------
# the pipelined forward
# ---------------------------------------------------------------------------

def build_pipeline_loss(cfg: TransformerConfig, pcfg: PipelineConfig,
                        mesh: Mesh, *, global_batch: int, seq: int):
    """Returns ``loss_fn(params, batch) -> (loss, metrics)`` whose body is a
    shard_map pipeline; differentiate + jit it like any other loss."""
    n_stages, tp = validate(cfg, pcfg, mesh)
    st_ax, tp_ax = pcfg.stage_axis, pcfg.tp_axis
    dp_ax = pcfg.dp_axis if (pcfg.dp_axis in mesh.axis_names) else None
    dp = mesh.shape[dp_ax] if dp_ax else 1
    n_mb = pcfg.microbatches
    assert global_batch % (n_mb * dp) == 0
    mb = global_batch // (n_mb * dp)          # sequences per microbatch
    L_loc = cfg.n_layers // n_stages
    H_loc = cfg.n_heads // tp
    S_loc = seq // tp
    dh = cfg.d_head
    dt = jnp.dtype(cfg.dtype)
    kv_per_q_group = cfg.n_heads // cfg.n_kv_heads

    def stage_block(lp, x_sh, positions, tp_rank):
        """One stage's L_loc layers; x_sh [mb, S_loc, D] seq-sharded."""
        def one_layer(x_sh, i):
            p = jax.tree.map(lambda a: a[i], lp)
            # -- attention (Megatron-SP) --------------------------------
            h_sh = L.rmsnorm(x_sh, p["attn_norm"].astype(jnp.float32),
                             cfg.norm_eps)
            h = lax.all_gather(h_sh, tp_ax, axis=1, tiled=True)  # [mb,S,D]
            q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
            k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
            if cfg.qkv_bias:
                q = q + p["bq"].astype(dt)
                k = k + p["bk"].astype(dt)
                v = v + p["bv"].astype(dt)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            # GQA under TP: device r's H_loc q-heads span the kv heads
            # [kv0, kv0+KV_loc) (kv-group-major head layout, as in the
            # reference model's [KV, rep] reshape)
            rep = kv_per_q_group
            kv_loc = max(1, H_loc // rep)
            rep_loc = min(rep, H_loc)
            kv0 = (tp_rank * H_loc) // rep
            ks = lax.dynamic_slice_in_dim(k, kv0, kv_loc, axis=2)
            vs = lax.dynamic_slice_in_dim(v, kv0, kv_loc, axis=2)
            B_, S_ = q.shape[0], q.shape[1]
            q5 = q.reshape(B_, S_, kv_loc, rep_loc, dh) * (dh ** -0.5)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", q5, ks,
                           preferred_element_type=jnp.float32)
            causal = positions[None, :] <= positions[:, None]
            s = jnp.where(causal[None, None, None], s, L.NEG_INF)
            a = jax.nn.softmax(s, axis=-1).astype(dt)
            o = jnp.einsum("bgrqk,bkgd->bqgrd", a, vs)
            o = o.reshape(B_, S_, H_loc, dh)
            part = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
            attn_sh = lax.psum_scatter(part, tp_ax, scatter_dimension=1,
                                       tiled=True)
            x_sh = x_sh + attn_sh
            # -- mlp ----------------------------------------------------
            h_sh = L.rmsnorm(x_sh, p["mlp_norm"].astype(jnp.float32),
                             cfg.norm_eps)
            h = lax.all_gather(h_sh, tp_ax, axis=1, tiled=True)
            if cfg.mlp == "swiglu":
                g = jnp.einsum("bsd,df->bsf", h, p["wi_gate"].astype(dt))
                u = jnp.einsum("bsd,df->bsf", h, p["wi_up"].astype(dt))
                hh = jax.nn.silu(g) * u
            else:
                hh = jnp.einsum("bsd,df->bsf", h, p["wi"].astype(dt))
                hh = jnp.square(jax.nn.relu(hh))
            part = jnp.einsum("bsf,fd->bsd", hh, p["wo_mlp"].astype(dt))
            mlp_sh = lax.psum_scatter(part, tp_ax, scatter_dimension=1,
                                      tiled=True)
            return x_sh + mlp_sh

        # (a nested per-layer checkpoint was tried and REFUTED: +24%
        # collective traffic from re-gathering activations in the extra
        # recompute pass, with no peak-memory gain — §Perf pair 1 iter 4)
        for i in range(L_loc):
            x_sh = one_layer(x_sh, i)
        return x_sh

    def body(tokens, labels, *flat_params):
        params = dict(zip(flat_names, flat_params))
        stage = lax.axis_index(st_ax)
        tp_rank = lax.axis_index(tp_ax)
        positions = jnp.arange(seq, dtype=jnp.int32)
        lp = {k.split("/", 1)[1]: v for k, v in params.items()
              if k.startswith("layers/")}
        emb = params["emb"]                      # [V, D_loc]
        head = params["head"] if "head" in params else None
        D_loc = emb.shape[1]

        def embed(tok):                          # [mb, S] -> [mb, S_loc, D]
            e_part = jnp.take(emb, tok, axis=0).astype(dt)  # [mb,S,D_loc]
            e = lax.all_gather(e_part, tp_ax, axis=2, tiled=True)
            return lax.dynamic_slice_in_dim(
                e, tp_rank * S_loc, S_loc, axis=1)

        def loss_of(x_sh, lab):
            # gather seq, final norm, vocab-sharded head + stable sharded CE
            x = lax.all_gather(x_sh, tp_ax, axis=1, tiled=True)
            x = L.rmsnorm(x, params["final_norm"].astype(jnp.float32),
                          cfg.norm_eps)
            if cfg.tie_embeddings:
                # emb is D-sharded → partial matmul over the local D slice
                x_part = lax.dynamic_slice_in_dim(
                    x, tp_rank * D_loc, D_loc, axis=2)
                logits = lax.psum(
                    jnp.einsum("bsd,vd->bsv", x_part, emb.astype(dt),
                               preferred_element_type=jnp.float32), tp_ax)
                lse = jax.nn.logsumexp(logits, axis=-1)
                onehot = lab[..., None] == jnp.arange(
                    logits.shape[-1], dtype=lab.dtype)
                ll = jnp.sum(jnp.where(onehot, logits, 0), axis=-1)
            else:
                logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt),
                                    preferred_element_type=jnp.float32)
                vlo = tp_rank * logits.shape[-1]
                # max-shift is for stability only; pmax has no VJP, so cut
                # the tape BEFORE it (the lse gradient stays exact)
                mx = lax.pmax(
                    lax.stop_gradient(jnp.max(logits, axis=-1)), tp_ax)
                zsum = lax.psum(
                    jnp.sum(jnp.exp(logits - mx[..., None]), -1), tp_ax)
                lse = jnp.log(zsum) + mx
                onehot = (lab[..., None]
                          == (jnp.arange(logits.shape[-1],
                                         dtype=lab.dtype) + vlo))
                ll = lax.psum(jnp.sum(jnp.where(onehot, logits, 0), -1),
                              tp_ax)
            mask = (lab >= 0).astype(jnp.float32)
            return jnp.sum((lse - ll) * mask), jnp.sum(mask)

        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            x_sh, nll, cnt = carry
            x_in = lax.ppermute(x_sh, st_ax, fwd_perm)
            m0 = jnp.clip(t, 0, n_mb - 1) * mb
            tok = lax.dynamic_slice_in_dim(tokens, m0, mb, axis=0)
            lab = lax.dynamic_slice_in_dim(labels, m0, mb, axis=0)
            x = jnp.where(stage == 0, embed(tok), x_in)
            x = stage_block(lp, x, positions, tp_rank)
            m_last = t - (n_stages - 1)
            m0l = jnp.clip(m_last, 0, n_mb - 1) * mb
            labl = lax.dynamic_slice_in_dim(labels, m0l, mb, axis=0)
            nll_m, cnt_m = loss_of(x, labl)
            # every TP rank of the last stage holds identical (psum'd)
            # values — emit from rank 0 only so the final psum is exact
            emit = ((stage == n_stages - 1) & (tp_rank == 0)
                    & (m_last >= 0) & (m_last < n_mb)).astype(jnp.float32)
            return (x, nll + emit * nll_m, cnt + emit * cnt_m), None

        tick_fn = jax.checkpoint(
            tick, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else tick
        # the carry inits must sit on the param (unknown) side of the
        # autodiff partial-eval split: shard_map's transpose rule (jax
        # 0.4.x) zips cotangents against in_names positionally, and
        # known-side residuals that receive linear cotangents (a scan
        # carry init does) shift that pairing and break grad() with a
        # _SpecError; 0 * finite-param keeps the values exactly zero
        zf = 0.0 * emb.ravel()[0].astype(jnp.float32)
        x0 = jnp.zeros((mb, S_loc, cfg.d_model), dt) + zf.astype(dt)
        n_ticks = n_mb + n_stages - 1
        (x_sh, nll, cnt), _ = lax.scan(
            tick_fn, (x0, zf, zf), jnp.arange(n_ticks))
        axes = (st_ax, tp_ax) + ((dp_ax,) if dp_ax else ())
        nll = lax.psum(nll, axes)
        cnt = lax.psum(cnt, axes)
        return nll / jnp.maximum(cnt, 1.0), cnt

    # ---- shard_map wiring ------------------------------------------------
    pspecs = param_pspecs(cfg, pcfg, mesh)
    from repro.models.transformer import model as M
    flat_names = sorted(M.param_shapes(cfg))
    in_param_specs = tuple(pspecs[n] for n in flat_names)
    batch_spec = P(dp_ax) if dp_ax else P()

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(batch_spec, batch_spec) + in_param_specs,
        out_specs=(P(), P()),
        check_rep=False)

    def loss_fn(params, batch):
        flat = [params[k] for k in flat_names]
        loss, cnt = smapped(batch["tokens"], batch["labels"], *flat)
        return loss, {"loss": loss, "tokens": cnt}

    loss_fn._flat_names = flat_names
    loss_fn._pspecs = {n: pspecs[n] for n in flat_names}
    return loss_fn


def pipeline_param_shardings(cfg: TransformerConfig, pcfg: PipelineConfig,
                             mesh: Mesh) -> Dict[str, NamedSharding]:
    from repro.models.transformer import model as M
    pspecs = param_pspecs(cfg, pcfg, mesh)
    return {k: NamedSharding(mesh, pspecs[k])
            for k in M.param_shapes(cfg)}
