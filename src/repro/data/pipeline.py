"""Deterministic synthetic data pipeline (host substrate).

Everything the training loops consume comes through here: token streams for
LM training, graph batches for GNNs, id/label streams for recsys.  All
streams are:
  * deterministic per (seed, step) — a restarted job regenerates the exact
    batch sequence from the checkpoint step (checkpoint/restart correctness
    does not depend on saving the data cursor);
  * prefetchable — ``prefetch(it, depth)`` overlaps host generation with
    device compute via a background thread;
  * shardable — batches are host-global; the launcher device_puts them with
    the batch sharding of the active mesh.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# generic machinery
# ---------------------------------------------------------------------------

def counted_stream(make_batch: Callable[[int], Dict], *, start: int = 0
                   ) -> Iterator[Dict]:
    step = start
    while True:
        yield make_batch(step)
        step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetcher (host→device overlap)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

def lm_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
              start: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Markov-ish synthetic token stream: learnable but non-trivial.

    tokens[t+1] = (a·tokens[t] + noise) mod vocab gives next-token structure
    a model can actually fit — smoke-scale loss curves are meaningful.
    """
    a = 31

    def make(step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((seed, step))
        x = np.empty((batch, seq + 1), np.int64)
        x[:, 0] = rng.integers(0, vocab, batch)
        noise = rng.integers(0, 7, (batch, seq))
        for t in range(seq):
            x[:, t + 1] = (a * x[:, t] + noise[:, t]) % vocab
        return {"tokens": jnp.asarray(x[:, :-1], jnp.int32),
                "labels": jnp.asarray(x[:, 1:], jnp.int32)}

    return counted_stream(make, start=start)


# ---------------------------------------------------------------------------
# GNN batches
# ---------------------------------------------------------------------------

def gnn_full_graph_batch(*, n: int, e: int, d_feat: int, n_out: int,
                         seed: int = 0, with_pos: bool = False
                         ) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out = {
        "nodes": jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, n_out, n), jnp.int32),
    }
    if with_pos:
        out["pos"] = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    return out


def graphsage_minibatch_stream(sampler, feats: np.ndarray,
                               labels: np.ndarray, *, batch_nodes: int,
                               fanouts: Sequence[int], seed: int = 0,
                               start: int = 0) -> Iterator[Dict]:
    """Wraps the real neighbor sampler into the trainer batch format."""
    def make(step: int) -> Dict:
        rng = np.random.default_rng((seed, step))
        seeds = rng.integers(0, sampler.n, size=batch_nodes)
        hops = sampler.sample_block(seeds, fanouts, rng)
        batch = {f"hop{i}": jnp.asarray(feats[h], jnp.float32)
                 for i, h in enumerate(hops)}
        batch["labels"] = jnp.asarray(labels[seeds], jnp.int32)
        return batch

    return counted_stream(make, start=start)


# ---------------------------------------------------------------------------
# recsys stream
# ---------------------------------------------------------------------------

def recsys_stream(n_fields: int, rows_per_field: int, batch: int, *,
                  seed: int = 0, start: int = 0) -> Iterator[Dict]:
    """CTR stream with planted structure: the label correlates with a hash
    of two field ids, so AUC above 0.5 is learnable."""
    offsets = np.arange(n_fields, dtype=np.int64) * rows_per_field

    def make(step: int) -> Dict:
        rng = np.random.default_rng((seed, step))
        local = rng.integers(0, rows_per_field, (batch, n_fields))
        ids = local + offsets[None, :]
        signal = ((local[:, 0] ^ local[:, 1 % n_fields]) % 7) < 3
        flip = rng.random(batch) < 0.2
        labels = np.where(flip, ~signal, signal).astype(np.float32)
        return {"ids": jnp.asarray(ids, jnp.int32),
                "labels": jnp.asarray(labels)}

    return counted_stream(make, start=start)


# ---------------------------------------------------------------------------
# dynamic-graph batch stream (the paper's workload)
# ---------------------------------------------------------------------------

def dynamic_graph_stream(hg, *, batch_frac: float, seed: int = 0,
                         deletions_frac: float = 0.5):
    """Yields (HostGraph_t-1, HostGraph_t, deletions, insertions) forever."""
    from repro.core.delta import random_batch
    step = 0
    while True:
        dels, ins = random_batch(hg, batch_frac, seed=(seed + step),
                                 deletions_frac=deletions_frac)
        hg_new = hg.apply_batch(dels, ins)
        yield hg, hg_new, dels, ins
        hg = hg_new
        step += 1
