"""Core: the paper's contribution — Dynamic Frontier lock-free PageRank."""
from repro.core.graph import GraphSnapshot, HostGraph
from repro.core.pagerank import (df_pagerank, dt_pagerank, nd_pagerank,
                                 static_pagerank, reference_pagerank,
                                 numpy_reference, linf, PagerankResult,
                                 default_engine)
from repro.core.pallas_engine import run_pallas, build_pull_matrix
from repro.core.incremental import IncrementalPullMatrix, MatrixAux
from repro.core.stream import StreamRunner, StreamReport, run_stream
from repro.core.faults import FaultPlan, NO_FAULTS

__all__ = [
    "GraphSnapshot", "HostGraph", "df_pagerank", "dt_pagerank",
    "nd_pagerank", "static_pagerank", "reference_pagerank",
    "numpy_reference", "linf", "PagerankResult", "FaultPlan", "NO_FAULTS",
    "default_engine", "run_pallas", "build_pull_matrix",
    "IncrementalPullMatrix", "MatrixAux", "StreamRunner", "StreamReport",
    "run_stream",
]
