"""Core: the paper's contribution — Dynamic Frontier lock-free PageRank."""
from repro.core.graph import GraphSnapshot, HostGraph
from repro.core.pagerank import (df_pagerank, dt_pagerank, nd_pagerank,
                                 static_pagerank, reference_pagerank,
                                 numpy_reference, linf, PagerankResult)
from repro.core.faults import FaultPlan, NO_FAULTS

__all__ = [
    "GraphSnapshot", "HostGraph", "df_pagerank", "dt_pagerank",
    "nd_pagerank", "static_pagerank", "reference_pagerank",
    "numpy_reference", "linf", "PagerankResult", "FaultPlan", "NO_FAULTS",
]
