"""Batch-update generation (paper §5.1.4).

Random batches: an equal mix of deletions (sampled uniformly from existing
edges) and insertions (uniform random non-connected pairs), sized as a
fraction of |E|.  Temporal batches: consecutive slices of a timestamped edge
stream after loading a 90% prefix.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.graph import HostGraph


def random_batch(g: HostGraph, frac: float, *, seed: int = 0,
                 deletions_frac: float = 0.5
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Random batch of size ``frac * |E|``: mix of deletions and insertions."""
    rng = np.random.default_rng(seed)
    b = max(1, int(round(frac * g.m)))
    n_del = int(b * deletions_frac)
    n_ins = b - n_del

    dels = np.zeros((0, 2), dtype=np.int64)
    if n_del and g.m:
        idx = rng.choice(g.m, size=min(n_del, g.m), replace=False)
        dels = g.edges[idx]

    ins = np.zeros((0, 2), dtype=np.int64)
    if n_ins:
        cand = np.stack([rng.integers(0, g.n, 2 * n_ins),
                         rng.integers(0, g.n, 2 * n_ins)], 1)
        cand = cand[cand[:, 0] != cand[:, 1]]
        keep = ~g.has_edges(cand)
        ins = cand[keep][:n_ins]
    return dels, ins


def signed_edge_delta(deletions: np.ndarray, insertions: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten a (deletions, insertions) batch into the signed coordinate
    form the incremental block-sparse builder consumes, in *pull* layout
    (rows = dst, cols = src): deletions carry -1, insertions +1."""
    dels = np.asarray(deletions, np.int64).reshape(-1, 2)
    ins = np.asarray(insertions, np.int64).reshape(-1, 2)
    rows = np.concatenate([dels[:, 1], ins[:, 1]])
    cols = np.concatenate([dels[:, 0], ins[:, 0]])
    vals = np.concatenate([-np.ones(len(dels)), np.ones(len(ins))])
    return rows, cols, vals


def pure_deletion_batch(g: HostGraph, frac: float, *, seed: int = 0
                        ) -> np.ndarray:
    """For the stability experiment (§5.2.3): delete-only batch."""
    rng = np.random.default_rng(seed)
    b = max(1, min(int(round(frac * g.m)), g.m))
    idx = rng.choice(g.m, size=b, replace=False)
    return g.edges[idx]


def temporal_batches(stream: np.ndarray, *, prefix_frac: float = 0.9,
                     batch_frac: float = 1e-3
                     ) -> Tuple[np.ndarray, Iterator[np.ndarray]]:
    """Split a timestamped stream into a 90% prefix + fixed-size batches."""
    m_total = stream.shape[0]
    cut = int(prefix_frac * m_total)
    bs = max(1, int(batch_frac * m_total))

    def batches() -> Iterator[np.ndarray]:
        for lo in range(cut, m_total, bs):
            yield stream[lo:lo + bs]

    return stream[:cut], batches()
