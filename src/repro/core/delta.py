"""Batch-update generation (paper §5.1.4).

Random batches: an equal mix of deletions (sampled uniformly from existing
edges) and insertions (uniform random non-connected pairs), sized as a
fraction of |E|.  Temporal batches: consecutive slices of a timestamped edge
stream after loading a 90% prefix.
"""
from __future__ import annotations

from typing import Iterator, Sequence, Tuple

import numpy as np

from repro.core.graph import HostGraph


def _as_edge_array(arr, what: str, n: int) -> np.ndarray:
    """Canonicalize one side of a batch into an ``(k, 2) int64`` array,
    rejecting malformed input with a clear error instead of letting it
    reach a device scatter (or a WAL append) as garbage."""
    a = np.asarray(arr)
    if a.dtype == object:
        raise ValueError(f"{what} must be numeric edge pairs, got object "
                         f"dtype (value: {arr!r})")
    if a.size == 0:
        return np.zeros((0, 2), np.int64)
    if a.ndim > 2 or (a.ndim == 2 and a.shape[1] != 2) \
            or (a.ndim == 1 and a.size % 2 != 0):
        raise ValueError(f"{what} must be (k, 2) edge pairs, got shape "
                         f"{a.shape}")
    if np.issubdtype(a.dtype, np.floating):
        # NaN/inf survive a bare .astype(int64) as garbage vertex ids —
        # this is where they get caught, before anything is applied
        if not np.isfinite(a).all():
            raise ValueError(f"{what} contain non-finite (NaN/inf) vertex "
                             "ids")
        if not (a == np.floor(a)).all():
            raise ValueError(f"{what} contain non-integral vertex ids "
                             "(fractional floats)")
    elif not np.issubdtype(a.dtype, np.integer):
        raise ValueError(f"{what} must be integer edge pairs, got dtype "
                         f"{a.dtype}")
    e = a.astype(np.int64).reshape(-1, 2)
    bad = (e < 0) | (e >= n)
    if bad.any():
        where = e[bad.any(axis=1)][:8].tolist()
        raise ValueError(
            f"{what} contain out-of-range vertex id(s) {where} for a graph "
            f"with {n} vertices (valid ids: 0..{n - 1})")
    return e


def _edge_keys(e: np.ndarray, n: int) -> np.ndarray:
    return e[:, 0] * np.int64(n) + e[:, 1]


def validate_edge_batch(deletions, insertions, n: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Validate one ``(deletions, insertions)`` update batch against an
    ``n``-vertex graph and return the canonical ``(k, 2) int64`` arrays.

    Raises ``ValueError`` on NaN/inf/non-integral vertex ids, out-of-range
    ids, self-loop insertions, duplicate edges within either side, or an
    edge appearing in both sides (ambiguous order within one batch).
    Sessions call this *before* the WAL append and before any device
    scatter, so a bad batch is never durably logged or half-applied."""
    dels = _as_edge_array(deletions, "deletions", n)
    ins = _as_edge_array(insertions, "insertions", n)
    loops = ins[:, 0] == ins[:, 1]
    if loops.any():
        raise ValueError(
            f"insertions contain self-loop(s) {ins[loops][:8].tolist()} — "
            "self-loops are managed internally (added per snapshot) and "
            "cannot be inserted")
    dk, ik = _edge_keys(dels, n), _edge_keys(ins, n)
    for what, keys, e in (("deletions", dk, dels), ("insertions", ik, ins)):
        uniq, cnt = np.unique(keys, return_counts=True)
        if (cnt > 1).any():
            dup = uniq[cnt > 1][:8]
            pairs = np.stack([dup // n, dup % n], 1).tolist()
            raise ValueError(f"{what} contain duplicate edge(s) {pairs} — "
                             "de-duplicate the batch before submitting")
    both = np.intersect1d(dk, ik)
    if both.size:
        pairs = np.stack([both[:8] // n, both[:8] % n], 1).tolist()
        raise ValueError(
            f"edge(s) {pairs} appear in both deletions and insertions of "
            "one batch — the order of operations within a batch is "
            "undefined; split them across two batches")
    return dels, ins


def coalesce_batches(batches: Sequence[Tuple[np.ndarray, np.ndarray]],
                     n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Fold an ordered run of update batches into ONE equivalent
    ``(deletions, insertions)`` batch (last write per edge wins), so a
    dispatcher can apply a stream's whole queue with a single scatter.

    Order-sensitive pairs collapse correctly: insert-then-delete nets to a
    deletion (a no-op if the edge never existed), delete-then-insert nets
    to an insertion.  The result contains no duplicates and no del/ins
    overlap, so it passes :func:`validate_edge_batch` by construction."""
    key_op: dict = {}
    for dels, ins in batches:
        d = np.asarray(dels, np.int64).reshape(-1, 2)
        i = np.asarray(ins, np.int64).reshape(-1, 2)
        for k in _edge_keys(d, n):
            key_op[int(k)] = -1
        for k in _edge_keys(i, n):
            key_op[int(k)] = +1
    if not key_op:
        z = np.zeros((0, 2), np.int64)
        return z, z

    def unpack(keys):
        a = np.asarray(sorted(keys), np.int64)
        if not a.size:
            return np.zeros((0, 2), np.int64)
        return np.stack([a // n, a % n], 1)

    return (unpack([k for k, op in key_op.items() if op < 0]),
            unpack([k for k, op in key_op.items() if op > 0]))


def random_batch(g: HostGraph, frac: float, *, seed: int = 0,
                 deletions_frac: float = 0.5
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Random batch of size ``frac * |E|``: mix of deletions and insertions."""
    rng = np.random.default_rng(seed)
    b = max(1, int(round(frac * g.m)))
    n_del = int(b * deletions_frac)
    n_ins = b - n_del

    dels = np.zeros((0, 2), dtype=np.int64)
    if n_del and g.m:
        idx = rng.choice(g.m, size=min(n_del, g.m), replace=False)
        dels = g.edges[idx]

    ins = np.zeros((0, 2), dtype=np.int64)
    if n_ins:
        cand = np.stack([rng.integers(0, g.n, 2 * n_ins),
                         rng.integers(0, g.n, 2 * n_ins)], 1)
        cand = cand[cand[:, 0] != cand[:, 1]]
        keep = ~g.has_edges(cand)
        ins = cand[keep][:n_ins]
    return dels, ins


def signed_edge_delta(deletions: np.ndarray, insertions: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten a (deletions, insertions) batch into the signed coordinate
    form the incremental block-sparse builder consumes, in *pull* layout
    (rows = dst, cols = src): deletions carry -1, insertions +1."""
    dels = np.asarray(deletions, np.int64).reshape(-1, 2)
    ins = np.asarray(insertions, np.int64).reshape(-1, 2)
    rows = np.concatenate([dels[:, 1], ins[:, 1]])
    cols = np.concatenate([dels[:, 0], ins[:, 0]])
    vals = np.concatenate([-np.ones(len(dels)), np.ones(len(ins))])
    return rows, cols, vals


def pure_deletion_batch(g: HostGraph, frac: float, *, seed: int = 0
                        ) -> np.ndarray:
    """For the stability experiment (§5.2.3): delete-only batch."""
    rng = np.random.default_rng(seed)
    b = max(1, min(int(round(frac * g.m)), g.m))
    idx = rng.choice(g.m, size=b, replace=False)
    return g.edges[idx]


def temporal_batches(stream: np.ndarray, *, prefix_frac: float = 0.9,
                     batch_frac: float = 1e-3
                     ) -> Tuple[np.ndarray, Iterator[np.ndarray]]:
    """Split a timestamped stream into a 90% prefix + fixed-size batches."""
    m_total = stream.shape[0]
    cut = int(prefix_frac * m_total)
    bs = max(1, int(batch_frac * m_total))

    def batches() -> Iterator[np.ndarray]:
        for lo in range(cut, m_total, bs):
            yield stream[lo:lo + bs]

    return stream[:cut], batches()
