"""State integrity under silent corruption (the corruption fault domain).

The paper's fault model — and the repo's first four domains
(thread/shard/process/session) — covers *visible* failures: a crashed
thread, a dead shard, a SIGKILLed process, a hung serving slot.  This
module covers the failure the fleet does not announce: a flipped bit in
the tile pool, a torn operand-mirror scatter, a drifted rank vector.
Three layers:

* **Invariant checks on the live iterate** (:func:`invariant_vec`): a
  correct (near-)converged PageRank iterate conserves rank mass
  (|Σx − 1| ≤ ε — every vertex carries a self-loop so no mass leaks
  through dangling nodes), is non-negative, is finite, and between two
  drives is *bit-identical* to the last verified iterate (queries never
  write ranks), so any L∞ drift without an intervening update is
  corruption.  The vector is computed on device and fetched fused with
  the driver's stats vector — one ``block_until_ready`` per drive, no
  extra host sync (`session._drive`).
* **Checksummed device state** (:func:`compare_digests`,
  :func:`tile_row_sums`, :func:`check_slot_tables`): chunked CRC32
  digests of the operand mirrors (``out_deg``/``rb_in``/``rb_out``/
  ``bmat``) against their host-truth twins (`MatrixAux` + the host
  graph), a per-row-block tile-pool sum check (every stored entry of the
  pull matrix is 1.0, so the live entries of row-block *i* must sum to
  exactly ``rb_in[i]``), and structural validation of the slot tables
  against the block-adjacency truth.  A background scrubber thread in
  ``PageRankService`` runs these on idle slots.
* **A repair ladder** (driven by ``PageRankSession.verify``): re-mark
  corrupted rows into the DF frontier and re-converge via the helping
  path (rung ``"frontier"`` — the paper's mechanism, repairing
  corruption instead of crashes), escalate to an operand-mirror /
  tile-pool rebuild from the host slot tables (rung ``"rebuild"``), and
  finally to a checkpoint+WAL restore (rung ``"restore"``).  Each rung
  emits a ``RecoveryRecord(domain="corruption")``.

Detection guarantees are calibrated, not absolute: sign/exponent-range
bit flips (the flips that change a value by ≥ 2×) are always caught;
a mantissa-tail flip in a *rank* is caught by the exact drift check,
while a mantissa-tail flip in a tile value below the 0.25 count
tolerance is the documented blind spot of the sum check.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp


#: Checks run by ``session.verify()`` (docs/FAULTS.md has the tolerances).
INTEGRITY_CHECKS = ("rank_mass", "rank_negativity", "rank_finite",
                    "rank_drift", "mirror_digest", "tile_sums",
                    "slot_tables", "graph_digest")

#: Repair-ladder rungs, cheapest first.
REPAIR_RUNGS = ("frontier", "rebuild", "restore")

#: Fields of the fused invariant vector, in order.
INVARIANT_FIELDS = ("mass_error", "negative", "nonfinite", "drift")
N_INVARIANTS = len(INVARIANT_FIELDS)


@dataclasses.dataclass(frozen=True)
class IntegrityConfig:
    """The ``EngineConfig(integrity=…)`` axis.

    ``mass_tol`` bounds |Σx − 1| on a converged iterate (the residual of
    an *unconverged* sweep-capped iterate contributes ≤ n·τ, so the
    default 1e-6 is safe for n up to ~10⁴ at τ=1e-10; scale it with n·τ
    for larger streams).  ``drift_tol`` bounds L∞ movement of the ranks
    *between* drives — legitimately zero, so the default is tight.
    ``scrub_interval_s`` paces the service scrubber per slot;
    ``scrub_chunk_bytes`` sizes the CRC chunks (smaller chunks localize
    a corrupted region at more digest overhead).  ``auto_repair`` lets
    a failed check climb the repair ladder automatically; ``fused``
    keeps the per-drive invariant fetch on (it rides the existing
    stats sync, so the cost is a handful of device FLOPs).
    """
    mass_tol: float = 1e-6
    drift_tol: float = 1e-9
    scrub_interval_s: float = 0.25
    scrub_chunk_bytes: int = 1 << 20
    auto_repair: bool = True
    fused: bool = True

    def __post_init__(self):
        if not (self.mass_tol > 0):
            raise ValueError(f"mass_tol must be > 0, got {self.mass_tol}")
        if not (self.drift_tol > 0):
            raise ValueError(f"drift_tol must be > 0, got {self.drift_tol}")
        if not (self.scrub_interval_s > 0):
            raise ValueError("scrub_interval_s must be > 0, got "
                             f"{self.scrub_interval_s}")
        if int(self.scrub_chunk_bytes) < 64:
            raise ValueError("scrub_chunk_bytes must be >= 64, got "
                             f"{self.scrub_chunk_bytes}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def coerce(cls, value: Any) -> Optional["IntegrityConfig"]:
        """None | IntegrityConfig | kwargs-dict → IntegrityConfig (or None).
        The dict form is what ``SessionStore`` meta round-trips."""
        if value is None or isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(
            f"integrity must be an IntegrityConfig or a kwargs dict, got "
            f"{type(value).__name__}")


# ---------------------------------------------------------------------------
# invariant checks on the live iterate
# ---------------------------------------------------------------------------

@jax.jit
def invariant_vec(R: jnp.ndarray, R_ref: jnp.ndarray,
                  valid: jnp.ndarray) -> jnp.ndarray:
    """[mass_error, negative_count, nonfinite_count, linf_drift] of the
    iterate, on device.  Fuse the fetch with the driver's stats vector
    (concatenate, one ``block_until_ready``) to keep the drive at a
    single host sync.  ``R_ref`` is the last verified iterate; pass
    ``R`` itself to skip the drift term (it is then exactly 0)."""
    x = jnp.where(valid, R, 0.0)
    finite = jnp.isfinite(R)
    # a non-finite iterate would poison the mass sum: mask it out so the
    # mass and drift terms stay informative alongside the finite count
    xf = jnp.where(finite, x, 0.0)
    mass_err = jnp.abs(jnp.sum(xf) - 1.0)
    neg = jnp.sum((xf < 0) & valid)
    nonfinite = jnp.sum(valid & ~finite)
    ref = jnp.where(valid & jnp.isfinite(R_ref), R_ref, 0.0)
    drift = jnp.max(jnp.abs(xf - ref))
    return jnp.stack([mass_err, neg.astype(R.dtype),
                      nonfinite.astype(R.dtype), drift])


# ---------------------------------------------------------------------------
# chunked checksums: device state vs host truth
# ---------------------------------------------------------------------------

def chunked_crc32(arr: np.ndarray, *,
                  chunk_bytes: int = 1 << 20) -> Tuple[int, ...]:
    """CRC32 digest of an array in fixed-size byte chunks (the repo's
    checkpoint idiom, ``ckpt/checkpoint.py``, chunked so a mismatch
    localizes the corrupted region)."""
    b = np.ascontiguousarray(arr).tobytes()
    step = max(64, int(chunk_bytes))
    if not b:
        return (0,)
    return tuple(zlib.crc32(b[i:i + step]) & 0xFFFFFFFF
                 for i in range(0, len(b), step))


def compare_digests(device_arr, host_arr, *,
                    chunk_bytes: int = 1 << 20) -> List[int]:
    """Chunk indices where a device mirror's digest disagrees with its
    host-truth twin (empty list = clean).  The host side is normalized
    to the device dtype first so the comparison is value-exact, not
    representation-accidental."""
    a = np.asarray(device_arr)
    b = np.asarray(host_arr)
    if a.shape != b.shape:
        return [-1]
    da = chunked_crc32(a, chunk_bytes=chunk_bytes)
    db = chunked_crc32(b.astype(a.dtype, copy=False),
                       chunk_bytes=chunk_bytes)
    if len(da) != len(db):
        return [-1]
    return [i for i, (x, y) in enumerate(zip(da, db)) if x != y]


@jax.jit
def _tile_row_sums(tiles: jnp.ndarray, tile_cols: jnp.ndarray,
                   tile_idx: jnp.ndarray) -> jnp.ndarray:
    n_rb, mt = tile_cols.shape
    T = tiles[tile_idx.reshape(n_rb, mt)]          # [n_rb, mt, B, B]
    occ = (tile_cols >= 0)[:, :, None, None]
    return jnp.sum(jnp.where(occ, T, 0), axis=(1, 2, 3))


def tile_row_sums(mat, *, chunk_rb: int = 0) -> np.ndarray:
    """Per-row-block sum of the live tiles of a pull matrix.  Every
    stored entry is 1.0 (one per in-edge incl. the self-loop), so row-
    block *i* must sum to exactly ``rb_in[i]`` — an aggregate checksum
    of the tile pool that needs no host twin of the tiles themselves.
    ``chunk_rb`` bounds the per-call gather footprint (0 = one call)."""
    tile_cols = mat.tile_cols
    n_rb = int(tile_cols.shape[0])
    mt = int(tile_cols.shape[1])
    tidx = mat.tile_idx.reshape(n_rb, mt)
    if chunk_rb <= 0 or chunk_rb >= n_rb:
        return np.asarray(_tile_row_sums(mat.tiles, tile_cols,
                                         mat.tile_idx))
    out = []
    for i in range(0, n_rb, chunk_rb):
        out.append(np.asarray(_tile_row_sums(
            mat.tiles, tile_cols[i:i + chunk_rb],
            tidx[i:i + chunk_rb].reshape(-1))))
    return np.concatenate(out)


def check_slot_tables(tile_cols: np.ndarray, tile_idx: np.ndarray,
                      bmat: np.ndarray, tile_capacity: int) -> List[dict]:
    """Structural validation of the slot tables against the host
    block-adjacency truth.  Catches bit flips in ``tile_cols`` /
    ``tile_idx``: out-of-range columns or tile ids, duplicate columns in
    one row, and occupancy that disagrees with ``bmat`` (occupancy and
    block adjacency grow in lock-step — tiles emptied by deletions stay
    referenced, `kernels/block_spmv/ops.py`)."""
    problems: List[dict] = []
    tile_cols = np.asarray(tile_cols)
    tile_idx = np.asarray(tile_idx).reshape(tile_cols.shape)
    bmat = np.asarray(bmat, bool)
    n_rb, n_cb = bmat.shape
    occ = tile_cols >= 0
    if tile_cols.min(initial=0) < -1 or \
            (occ & (tile_cols >= n_cb)).any():
        problems.append({"check": "slot_tables", "what": "col_range"})
    tid = tile_idx[occ]
    if len(tid) and (tid.min() < 0 or tid.max() >= tile_capacity
                     or len(np.unique(tid)) != len(tid)):
        problems.append({"check": "slot_tables", "what": "tile_idx"})
    # occupancy vs block adjacency (and duplicate columns, via counting)
    cols = np.clip(tile_cols, 0, n_cb - 1)
    counts = np.zeros((n_rb, n_cb), np.int64)
    rb = np.broadcast_to(np.arange(n_rb)[:, None], tile_cols.shape)
    np.add.at(counts, (rb[occ], cols[occ]), 1)
    if (counts > 1).any():
        problems.append({"check": "slot_tables", "what": "col_dup"})
    mism = np.nonzero((counts > 0) != bmat)
    if len(mism[0]):
        problems.append({"check": "slot_tables", "what": "bmat_mismatch",
                         "row_blocks": sorted(set(int(r)
                                                  for r in mism[0]))[:8]})
    return problems


# ---------------------------------------------------------------------------
# corruption injection primitives (chaos harness + tests)
# ---------------------------------------------------------------------------

def flipped_float(value, bit: int) -> float:
    """``value`` with IEEE bit ``bit`` flipped (f32 or f64).  Exponent /
    sign bits (52..63 for f64) produce the ≥2× perturbations the
    invariant and sum checks are calibrated to always catch."""
    dt = np.dtype(np.asarray(value).dtype)
    if dt.itemsize == 8:
        u = np.asarray(value, dt).view(np.uint64) ^ np.uint64(1 << bit)
        return float(u.view(dt))
    u = np.asarray(value, np.float32).view(np.uint32) ^ np.uint32(1 << bit)
    return float(u.view(np.float32))


def exponent_bit(dtype, rng: np.random.Generator) -> int:
    """A deterministic exponent-range bit index for ``dtype``."""
    if np.dtype(dtype).itemsize == 8:
        return int(rng.integers(52, 62))
    return int(rng.integers(23, 30))


# ---------------------------------------------------------------------------
# verify() result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IntegrityReport:
    """Result of one ``session.verify()`` pass: what was checked, what
    failed (before any repair), which ladder rungs ran, and whether the
    final state is clean."""
    ok: bool
    checks_run: int
    failures: List[Dict[str, Any]]
    repairs: List[str]                  # rungs applied, in order
    mass_error: float
    drift: float
    wall_time_s: float

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": bool(self.ok),
            "checks_run": int(self.checks_run),
            "failures": list(self.failures),
            "repairs": list(self.repairs),
            "mass_error": float(self.mass_error),
            "drift": float(self.drift),
            "wall_time_s": round(float(self.wall_time_s), 6),
        }
