"""Tiered graph storage — host-paged cold tiles + a frontier-biased device
hot set (docs/SCALE.md).

Every subsystem below the streaming session assumed the whole block-sparse
tile pool fits on the device.  This module removes that assumption with a
two-tier layout:

* :class:`HostTilePool` — the **host tier**: the full tile pool and slot
  tables as plain numpy arrays (the ``to_device=False`` layout of
  :func:`repro.kernels.block_spmv.ops.build_block_sparse`).  Delta batches
  are applied host-side through the *same* bookkeeping path the device
  scatter uses (:func:`ops.plan_delta` + one ``np.add.at``), so the two
  tiers cannot diverge structurally.  This is durable truth: ``save()`` /
  ``restore()`` and the integrity scrubber key off it, never off the slab.

* :class:`HotSetManager` — the **device tier**: a fixed-capacity tile slab
  (sized from ``EngineConfig.device_budget_bytes``) plus device slot tables
  that indirect *through the existing BlockSparse layout*: the manager's
  :meth:`HotSetManager.view` is an ordinary :class:`ops.BlockSparse` whose
  ``tiles`` is the slab and whose ``tile_idx`` maps each occupied slot of a
  **resident** row-block to its slab slot.  Non-resident blocks map to the
  reserved all-zero slab slot 0, and a per-row-block residency indicator
  (``rb_res``) tells the fused driver which rows it may update — a sweep
  touching a non-resident block *defers* it (re-marks the whole block for
  the next drive, mirroring the paper's helping mechanism) instead of
  paying a mid-sweep host sync.

Admission is **frontier-biased**: before each drive the session admits the
row-blocks touched by the delta batch, the seed frontier and their
tile-adjacent candidates in ONE batched host→device gather (payload length
bucketed on the capacity ladder, so post-warmup retraces stay 0).  Eviction
is clock/second-chance over a per-block last-touched counter: a block
referenced since the hand last passed gets a second chance; cold blocks are
reclaimed oldest-first.  Counters (hits / misses / evictions / transfer
bytes / refill drives) surface through ``session.report()["tiering"]``.

:class:`EdgePager` gives the blocked Gauss–Seidel oracle the analogous
facility over its per-block edge extents, so ``run_blocked`` can cross-check
tiered results at sizes whose edge slabs exceed the budget too.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.block_spmv import ops


def slab_tiles_for_budget(budget_bytes: int, block: int, dtype) -> int:
    """Tile capacity of a device slab under ``budget_bytes``: the budget is
    spent on B×B dense tiles (slot tables and the residency indicator are
    index-sized and not charged).  Slot 0 is the reserved zero tile, so the
    usable capacity is one less than what is returned here."""
    tile_bytes = block * block * np.dtype(dtype).itemsize
    return max(int(budget_bytes) // tile_bytes, 0)


def budget_hint(block: int, dtype, *, max_tiles_rb: int) -> str:
    """Sizing rule rendered for error messages (docs/SCALE.md §Budget)."""
    tile_bytes = block * block * np.dtype(dtype).itemsize
    need = (max_tiles_rb + 1) * tile_bytes
    return (f"one {block}x{block} {np.dtype(dtype).name} tile is "
            f"{tile_bytes} bytes and the widest row-block holds "
            f"{max_tiles_rb} tiles, so the floor is "
            f"(max_tiles_per_row_block + 1) * tile_bytes = {need} bytes; "
            "size the budget at >= 2x the expected frontier working set")


class HostTilePool:
    """Host tier: the full padded tile pool + slot tables (numpy).

    ``mat`` is a numpy-backed :class:`ops.BlockSparse` on the same growth
    ladder as the device layout; :meth:`apply_delta` patches it in O(batch)
    through :func:`ops.plan_delta` and returns the plan so callers can
    invalidate / re-admit exactly the touched row-blocks."""

    def __init__(self, mat: ops.BlockSparse):
        if not isinstance(mat.tiles, np.ndarray):
            raise TypeError(
                "HostTilePool wraps the numpy layout — build the matrix "
                "with build_block_sparse(..., to_device=False)")
        self.mat = mat

    @classmethod
    def from_edges(cls, rows: np.ndarray, cols: np.ndarray, n_rows: int,
                   n_cols: int, *, block: int, dtype=np.float32
                   ) -> "HostTilePool":
        return cls(ops.build_block_sparse(
            rows, cols, n_rows, n_cols, block=block, dtype=dtype,
            padded=True, to_device=False))

    # -- structure accessors -------------------------------------------------
    @property
    def n_rb(self) -> int:
        return self.mat.n_rb

    @property
    def block(self) -> int:
        return self.mat.block

    @property
    def tile_cols(self) -> np.ndarray:
        return self.mat.tile_cols

    @property
    def tile_idx2d(self) -> np.ndarray:
        return self.mat.tile_idx.reshape(self.mat.tile_cols.shape)

    @property
    def nbytes(self) -> int:
        return int(self.mat.tiles.nbytes + self.mat.tile_cols.nbytes
                   + self.mat.tile_idx.nbytes)

    def apply_delta(self, rows: np.ndarray, cols: np.ndarray,
                    values: np.ndarray) -> ops.DeltaPlan:
        """Host-tier sibling of :func:`ops.apply_delta`: same plan, same
        ladder growth, one ``np.add.at`` instead of the device scatter."""
        mat = self.mat
        B, n_rb, n_cb = mat.block, mat.n_rb, mat.n_cb
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(values, dtype=mat.tiles.dtype)
        if len(rows) == 0:
            return ops.DeltaPlan(tid=rows, n_old=0, n_new=0, tile_cols=None,
                                 tile_idx=None, max_tiles=mat.max_tiles,
                                 touched_rb=np.zeros(0, np.int32))
        if (rows.min() < 0 or cols.min() < 0 or rows.max() >= mat.n_rows
                or cols.max() >= mat.n_cols):
            raise ValueError(
                f"delta coordinates outside the fixed {mat.n_rows}x"
                f"{mat.n_cols} host-tier block grid; rebuild the pool")
        plan = ops.plan_delta(mat.tile_cols, self.tile_idx2d, rows, cols,
                              n_cb=n_cb, block=B, max_tiles=mat.max_tiles)
        tiles = mat.tiles
        if plan.n_live > tiles.shape[0]:
            cap = ops.capacity_bucket(plan.n_live)
            tiles = np.concatenate(
                [tiles, np.zeros((cap - tiles.shape[0], B, B), tiles.dtype)])
        # flat offsets stay int64: capacity * B^2 can exceed 2^31
        flat = (plan.tid.astype(np.int64) * (B * B)
                + (rows % B) * B + (cols % B))
        np.add.at(tiles.reshape(-1), flat, vals)
        tile_cols, tile_idx = mat.tile_cols, mat.tile_idx
        max_tiles = mat.max_tiles
        if plan.tile_cols is not None:
            tile_cols = plan.tile_cols
            tile_idx = plan.tile_idx.reshape(-1)
            max_tiles = plan.max_tiles
        self.mat = ops.BlockSparse(
            n_rows=mat.n_rows, n_cols=mat.n_cols, block=B,
            max_tiles=max_tiles, tiles=tiles, tile_cols=tile_cols,
            tile_idx=tile_idx)
        return plan

    def row_sums(self) -> np.ndarray:
        """Per-row-block sum of live tile entries (the host-truth side of
        the integrity scrubber's ``tile_sums`` check)."""
        tc = self.mat.tile_cols
        occ_rb, occ_slot = np.nonzero(tc >= 0)
        tid = self.tile_idx2d[occ_rb, occ_slot]
        per_tile = self.mat.tiles.reshape(self.mat.tiles.shape[0], -1).sum(1)
        out = np.zeros(self.n_rb, per_tile.dtype)
        np.add.at(out, occ_rb, per_tile[tid])
        return out

    def copy(self) -> "HostTilePool":
        m = self.mat
        return HostTilePool(ops.BlockSparse(
            n_rows=m.n_rows, n_cols=m.n_cols, block=m.block,
            max_tiles=m.max_tiles, tiles=m.tiles.copy(),
            tile_cols=m.tile_cols.copy(), tile_idx=m.tile_idx.copy()))


ADMIT_BUCKET = 8     # minimum padded admit-payload length (tiles)


@jax.jit
def _admit_scatter(slab: jnp.ndarray, payload: jnp.ndarray,
                   slots: jnp.ndarray) -> jnp.ndarray:
    """One batched host→device gather landing: padded payload entries carry
    slot == slab capacity and are dropped by the out-of-bounds scatter."""
    return slab.at[slots].set(payload, mode="drop")


class HotSetManager:
    """Fixed-budget device slab of hot row-blocks over a host tile pool.

    Residency is per **row-block** (a block is resident iff every occupied
    tile of its slot row is in the slab) — the granularity the fused
    driver's frontier compaction already works at.  Slab slot 0 is a
    permanent all-zero tile that every non-resident slot maps to, so the
    device view is always a well-formed :class:`ops.BlockSparse` and the
    SpMV kernels need no tiering awareness at all.
    """

    def __init__(self, pool: HostTilePool, device_budget_bytes: int):
        B = pool.block
        dtype = pool.mat.tiles.dtype
        self.pool = pool
        self.budget_bytes = int(device_budget_bytes)
        self.tile_bytes = B * B * np.dtype(dtype).itemsize
        cap = slab_tiles_for_budget(device_budget_bytes, B, dtype)
        max_rb = int((pool.tile_cols >= 0).sum(axis=1).max(initial=1))
        if cap < max_rb + 1:
            raise ValueError(
                f"device_budget_bytes={device_budget_bytes} holds only "
                f"{cap} tile(s) — too small to make a single row-block "
                f"resident: {budget_hint(B, dtype, max_tiles_rb=max_rb)}")
        self.slab_cap = cap
        n_rb = pool.n_rb
        # host bookkeeping
        self.resident = np.zeros(n_rb, bool)
        self.last_touch = np.zeros(n_rb, np.int64)
        self._last_admit = np.zeros(n_rb, np.int64)
        self._ref = np.zeros(n_rb, bool)          # second-chance bit
        self._step = 0
        self._slot_of_tile = np.zeros(pool.mat.tiles.shape[0], np.int32)
        self._free: List[int] = list(range(cap - 1, 0, -1))  # slot 0 reserved
        self._rb_slots: Dict[int, List[int]] = {}
        self._tables_dirty = True
        # device state
        self._slab = jnp.zeros((cap, B, B), dtype)
        self._dev_tile_cols = jnp.asarray(pool.tile_cols)
        self._dev_tile_idx = jnp.zeros((n_rb * pool.mat.max_tiles,),
                                       jnp.int32)
        self._rb_res = jnp.zeros((n_rb,), bool)
        self.counters = {"hits": 0, "misses": 0, "evictions": 0,
                         "admitted_tiles": 0, "transfer_bytes": 0,
                         "refill_drives": 0, "refill_stalls": 0}

    # -- device view ---------------------------------------------------------
    def view(self) -> ops.BlockSparse:
        """The slab as an ordinary BlockSparse (what the fused driver and
        the SpMV kernels consume — same slot-table indirection, slab-slot
        tile ids)."""
        m = self.pool.mat
        return ops.BlockSparse(
            n_rows=m.n_rows, n_cols=m.n_cols, block=m.block,
            max_tiles=m.max_tiles, tiles=self._slab,
            tile_cols=self._dev_tile_cols, tile_idx=self._dev_tile_idx)

    @property
    def rb_res(self) -> jnp.ndarray:
        return self._rb_res

    def adopt_view(self, mat: ops.BlockSparse) -> None:
        """Re-adopt a functionally patched view (e.g. after a corruption
        injection rebinding ``tiles`` / ``tile_cols``) so the manager's
        device handles stay the scrubber's single source of slab state."""
        self._slab = mat.tiles
        self._dev_tile_cols = mat.tile_cols
        self._dev_tile_idx = mat.tile_idx

    # -- invalidation --------------------------------------------------------
    def invalidate(self, touched_rb: np.ndarray, *,
                   structure_changed: bool = False) -> None:
        """Drop residency of delta-touched row-blocks (their slab tiles are
        stale); the next :meth:`admit` re-gathers them from host truth.
        ``structure_changed`` additionally marks the slot tables dirty (the
        pool rewidened or appended tiles)."""
        rbs = np.asarray(touched_rb, np.int64).reshape(-1)
        # grow the tile→slot map FIRST: _drop reads post-growth tile ids
        # from the pool's (possibly just-rewidened) tile_idx2d
        cap = self.pool.mat.tiles.shape[0]
        if cap > len(self._slot_of_tile):
            grown = np.zeros(cap, np.int32)
            grown[:len(self._slot_of_tile)] = self._slot_of_tile
            self._slot_of_tile = grown
            self._tables_dirty = True
        for rb in rbs.tolist():
            self._drop(int(rb))
        if len(rbs) or structure_changed:
            self._tables_dirty = True

    def invalidate_all(self) -> None:
        self.invalidate(np.nonzero(self.resident)[0],
                        structure_changed=True)

    def _drop(self, rb: int) -> None:
        if not self.resident[rb]:
            return
        for slot in self._rb_slots.pop(rb, ()):
            self._free.append(slot)
        self.resident[rb] = False
        self._ref[rb] = False
        # tiles of rb fall back to the zero slot
        tc = self.pool.tile_cols[rb]
        tid = self.pool.tile_idx2d[rb][tc >= 0]
        self._slot_of_tile[tid] = 0

    # -- eviction (clock / second-chance over last_touch) --------------------
    def _evict_until(self, need: int, protected: np.ndarray) -> None:
        """Free slab slots until ``need`` fit, walking resident blocks
        oldest-touch-first; a block whose reference bit is set since the
        hand last passed is skipped once (second chance)."""
        while len(self._free) < need:
            cand = np.nonzero(self.resident & ~protected)[0]
            if len(cand) == 0:
                return                      # nothing evictable; caller defers
            order = cand[np.argsort(self.last_touch[cand], kind="stable")]
            evicted = False
            for rb in order.tolist():
                if self._ref[rb]:
                    self._ref[rb] = False   # second chance
                    continue
                self._drop(int(rb))
                self.counters["evictions"] += 1
                evicted = True
                break
            if not evicted:
                # every candidate spent its second chance this pass; the
                # next pass evicts the oldest unconditionally
                self._ref[order] = False

    # -- admission -----------------------------------------------------------
    def admit(self, want_rb: np.ndarray) -> int:
        """Make the requested row-blocks device-resident (as many as fit):
        one batched, bucket-padded tile gather + one slot-table upload.
        Returns the number admitted (misses that fit).  Blocks that do not
        fit stay non-resident — the driver defers them and the session's
        refill loop retries after this admission freed/landed others."""
        self._step += 1
        want = np.unique(np.asarray(want_rb, np.int64).reshape(-1))
        want = want[(want >= 0) & (want < self.pool.n_rb)]
        if len(want) == 0:
            if self._tables_dirty:
                self._upload_tables()
            return 0
        hit = self.resident[want]
        self.counters["hits"] += int(hit.sum())
        self.counters["misses"] += int((~hit).sum())
        self.last_touch[want] = self._step
        self._ref[want] = True
        missing = want[~hit]
        # fairness: least-recently-admitted first, else a want set larger
        # than the slab starves its tail forever (sorted order would hand
        # the same leading blocks the slab on every refill round)
        missing = missing[np.argsort(self._last_admit[missing],
                                     kind="stable")]
        protected = np.zeros(self.pool.n_rb, bool)
        protected[want] = True
        admitted = 0
        tids: List[np.ndarray] = []
        slots: List[int] = []
        tc = self.pool.tile_cols
        ti = self.pool.tile_idx2d
        for rb in missing.tolist():
            rb_tid = ti[rb][tc[rb] >= 0]
            need = len(rb_tid)
            if need > len(self._free):
                self._evict_until(need, protected)
            if need > len(self._free):
                continue                    # defer: retried next refill
            rb_slots = [self._free.pop() for _ in range(need)]
            self._rb_slots[rb] = rb_slots
            self._slot_of_tile[rb_tid] = np.asarray(rb_slots, np.int32)
            self.resident[rb] = True
            self._last_admit[rb] = self._step
            tids.append(rb_tid)
            slots.extend(rb_slots)
            admitted += 1
        if tids:
            tid_all = np.concatenate(tids)
            payload = self.pool.mat.tiles[tid_all]      # host gather
            k = len(slots)
            k_pad = ops.capacity_bucket(k, ADMIT_BUCKET)
            B = self.pool.block
            pay = np.zeros((k_pad, B, B), payload.dtype)
            pay[:k] = payload
            # padded slots target the (dropped) out-of-bounds slot
            sl = np.full(k_pad, self.slab_cap, np.int32)
            sl[:k] = np.asarray(slots, np.int32)
            self._slab = _admit_scatter(self._slab, jnp.asarray(pay),
                                        jnp.asarray(sl))
            self.counters["admitted_tiles"] += k
            self.counters["transfer_bytes"] += k * self.tile_bytes
            self._tables_dirty = True
        if self._tables_dirty:
            self._upload_tables()
        return admitted

    def _upload_tables(self) -> None:
        """Re-derive + upload the device slot tables and residency from the
        host bookkeeping (index-sized; counted in transfer_bytes)."""
        pool = self.pool
        dev_idx = self._slot_of_tile[pool.tile_idx2d.reshape(-1)]
        self._dev_tile_cols = jnp.asarray(pool.tile_cols)
        self._dev_tile_idx = jnp.asarray(dev_idx)
        self._rb_res = jnp.asarray(self.resident)
        self.counters["transfer_bytes"] += (
            pool.tile_cols.nbytes + dev_idx.nbytes + self.resident.nbytes)
        self._tables_dirty = False

    # -- introspection -------------------------------------------------------
    def device_bytes(self) -> int:
        return int(self._slab.nbytes + self._dev_tile_cols.nbytes
                   + self._dev_tile_idx.nbytes + self._rb_res.nbytes)

    def stats(self) -> dict:
        c = self.counters
        lookups = c["hits"] + c["misses"]
        return {
            "slab_tiles": int(self.slab_cap),
            "slab_bytes": int(self.slab_cap * self.tile_bytes),
            "budget_bytes": int(self.budget_bytes),
            "pool_tiles": int(self.pool.mat.tiles.shape[0]),
            "pool_bytes": int(self.pool.nbytes),
            "resident_blocks": int(self.resident.sum()),
            "hit_rate": (c["hits"] / lookups) if lookups else 1.0,
            **{k: int(v) for k, v in c.items()},
        }

    def scrub(self, slab_tiles: Optional[np.ndarray] = None) -> List[dict]:
        """CRC the slab's resident tiles against the host tier (the twin
        the integrity scrubber checksums).  Returns failure dicts in the
        ``_integrity_check`` shape; empty list = clean."""
        slab = (np.asarray(self._slab) if slab_tiles is None
                else np.asarray(slab_tiles))
        bad: List[int] = []
        for rb, slots in self._rb_slots.items():
            tc = self.pool.tile_cols[rb]
            tid = self.pool.tile_idx2d[rb][tc >= 0]
            for t, s in zip(tid.tolist(), slots):
                a = zlib.crc32(np.ascontiguousarray(
                    self.pool.mat.tiles[t]).tobytes())
                b = zlib.crc32(np.ascontiguousarray(slab[s]).tobytes())
                if a != b:
                    bad.append(rb)
                    break
        if bad:
            return [{"check": "hot_slab", "row_blocks": sorted(bad)[:8]}]
        return []

    def fork(self, pool: HostTilePool) -> "HotSetManager":
        """Twin over a copied pool: shares the immutable slab arrays,
        copies every mutable host table and the counters."""
        new = object.__new__(HotSetManager)
        new.__dict__.update(self.__dict__)
        new.pool = pool
        new.resident = self.resident.copy()
        new.last_touch = self.last_touch.copy()
        new._last_admit = self._last_admit.copy()
        new._ref = self._ref.copy()
        new._slot_of_tile = self._slot_of_tile.copy()
        new._free = list(self._free)
        new._rb_slots = {k: list(v) for k, v in self._rb_slots.items()}
        new.counters = dict(self.counters)
        return new


def host_block_adjacency(tile_cols: np.ndarray, n_cb: int) -> np.ndarray:
    """Numpy twin of :func:`ops.block_adjacency` for the host tier (the
    stream keeps ``MatrixAux`` host-side; tiered init must not round-trip
    the table through the device just to OR it)."""
    n_rb = tile_cols.shape[0]
    out = np.zeros((n_rb, n_cb), bool)
    rb, slot = np.nonzero(tile_cols >= 0)
    out[rb, tile_cols[rb, slot]] = True
    return out


# ---------------------------------------------------------------------------
# EdgePager — the blocked oracle's analogue over per-block edge extents
# ---------------------------------------------------------------------------

#: the 8-tuple ``ensure`` returns, in sweep-operand order:
#: (src, dst, osrc, odst, in_lo, in_len, out_lo, out_len)
EdgeView = Tuple


@dataclasses.dataclass
class _HostEdges:
    """Host copies of a snapshot's per-block edge extents."""
    src: np.ndarray
    dst: np.ndarray
    in_ptr: np.ndarray
    osrc: np.ndarray
    odst: np.ndarray
    out_ptr: np.ndarray


class EdgePager:
    """Host-paged per-block edge extents for :func:`run_blocked`.

    The oracle's sweep reads each active block's in-edge slice (pull) and
    out-edge slice (expansion).  The pager keeps both on host and stages
    the active set's slices into two fixed device slabs before each sweep;
    per-block ``lo``/``len`` tables (full-length, index-sized) redirect the
    sweep into the slab.  A sweep whose active set outgrows the slab
    *repacks*: blocks outside the requested set are dropped (counted as
    evictions) and the slab is rebuilt from the want set; a want set that
    cannot fit at all raises with the sizing rule.  The blocked engine
    already pays a host sync per sweep, so the staging adds no new
    synchronization points.
    """

    def __init__(self, g, budget_bytes: int):
        self.h = _HostEdges(
            src=np.asarray(g.src), dst=np.asarray(g.dst),
            in_ptr=np.asarray(g.in_block_ptr, np.int64),
            osrc=np.asarray(g.osrc), odst=np.asarray(g.odst),
            out_ptr=np.asarray(g.out_block_ptr, np.int64))
        self.n_blocks = len(self.h.in_ptr) - 1
        # 4 slab arrays (in src/dst + out src/dst) of int32
        cap = int(budget_bytes) // (4 * 4)
        sizes = (np.diff(self.h.in_ptr) + np.diff(self.h.out_ptr))
        if cap < int(sizes.max(initial=1)) + 1:
            raise ValueError(
                f"edge budget {budget_bytes} bytes holds {cap} edges per "
                f"slab but the largest block needs {int(sizes.max())} — "
                "raise the budget above max_block_edges * 16 bytes")
        self.cap = cap
        guard = 1024                       # dynamic_slice tail guard
        self._hsrc = np.zeros(cap + guard, np.int32)
        self._hdst = np.zeros(cap + guard, np.int32)
        self._hosrc = np.zeros(cap + guard, np.int32)
        self._hodst = np.zeros(cap + guard, np.int32)
        self._in_lo = np.zeros(self.n_blocks + 1, np.int32)
        self._in_len = np.zeros(self.n_blocks, np.int32)
        self._out_lo = np.zeros(self.n_blocks + 1, np.int32)
        self._out_len = np.zeros(self.n_blocks, np.int32)
        self._resident = np.zeros(self.n_blocks, bool)
        self._cursor = 0                   # bump allocator over the slab
        self._dirty = True
        self._dev = None
        self.counters = {"hits": 0, "misses": 0, "evictions": 0,
                         "repacks": 0, "transfer_bytes": 0}

    def _stage(self, b: int) -> bool:
        h = self.h
        ilo, ihi = int(h.in_ptr[b]), int(h.in_ptr[b + 1])
        olo, ohi = int(h.out_ptr[b]), int(h.out_ptr[b + 1])
        need = max(ihi - ilo, ohi - olo)
        if self._cursor + need > self.cap:
            return False
        at = self._cursor
        self._hsrc[at:at + ihi - ilo] = h.src[ilo:ihi]
        self._hdst[at:at + ihi - ilo] = h.dst[ilo:ihi]
        self._hosrc[at:at + ohi - olo] = h.osrc[olo:ohi]
        self._hodst[at:at + ohi - olo] = h.odst[olo:ohi]
        self._in_lo[b], self._in_len[b] = at, ihi - ilo
        self._out_lo[b], self._out_len[b] = at, ohi - olo
        self._cursor = at + need
        self._resident[b] = True
        self._dirty = True
        return True

    def ensure(self, block_ids: np.ndarray):
        """Stage the given blocks, repacking the slab if they do not fit;
        returns the device EdgeView (stable shapes) for the sweep."""
        ids = np.unique(np.asarray(block_ids, np.int64).reshape(-1))
        ids = ids[(ids >= 0) & (ids < self.n_blocks)]
        hit = self._resident[ids]
        self.counters["hits"] += int(hit.sum())
        self.counters["misses"] += int((~hit).sum())
        missing = ids[~hit].tolist()
        for b in list(missing):
            if self._stage(int(b)):
                missing.remove(b)
        if missing:
            # repack: keep only the want set, then stage the rest
            self.counters["repacks"] += 1
            self.counters["evictions"] += int(
                (self._resident & ~np.isin(np.arange(self.n_blocks),
                                           ids)).sum())
            keep = [int(b) for b in ids if self._resident[b]]
            self._resident[:] = False
            self._cursor = 0
            for b in keep + [int(b) for b in missing]:
                if not self._stage(b):
                    raise ValueError(
                        "active set does not fit the edge slab even after "
                        "a repack — raise the pager budget")
        if self._dirty:
            self._dev = tuple(jnp.asarray(a) for a in (
                self._hsrc, self._hdst, self._hosrc, self._hodst,
                self._in_lo[:-1], self._in_len,
                self._out_lo[:-1], self._out_len))
            self.counters["transfer_bytes"] += sum(
                a.nbytes for a in (self._hsrc, self._hdst, self._hosrc,
                                   self._hodst))
            self._dirty = False
        return self._dev

    def stats(self) -> dict:
        c = self.counters
        lookups = c["hits"] + c["misses"]
        return {"slab_edges": int(self.cap),
                "hit_rate": (c["hits"] / lookups) if lookups else 1.0,
                **{k: int(v) for k, v in c.items()}}


def paged_snapshot(g):
    """A twin of ``g`` whose O(m) edge arrays are 1-element stubs — pass it
    to ``run_blocked(..., pager=EdgePager(g, budget))`` so the device never
    holds the full CSR: the pager's bounded slab becomes the only O(edges)
    device allocation.  The index-sized per-block ptr tables and per-vertex
    arrays are kept (the sweep still reads ``vertex_valid``/``out_deg``).
    Build the :class:`EdgePager` from the *original* snapshot first — it
    copies the edge arrays to host in its constructor."""
    z = jnp.zeros((1,), jnp.int32)
    return dataclasses.replace(g, src=z, dst=z, osrc=z, odst=z)
