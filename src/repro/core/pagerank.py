"""PageRank variants — Static / ND / DT / DF × BB / LF (paper Algorithms 1-8).

Three engines back every variant (full matrix: docs/ENGINES.md):
  * ``dense``   — full-SpMV Jacobi / block-sequential Gauss–Seidel over all
                  blocks; simple, used for oracles and the distributed path;
  * ``blocked`` — the frontier-compacted sweep engine (:mod:`.blocked`):
                  Python driver, per-sweep host syncs, in-sweep Gauss–Seidel;
                  the reference production engine and fault-model oracle;
  * ``pallas``  — the fused frontier engine (:mod:`.pallas_engine`): the
                  whole sweep loop inside one ``lax.while_loop`` with the
                  MXU block-sparse SpMV pull and OR-semiring expansion —
                  zero host syncs until convergence.  Default for
                  blocked-class workloads on TPU; opt-in (interpret mode)
                  on CPU containers.

Variant = (initial ranks, initial affected set, expand?) × (mode):
    Static : R0 = 1/n,      affected = all,              expand = off
    ND     : R0 = R^{t-1},  affected = all,              expand = off
    DT     : R0 = R^{t-1},  affected = reachable(Δ),     expand = off
    DF     : R0 = R^{t-1},  affected = out-nbrs(src(Δ)), expand = on (τ_f)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import blocked as blk
from repro.core import faults as flt   # noqa: F401  (re-export: tests and
#                                        callers reach FaultPlan as pr.flt)
from repro.core import frontier as fr
from repro.core.graph import (GraphSnapshot, initial_ranks, pull_all,
                              pad_ranks)

DEFAULT_ALPHA = 0.85
DEFAULT_TAU = 1e-10          # paper: 1e-10 (f64); use ~1e-7 for f32 runs
MAX_ITERATIONS = 500


@dataclasses.dataclass
class PagerankResult:
    ranks: jnp.ndarray              # [n_pad]
    stats: blk.SweepStats
    wall_time_s: float = 0.0

    @property
    def converged(self) -> bool:
        return self.stats.converged


def default_dtype() -> jnp.dtype:
    return jnp.dtype(jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)


def default_engine() -> str:
    """Engine used when a variant is called with ``engine=None``.

    On TPU the fused Pallas engine is the production default for the
    blocked-class workloads; on CPU containers the kernels would run in
    interpret mode (validation-grade, not fast), so the blocked engine
    stays the default.  Override with ``REPRO_ENGINE=dense|blocked|pallas``
    — the override is validated against :mod:`repro.api.registry` eagerly,
    with the registered-name list in the error."""
    from repro.api import registry
    return registry.default_engine()


# ---------------------------------------------------------------------------
# dense engine (oracle-grade, full work every iteration)
# ---------------------------------------------------------------------------

def dense_jacobi(g: GraphSnapshot, R0, affected0, *, expand: bool,
                 alpha: float = DEFAULT_ALPHA, tau: float = DEFAULT_TAU,
                 tau_f: Optional[float] = None,
                 max_iterations: int = MAX_ITERATIONS,
                 personalization=None
                 ) -> Tuple[jnp.ndarray, int, bool]:
    """Barrier-based engine: masked full-SpMV per iteration (Alg. 1/3/5/7).

    ``personalization`` (restart distribution [n_pad]) swaps the uniform
    teleport for a personalized one — the exact-PPR oracle the walk
    engine's parity gates compare against on small graphs."""
    tau_f = (tau / 1000.0) if (expand and tau_f is None) else (
        tau_f if tau_f is not None else float("inf"))
    pvec = (None if personalization is None
            else jnp.asarray(personalization))

    def cond(state):
        R, affected, dR, i = state
        return jnp.logical_and(dR > tau, i < max_iterations)

    def body(state):
        R, affected, _, i = state
        r_all = pull_all(g, R, alpha=alpha, personalization=pvec)
        r_new = jnp.where(affected, r_all, R)
        dr = jnp.abs(r_new - R)
        if expand:
            changed = affected & (dr > tau_f)
            affected, _ = fr.expand_frontier(g, changed, affected,
                                             jnp.zeros_like(affected))
        return r_new, affected, jnp.max(dr), i + 1

    R = jnp.where(g.vertex_valid, R0[:g.n_pad], 0)
    init = (R, affected0[:g.n_pad] & g.vertex_valid,
            jnp.asarray(jnp.inf, R.dtype), jnp.int32(0))
    R, _, dR, iters = jax.lax.while_loop(cond, body, init)
    return R, int(iters), bool(dR <= tau)


# ---------------------------------------------------------------------------
# legacy variant functions — deprecated shims over repro.api.PageRankSession
# ---------------------------------------------------------------------------
#
# Each builds the session the call routes through (snapshot mode, the
# registry-resolved engine) and converges through it — the session path IS
# the implementation; parity is bit-for-bit (tests/test_api_session.py).
# Unknown kwargs are rejected here with the valid-key list instead of being
# silently forwarded into the engine stack (the old ``_defaults()`` hole).

_LEGACY_KEYS = ("alpha", "tau", "tau_f", "max_iterations", "faults", "tile",
                "active_policy", "pallas_mat", "pallas_aux", "pallas_backend")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.pagerank.{old}() is deprecated; use repro.api.{new} "
        "instead (docs/API.md has the migration table)",
        DeprecationWarning, stacklevel=3)


def _legacy_session(g: GraphSnapshot, R0, *, mode: str,
                    engine: Optional[str], dtype=None, kw: dict):
    """The session a legacy variant call routes through, plus the pallas
    engine's per-call operands split out of the legacy kwargs."""
    unknown = sorted(set(kw) - set(_LEGACY_KEYS))
    if unknown:
        raise TypeError(
            f"unknown keyword argument(s) {unknown} for a PageRank "
            f"variant; valid keys: {sorted(_LEGACY_KEYS)}")
    kw = dict(kw)
    mat = kw.pop("pallas_mat", None)
    aux = kw.pop("pallas_aux", None)
    backend = kw.pop("pallas_backend", None)
    from repro.api import EngineConfig, PageRankSession
    cfg = EngineConfig.from_kwargs(mode=mode, engine=engine,
                                   backend=backend, dtype=dtype, **kw)
    sess = PageRankSession.from_snapshot(g, config=cfg, r0=R0)
    return sess, mat, aux


def _all_affected(g: GraphSnapshot) -> jnp.ndarray:
    return g.vertex_valid


# -- Static -----------------------------------------------------------------

def static_pagerank(g: GraphSnapshot, *, mode: str = "bb",
                    engine: Optional[str] = None, dtype=None, **kw
                    ) -> PagerankResult:
    """Deprecated: use ``PageRankSession.recompute(variant="static")``."""
    _deprecated("static_pagerank", 'PageRankSession.recompute("static")')
    R0 = initial_ranks(g, dtype or default_dtype())
    sess, mat, aux = _legacy_session(g, R0, mode=mode, engine=engine,
                                     dtype=dtype, kw=kw)
    return sess._converge(R0, _all_affected(g), expand=False,
                          mat=mat, aux=aux)


# -- Naive-dynamic ------------------------------------------------------------

def nd_pagerank(g: GraphSnapshot, r_prev: jnp.ndarray, *, mode: str = "bb",
                engine: Optional[str] = None, **kw) -> PagerankResult:
    """Deprecated: use ``PageRankSession.recompute(variant="nd")``."""
    _deprecated("nd_pagerank", 'PageRankSession.recompute("nd")')
    R0 = pad_ranks(g, r_prev)
    sess, mat, aux = _legacy_session(g, R0, mode=mode, engine=engine, kw=kw)
    return sess._converge(R0, _all_affected(g), expand=False,
                          mat=mat, aux=aux)


# -- Dynamic Traversal ---------------------------------------------------------

def dt_pagerank(g_prev: GraphSnapshot, g: GraphSnapshot, batch: jnp.ndarray,
                r_prev: jnp.ndarray, *, mode: str = "bb",
                engine: Optional[str] = None, **kw) -> PagerankResult:
    """Deprecated: use ``PageRankSession.update(..., variant="dt")``."""
    _deprecated("dt_pagerank", 'PageRankSession.update(variant="dt")')
    affected = fr.dt_affected(g_prev, g, batch)
    R0 = pad_ranks(g, r_prev)
    sess, mat, aux = _legacy_session(g, R0, mode=mode, engine=engine, kw=kw)
    return sess._converge(R0, affected, expand=False, mat=mat, aux=aux)


# -- Dynamic Frontier (the paper's contribution) -------------------------------

def df_pagerank(g_prev: GraphSnapshot, g: GraphSnapshot, batch: jnp.ndarray,
                r_prev: jnp.ndarray, *, mode: str = "lf",
                engine: Optional[str] = None,
                helping_first_pass: Optional[jnp.ndarray] = None,
                **kw) -> PagerankResult:
    """DF_BB (mode="bb") / DF_LF (mode="lf"), Algorithms 1 & 2.

    Deprecated: use ``PageRankSession.update`` (the recompile-free
    streaming hot path) for dynamic streams."""
    _deprecated("df_pagerank", "PageRankSession.update")
    if helping_first_pass is not None:
        affected, _, _ = fr.initial_affected_with_helping(
            g_prev, g, batch, helping_first_pass)
    else:
        affected = fr.initial_affected(g_prev, g, batch)
    R0 = pad_ranks(g, r_prev)
    sess, mat, aux = _legacy_session(g, R0, mode=mode, engine=engine, kw=kw)
    return sess._converge(R0, affected, expand=True, mat=mat, aux=aux)


# ---------------------------------------------------------------------------
# reference oracle (paper §5.1.5: barrier-based static at τ=1e-100, ≤500 it)
# ---------------------------------------------------------------------------

def reference_pagerank(g: GraphSnapshot, *, alpha: float = DEFAULT_ALPHA,
                       iterations: int = MAX_ITERATIONS, dtype=None
                       ) -> jnp.ndarray:
    dtype = dtype or default_dtype()

    def body(i, R):
        return pull_all(g, R, alpha=alpha)

    return jax.lax.fori_loop(0, iterations, body, initial_ranks(g, dtype))


def numpy_reference(g: GraphSnapshot, *, alpha: float = DEFAULT_ALPHA,
                    iterations: int = 200) -> np.ndarray:
    """Independent numpy oracle (f64) for tests."""
    n, n_pad = g.n, g.n_pad
    src = np.asarray(g.src)[:g.m]
    dst = np.asarray(g.dst)[:g.m]
    deg = np.maximum(np.asarray(g.out_deg), 1).astype(np.float64)
    R = np.full(n_pad, 1.0 / n)
    R[n:] = 0
    for _ in range(iterations):
        c = R / deg
        pulled = np.bincount(dst, weights=c[src], minlength=n_pad)[:n_pad]
        R_new = (1 - alpha) / n + alpha * pulled
        R_new[n:] = 0
        R = R_new
    return R


def restart_vector(g: GraphSnapshot, seeds, dtype=np.float64) -> np.ndarray:
    """Uniform restart distribution [n_pad] over a seed set — the
    ``personalization`` operand :func:`dense_jacobi` / :func:`pull_all`
    take, and the distribution the walk engine's seed sampling realizes."""
    seeds = np.asarray(seeds, np.int64).reshape(-1)
    if seeds.size == 0:
        raise ValueError("restart_vector needs at least one seed vertex")
    if (seeds < 0).any() or (seeds >= g.n).any():
        raise ValueError(f"seed(s) out of range for a graph with {g.n} "
                         "vertices")
    p = np.zeros(g.n_pad, np.dtype(dtype))
    np.add.at(p, seeds, 1.0 / seeds.size)
    return p


def ppr_numpy_reference(g: GraphSnapshot, seeds, *,
                        alpha: float = DEFAULT_ALPHA,
                        iterations: int = 200) -> np.ndarray:
    """Independent numpy oracle (f64) for personalized PageRank with a
    uniform restart over ``seeds`` — same pull semantics as
    :func:`numpy_reference`, personalized teleport."""
    n_pad = g.n_pad
    src = np.asarray(g.src)[:g.m]
    dst = np.asarray(g.dst)[:g.m]
    deg = np.maximum(np.asarray(g.out_deg), 1).astype(np.float64)
    p = restart_vector(g, seeds)
    R = p.copy()
    for _ in range(iterations):
        c = R / deg
        pulled = np.bincount(dst, weights=c[src], minlength=n_pad)[:n_pad]
        R_new = (1 - alpha) * p + alpha * pulled
        R_new[g.n:] = 0
        R = R_new
    return R


def linf(a, b) -> float:
    return float(jnp.max(jnp.abs(a - b)))


# ---------------------------------------------------------------------------
# repro.api engine adapter (Engine protocol; discovered lazily by
# repro.api.registry so this module never imports the api package at
# import time)
# ---------------------------------------------------------------------------

class DenseEngine:
    """Registry adapter for the oracle-grade dense engine: masked full-SpMV
    Jacobi in BB mode; LF mode reuses the blocked engine (dense LF ==
    blocked with every block active)."""

    name = "dense"
    fault_domains = ("thread", "process")

    def run(self, g, R0, affected0, *, mode, expand, alpha, tau, tau_f,
            max_iterations, faults, tile, active_policy,
            mat=None, aux=None, backend=None, interpret=None, shards=None):
        from repro.api.registry import (reject_shard_spec,
                                        reject_tile_operands)
        reject_tile_operands(self.name, mat, aux, backend)
        reject_shard_spec(self.name, shards)
        if mode == "bb":
            R, iters, conv = dense_jacobi(
                g, R0, affected0, expand=expand, alpha=alpha, tau=tau,
                tau_f=tau_f, max_iterations=max_iterations)
            stats = blk.SweepStats(sweeps=iters, iterations=iters,
                                   converged=conv,
                                   edges_processed=iters * g.m)
            return jax.block_until_ready(R), stats
        R, stats = blk.run_blocked(
            g, R0, affected0, mode="lf", expand=expand, alpha=alpha,
            tau=tau, tau_f=tau_f, max_iterations=max_iterations,
            tile=tile, faults=faults, active_policy=active_policy)
        return jax.block_until_ready(R), stats


def as_engine() -> DenseEngine:
    return DenseEngine()
