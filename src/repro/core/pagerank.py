"""PageRank variants — Static / ND / DT / DF × BB / LF (paper Algorithms 1-8).

Three engines back every variant (full matrix: docs/ENGINES.md):
  * ``dense``   — full-SpMV Jacobi / block-sequential Gauss–Seidel over all
                  blocks; simple, used for oracles and the distributed path;
  * ``blocked`` — the frontier-compacted sweep engine (:mod:`.blocked`):
                  Python driver, per-sweep host syncs, in-sweep Gauss–Seidel;
                  the reference production engine and fault-model oracle;
  * ``pallas``  — the fused frontier engine (:mod:`.pallas_engine`): the
                  whole sweep loop inside one ``lax.while_loop`` with the
                  MXU block-sparse SpMV pull and OR-semiring expansion —
                  zero host syncs until convergence.  Default for
                  blocked-class workloads on TPU; opt-in (interpret mode)
                  on CPU containers.

Variant = (initial ranks, initial affected set, expand?) × (mode):
    Static : R0 = 1/n,      affected = all,              expand = off
    ND     : R0 = R^{t-1},  affected = all,              expand = off
    DT     : R0 = R^{t-1},  affected = reachable(Δ),     expand = off
    DF     : R0 = R^{t-1},  affected = out-nbrs(src(Δ)), expand = on (τ_f)
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import blocked as blk
from repro.core import faults as flt
from repro.core import frontier as fr
from repro.core import pallas_engine as pe
from repro.core.graph import (GraphSnapshot, initial_ranks, pull_all,
                              pad_ranks)

DEFAULT_ALPHA = 0.85
DEFAULT_TAU = 1e-10          # paper: 1e-10 (f64); use ~1e-7 for f32 runs
MAX_ITERATIONS = 500


@dataclasses.dataclass
class PagerankResult:
    ranks: jnp.ndarray              # [n_pad]
    stats: blk.SweepStats
    wall_time_s: float = 0.0

    @property
    def converged(self) -> bool:
        return self.stats.converged


def default_dtype() -> jnp.dtype:
    return jnp.dtype(jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)


def default_engine() -> str:
    """Engine used when a variant is called with ``engine=None``.

    On TPU the fused Pallas engine is the production default for the
    blocked-class workloads; on CPU containers the kernels would run in
    interpret mode (validation-grade, not fast), so the blocked engine
    stays the default.  Override with ``REPRO_ENGINE=dense|blocked|pallas``.
    """
    env = os.environ.get("REPRO_ENGINE")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


# ---------------------------------------------------------------------------
# dense engine (oracle-grade, full work every iteration)
# ---------------------------------------------------------------------------

def dense_jacobi(g: GraphSnapshot, R0, affected0, *, expand: bool,
                 alpha: float = DEFAULT_ALPHA, tau: float = DEFAULT_TAU,
                 tau_f: Optional[float] = None,
                 max_iterations: int = MAX_ITERATIONS
                 ) -> Tuple[jnp.ndarray, int, bool]:
    """Barrier-based engine: masked full-SpMV per iteration (Alg. 1/3/5/7)."""
    tau_f = (tau / 1000.0) if (expand and tau_f is None) else (
        tau_f if tau_f is not None else float("inf"))

    def cond(state):
        R, affected, dR, i = state
        return jnp.logical_and(dR > tau, i < max_iterations)

    def body(state):
        R, affected, _, i = state
        r_all = pull_all(g, R, alpha=alpha)
        r_new = jnp.where(affected, r_all, R)
        dr = jnp.abs(r_new - R)
        if expand:
            changed = affected & (dr > tau_f)
            affected, _ = fr.expand_frontier(g, changed, affected,
                                             jnp.zeros_like(affected))
        return r_new, affected, jnp.max(dr), i + 1

    R = jnp.where(g.vertex_valid, R0[:g.n_pad], 0)
    init = (R, affected0[:g.n_pad] & g.vertex_valid,
            jnp.asarray(jnp.inf, R.dtype), jnp.int32(0))
    R, _, dR, iters = jax.lax.while_loop(cond, body, init)
    return R, int(iters), bool(dR <= tau)


# ---------------------------------------------------------------------------
# unified runner
# ---------------------------------------------------------------------------

def _run(g: GraphSnapshot, R0, affected0, *, mode: str, expand: bool,
         engine: Optional[str], alpha: float, tau: float,
         tau_f: Optional[float], max_iterations: int,
         faults: Optional[flt.FaultPlan], tile: int,
         active_policy: str = "affected",
         pallas_mat=None, pallas_aux=None,
         pallas_backend: Optional[str] = None) -> PagerankResult:
    engine = engine or default_engine()
    if engine != "pallas":
        for name, val in (("pallas_mat", pallas_mat),
                          ("pallas_aux", pallas_aux),
                          ("pallas_backend", pallas_backend)):
            if val is not None:
                raise ValueError(
                    f"{name} is only consumed by engine='pallas' "
                    f"(resolved engine: {engine!r})")
    t0 = time.perf_counter()
    if engine == "dense":
        if mode == "bb":
            R, iters, conv = dense_jacobi(
                g, R0, affected0, expand=expand, alpha=alpha, tau=tau,
                tau_f=tau_f, max_iterations=max_iterations)
            R = jax.block_until_ready(R)
            stats = blk.SweepStats(sweeps=iters, iterations=iters,
                                   converged=conv,
                                   edges_processed=iters * g.m)
        else:
            # dense LF == blocked engine with every block active; reuse it
            R, stats = blk.run_blocked(
                g, R0, affected0, mode="lf", expand=expand, alpha=alpha,
                tau=tau, tau_f=tau_f, max_iterations=max_iterations,
                tile=tile, faults=faults, active_policy=active_policy)
            R = jax.block_until_ready(R)
    elif engine == "blocked":
        R, stats = blk.run_blocked(
            g, R0, affected0, mode=mode, expand=expand, alpha=alpha, tau=tau,
            tau_f=tau_f, max_iterations=max_iterations, tile=tile,
            faults=faults, active_policy=active_policy)
        R = jax.block_until_ready(R)
    elif engine == "pallas":
        R, stats = pe.run_pallas(
            g, R0, affected0, mode=mode, expand=expand, alpha=alpha, tau=tau,
            tau_f=tau_f, max_iterations=max_iterations, faults=faults,
            active_policy=active_policy, mat=pallas_mat, aux=pallas_aux,
            backend=pallas_backend)
        R = jax.block_until_ready(R)
    else:
        raise ValueError(engine)
    return PagerankResult(ranks=R, stats=stats,
                          wall_time_s=time.perf_counter() - t0)


def _all_affected(g: GraphSnapshot) -> jnp.ndarray:
    return g.vertex_valid


# -- Static -----------------------------------------------------------------

def static_pagerank(g: GraphSnapshot, *, mode: str = "bb",
                    engine: Optional[str] = None, dtype=None, **kw
                    ) -> PagerankResult:
    dtype = dtype or default_dtype()
    return _run(g, initial_ranks(g, dtype), _all_affected(g), mode=mode,
                expand=False, engine=engine, **_defaults(kw))


# -- Naive-dynamic ------------------------------------------------------------

def nd_pagerank(g: GraphSnapshot, r_prev: jnp.ndarray, *, mode: str = "bb",
                engine: Optional[str] = None, **kw) -> PagerankResult:
    return _run(g, pad_ranks(g, r_prev), _all_affected(g), mode=mode,
                expand=False, engine=engine, **_defaults(kw))


# -- Dynamic Traversal ---------------------------------------------------------

def dt_pagerank(g_prev: GraphSnapshot, g: GraphSnapshot, batch: jnp.ndarray,
                r_prev: jnp.ndarray, *, mode: str = "bb",
                engine: Optional[str] = None, **kw) -> PagerankResult:
    affected = fr.dt_affected(g_prev, g, batch)
    return _run(g, pad_ranks(g, r_prev), affected, mode=mode, expand=False,
                engine=engine, **_defaults(kw))


# -- Dynamic Frontier (the paper's contribution) -------------------------------

def df_pagerank(g_prev: GraphSnapshot, g: GraphSnapshot, batch: jnp.ndarray,
                r_prev: jnp.ndarray, *, mode: str = "lf",
                engine: Optional[str] = None,
                helping_first_pass: Optional[jnp.ndarray] = None,
                **kw) -> PagerankResult:
    """DF_BB (mode="bb") / DF_LF (mode="lf"), Algorithms 1 & 2."""
    if helping_first_pass is not None:
        affected, _, _ = fr.initial_affected_with_helping(
            g_prev, g, batch, helping_first_pass)
    else:
        affected = fr.initial_affected(g_prev, g, batch)
    return _run(g, pad_ranks(g, r_prev), affected, mode=mode, expand=True,
                engine=engine, **_defaults(kw))


def _defaults(kw: dict) -> dict:
    out = dict(alpha=DEFAULT_ALPHA, tau=DEFAULT_TAU, tau_f=None,
               max_iterations=MAX_ITERATIONS, faults=None, tile=512,
               active_policy="affected", pallas_mat=None, pallas_aux=None,
               pallas_backend=None)
    out.update(kw)
    return out


# ---------------------------------------------------------------------------
# reference oracle (paper §5.1.5: barrier-based static at τ=1e-100, ≤500 it)
# ---------------------------------------------------------------------------

def reference_pagerank(g: GraphSnapshot, *, alpha: float = DEFAULT_ALPHA,
                       iterations: int = MAX_ITERATIONS, dtype=None
                       ) -> jnp.ndarray:
    dtype = dtype or default_dtype()

    def body(i, R):
        return pull_all(g, R, alpha=alpha)

    return jax.lax.fori_loop(0, iterations, body, initial_ranks(g, dtype))


def numpy_reference(g: GraphSnapshot, *, alpha: float = DEFAULT_ALPHA,
                    iterations: int = 200) -> np.ndarray:
    """Independent numpy oracle (f64) for tests."""
    n, n_pad = g.n, g.n_pad
    src = np.asarray(g.src)[:g.m]
    dst = np.asarray(g.dst)[:g.m]
    deg = np.maximum(np.asarray(g.out_deg), 1).astype(np.float64)
    R = np.full(n_pad, 1.0 / n)
    R[n:] = 0
    for _ in range(iterations):
        c = R / deg
        pulled = np.bincount(dst, weights=c[src], minlength=n_pad)[:n_pad]
        R_new = (1 - alpha) / n + alpha * pulled
        R_new[n:] = 0
        R = R_new
    return R


def linf(a, b) -> float:
    return float(jnp.max(jnp.abs(a - b)))
