"""Distributed Dynamic-Frontier PageRank via shard_map (DESIGN.md §2, §5).

1-D vertex partition: device d owns the contiguous vertex range
[d·n_loc, (d+1)·n_loc).  In-edges are partitioned by destination owner (pull),
out-edges by source owner (frontier expansion).  Per sweep:

    1. contribution exchange — one of
         "full"  : all-gather of the n-float contribution vector
         "bf16"  : the same, cast to bf16 on the wire (½ the collective bytes,
                   f32 master kept locally) — gradient-compression analogue
         "delta" : *sparse delta all-gather* — only the ≤K contributions that
                   changed since the last exchange travel, as (idx, val)
                   pairs; overflow falls back to a full exchange.  This is the
                   frontier-aware collective that makes the DF approach pay
                   off at the wire level (beyond-paper optimization);
    2. local update of affected vertices (Jacobi, or ``local_gs_sweeps`` > 1
       block-Gauss–Seidel sweeps against *stale* remote contributions — the
       TPU analogue of the paper's lock-free staleness tolerance);
    3. frontier expansion: local out-edge OR-scatter, then a pmax exchange of
       the mark vector;
    4. convergence: psum of outstanding per-vertex RC flags.

A straggling device simply delivers one-sweep-stale contributions; all other
devices keep making progress — the paper's helping/stale-read argument,
re-expressed as stale-synchronous data flow.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.graph import HostGraph


@dataclasses.dataclass(frozen=True)
class DistGraph:
    """Device-partitioned dynamic-graph snapshot (host-built)."""
    n: int
    n_pad: int
    n_dev: int
    # in-edges grouped by destination owner; [n_dev, m_in_pad]
    src_in: jnp.ndarray
    dst_in: jnp.ndarray
    # out-edges grouped by source owner; [n_dev, m_out_pad]
    src_out: jnp.ndarray
    dst_out: jnp.ndarray
    inv_deg: jnp.ndarray       # [n_pad] f32/f64 (0 on invalid)
    vertex_valid: jnp.ndarray  # [n_pad] bool
    # ring layout (exchange="ring"): this device's in-edges re-grouped by
    # SOURCE owner — [n_dev, n_dev_owners, ring_cap]; hop k consumes the
    # slice of the owner whose chunk just arrived
    src_in_ring: Optional[jnp.ndarray] = None
    dst_in_ring: Optional[jnp.ndarray] = None

    @property
    def n_loc(self) -> int:
        return self.n_pad // self.n_dev


def build_dist_graph(hg: HostGraph, n_dev: int, *, dtype=jnp.float32,
                     ring: bool = False) -> DistGraph:
    n = hg.n
    n_loc = -(-n // n_dev)
    n_pad = n_loc * n_dev
    e = hg.edges
    loops = np.arange(n, dtype=np.int64)
    src = np.concatenate([e[:, 0], loops])
    dst = np.concatenate([e[:, 1], loops])
    out_deg = np.bincount(src, minlength=n_pad)

    def partition(owner: np.ndarray, a: np.ndarray, b: np.ndarray):
        dev = owner // n_loc
        order = np.argsort(dev, kind="stable")
        a, b, dev = a[order], b[order], dev[order]
        counts = np.bincount(dev, minlength=n_dev)
        cap = int(counts.max(initial=1))
        A = np.full((n_dev, cap), n_pad, dtype=np.int32)
        B = np.full((n_dev, cap), n_pad, dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        for d in range(n_dev):
            s, c = starts[d], counts[d]
            A[d, :c] = a[s:s + c]
            B[d, :c] = b[s:s + c]
        return jnp.asarray(A), jnp.asarray(B)

    src_in, dst_in = partition(dst, src, dst)
    src_out, dst_out = partition(src, src, dst)

    sir = dir_ = None
    if ring:
        # per (dst-owner device, src-owner) edge slabs for the ring schedule
        ddev = dst // n_loc
        sdev = src // n_loc
        key = ddev * n_dev + sdev
        order = np.argsort(key, kind="stable")
        s_s, d_s, key_s = src[order], dst[order], key[order]
        counts = np.bincount(key_s, minlength=n_dev * n_dev)
        cap = max(8, int(counts.max(initial=1)))
        SIR = np.full((n_dev, n_dev, cap), n_pad, dtype=np.int32)
        DIR = np.full((n_dev, n_dev, cap), n_pad, dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        for kk in np.nonzero(counts)[0]:
            dd, so = divmod(int(kk), n_dev)
            lo, c = starts[kk], counts[kk]
            SIR[dd, so, :c] = s_s[lo:lo + c]
            DIR[dd, so, :c] = d_s[lo:lo + c]
        sir, dir_ = jnp.asarray(SIR), jnp.asarray(DIR)

    vv = np.zeros(n_pad, dtype=bool)
    vv[:n] = True
    inv = np.where(vv, 1.0 / np.maximum(out_deg, 1), 0.0)
    return DistGraph(n=n, n_pad=n_pad, n_dev=n_dev,
                     src_in=src_in, dst_in=dst_in,
                     src_out=src_out, dst_out=dst_out,
                     inv_deg=jnp.asarray(inv, dtype),
                     vertex_valid=jnp.asarray(vv),
                     src_in_ring=sir, dst_in_ring=dir_)


def make_sweep(dg: DistGraph, mesh: Mesh, axis, *, alpha: float,
               tau: float, tau_f: float, expand: bool,
               exchange: str = "full", delta_capacity: int = 1024,
               local_gs_sweeps: int = 1, local_blocks: int = 4,
               marks_dtype=jnp.int32):
    """Build the jitted shard_map sweep.  State carried across sweeps:
    (R_loc, affected_loc, rc_loc, contrib_cache_loc_view).

    ``axis`` may be one mesh axis name or a tuple of axis names — the
    production mesh partitions vertices over all of ("pod","data","model").
    """
    n, n_pad, n_dev, n_loc = dg.n, dg.n_pad, dg.n_dev, dg.n_loc
    dt = dg.inv_deg.dtype
    base = (1.0 - alpha) / n
    delta_capacity = min(delta_capacity, n_loc)
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def _flat_index():
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + lax.axis_index(a)
        return idx

    def local_update(R_loc, contrib_full, aff_loc, vv_loc, src, dst, off,
                     inv_loc):
        """One (or several, Gauss–Seidel) local pull updates."""
        dst_loc = jnp.clip(dst - off, 0, n_loc)   # pad edges → bin n_loc

        def one(R_loc, contrib_full):
            pulled = jax.ops.segment_sum(
                contrib_full[jnp.minimum(src, n_pad - 1)]
                * (src < n_pad),
                dst_loc, num_segments=n_loc + 1)[:n_loc]
            r_new = base + alpha * pulled.astype(dt)
            return jnp.where(aff_loc & vv_loc, r_new, R_loc)

        if local_gs_sweeps <= 1:
            return one(R_loc, contrib_full)
        # block-Gauss–Seidel against stale remote contributions: refresh the
        # *local* slice of the contribution vector between inner sweeps
        for _ in range(local_gs_sweeps):
            R_loc = one(R_loc, contrib_full)
            contrib_full = lax.dynamic_update_slice(
                contrib_full, R_loc * inv_loc, (off,))
        return R_loc

    def sweep(R_loc, aff_loc, rc_loc, cache_slab,
              src_in, dst_in, src_out, dst_out, inv_loc, vv_loc,
              *ring_slabs):
        # squeeze the leading device dim shard_map leaves on the slabs
        src_in, dst_in = src_in[0], dst_in[0]
        src_out, dst_out = src_out[0], dst_out[0]
        # the delta-exchange cache is each device's PRIVATE view of the
        # global contribution vector: it travels as a [n_dev, n] slab so no
        # output collective is ever needed (a replicated [n] output spec
        # costs a hidden full all-gather per sweep — measured, see §Perf)
        cache_loc = cache_slab[0]
        idx = _flat_index()
        off = idx * n_loc

        contrib_loc = R_loc * inv_loc
        if exchange == "ring":
            # ring schedule: n_dev−1 collective_permute hops; hop k consumes
            # the chunk of owner (me−k) against the pre-sliced edge slab for
            # that owner.  On TPU the next hop's DMA overlaps the current
            # hop's partial SpMV — the lock-free paper's "never wait at a
            # barrier" insight applied to the exchange itself.
            src_ring, dst_ring = ring_slabs[0][0], ring_slabs[1][0]
            me = idx
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

            def hop(k, state):
                acc, chunk = state
                owner = (me - k) % n_dev
                sl = lax.dynamic_index_in_dim(src_ring, owner, 0,
                                              keepdims=False)
                dl = lax.dynamic_index_in_dim(dst_ring, owner, 0,
                                              keepdims=False)
                dloc = jnp.clip(dl - off, 0, n_loc)
                c = jnp.where(
                    sl < n_pad,
                    chunk[jnp.clip(sl - owner * n_loc, 0, n_loc - 1)], 0)
                acc = acc + jax.ops.segment_sum(
                    c, dloc, num_segments=n_loc + 1)[:n_loc]
                chunk = lax.ppermute(chunk, axes, perm)
                return acc, chunk

            pulled, _ = lax.fori_loop(
                0, n_dev, hop, (jnp.zeros((n_loc,), dt), contrib_loc))
            r_new = base + alpha * pulled.astype(dt)
            R_new = jnp.where(aff_loc & vv_loc, r_new, R_loc)
            overflow = jnp.zeros((), bool)
        elif exchange == "full":
            contrib_full = lax.all_gather(contrib_loc, axes, tiled=True)
            overflow = jnp.zeros((), bool)
        elif exchange == "bf16":
            # the barrier pins the bf16 convert BEFORE the gather: XLA is
            # otherwise free to sink it past the collective (same values,
            # 2× the wire bytes — observed; see EXPERIMENTS.md §Perf)
            wire = lax.optimization_barrier(
                contrib_loc.astype(jnp.bfloat16))
            contrib_full = lax.all_gather(wire, axes, tiled=True
                                          ).astype(dt)
            overflow = jnp.zeros((), bool)
        elif exchange == "delta":
            delta = contrib_loc - lax.dynamic_slice(cache_loc, (off,),
                                                    (n_loc,))
            n_changed = (delta != 0).sum()
            overflow = n_changed > delta_capacity
            mag, pos = lax.top_k(jnp.abs(delta), delta_capacity)
            vals = contrib_loc[pos]
            live = mag > 0
            gidx = jnp.where(live, pos + off, n_pad)
            all_idx = lax.all_gather(gidx, axes).reshape(-1)
            all_val = lax.all_gather(jnp.where(live, vals, 0), axes
                                     ).reshape(-1)
            patched = jnp.concatenate([cache_loc, jnp.zeros((1,), dt)])
            patched = patched.at[all_idx].set(all_val)
            contrib_delta = patched[:n_pad]
            # overflow anywhere → fall back to a full gather (correctness).
            # The fallback lives under lax.cond so its all-gather only
            # executes on overflow sweeps — every device agrees on the
            # branch (any_ovf is pmax'd), keeping the SPMD program uniform.
            any_ovf = lax.pmax(overflow.astype(jnp.int32), axes) > 0
            contrib_full = lax.cond(
                any_ovf,
                lambda: lax.all_gather(contrib_loc, axes, tiled=True),
                lambda: contrib_delta)
            overflow = any_ovf
        else:
            raise ValueError(exchange)

        if exchange != "ring":
            R_new = local_update(R_loc, contrib_full, aff_loc, vv_loc,
                                 src_in, dst_in, off, inv_loc)
        dr = jnp.abs(R_new - R_loc)
        changed = aff_loc & (dr > tau_f)
        rc_new = jnp.where(aff_loc & vv_loc, dr > tau, rc_loc)

        if expand:
            # local out-edges: src are owned here; mark global dst
            src_loc = jnp.clip(src_out - off, 0, n_loc - 1)
            flag = (src_out < n_pad) & changed[src_loc]
            # frontier marks travel as marks_dtype on the wire (int8 is
            # the compressed §Perf variant — 4× fewer pmax bytes)
            marks = jnp.zeros((n_pad + 1,), marks_dtype).at[
                jnp.where(flag, dst_out, n_pad)].set(1)[:n_pad]
            marks = lax.pmax(marks, axes) > 0
            marks_loc = lax.dynamic_slice(marks, (off,), (n_loc,)) & vv_loc
            aff_loc = aff_loc | marks_loc
            rc_new = rc_new | marks_loc

        outstanding = lax.psum(rc_new.sum(), axes)
        max_dr = lax.pmax(jnp.max(dr), axes)
        cache_new = (contrib_full if exchange == "delta"
                     else cache_loc)
        return (R_new, aff_loc, rc_new, cache_new[None], outstanding,
                max_dr, overflow)

    ax = axes if len(axes) > 1 else axes[0]
    specs_state = (P(ax), P(ax), P(ax), P(ax, None))
    specs_graph = (P(ax, None),) * 4 + (P(ax), P(ax))
    if exchange == "ring":
        specs_graph = specs_graph + (P(ax, None, None),) * 2
    fn = shard_map(sweep, mesh=mesh,
                   in_specs=specs_state + specs_graph,
                   out_specs=(P(ax), P(ax), P(ax), P(ax, None), P(), P(),
                              P()),
                   check_rep=False)
    return jax.jit(fn)


@dataclasses.dataclass
class DistStats:
    sweeps: int = 0
    converged: bool = False
    full_exchanges: int = 0
    delta_exchanges: int = 0


def run_distributed(hg_or_dg, mesh: Mesh, *, axis: str = "data",
                    r_prev: Optional[jnp.ndarray] = None,
                    affected0: Optional[jnp.ndarray] = None,
                    alpha: float = 0.85, tau: float = 1e-10,
                    tau_f: Optional[float] = None, expand: bool = True,
                    exchange: str = "full", delta_capacity: int = 1024,
                    local_gs_sweeps: int = 1, max_sweeps: int = 500,
                    marks_dtype=jnp.int32,
                    dtype=jnp.float64) -> Tuple[jnp.ndarray, DistStats]:
    """Driver: converges the distributed DF sweep to all-RC-clear."""
    if isinstance(hg_or_dg, DistGraph):
        dg = hg_or_dg
    else:
        n_dev = int(np.prod([mesh.shape[a] for a in ([axis] if isinstance(
            axis, str) else axis)]))
        dg = build_dist_graph(hg_or_dg, n_dev, dtype=dtype,
                              ring=(exchange == "ring"))
    if tau_f is None:
        tau_f = tau / 1000.0 if expand else float("inf")

    R = (jnp.full((dg.n_pad,), 1.0 / dg.n, dtype)
         if r_prev is None else jnp.asarray(r_prev, dtype))
    R = jnp.where(dg.vertex_valid, R[:dg.n_pad], 0)
    aff = (dg.vertex_valid if affected0 is None
           else (affected0[:dg.n_pad] & dg.vertex_valid))
    rc = aff
    cache_w = dg.n_pad if exchange == "delta" else 1
    cache = jnp.zeros((dg.n_dev, cache_w), dtype)

    sweep = make_sweep(dg, mesh, axis, alpha=alpha, tau=tau, tau_f=tau_f,
                       expand=expand, exchange=exchange,
                       delta_capacity=delta_capacity,
                       local_gs_sweeps=local_gs_sweeps,
                       marks_dtype=marks_dtype)
    stats = DistStats()
    extra = ((dg.src_in_ring, dg.dst_in_ring)
             if exchange == "ring" else ())
    for i in range(max_sweeps):
        (R, aff, rc, cache, outstanding, max_dr, overflow) = sweep(
            R, aff, rc, cache, dg.src_in, dg.dst_in, dg.src_out, dg.dst_out,
            dg.inv_deg, dg.vertex_valid, *extra)
        stats.sweeps += 1
        if exchange == "delta":
            if bool(overflow):
                stats.full_exchanges += 1
            else:
                stats.delta_exchanges += 1
        else:
            stats.full_exchanges += 1
        if int(outstanding) == 0:
            stats.converged = True
            break
    return R, stats
