"""Distributed Dynamic-Frontier PageRank via shard_map (DESIGN.md §2, §5).

1-D vertex partition: device d owns the contiguous vertex range
[d·n_loc, (d+1)·n_loc).  In-edges are partitioned by destination owner (pull),
out-edges by source owner (frontier expansion).  Per sweep:

    1. contribution exchange — one of
         "full"  : all-gather of the n-float contribution vector
         "bf16"  : the same, cast to bf16 on the wire (½ the collective bytes,
                   f32 master kept locally) — gradient-compression analogue
         "delta" : *sparse delta all-gather* — only the ≤K contributions that
                   changed since the last exchange travel, as (idx, val)
                   pairs; overflow falls back to a full exchange.  This is the
                   frontier-aware collective that makes the DF approach pay
                   off at the wire level (beyond-paper optimization);
    2. local update of affected vertices (Jacobi, or ``local_gs_sweeps`` > 1
       block-Gauss–Seidel sweeps against *stale* remote contributions — the
       TPU analogue of the paper's lock-free staleness tolerance);
    3. frontier expansion: local out-edge OR-scatter, then a pmax exchange of
       the mark vector;
    4. convergence: psum of outstanding per-vertex RC flags.

A straggling device simply delivers one-sweep-stale contributions; all other
devices keep making progress — the paper's helping/stale-read argument,
re-expressed as stale-synchronous data flow.

Two ways in:

* :class:`DistRuntime` — the **incremental** sharded runtime behind
  ``repro.api.PageRankSession(topology="sharded")``: device-resident edge
  slabs and degree vectors patched by O(batch) scatters per update batch,
  one compiled sweep reused across every batch (zero post-warmup
  retraces).  This is the supported path.
* :func:`run_distributed` / :func:`build_dist_graph` — the one-shot
  rebuild-everything driver.  **Deprecated for direct use**: construct a
  session with ``EngineConfig(topology="sharded")`` instead (docs/API.md
  migration table); the ``distributed`` engine adapter and the tests keep
  calling it internally.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.graph import HostGraph


@dataclasses.dataclass(frozen=True)
class DistGraph:
    """Device-partitioned dynamic-graph snapshot (host-built)."""
    n: int
    n_pad: int
    n_dev: int
    # in-edges grouped by destination owner; [n_dev, m_in_pad]
    src_in: jnp.ndarray
    dst_in: jnp.ndarray
    # out-edges grouped by source owner; [n_dev, m_out_pad]
    src_out: jnp.ndarray
    dst_out: jnp.ndarray
    inv_deg: jnp.ndarray       # [n_pad] f32/f64 (0 on invalid)
    vertex_valid: jnp.ndarray  # [n_pad] bool
    # ring layout (exchange="ring"): this device's in-edges re-grouped by
    # SOURCE owner — [n_dev, n_dev_owners, ring_cap]; hop k consumes the
    # slice of the owner whose chunk just arrived
    src_in_ring: Optional[jnp.ndarray] = None
    dst_in_ring: Optional[jnp.ndarray] = None

    @property
    def n_loc(self) -> int:
        return self.n_pad // self.n_dev


def build_dist_graph(hg: HostGraph, n_dev: int, *, dtype=jnp.float32,
                     ring: bool = False) -> DistGraph:
    n = hg.n
    n_loc = -(-n // n_dev)
    n_pad = n_loc * n_dev
    e = hg.edges
    loops = np.arange(n, dtype=np.int64)
    src = np.concatenate([e[:, 0], loops])
    dst = np.concatenate([e[:, 1], loops])
    out_deg = np.bincount(src, minlength=n_pad)

    def partition(owner: np.ndarray, a: np.ndarray, b: np.ndarray):
        dev = owner // n_loc
        order = np.argsort(dev, kind="stable")
        a, b, dev = a[order], b[order], dev[order]
        counts = np.bincount(dev, minlength=n_dev)
        cap = int(counts.max(initial=1))
        A = np.full((n_dev, cap), n_pad, dtype=np.int32)
        B = np.full((n_dev, cap), n_pad, dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        for d in range(n_dev):
            s, c = starts[d], counts[d]
            A[d, :c] = a[s:s + c]
            B[d, :c] = b[s:s + c]
        return jnp.asarray(A), jnp.asarray(B)

    src_in, dst_in = partition(dst, src, dst)
    src_out, dst_out = partition(src, src, dst)

    sir = dir_ = None
    if ring:
        # per (dst-owner device, src-owner) edge slabs for the ring schedule
        ddev = dst // n_loc
        sdev = src // n_loc
        key = ddev * n_dev + sdev
        order = np.argsort(key, kind="stable")
        s_s, d_s, key_s = src[order], dst[order], key[order]
        counts = np.bincount(key_s, minlength=n_dev * n_dev)
        cap = max(8, int(counts.max(initial=1)))
        SIR = np.full((n_dev, n_dev, cap), n_pad, dtype=np.int32)
        DIR = np.full((n_dev, n_dev, cap), n_pad, dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        for kk in np.nonzero(counts)[0]:
            dd, so = divmod(int(kk), n_dev)
            lo, c = starts[kk], counts[kk]
            SIR[dd, so, :c] = s_s[lo:lo + c]
            DIR[dd, so, :c] = d_s[lo:lo + c]
        sir, dir_ = jnp.asarray(SIR), jnp.asarray(DIR)

    vv = np.zeros(n_pad, dtype=bool)
    vv[:n] = True
    inv = np.where(vv, 1.0 / np.maximum(out_deg, 1), 0.0)
    return DistGraph(n=n, n_pad=n_pad, n_dev=n_dev,
                     src_in=src_in, dst_in=dst_in,
                     src_out=src_out, dst_out=dst_out,
                     inv_deg=jnp.asarray(inv, dtype),
                     vertex_valid=jnp.asarray(vv),
                     src_in_ring=sir, dst_in_ring=dir_)


def make_sweep(dg: DistGraph, mesh: Mesh, axis, *, alpha: float,
               tau: float, tau_f: float, expand: bool,
               exchange: str = "full", delta_capacity: int = 1024,
               local_gs_sweeps: int = 1, local_blocks: int = 4,
               marks_dtype=jnp.int32):
    """Build the jitted shard_map sweep.  State carried across sweeps:
    (R_loc, affected_loc, rc_loc, contrib_cache_loc_view).

    ``axis`` may be one mesh axis name or a tuple of axis names — the
    production mesh partitions vertices over all of ("pod","data","model").
    """
    n, n_pad, n_dev, n_loc = dg.n, dg.n_pad, dg.n_dev, dg.n_loc
    dt = dg.inv_deg.dtype
    base = (1.0 - alpha) / n
    delta_capacity = min(delta_capacity, n_loc)
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def _flat_index():
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + lax.axis_index(a)
        return idx

    def local_update(R_loc, contrib_full, aff_loc, vv_loc, src, dst, off,
                     inv_loc):
        """One (or several, Gauss–Seidel) local pull updates."""
        dst_loc = jnp.clip(dst - off, 0, n_loc)   # pad edges → bin n_loc

        def one(R_loc, contrib_full):
            pulled = jax.ops.segment_sum(
                contrib_full[jnp.minimum(src, n_pad - 1)]
                * (src < n_pad),
                dst_loc, num_segments=n_loc + 1)[:n_loc]
            r_new = base + alpha * pulled.astype(dt)
            return jnp.where(aff_loc & vv_loc, r_new, R_loc)

        if local_gs_sweeps <= 1:
            return one(R_loc, contrib_full)
        # block-Gauss–Seidel against stale remote contributions: refresh the
        # *local* slice of the contribution vector between inner sweeps
        for _ in range(local_gs_sweeps):
            R_loc = one(R_loc, contrib_full)
            contrib_full = lax.dynamic_update_slice(
                contrib_full, R_loc * inv_loc, (off,))
        return R_loc

    def sweep(R_loc, aff_loc, rc_loc, cache_slab,
              src_in, dst_in, src_out, dst_out, inv_loc, vv_loc,
              *ring_slabs):
        # squeeze the leading device dim shard_map leaves on the slabs
        src_in, dst_in = src_in[0], dst_in[0]
        src_out, dst_out = src_out[0], dst_out[0]
        # frontier-proportional work metric: in-edges whose destination is
        # in this sweep's affected set (the edges the pull actually uses)
        idx0 = _flat_index()
        dst_l0 = jnp.clip(dst_in - idx0 * n_loc, 0, n_loc - 1)
        edges_active = ((src_in < n_pad) & (dst_in < n_pad)
                        & aff_loc[dst_l0]).sum()
        # the delta-exchange cache is each device's PRIVATE view of the
        # global contribution vector: it travels as a [n_dev, n] slab so no
        # output collective is ever needed (a replicated [n] output spec
        # costs a hidden full all-gather per sweep — measured, see §Perf)
        cache_loc = cache_slab[0]
        idx = _flat_index()
        off = idx * n_loc

        contrib_loc = R_loc * inv_loc
        if exchange == "ring":
            # ring schedule: n_dev−1 collective_permute hops; hop k consumes
            # the chunk of owner (me−k) against the pre-sliced edge slab for
            # that owner.  On TPU the next hop's DMA overlaps the current
            # hop's partial SpMV — the lock-free paper's "never wait at a
            # barrier" insight applied to the exchange itself.
            src_ring, dst_ring = ring_slabs[0][0], ring_slabs[1][0]
            me = idx
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

            def hop(k, state):
                acc, chunk = state
                owner = (me - k) % n_dev
                sl = lax.dynamic_index_in_dim(src_ring, owner, 0,
                                              keepdims=False)
                dl = lax.dynamic_index_in_dim(dst_ring, owner, 0,
                                              keepdims=False)
                dloc = jnp.clip(dl - off, 0, n_loc)
                c = jnp.where(
                    sl < n_pad,
                    chunk[jnp.clip(sl - owner * n_loc, 0, n_loc - 1)], 0)
                acc = acc + jax.ops.segment_sum(
                    c, dloc, num_segments=n_loc + 1)[:n_loc]
                chunk = lax.ppermute(chunk, axes, perm)
                return acc, chunk

            pulled, _ = lax.fori_loop(
                0, n_dev, hop, (jnp.zeros((n_loc,), dt), contrib_loc))
            r_new = base + alpha * pulled.astype(dt)
            R_new = jnp.where(aff_loc & vv_loc, r_new, R_loc)
            overflow = jnp.zeros((), bool)
        elif exchange == "full":
            contrib_full = lax.all_gather(contrib_loc, axes, tiled=True)
            overflow = jnp.zeros((), bool)
        elif exchange == "bf16":
            # the barrier pins the bf16 convert BEFORE the gather: XLA is
            # otherwise free to sink it past the collective (same values,
            # 2× the wire bytes — observed; see EXPERIMENTS.md §Perf)
            wire = lax.optimization_barrier(
                contrib_loc.astype(jnp.bfloat16))
            contrib_full = lax.all_gather(wire, axes, tiled=True
                                          ).astype(dt)
            overflow = jnp.zeros((), bool)
        elif exchange == "delta":
            delta = contrib_loc - lax.dynamic_slice(cache_loc, (off,),
                                                    (n_loc,))
            n_changed = (delta != 0).sum()
            overflow = n_changed > delta_capacity
            mag, pos = lax.top_k(jnp.abs(delta), delta_capacity)
            vals = contrib_loc[pos]
            live = mag > 0
            gidx = jnp.where(live, pos + off, n_pad)
            all_idx = lax.all_gather(gidx, axes).reshape(-1)
            all_val = lax.all_gather(jnp.where(live, vals, 0), axes
                                     ).reshape(-1)
            patched = jnp.concatenate([cache_loc, jnp.zeros((1,), dt)])
            patched = patched.at[all_idx].set(all_val)
            contrib_delta = patched[:n_pad]
            # overflow anywhere → fall back to a full gather (correctness).
            # The fallback lives under lax.cond so its all-gather only
            # executes on overflow sweeps — every device agrees on the
            # branch (any_ovf is pmax'd), keeping the SPMD program uniform.
            any_ovf = lax.pmax(overflow.astype(jnp.int32), axes) > 0
            contrib_full = lax.cond(
                any_ovf,
                lambda: lax.all_gather(contrib_loc, axes, tiled=True),
                lambda: contrib_delta)
            overflow = any_ovf
        else:
            raise ValueError(exchange)

        if exchange != "ring":
            R_new = local_update(R_loc, contrib_full, aff_loc, vv_loc,
                                 src_in, dst_in, off, inv_loc)
        dr = jnp.abs(R_new - R_loc)
        changed = aff_loc & (dr > tau_f)
        rc_new = jnp.where(aff_loc & vv_loc, dr > tau, rc_loc)

        if expand:
            # local out-edges: src are owned here; mark global dst
            src_loc = jnp.clip(src_out - off, 0, n_loc - 1)
            flag = (src_out < n_pad) & changed[src_loc]
            # frontier marks travel as marks_dtype on the wire (int8 is
            # the compressed §Perf variant — 4× fewer pmax bytes)
            marks = jnp.zeros((n_pad + 1,), marks_dtype).at[
                jnp.where(flag, dst_out, n_pad)].set(1)[:n_pad]
            marks = lax.pmax(marks, axes) > 0
            marks_loc = lax.dynamic_slice(marks, (off,), (n_loc,)) & vv_loc
            aff_loc = aff_loc | marks_loc
            rc_new = rc_new | marks_loc

        outstanding = lax.psum(rc_new.sum(), axes)
        max_dr = lax.pmax(jnp.max(dr), axes)
        edges_total = lax.psum(edges_active, axes)
        cache_new = (contrib_full if exchange == "delta"
                     else cache_loc)
        return (R_new, aff_loc, rc_new, cache_new[None], outstanding,
                max_dr, overflow, edges_total)

    ax = axes if len(axes) > 1 else axes[0]
    specs_state = (P(ax), P(ax), P(ax), P(ax, None))
    specs_graph = (P(ax, None),) * 4 + (P(ax), P(ax))
    if exchange == "ring":
        specs_graph = specs_graph + (P(ax, None, None),) * 2
    fn = shard_map(sweep, mesh=mesh,
                   in_specs=specs_state + specs_graph,
                   out_specs=(P(ax), P(ax), P(ax), P(ax, None), P(), P(),
                              P(), P()),
                   check_rep=False)
    return jax.jit(fn)


@dataclasses.dataclass
class DistStats:
    sweeps: int = 0
    converged: bool = False
    full_exchanges: int = 0
    delta_exchanges: int = 0
    edges_processed: int = 0      # in-edges with affected dst, summed/sweep


def run_distributed(hg_or_dg, mesh: Mesh, *, axis: str = "data",
                    r_prev: Optional[jnp.ndarray] = None,
                    affected0: Optional[jnp.ndarray] = None,
                    alpha: float = 0.85, tau: float = 1e-10,
                    tau_f: Optional[float] = None, expand: bool = True,
                    exchange: str = "full", delta_capacity: int = 1024,
                    local_gs_sweeps: int = 1, max_sweeps: int = 500,
                    marks_dtype=jnp.int32,
                    dtype=jnp.float64) -> Tuple[jnp.ndarray, DistStats]:
    """Driver: converges the distributed DF sweep to all-RC-clear."""
    if isinstance(hg_or_dg, DistGraph):
        dg = hg_or_dg
    else:
        n_dev = int(np.prod([mesh.shape[a] for a in ([axis] if isinstance(
            axis, str) else axis)]))
        dg = build_dist_graph(hg_or_dg, n_dev, dtype=dtype,
                              ring=(exchange == "ring"))
    if tau_f is None:
        tau_f = tau / 1000.0 if expand else float("inf")

    R = (jnp.full((dg.n_pad,), 1.0 / dg.n, dtype)
         if r_prev is None else jnp.asarray(r_prev, dtype))
    R = jnp.where(dg.vertex_valid, R[:dg.n_pad], 0)
    aff = (dg.vertex_valid if affected0 is None
           else (affected0[:dg.n_pad] & dg.vertex_valid))
    rc = aff
    cache_w = dg.n_pad if exchange == "delta" else 1
    cache = jnp.zeros((dg.n_dev, cache_w), dtype)

    sweep = make_sweep(dg, mesh, axis, alpha=alpha, tau=tau, tau_f=tau_f,
                       expand=expand, exchange=exchange,
                       delta_capacity=delta_capacity,
                       local_gs_sweeps=local_gs_sweeps,
                       marks_dtype=marks_dtype)
    stats = DistStats()
    extra = ((dg.src_in_ring, dg.dst_in_ring)
             if exchange == "ring" else ())
    for i in range(max_sweeps):
        (R, aff, rc, cache, outstanding, max_dr, overflow, edges) = sweep(
            R, aff, rc, cache, dg.src_in, dg.dst_in, dg.src_out, dg.dst_out,
            dg.inv_deg, dg.vertex_valid, *extra)
        stats.sweeps += 1
        stats.edges_processed += int(edges)
        if exchange == "delta":
            if bool(overflow):
                stats.full_exchanges += 1
            else:
                stats.delta_exchanges += 1
        else:
            stats.full_exchanges += 1
        if int(outstanding) == 0:
            stats.converged = True
            break
    return R, stats


# ---------------------------------------------------------------------------
# Topology plumbing for the session API
# ---------------------------------------------------------------------------

EXCHANGES = ("full", "bf16", "delta", "ring")
# exchanges the incremental runtime supports (ring needs the per-owner edge
# slabs re-grouped on every batch — rebuild-only, excluded from sessions)
SESSION_EXCHANGES = ("full", "bf16", "delta")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Topology request handed from ``EngineConfig`` to the distributed
    engine / runtime: how many mesh devices, which partitioner relabels the
    vertex space, and which contribution-exchange variant runs per sweep."""
    n_shards: int
    partitioner: str = "contiguous"
    exchange: str = "full"
    delta_capacity: int = 1024


_SLAB_BUCKET = 64        # batch-pad / slab-capacity growth ladder base
_SEED_BUCKET = 1024      # affected-seed index pad (frontier-sized)


def _bucket(k: int, base: int = _SLAB_BUCKET) -> int:
    cap = base
    while cap < k:
        cap *= 2
    return cap


@jax.jit
def _patch_slab(A, B, dev, slot, a, b):
    """O(batch) device-side slab patch: write (a, b) at [dev, slot].
    Padded entries carry ``slot == capacity`` and are dropped."""
    return (A.at[dev, slot].set(a, mode="drop"),
            B.at[dev, slot].set(b, mode="drop"))


@jax.jit
def _patch_degrees(out_deg, inv_deg, valid, idx, dval):
    """O(batch) update of the out-degree vector and its inverse at the
    touched source vertices (padded entries carry ``idx == n_pad`` and are
    dropped; the gather after the scatter-add makes duplicate sources
    exact)."""
    out_deg = out_deg.at[idx].add(dval, mode="drop")
    n_pad = out_deg.shape[0]
    safe = jnp.minimum(idx, n_pad - 1)
    deg = jnp.maximum(out_deg[safe], 1).astype(inv_deg.dtype)
    new = jnp.where(valid[safe], 1.0 / deg, 0.0).astype(inv_deg.dtype)
    inv_deg = inv_deg.at[idx].set(new, mode="drop")
    return out_deg, inv_deg


@jax.jit
def _scatter_mask(valid, idx):
    """Bucketed index list → [n_pad] bool indicator (device-side scatter;
    only the padded index vector crosses host→device)."""
    m = jnp.zeros(valid.shape, bool).at[idx].set(True, mode="drop")
    return m & valid


class _SlabSet:
    """Host bookkeeping for one [n_dev, cap] edge-slab pair (in-edges
    grouped by dst owner, or out-edges grouped by src owner): where every
    edge lives, which slots are free, when capacity overflows.  The device
    slabs themselves live in the runtime's :class:`DistGraph`; this class
    only stages the O(batch) writes that patch them."""

    def __init__(self, *, by: str, n: int, n_loc: int, sentinel: int):
        assert by in ("src", "dst")
        self.by = by
        self.n = n
        self.n_loc = n_loc
        self.sentinel = sentinel
        self.cap = 0
        self.fill: list = []
        self.free: list = []
        self.slot_of: dict = {}

    def _owner(self, s: int, d: int) -> int:
        return (d if self.by == "dst" else s) // self.n_loc

    def build(self, src: np.ndarray, dst: np.ndarray, n_dev: int,
              *, headroom: int = _SLAB_BUCKET
              ) -> Tuple[np.ndarray, np.ndarray]:
        """(Re)build the numpy slab pair from an edge list, registering
        every edge's slot.  Capacity lands on the growth ladder with
        ``headroom`` slack so steady-state streams never reallocate."""
        owner = ((dst if self.by == "dst" else src) // self.n_loc).astype(
            np.int64)
        counts = np.bincount(owner, minlength=n_dev)
        self.cap = _bucket(int(counts.max(initial=1)) + headroom)
        A = np.full((n_dev, self.cap), self.sentinel, np.int32)
        B = np.full((n_dev, self.cap), self.sentinel, np.int32)
        self.fill = [0] * n_dev
        self.free = [[] for _ in range(n_dev)]
        self.slot_of = {}
        n = self.n
        for s, d, o in zip(src.tolist(), dst.tolist(), owner.tolist()):
            sl = self.fill[o]
            self.fill[o] += 1
            A[o, sl] = s
            B[o, sl] = d
            self.slot_of[s * n + d] = (o, sl)
        return A, B

    def edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """The registered (src, dst) edge set decoded from the slot-table
        keys — the single owner of the ``s*n + d`` key scheme."""
        keys = np.fromiter(self.slot_of.keys(), np.int64,
                           count=len(self.slot_of))
        return keys // self.n, keys % self.n

    def rebuild(self, n_dev: int) -> Tuple[np.ndarray, np.ndarray]:
        """Reconstruct the slabs from the registered edge set at the next
        capacity bucket (the growth event — one sweep retrace)."""
        src, dst = self.edges()
        return self.build(src, dst, n_dev)

    def stage(self, dels: np.ndarray, ins: np.ndarray):
        """Register one effective batch and return the (dev, slot, src,
        dst) writes that realize it on the device slabs, or ``None`` on
        capacity overflow (host state is already consistent — call
        :meth:`rebuild`).  Slots freed by this batch's deletions are not
        recycled until the *next* batch, so one scatter never writes the
        same slot twice."""
        dev, slot, a, b = [], [], [], []
        freed = []
        n, sent = self.n, self.sentinel
        for s, d in np.asarray(dels, np.int64).reshape(-1, 2):
            o, sl = self.slot_of.pop(int(s) * n + int(d))
            dev.append(o)
            slot.append(sl)
            a.append(sent)
            b.append(sent)
            freed.append((o, sl))
        grew = False
        for s, d in np.asarray(ins, np.int64).reshape(-1, 2):
            s, d = int(s), int(d)
            o = self._owner(s, d)
            if self.free[o]:
                sl = self.free[o].pop()
            else:
                sl = self.fill[o]
                self.fill[o] += 1
                if sl >= self.cap:
                    grew = True
            self.slot_of[s * n + d] = (o, sl)
            if not grew:
                dev.append(o)
                slot.append(sl)
                a.append(s)
                b.append(d)
        for o, sl in freed:
            self.free[o].append(sl)
        if grew:
            return None
        return dev, slot, a, b

    def fork(self) -> "_SlabSet":
        new = _SlabSet(by=self.by, n=self.n, n_loc=self.n_loc,
                       sentinel=self.sentinel)
        new.cap = self.cap
        new.fill = list(self.fill)
        new.free = [list(f) for f in self.free]
        new.slot_of = dict(self.slot_of)
        return new


class DistRuntime:
    """Incrementally maintained sharded DF_LF runtime — the sharded
    analogue of the stream-mode operand mirrors: per-device edge slabs and
    the degree vectors are device-resident state patched by O(batch)
    scatters per update batch (never a host gather of ranks, never an
    O(m) rebuild), and the compiled shard_map sweep is built **once** per
    (expand,) variant and re-entered for every batch — zero post-warmup
    retraces, accounted via :meth:`cache_size`.

    Vertex ids are in the runtime's own (partitioner-relabeled) space; the
    session layer owns the relabeling.  The vertex set is fixed for the
    runtime's lifetime; edge capacity grows on a doubling ladder (a growth
    event reallocates the slabs and costs one sweep retrace)."""

    def __init__(self, hg: HostGraph, mesh: Mesh, *, axis="shards",
                 alpha: float = 0.85, tau: float = 1e-10,
                 tau_f: Optional[float] = None, exchange: str = "full",
                 delta_capacity: int = 1024, dtype=jnp.float64,
                 marks_dtype=jnp.int32):
        if exchange not in SESSION_EXCHANGES:
            raise ValueError(
                f"exchange={exchange!r} is not supported by the incremental "
                f"runtime; expected one of {SESSION_EXCHANGES}")
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        self.mesh, self.axis = mesh, axis
        n_dev = int(np.prod([mesh.shape[a] for a in axes]))
        n = hg.n
        n_loc = -(-n // n_dev)
        n_pad = n_loc * n_dev
        self.n, self.n_dev, self.n_loc, self.n_pad = n, n_dev, n_loc, n_pad
        self.dtype = jnp.dtype(dtype)
        self.exchange = exchange
        self.delta_capacity = delta_capacity
        self._alpha = float(alpha)
        self._tau = float(tau)
        self._tau_f = (float(tau_f) if tau_f is not None else tau / 1000.0)
        self._marks_dtype = marks_dtype
        self._sweeps: dict = {}

        e = hg.edges
        loops = np.arange(n, dtype=np.int64)
        src = np.concatenate([e[:, 0], loops])
        dst = np.concatenate([e[:, 1], loops])
        out_deg = np.bincount(src, minlength=n_pad)
        vv = np.zeros(n_pad, bool)
        vv[:n] = True
        self._in = _SlabSet(by="dst", n=n, n_loc=n_loc, sentinel=n_pad)
        self._out = _SlabSet(by="src", n=n, n_loc=n_loc, sentinel=n_pad)
        A_in, B_in = self._in.build(src, dst, n_dev)
        A_out, B_out = self._out.build(src, dst, n_dev)
        sh_vec, sh_slab = self._shardings()
        self._out_deg = jax.device_put(jnp.asarray(out_deg, jnp.int32),
                                       sh_vec)
        inv = np.where(vv, 1.0 / np.maximum(out_deg, 1), 0.0)
        self.dg = DistGraph(
            n=n, n_pad=n_pad, n_dev=n_dev,
            src_in=jax.device_put(jnp.asarray(A_in), sh_slab),
            dst_in=jax.device_put(jnp.asarray(B_in), sh_slab),
            src_out=jax.device_put(jnp.asarray(A_out), sh_slab),
            dst_out=jax.device_put(jnp.asarray(B_out), sh_slab),
            inv_deg=jax.device_put(jnp.asarray(inv, self.dtype), sh_vec),
            vertex_valid=jax.device_put(jnp.asarray(vv), sh_vec))
        # the delta-exchange contribution cache persists across drives:
        # every device holds a consistent view of the last-exchanged
        # contributions (zeros before the first sweep), so a new drive
        # starts from a warm cache — and the array keeps the sweep's own
        # canonical sharding, avoiding a one-off re-layout retrace
        cache_w = n_pad if exchange == "delta" else 1
        self._cache = jax.device_put(
            jnp.zeros((n_dev, cache_w), self.dtype), sh_slab)

    def _shardings(self):
        """(vector, slab) NamedShardings matching the sweep's out_specs —
        every array entering the compiled sweep is committed to these, so
        the sweep only ever sees **one** input-layout signature (uncommitted
        inputs would retrace it once per distinct layout)."""
        from jax.sharding import NamedSharding
        axes = ((self.axis,) if isinstance(self.axis, str)
                else tuple(self.axis))
        ax = axes if len(axes) > 1 else axes[0]
        return (NamedSharding(self.mesh, P(ax)),
                NamedSharding(self.mesh, P(ax, None)))

    @property
    def valid(self) -> jnp.ndarray:
        return self.dg.vertex_valid

    # -- O(batch) delta application -----------------------------------------
    def apply_batch(self, dels: np.ndarray, ins: np.ndarray) -> None:
        """Route one *effective* (deletions, insertions) batch to its
        owning shards: stage the per-slab writes on host (dict lookups,
        O(batch)), then patch each device slab pair with one bucketed
        scatter.  A capacity overflow rebuilds the overflowing slab at the
        next bucket instead (rare; one retrace)."""
        dels = np.asarray(dels, np.int64).reshape(-1, 2)
        ins = np.asarray(ins, np.int64).reshape(-1, 2)
        dg = self.dg
        new_slabs = {}
        for name_a, name_b, slabset in (
                ("src_in", "dst_in", self._in),
                ("src_out", "dst_out", self._out)):
            staged = slabset.stage(dels, ins)
            if staged is None:
                A, B = slabset.rebuild(self.n_dev)
                _, sh_slab = self._shardings()
                new_slabs[name_a] = jax.device_put(jnp.asarray(A), sh_slab)
                new_slabs[name_b] = jax.device_put(jnp.asarray(B), sh_slab)
                continue
            dev, slot, a, b = staged
            pad = _bucket(max(len(dev), 1)) - len(dev)
            dev = np.asarray(dev + [0] * pad, np.int32)
            # padded writes land at slot == cap → dropped by the scatter
            slot = np.asarray(slot + [slabset.cap] * pad, np.int32)
            a = np.asarray(a + [slabset.sentinel] * pad, np.int32)
            b = np.asarray(b + [slabset.sentinel] * pad, np.int32)
            A, B = _patch_slab(getattr(dg, name_a), getattr(dg, name_b),
                               jnp.asarray(dev), jnp.asarray(slot),
                               jnp.asarray(a), jnp.asarray(b))
            new_slabs[name_a] = A
            new_slabs[name_b] = B

        srcs = np.concatenate([dels[:, 0], ins[:, 0]])
        dval = np.concatenate([-np.ones(len(dels), np.int32),
                               np.ones(len(ins), np.int32)])
        pad = _bucket(max(len(srcs), 1)) - len(srcs)
        idx = np.concatenate([srcs, np.full(pad, self.n_pad)]).astype(
            np.int32)
        dval = np.concatenate([dval, np.zeros(pad, np.int32)])
        self._out_deg, inv_deg = _patch_degrees(
            self._out_deg, dg.inv_deg, dg.vertex_valid,
            jnp.asarray(idx), jnp.asarray(dval))
        self.dg = dataclasses.replace(dg, inv_deg=inv_deg, **new_slabs)

    def mask_from_indices(self, idx: np.ndarray) -> jnp.ndarray:
        """Bucketed device scatter of a vertex-index list into a [n_pad]
        indicator (the affected-seed upload path: O(frontier) host→device,
        never the graph-sized vector)."""
        idx = np.asarray(idx, np.int64).reshape(-1)
        pad = _bucket(max(len(idx), 1), _SEED_BUCKET) - len(idx)
        idx = np.concatenate([idx, np.full(pad, self.n_pad)]).astype(
            np.int32)
        return _scatter_mask(self.dg.vertex_valid, jnp.asarray(idx))

    # -- the reused compiled sweep ------------------------------------------
    def _sweep_for(self, expand: bool):
        key = bool(expand)
        if key not in self._sweeps:
            self._sweeps[key] = make_sweep(
                self.dg, self.mesh, self.axis, alpha=self._alpha,
                tau=self._tau,
                tau_f=(self._tau_f if expand else float("inf")),
                expand=expand, exchange=self.exchange,
                delta_capacity=self.delta_capacity,
                marks_dtype=self._marks_dtype)
        return self._sweeps[key]

    def drive(self, R, affected, *, expand: bool, max_sweeps: int = 500,
              rc0=None, collect_state: bool = False):
        """Converge one (R, affected) problem through the cached compiled
        sweep.  Ranks stay device-resident throughout; the per-sweep host
        sync is the scalar convergence counter.

        ``rc0`` seeds the per-vertex still-unconverged flags (defaults to
        the affected set); ``collect_state=True`` additionally returns the
        final ``(affected, rc)`` vectors so a caller can *suspend* a drive
        (e.g. at a shard-fault injection point) and resume it later —
        possibly on a different mesh — from exactly the un-converged
        row set.  Returns ``(R, stats)`` or ``(R, stats, (aff, rc))``."""
        sweep = self._sweep_for(expand)
        dg = self.dg
        sh_vec, _ = self._shardings()
        R = jnp.asarray(R, self.dtype)
        R = jax.device_put(jnp.where(dg.vertex_valid, R[:self.n_pad], 0),
                           sh_vec)
        aff = jax.device_put(affected & dg.vertex_valid, sh_vec)
        rc = (aff if rc0 is None
              else jax.device_put(rc0 & dg.vertex_valid, sh_vec))
        cache = self._cache
        stats = DistStats()
        for _ in range(max_sweeps):
            (R, aff, rc, cache, outstanding, _max_dr, overflow,
             edges) = sweep(R, aff, rc, cache, dg.src_in, dg.dst_in,
                            dg.src_out, dg.dst_out, dg.inv_deg,
                            dg.vertex_valid)
            stats.sweeps += 1
            stats.edges_processed += int(edges)
            if self.exchange == "delta":
                if bool(overflow):
                    stats.full_exchanges += 1
                else:
                    stats.delta_exchanges += 1
            else:
                stats.full_exchanges += 1
            if int(outstanding) == 0:
                stats.converged = True
                break
        self._cache = cache
        if collect_state:
            return R, stats, (aff, rc)
        return R, stats

    # -- shard fault domain ---------------------------------------------------
    def owned_range(self, shard: int) -> Tuple[int, int]:
        """[lo, hi) of real vertex ids (runtime-relabeled space) owned by
        ``shard`` under the contiguous layout."""
        lo = shard * self.n_loc
        return lo, min((shard + 1) * self.n_loc, self.n)

    def registered_edges(self) -> np.ndarray:
        """The authoritative edge set (self-loops excluded) recovered from
        the in-slab slot table — the survivors' view of the graph, used to
        rebuild slabs after a permanent shard loss."""
        src, dst = self._in.edges()
        keep = src != dst
        return np.stack([src[keep], dst[keep]], axis=1)

    def shrink(self, dead: int) -> "DistRuntime":
        """Elastic re-partition after a *permanent* shard loss: rebuild the
        runtime on the surviving ``n_dev - 1`` devices, with the edge slabs
        reconstructed from the (host-side) slot tables — no device in the
        old mesh needs to be alive for this, which is the point.  Vertex
        relabeling is untouched; only the contiguous ownership split
        changes.  The recovery event costs one slab rebuild + sweep
        compile; steady-state streaming resumes recompile-free after."""
        axes = ((self.axis,) if isinstance(self.axis, str)
                else tuple(self.axis))
        if len(axes) != 1:
            raise ValueError("shrink() supports single-axis meshes "
                             f"(got axes {axes})")
        if self.n_dev <= 1:
            raise ValueError("cannot shrink a 1-shard runtime")
        if not (0 <= dead < self.n_dev):
            raise ValueError(f"dead shard {dead} out of range "
                             f"(n_dev={self.n_dev})")
        survivors = [d for i, d in enumerate(self.mesh.devices.flat)
                     if i != dead]
        mesh = Mesh(np.asarray(survivors), axes)
        hg = HostGraph(self.n, self.registered_edges())
        return DistRuntime(
            hg, mesh, axis=self.axis, alpha=self._alpha, tau=self._tau,
            tau_f=self._tau_f, exchange=self.exchange,
            delta_capacity=self.delta_capacity, dtype=self.dtype,
            marks_dtype=self._marks_dtype)

    def warmup(self, R) -> None:
        """Trace the per-batch pipeline (slab/degree patch at the base
        batch bucket, seed scatter at the base frontier bucket, the
        expand sweep) without perturbing graph or rank state.  Two
        one-sweep drives: the second runs against the first's
        canonically-laid-out cache, covering both sweep signatures."""
        empty = np.zeros((0, 2), np.int64)
        self.apply_batch(empty, empty)
        aff = self.mask_from_indices(np.zeros(0, np.int64))
        self.drive(R, aff, expand=True, max_sweeps=1)
        self.drive(R, aff, expand=True, max_sweeps=1)

    def cache_size(self) -> int:
        """Total jit-cache entries of the sweep(s) + patch functions (the
        sharded analogue of the fused driver's cache size; -1 when the
        cache stats API is unavailable)."""
        total = 0
        fns = list(self._sweeps.values()) + [_patch_slab, _patch_degrees,
                                             _scatter_mask]
        for fn in fns:
            try:
                total += int(fn._cache_size())
            except Exception:       # pragma: no cover - older jax fallback
                return -1
        return total

    def fork(self) -> "DistRuntime":
        """Twin sharing every device array (immutable; patches are
        functional) with independent host bookkeeping.  Already-compiled
        sweeps are shared."""
        new = object.__new__(DistRuntime)
        new.__dict__.update(self.__dict__)
        new._in = self._in.fork()
        new._out = self._out.fork()
        new._sweeps = dict(self._sweeps)
        return new


def df_seed_indices(hg_prev: HostGraph, hg_cur: HostGraph,
                    sources: np.ndarray) -> np.ndarray:
    """Paper Alg. 1 lines 4-6, host-side in O(batch · deg): the
    out-neighbors of every update source in G^{t-1} **and** G^t, plus the
    sources themselves (the per-vertex self-loops every device graph
    carries make a source its own out-neighbor, matching
    :func:`repro.core.frontier.initial_affected` on snapshots)."""
    sources = np.unique(np.asarray(sources, np.int64).reshape(-1))
    sources = sources[(sources >= 0) & (sources < hg_cur.n)]
    out = [sources]
    for hg in (hg_prev, hg_cur):
        keys = hg._keys
        n = np.int64(hg.n)
        lo = np.searchsorted(keys, sources * n)
        hi = np.searchsorted(keys, (sources + 1) * n)
        for k0, k1 in zip(lo.tolist(), hi.tolist()):
            if k1 > k0:
                out.append(keys[k0:k1] % n)
    return np.unique(np.concatenate(out)) if out else sources


def collective_bytes_per_sweep(*, n_pad: int, n_dev: int, exchange: str,
                               rank_bytes: int, marks_bytes: int = 4,
                               delta_capacity: int = 1024,
                               expand: bool = True,
                               frac_full: float = 1.0) -> float:
    """Analytic wire-traffic model for one sweep, summed over devices
    (host-CPU "devices" have no physical wire — this is the number the
    partitioner/exchange choice controls on a real mesh).

    Contribution exchange: every device ships its n_loc chunk to the other
    n_dev−1 devices (`full`: rank_bytes/entry; `bf16`: 2 bytes; `delta`:
    (4-byte idx + value) × delta_capacity, with `frac_full` of sweeps
    falling back to the full gather on overflow).  Frontier expansion adds
    one all-reduce of the [n_pad] mark vector.  Scalar reductions (RC
    count, max |Δr|) are negligible and omitted."""
    n_loc = n_pad // max(n_dev, 1)
    pairs = n_dev * (n_dev - 1)
    gather_full = pairs * n_loc * rank_bytes
    if exchange == "full":
        g = gather_full
    elif exchange == "bf16":
        g = pairs * n_loc * 2
    elif exchange == "delta":
        g_delta = pairs * delta_capacity * (4 + rank_bytes)
        g = frac_full * gather_full + (1.0 - frac_full) * g_delta
    else:
        raise ValueError(f"exchange={exchange!r}; "
                         f"expected one of {SESSION_EXCHANGES}")
    marks = pairs * n_pad * marks_bytes if expand else 0
    return float(g + marks)


# ---------------------------------------------------------------------------
# repro.api engine adapter (Engine protocol; discovered lazily by
# repro.api.registry so this module never imports the api package)
# ---------------------------------------------------------------------------

class DistributedEngine:
    """Registry adapter for the sharded stale-synchronous engine: a
    one-shot solve that partitions the snapshot over the device mesh.
    Sessions with ``topology="sharded"`` bypass this adapter and drive
    :class:`DistRuntime` directly (the O(batch) incremental path); the
    adapter is the snapshot-level interop surface."""

    name = "distributed"
    fault_domains = ("shard", "process")

    def run(self, g, R0, affected0, *, mode, expand, alpha, tau, tau_f,
            max_iterations, faults, tile, active_policy,
            mat=None, aux=None, backend=None, interpret=None, shards=None):
        from repro.api.registry import reject_tile_operands
        from repro.graphs import partition as gpart
        reject_tile_operands(self.name, mat, aux, backend)
        del mode, tile, active_policy, interpret   # single-device knobs:
        # the sharded sweep is stale-synchronous block-Jacobi by design
        if faults is not None:
            raise ValueError(
                "fault simulation is not supported by engine='distributed' "
                "(stragglers are the model: stale contributions, no crash "
                "tables) — use engine='blocked'/'pallas' with a FaultPlan")
        spec = shards if shards is not None else ShardSpec(
            n_shards=len(jax.devices()))
        src, dst = g.in_edges_host()
        hg = HostGraph(g.n, np.stack([src, dst], 1))
        order, inv, _ = gpart.make_partition(hg, spec.n_shards,
                                             spec.partitioner)
        hg_rel, _ = gpart.relabel(hg, order)
        mesh = Mesh(np.asarray(jax.devices()[:spec.n_shards]), ("shards",))
        n_loc = -(-g.n // spec.n_shards)
        n_pad_rel = n_loc * spec.n_shards
        R0h = np.asarray(R0)
        r_rel = np.zeros(n_pad_rel, R0h.dtype)
        r_rel[:g.n] = R0h[order]
        affh = np.asarray(affected0)[:g.n_pad]
        a_rel = np.zeros(n_pad_rel, bool)
        a_rel[:g.n] = affh[order]
        R, st = run_distributed(
            hg_rel, mesh, axis="shards", r_prev=jnp.asarray(r_rel),
            affected0=jnp.asarray(a_rel), alpha=alpha, tau=tau, tau_f=tau_f,
            expand=expand, exchange=spec.exchange,
            delta_capacity=spec.delta_capacity,
            max_sweeps=max_iterations, dtype=R0h.dtype)
        from repro.core.blocked import SweepStats
        Rh = np.asarray(R)
        out = np.zeros(g.n_pad, Rh.dtype)
        out[order] = Rh[:g.n]
        stats = SweepStats(sweeps=st.sweeps, iterations=st.sweeps,
                           edges_processed=st.edges_processed,
                           converged=st.converged)
        return jax.block_until_ready(jnp.asarray(out)), stats


def as_engine() -> DistributedEngine:
    return DistributedEngine()
